"""AOT lowering: L2 worker graphs → artifacts/*.hlo.txt + manifest.json.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact per (task, shape-class).  The shape classes below mirror
rust/src/data/registry.rs — every dataset the experiments use, with N_m
= padded per-worker rows after an even split across M workers.  Rust
reads manifest.json to find the artifact and its argument layout.

Run:  python -m compile.aot --out-dir ../artifacts [--only ijcnn1]
`make artifacts` is a no-op when inputs are older than the manifest.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# shape classes — keep in sync with rust/src/data/registry.rs
# ---------------------------------------------------------------------------

# name → (N_total, d, M, tasks)
DATASETS = {
    # synthetic, paper Fig. 1/2/3/11/12: M=9 workers, 50 samples of 50
    # features each
    "synth": (450, 50, 9, ("linreg", "logreg")),
    # ijcnn1 (49 990 × 22), evenly split over 9 workers (Table I)
    "ijcnn1": (49_990, 22, 9, ("linreg", "logreg", "lasso", "nn")),
    # MNIST (60 000 × 784), 9 workers (Table III)
    "mnist": (60_000, 784, 9, ("linreg", "logreg", "lasso", "nn")),
    # Experiment-set-2 small datasets, 3 workers, features truncated to
    # the per-task-group minimum (paper §IV-B protocol): linreg trio → 8,
    # logreg/lasso/nn trio → 14
    "housing": (506, 8, 3, ("linreg",)),
    "bodyfat": (252, 8, 3, ("linreg",)),
    "abalone": (4_177, 8, 3, ("linreg",)),
    "ionosphere": (351, 14, 3, ("logreg", "lasso")),
    "adult": (1_605, 14, 3, ("logreg", "lasso", "nn")),
    "derm": (366, 14, 3, ("logreg", "lasso")),
}


def per_worker_padded(n_total: int, m: int) -> int:
    """Rows per worker after even split + padding to the kernel tile."""
    n_m = (n_total + m - 1) // m
    return model.padded_n(n_m)


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(task: str, n_pad: int, d: int):
    """Lower one worker graph; returns (hlo_text, arg spec list)."""
    fn, needs_mask, needs_lam = model.worker_fn(task)
    p = model.theta_dim(task, d)
    f32 = jnp.float32
    specs = [
        ("theta", (p,)),
        ("x", (n_pad, d)),
        ("y", (n_pad,)),
    ]
    if needs_mask:
        specs.append(("mask", (n_pad,)))
    if needs_lam:
        specs.append(("lam", (1,)))
    if task == "nn":
        specs.append(("wscale", (1,)))
    args = [jax.ShapeDtypeStruct(s, f32) for _, s in specs]
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), [
        {"name": nm, "shape": list(s)} for nm, s in specs
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated dataset filter")
    ap.add_argument("--tasks", default=None,
                    help="comma-separated task filter")
    ns = ap.parse_args(argv)
    os.makedirs(ns.out_dir, exist_ok=True)

    only = set(ns.only.split(",")) if ns.only else None
    task_filter = set(ns.tasks.split(",")) if ns.tasks else None

    manifest = {"block_n": model.BLOCK_N, "hidden": model.HIDDEN,
                "artifacts": []}
    for ds, (n_total, d, m, tasks) in DATASETS.items():
        if only and ds not in only:
            continue
        n_pad = per_worker_padded(n_total, m)
        for task in tasks:
            if task_filter and task not in task_filter:
                continue
            name = f"{task}_{ds}"
            path = f"{name}.hlo.txt"
            print(f"lowering {name}: n_pad={n_pad} d={d} ...",
                  flush=True)
            hlo, arg_specs = lower_artifact(task, n_pad, d)
            with open(os.path.join(ns.out_dir, path), "w") as f:
                f.write(hlo)
            manifest["artifacts"].append({
                "name": name,
                "task": task,
                "dataset": ds,
                "file": path,
                "n_total": n_total,
                "workers": m,
                "n_pad": n_pad,
                "d": d,
                "theta_dim": model.theta_dim(task, d),
                "args": arg_specs,
                "outputs": ["grad", "loss"],
                "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
            })
    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {ns.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
