"""Fused lasso subgradient kernel.

subgrad = Xᵀ(Xθ − y) + λ·sign(θ)      (sign(0) := 0)
loss    = ½‖Xθ − y‖² + λ‖θ‖₁

Identical streaming schedule to linreg; the nondifferentiable λ·sign(θ)
term (the paper replaces the gradient by a subgradient for lasso, §IV)
is applied once on the final grid step.  Zero-padded rows contribute 0.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, choose_block_n


def _lasso_grad_kernel(theta_ref, x_ref, y_ref, lam_ref, g_ref, loss_ref):
    i = pl.program_id(0)
    steps = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]
    r = x @ theta_ref[...] - y_ref[...]
    g_ref[...] += r @ x
    loss_ref[...] += 0.5 * jnp.sum(r * r)[None]

    @pl.when(i == steps - 1)
    def _l1():
        lam = lam_ref[0]
        theta = theta_ref[...]
        g_ref[...] += lam * jnp.sign(theta)
        loss_ref[...] += lam * jnp.sum(jnp.abs(theta))[None]


@functools.partial(jax.jit, static_argnames=("block_n",))
def lasso_grad_loss(theta, x, y, lam, block_n: int = 0):
    """Returns (subgrad (d,), loss (1,)).  lam: shape-(1,) array."""
    n, d = x.shape
    bn = choose_block_n(n) if block_n == 0 else block_n
    assert n % bn == 0, f"N={n} not a multiple of block_n={bn}"
    grid = (n // bn,)
    return pl.pallas_call(
        _lasso_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ],
        interpret=True,
    )(theta, x, y, lam)
