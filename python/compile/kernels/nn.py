"""Fused forward+backward kernel for the paper's 1×30 sigmoid network.

One pallas_call per worker computes, in a single streaming pass over X,
the full manual backprop of

    pred = σ(XW1 + b1) · w2 + b2,   loss = ½‖pred − y‖² + ½λ‖θ‖²

emitting (gW1, gb1, gw2, gb2, loss).  All parameter-sized accumulators
(d×h + 3h + 2 floats) stay resident in VMEM across the grid; only X/y
row tiles stream.  Padded rows are masked (a zero row still produces
pred = σ(b1)·w2 + b2 ≠ 0).

jax.grad cannot differentiate through pallas_call, so the backward pass
is written out by hand — matching ref.nn_grad exactly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, choose_block_n


def _sigmoid(z):
    return 0.5 * (jnp.tanh(0.5 * z) + 1.0)


def _nn_grad_kernel(w1_ref, b1_ref, w2_ref, b2_ref, x_ref, y_ref,
                    mask_ref, lam_ref, wscale_ref,
                    gw1_ref, gb1_ref, gw2_ref, gb2_ref, loss_ref):
    i = pl.program_id(0)
    steps = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        gw1_ref[...] = jnp.zeros_like(gw1_ref)
        gb1_ref[...] = jnp.zeros_like(gb1_ref)
        gw2_ref[...] = jnp.zeros_like(gw2_ref)
        gb2_ref[...] = jnp.zeros_like(gb2_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]  # (bn, d)
    y = y_ref[...]  # (bn,)
    mask = mask_ref[...]  # (bn,)
    w1 = w1_ref[...]  # (d, h)
    w2 = w2_ref[...]  # (h,)

    # forward
    z = _sigmoid(x @ w1 + b1_ref[...])  # (bn, h)
    pred = z @ w2 + b2_ref[0]  # (bn,)
    r = (pred - y) * mask  # (bn,) masked residual

    # backward (manual)
    gw2_ref[...] += r @ z  # zᵀr
    gb2_ref[...] += jnp.sum(r)[None]
    dz = r[:, None] * w2[None, :] * z * (1.0 - z)  # (bn, h)
    gw1_ref[...] += x.T @ dz
    gb1_ref[...] += jnp.sum(dz, axis=0)
    loss_ref[...] += 0.5 * jnp.sum(r * r)[None]

    @pl.when(i == steps - 1)
    def _finalize():
        # scale the accumulated data terms (wscale = 1/N_m gives the
        # paper's mean-loss regime), then add the ℓ2 term once
        ws = wscale_ref[0]
        gw1_ref[...] *= ws
        gb1_ref[...] *= ws
        gw2_ref[...] *= ws
        gb2_ref[...] *= ws
        loss_ref[...] *= ws
        lam = lam_ref[0]
        gw1_ref[...] += lam * w1_ref[...]
        gb1_ref[...] += lam * b1_ref[...]
        gw2_ref[...] += lam * w2_ref[...]
        gb2_ref[...] += lam * b2_ref[...]
        sq = (jnp.sum(w1_ref[...] ** 2) + jnp.sum(b1_ref[...] ** 2)
              + jnp.sum(w2_ref[...] ** 2) + jnp.sum(b2_ref[...] ** 2))
        loss_ref[...] += 0.5 * lam * sq[None]


@functools.partial(jax.jit, static_argnames=("block_n",))
def nn_grad_loss(w1, b1, w2, b2, x, y, mask, lam, wscale=None,
                 block_n: int = 0):
    """Returns (gW1 (d,h), gb1 (h,), gw2 (h,), gb2 (1,), loss (1,)).

    b2, lam, wscale are shape-(1,) arrays.  wscale multiplies the data
    terms (1/N_m → mean loss, the paper's NN regime); defaults to 1.
    x: (N,d), N % block_n == 0.
    """
    n, d = x.shape
    h = w1.shape[1]
    if wscale is None:
        wscale = jnp.ones((1,), x.dtype)
    bn = choose_block_n(n) if block_n == 0 else block_n
    assert n % bn == 0, f"N={n} not a multiple of block_n={bn}"
    grid = (n // bn,)
    return pl.pallas_call(
        _nn_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, h), DTYPE),
            jax.ShapeDtypeStruct((h,), DTYPE),
            jax.ShapeDtypeStruct((h,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ],
        interpret=True,
    )(w1, b1, w2, b2, x, y, mask, lam, wscale)
