"""Tiled Pallas matmul — the MXU building block.

C[M,N] = A[M,K] @ B[K,N] with a 3-D grid over (M/bm, N/bn, K/bk) tiles
and accumulation in the revisited output block.  This is the canonical
TPU schedule: each (i, j) output tile stays resident in VMEM while the
K dimension streams through.

Shapes must be multiples of the block sizes; ``model.py`` pads.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += a_ref[...] @ b_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm: int = 128, bn: int = 128, bk: int = 128):
    """Pallas tiled matmul.  a: (M,K), b: (K,N), all multiples of tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims disagree: {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not tileable by ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=True,
    )(a, b)
