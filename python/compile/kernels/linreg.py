"""Fused linear-regression gradient kernel.

One pallas_call computes both the worker gradient Xᵀ(Xθ − y) and the
loss ½‖Xθ − y‖² in a single pass over X: the grid streams row tiles of
X through VMEM while the (d,) gradient accumulator and the scalar loss
stay resident in the revisited output blocks.  This is the paper's
worker hot-spot (every worker, every iteration).

Zero-padded rows (x = 0, y = 0) contribute exactly 0 to both outputs,
so the caller may pad N up to a tile multiple with no mask needed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, choose_block_n


def _linreg_grad_kernel(theta_ref, x_ref, y_ref, g_ref, loss_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]  # (bn, d) tile
    r = x @ theta_ref[...] - y_ref[...]  # (bn,) residual
    g_ref[...] += r @ x  # Xᵀr for this tile
    loss_ref[...] += 0.5 * jnp.sum(r * r)[None]


@functools.partial(jax.jit, static_argnames=("block_n",))
def linreg_grad_loss(theta, x, y, block_n: int = 0):
    """Returns (grad (d,), loss (1,)).  x: (N,d) with N % block_n == 0."""
    n, d = x.shape
    bn = choose_block_n(n) if block_n == 0 else block_n
    assert n % bn == 0, f"N={n} not a multiple of block_n={bn}"
    grid = (n // bn,)
    return pl.pallas_call(
        _linreg_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ],
        interpret=True,
    )(theta, x, y)
