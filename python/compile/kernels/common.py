"""Shared helpers for the Pallas kernel suite.

All kernels in this package are written for the TPU memory model —
BlockSpec expresses the HBM→VMEM schedule, accumulators live in the
revisited output block — but are *executed* with ``interpret=True``
because the CPU PJRT plugin cannot run Mosaic custom-calls (see
DESIGN.md §Hardware-Adaptation). Structure is TPU-shaped; numerics are
validated on CPU.
"""

import jax.numpy as jnp

# Default row-tile: one HBM→VMEM transfer of the data matrix per grid
# step.  256 rows × ≤1024 features × 4 B = ≤1 MiB, comfortably inside
# the ~16 MiB VMEM budget together with θ and the accumulator.
DEFAULT_BLOCK_N = 256

# float32 everywhere: the paper's workloads are small-dimension convex
# problems where bf16 would visibly perturb the censoring decisions.
DTYPE = jnp.float32


# VMEM budget for the X row-tile (half of a ~16 MiB VMEM, leaving room
# for θ, y, and the accumulators).  The largest tile that fits gives
# the fewest grid steps — on interpret-mode CPU that minimizes XLA
# while-loop overhead, and on a real TPU it maximizes the compute per
# HBM→VMEM transfer (see tuning.py).
VMEM_TILE_BUDGET = 8 * 1024 * 1024


def choose_block_n(n: int, block_n: int = DEFAULT_BLOCK_N) -> int:
    """Largest row-tile ≤ block_n; caller pads n up to a multiple."""
    return min(n, block_n)


def best_block_n(n_pad: int, d: int,
                 budget: int = VMEM_TILE_BUDGET) -> int:
    """Largest divisor of n_pad whose (block × d) f32 tile fits the
    VMEM budget.  n_pad is already a multiple of DEFAULT_BLOCK_N (or
    equals the raw n for small shards), so candidate blocks are the
    divisors of n_pad — the BlockSpec grid must tile exactly."""
    if n_pad * d * 4 <= budget:
        return n_pad
    best = 1
    limit = max(1, budget // (4 * d))
    k = 1
    while k * k <= n_pad:
        if n_pad % k == 0:
            for div in (k, n_pad // k):
                if div <= limit and div > best:
                    best = div
        k += 1
    return best


def padded_rows(n: int, block_n: int) -> int:
    """n rounded up to a multiple of the row tile."""
    return ((n + block_n - 1) // block_n) * block_n


def vmem_bytes(block_n: int, d: int, extra: int = 0) -> int:
    """Estimated VMEM footprint of one grid step of a fused-gradient
    kernel: X tile + θ + y tile + accumulator (+ task-specific extra
    floats).  Used by tuning.py and quoted in EXPERIMENTS.md §Perf."""
    floats = block_n * d + d + block_n + d + extra
    return 4 * floats
