"""Fused ℓ2-regularized logistic-regression gradient kernel.

grad  = −Xᵀ(y ⊙ σ(−y ⊙ Xθ)) + λθ
loss  =  Σ_n log(1 + exp(−y_n x_nᵀθ)) + ½λ‖θ‖²

Single pass over X, same streaming schedule as linreg.  Padded rows are
masked via ``mask`` (1.0 real / 0.0 pad) because a zero row still
contributes log 2 to the unmasked loss.  The λθ / ½λ‖θ‖² terms are added
on the *final* grid step so they appear exactly once.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import DTYPE, choose_block_n


def _sigmoid(z):
    return 0.5 * (jnp.tanh(0.5 * z) + 1.0)


def _log1pexp(z):
    return jnp.logaddexp(0.0, z)


def _logreg_grad_kernel(theta_ref, x_ref, y_ref, mask_ref, lam_ref,
                        g_ref, loss_ref):
    i = pl.program_id(0)
    steps = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    x = x_ref[...]  # (bn, d)
    y = y_ref[...]  # (bn,)
    mask = mask_ref[...]  # (bn,)
    margins = y * (x @ theta_ref[...])
    coeff = -y * _sigmoid(-margins) * mask
    g_ref[...] += coeff @ x
    loss_ref[...] += jnp.sum(_log1pexp(-margins) * mask)[None]

    @pl.when(i == steps - 1)
    def _regularize():
        lam = lam_ref[0]
        theta = theta_ref[...]
        g_ref[...] += lam * theta
        loss_ref[...] += 0.5 * lam * jnp.sum(theta * theta)[None]


@functools.partial(jax.jit, static_argnames=("block_n",))
def logreg_grad_loss(theta, x, y, mask, lam, block_n: int = 0):
    """Returns (grad (d,), loss (1,)).  lam: shape-(1,) array."""
    n, d = x.shape
    bn = choose_block_n(n) if block_n == 0 else block_n
    assert n % bn == 0, f"N={n} not a multiple of block_n={bn}"
    grid = (n // bn,)
    return pl.pallas_call(
        _logreg_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), DTYPE),
            jax.ShapeDtypeStruct((1,), DTYPE),
        ],
        interpret=True,
    )(theta, x, y, mask, lam)
