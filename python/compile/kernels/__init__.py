"""L1 Pallas kernel suite for the CHB federated-learning workers.

Each module provides one fused worker-gradient kernel (interpret=True —
see common.py for why) plus its streaming HBM->VMEM schedule:

  matmul  — tiled MXU matmul building block
  linreg  — X^T(X theta - y) + loss, one pass
  logreg  — regularized logistic gradient + loss
  lasso   — lasso subgradient + loss
  nn      — fused fwd + manual-bwd of the 1x30 sigmoid network

ref.py holds the pure-jnp oracles every kernel is tested against.
"""

from .linreg import linreg_grad_loss
from .logreg import logreg_grad_loss
from .lasso import lasso_grad_loss
from .matmul import matmul
from .nn import nn_grad_loss

__all__ = [
    "linreg_grad_loss",
    "logreg_grad_loss",
    "lasso_grad_loss",
    "matmul",
    "nn_grad_loss",
]
