"""Pure-jnp reference oracles for every L1 Pallas kernel and L2 graph.

These are the *correctness ground truth*: pytest compares each Pallas
kernel (interpret=True) and each composed model graph against the
functions here with ``assert_allclose``. Keep these boring and obviously
correct — no tiling, no fusion, just textbook math.

Loss conventions (match the paper, Section IV):
  linear regression    f_m(θ) = ½‖X θ − y‖²
  logistic regression  f_m(θ) = Σ_n log(1 + exp(−y_n x_nᵀθ)) + ½ λ_m ‖θ‖²
                       (labels y ∈ {−1, +1})
  lasso                f_m(θ) = ½‖X θ − y‖² + λ_m ‖θ‖₁   (subgradient used)
  neural network       1 hidden layer, H=30, sigmoid activation, linear
                       output, ½ MSE loss + ½ λ_m ‖θ‖²; θ packs
                       (W1[d,H], b1[H], w2[H], b2) row-major.
"""

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# elementary pieces
# ---------------------------------------------------------------------------


def sigmoid(z):
    """Numerically-stable logistic function."""
    return 0.5 * (jnp.tanh(0.5 * z) + 1.0)


def log1pexp(z):
    """log(1 + exp(z)) without overflow."""
    return jnp.logaddexp(0.0, z)


# ---------------------------------------------------------------------------
# linear regression
# ---------------------------------------------------------------------------


def linreg_loss(theta, x, y):
    r = x @ theta - y
    return 0.5 * jnp.dot(r, r)


def linreg_grad(theta, x, y):
    """∇ ½‖Xθ − y‖² = Xᵀ(Xθ − y)."""
    return x.T @ (x @ theta - y)


# ---------------------------------------------------------------------------
# (regularized) logistic regression
# ---------------------------------------------------------------------------


def logreg_loss(theta, x, y, lam):
    margins = y * (x @ theta)
    return jnp.sum(log1pexp(-margins)) + 0.5 * lam * jnp.dot(theta, theta)


def logreg_grad(theta, x, y, lam):
    """∇ Σ log(1+exp(−y xᵀθ)) + ½λ‖θ‖² = −Xᵀ(y·σ(−y Xθ)) + λθ."""
    margins = y * (x @ theta)
    coeff = -y * sigmoid(-margins)  # (N,)
    return x.T @ coeff + lam * theta


# ---------------------------------------------------------------------------
# lasso (subgradient)
# ---------------------------------------------------------------------------


def lasso_loss(theta, x, y, lam):
    r = x @ theta - y
    return 0.5 * jnp.dot(r, r) + lam * jnp.sum(jnp.abs(theta))


def lasso_subgrad(theta, x, y, lam):
    """Subgradient Xᵀ(Xθ−y) + λ·sign(θ); sign(0) := 0."""
    return x.T @ (x @ theta - y) + lam * jnp.sign(theta)


# ---------------------------------------------------------------------------
# 1-hidden-layer sigmoid network
# ---------------------------------------------------------------------------


def nn_unpack(theta, d, h):
    """Split flat θ into (W1[d,h], b1[h], w2[h], b2)."""
    i = 0
    w1 = theta[i : i + d * h].reshape(d, h)
    i += d * h
    b1 = theta[i : i + h]
    i += h
    w2 = theta[i : i + h]
    i += h
    b2 = theta[i]
    return w1, b1, w2, b2


def nn_pack(w1, b1, w2, b2):
    return jnp.concatenate([w1.reshape(-1), b1, w2, jnp.atleast_1d(b2)])


def nn_dim(d, h=30):
    """Flat parameter count for feature dim d and hidden width h."""
    return d * h + h + h + 1


def nn_forward(theta, x, d, h):
    w1, b1, w2, b2 = nn_unpack(theta, d, h)
    z = sigmoid(x @ w1 + b1)  # (N, h)
    return z @ w2 + b2  # (N,)


def nn_loss(theta, x, y, lam, h=30):
    d = x.shape[1]
    pred = nn_forward(theta, x, d, h)
    r = pred - y
    return 0.5 * jnp.dot(r, r) + 0.5 * lam * jnp.dot(theta, theta)


def nn_grad(theta, x, y, lam, h=30):
    """Manual backprop for the ½MSE + ½λ‖θ‖² objective."""
    d = x.shape[1]
    w1, b1, w2, b2 = nn_unpack(theta, d, h)
    a = x @ w1 + b1  # (N, h) pre-activation
    z = sigmoid(a)  # (N, h)
    pred = z @ w2 + b2  # (N,)
    r = pred - y  # (N,)
    gw2 = z.T @ r  # (h,)
    gb2 = jnp.sum(r)
    dz = jnp.outer(r, w2) * z * (1.0 - z)  # (N, h)
    gw1 = x.T @ dz  # (d, h)
    gb1 = jnp.sum(dz, axis=0)  # (h,)
    g = nn_pack(gw1, gb1, gw2, gb2)
    return g + lam * theta
