"""L1 structural tuning: VMEM footprint + MXU-utilization estimates.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so
per the hardware-adaptation note in DESIGN.md the kernels are tuned
*structurally*: pick the block shape that (a) fits the VMEM budget,
(b) minimizes grid steps (fewest HBM→VMEM round-trips), and
(c) keeps the MXU tile (128×128 systolic array) well fed.

Run:  python -m compile.tuning          # prints the tuning table
"""

from dataclasses import dataclass

from .kernels.common import best_block_n, VMEM_TILE_BUDGET
from . import aot, model

# TPU architectural constants used for the *estimates* (v4-ish).
MXU_DIM = 128          # systolic array is 128×128
VMEM_BYTES = 16 * 2**20
HBM_GBPS = 1_200e9     # ~1.2 TB/s
MXU_BF16_FLOPS = 275e12


@dataclass
class KernelEstimate:
    """Static performance model for one fused-gradient artifact."""

    name: str
    n_pad: int
    d: int
    block_n: int

    @property
    def grid_steps(self) -> int:
        return self.n_pad // self.block_n

    @property
    def vmem_per_step(self) -> int:
        """X tile + θ + y tile + grad accumulator, f32."""
        return 4 * (self.block_n * self.d + self.d + self.block_n + self.d)

    @property
    def hbm_bytes(self) -> int:
        """One full pass over X dominates traffic."""
        return 4 * self.n_pad * self.d

    @property
    def flops(self) -> int:
        """Two GEMV-shaped passes fused into one sweep: 4·N·d."""
        return 4 * self.n_pad * self.d

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.hbm_bytes

    @property
    def mxu_row_utilization(self) -> float:
        """Fraction of the 128-wide MXU row the d-dimension fills —
        the structural ceiling on matmul-unit efficiency for a
        (block×d)·(d,) contraction."""
        return min(1.0, self.d / MXU_DIM)

    @property
    def est_time_us(self) -> float:
        """Roofline estimate: memory-bound (intensity 1 ≪ ridge)."""
        return self.hbm_bytes / HBM_GBPS * 1e6

    def row(self) -> str:
        return (
            f"{self.name:<18} {self.n_pad:>6}x{self.d:<4} "
            f"bn={self.block_n:<5} steps={self.grid_steps:<3} "
            f"VMEM/step={self.vmem_per_step / 2**20:6.2f}MiB "
            f"AI={self.arithmetic_intensity:4.1f} "
            f"MXU-row={self.mxu_row_utilization * 100:5.1f}% "
            f"~{self.est_time_us:7.1f}µs HBM-bound"
        )


def estimates():
    out = []
    for ds, (n_total, d, m, tasks) in aot.DATASETS.items():
        n_pad = aot.per_worker_padded(n_total, m)
        bn = best_block_n(n_pad, d)
        for task in tasks:
            if task == "nn":
                continue  # parameter-resident accumulators, see below
            out.append(KernelEstimate(f"{task}_{ds}", n_pad, d, bn))
    return out


def main():
    print(f"VMEM tile budget: {VMEM_TILE_BUDGET / 2**20:.0f} MiB "
          f"(of {VMEM_BYTES / 2**20:.0f} MiB)")
    print("fused-gradient kernels (one X sweep, grad accumulator "
          "resident):\n")
    for e in estimates():
        assert e.vmem_per_step <= VMEM_BYTES, f"{e.name} exceeds VMEM!"
        print(e.row())
    # NN: the d×h accumulator must also stay resident
    d, h = 784, model.HIDDEN
    acc = 4 * (d * h + 2 * h + 2)
    print(f"\nnn kernels: extra resident accumulators (d=784): "
          f"{acc / 2**10:.0f} KiB — fits alongside the X tile")
    print("\nConclusion: every kernel is HBM-bandwidth-bound "
          "(AI ≈ 1 ≪ MXU ridge ≈ 230); block choice therefore "
          "minimizes grid steps, matching best_block_n().")


if __name__ == "__main__":
    main()
