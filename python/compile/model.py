"""L2 — per-worker compute graphs for the four paper tasks.

Each task exposes one jax function mapping
    (θ, X_m, y_m, …) → (∇f_m(θ), f_m(θ))
built on the L1 Pallas kernels (kernels/*).  These are the graphs
``aot.py`` lowers to HLO text; the rust coordinator executes one per
worker per iteration, and Python never runs at that point.

Shape protocol (mirrored by rust/src/data/mod.rs — keep in sync):
  * N_m is padded up to a multiple of BLOCK_N with zero rows; logistic
    and NN take an explicit {0,1} mask so padded rows are inert.
  * d is the dataset's true feature count (no column padding).
  * θ for the NN task is the flat (d·h + 2h + 1) parameter vector; the
    unpack/pack is part of the lowered graph.
"""

import jax.numpy as jnp

from .kernels import (
    lasso_grad_loss,
    linreg_grad_loss,
    logreg_grad_loss,
    nn_grad_loss,
)
from .kernels.common import best_block_n, DEFAULT_BLOCK_N
from .kernels import ref

BLOCK_N = DEFAULT_BLOCK_N
HIDDEN = 30  # paper: one hidden layer with 30 nodes

TASKS = ("linreg", "logreg", "lasso", "nn")


def padded_n(n: int) -> int:
    """Rows after padding to the kernel row-tile."""
    block = min(n, BLOCK_N)
    return ((n + block - 1) // block) * block


def nn_param_dim(d: int, h: int = HIDDEN) -> int:
    return ref.nn_dim(d, h)


# ---------------------------------------------------------------------------
# task graphs
# ---------------------------------------------------------------------------


def linreg_worker(theta, x, y):
    """(∇½‖Xθ−y‖², loss). x: (Np, d) zero-padded."""
    bn = best_block_n(x.shape[0], x.shape[1])
    return linreg_grad_loss(theta, x, y, block_n=bn)


def logreg_worker(theta, x, y, mask, lam):
    """ℓ2-regularized logistic gradient + loss. lam: (1,)."""
    bn = best_block_n(x.shape[0], x.shape[1])
    return logreg_grad_loss(theta, x, y, mask, lam, block_n=bn)


def lasso_worker(theta, x, y, lam):
    """Lasso subgradient + loss. lam: (1,)."""
    bn = best_block_n(x.shape[0], x.shape[1])
    return lasso_grad_loss(theta, x, y, lam, block_n=bn)


def nn_worker(theta, x, y, mask, lam, wscale, h: int = HIDDEN):
    """Flat-θ wrapper around the fused NN kernel.

    Unpacks θ → (W1, b1, w2, b2), runs the fused fwd+bwd Pallas kernel,
    and repacks the gradients into a flat vector so the coordinator only
    ever sees ℝ^P vectors (same code path as every other task).
    `wscale` = 1/N_m gives the paper's mean-loss NN regime.
    """
    d = x.shape[1]
    w1, b1, w2, b2 = ref.nn_unpack(theta, d, h)
    bn = best_block_n(x.shape[0], x.shape[1])
    gw1, gb1, gw2, gb2, loss = nn_grad_loss(
        w1, b1, w2, jnp.atleast_1d(b2), x, y, mask, lam, wscale, block_n=bn
    )
    grad = jnp.concatenate([gw1.reshape(-1), gb1, gw2, gb2])
    return grad, loss


# ---------------------------------------------------------------------------
# registry used by aot.py
# ---------------------------------------------------------------------------


def worker_fn(task: str):
    """Return (fn, needs_mask, needs_lam) for a task name."""
    if task == "linreg":
        return linreg_worker, False, False
    if task == "logreg":
        return logreg_worker, True, True
    if task == "lasso":
        return lasso_worker, False, True
    if task == "nn":
        return nn_worker, True, True
    raise ValueError(f"unknown task {task!r} (want one of {TASKS})")


def theta_dim(task: str, d: int) -> int:
    return nn_param_dim(d) if task == "nn" else d
