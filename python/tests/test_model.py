"""L2 model graphs: shapes, composition, and agreement with ref.py."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def _worker_data(seed, n, d):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    mask = np.ones(n, np.float32)
    return x, y, mask


@pytest.mark.parametrize("task", model.TASKS)
def test_worker_fn_signature_and_shapes(task):
    fn, needs_mask, needs_lam = model.worker_fn(task)
    n, d = 64, 10
    x, y, mask = _worker_data(0, n, d)
    p = model.theta_dim(task, d)
    theta = (0.1 * np.arange(p, dtype=np.float32) % 1.0) - 0.5
    args = [theta, x, y]
    if needs_mask:
        args.append(mask)
    if needs_lam:
        args.append(np.float32([0.01]))
    if task == "nn":
        args.append(np.float32([1.0 / n]))
    grad, loss = fn(*args)
    assert grad.shape == (p,)
    assert loss.shape == (1,)
    assert np.isfinite(np.asarray(grad)).all()
    assert np.isfinite(float(loss[0]))


def test_linreg_model_matches_ref():
    x, y, _ = _worker_data(1, 50, 8)
    theta = np.linspace(-1, 1, 8, dtype=np.float32)
    grad, loss = model.linreg_worker(theta, x, y)
    assert_allclose(
        np.asarray(grad), np.asarray(ref.linreg_grad(theta, x, y)),
        rtol=1e-4, atol=1e-3,
    )
    assert_allclose(float(loss[0]), float(ref.linreg_loss(theta, x, y)),
                    rtol=1e-4)


def test_nn_model_flat_theta_round_trip():
    """nn_worker must unpack/pack exactly like ref.nn_grad (sum mode)."""
    n, d, h = 32, 6, model.HIDDEN
    x, y, mask = _worker_data(2, n, d)
    rng = np.random.default_rng(3)
    theta = (0.3 * rng.standard_normal(model.nn_param_dim(d))).astype(
        np.float32
    )
    lam = np.float32([0.01])
    grad, loss = model.nn_worker(theta, x, y, mask, lam, np.float32([1.0]))
    g_ref = np.asarray(ref.nn_grad(theta, x, y, 0.01, h=h))
    scale = max(1.0, float(np.abs(g_ref).max()))
    assert_allclose(np.asarray(grad), g_ref, rtol=5e-4, atol=5e-4 * scale)
    assert_allclose(float(loss[0]), float(ref.nn_loss(theta, x, y, 0.01, h=h)),
                    rtol=5e-4)


def test_nn_wscale_scales_data_terms_only():
    n, d = 16, 4
    x, y, mask = _worker_data(4, n, d)
    rng = np.random.default_rng(5)
    theta = (0.3 * rng.standard_normal(model.nn_param_dim(d))).astype(
        np.float32
    )
    lam = np.float32([0.0])  # isolate the data term
    g1, l1 = model.nn_worker(theta, x, y, mask, lam, np.float32([1.0]))
    g2, l2 = model.nn_worker(theta, x, y, mask, lam, np.float32([0.25]))
    assert_allclose(np.asarray(g2), 0.25 * np.asarray(g1), rtol=1e-5)
    assert_allclose(float(l2[0]), 0.25 * float(l1[0]), rtol=1e-5)


def test_padded_n_protocol():
    # mirrors rust data::padded_n tests — keep the two in sync
    assert model.padded_n(50) == 50
    assert model.padded_n(5555) == 5632
    assert model.padded_n(6667) == 6912
    assert model.padded_n(256) == 256
    assert model.padded_n(257) == 512
