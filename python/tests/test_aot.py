"""AOT lowering: manifest consistency and HLO-text well-formedness."""

import json
import os

import pytest

from compile import aot, model


def test_per_worker_padded_matches_protocol():
    # ijcnn1: ceil(49990/9) = 5555 → 5632
    assert aot.per_worker_padded(49_990, 9) == 5632
    # mnist: ceil(60000/9) = 6667 → 6912
    assert aot.per_worker_padded(60_000, 9) == 6912
    # small sets: no padding when n_m < block
    assert aot.per_worker_padded(450, 9) == 50
    assert aot.per_worker_padded(506, 3) == 169


def test_dataset_table_covers_every_task():
    tasks = set()
    for _, (_, _, _, ts) in aot.DATASETS.items():
        tasks.update(ts)
    assert tasks == set(model.TASKS)


def test_lower_artifact_produces_hlo_text_and_specs():
    hlo, specs = aot.lower_artifact("linreg", 50, 8)
    assert hlo.startswith("HloModule")
    assert "f32[50,8]" in hlo
    assert [s["name"] for s in specs] == ["theta", "x", "y"]
    hlo, specs = aot.lower_artifact("nn", 50, 8)
    names = [s["name"] for s in specs]
    assert names == ["theta", "x", "y", "mask", "lam", "wscale"]
    # flat θ dim: 8·30 + 61
    assert specs[0]["shape"] == [8 * 30 + 61]


def test_main_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    rc = aot.main(["--out-dir", str(out), "--only", "synth",
                   "--tasks", "linreg"])
    assert rc == 0
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["block_n"] == model.BLOCK_N
    (entry,) = manifest["artifacts"]
    assert entry["name"] == "linreg_synth"
    assert os.path.exists(out / entry["file"])
    assert len(entry["sha256"]) == 64


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "..", "..", "artifacts",
                                    "manifest.json")),
    reason="run `make artifacts` first",
)
def test_checked_in_manifest_is_consistent():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = json.loads(open(os.path.join(root, "manifest.json")).read())
    names = set()
    for a in manifest["artifacts"]:
        assert a["name"] not in names, "duplicate artifact"
        names.add(a["name"])
        assert os.path.exists(os.path.join(root, a["file"])), a["file"]
        spec = aot.DATASETS[a["dataset"]]
        assert a["n_total"] == spec[0]
        assert a["d"] == spec[1]
        assert a["workers"] == spec[2]
        assert a["n_pad"] == aot.per_worker_padded(spec[0], spec[2])
