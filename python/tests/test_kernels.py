"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes, seeds, padding amounts, and block sizes;
assert_allclose is the pass criterion (f32, so atol/rtol ~1e-4 relative
to problem scale).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    lasso_grad_loss,
    linreg_grad_loss,
    logreg_grad_loss,
    matmul,
    nn_grad_loss,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _problem(seed, n, d, pad=0, labels="gauss"):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    if labels == "pm1":
        y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    else:
        y = rng.standard_normal(n).astype(np.float32)
    theta = rng.standard_normal(d).astype(np.float32)
    mask = np.ones(n, dtype=np.float32)
    if pad:
        x = np.vstack([x, np.zeros((pad, d), np.float32)])
        y = np.concatenate([y, np.zeros(pad, np.float32)])
        mask = np.concatenate([mask, np.zeros(pad, np.float32)])
    return theta, x, y, mask


def _block(n_total, frac_idx):
    """Pick a block size that divides n_total."""
    divisors = [b for b in range(1, n_total + 1) if n_total % b == 0]
    return divisors[frac_idx % len(divisors)]


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31),
    mi=st.integers(1, 3),
    ni=st.integers(1, 3),
    ki=st.integers(1, 3),
)
def test_matmul_vs_jnp(seed, mi, ni, ki):
    m, n, k = 32 * mi, 32 * ni, 32 * ki
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = matmul(jnp.asarray(a), jnp.asarray(b), bm=32, bn=32, bk=32)
    assert_allclose(np.asarray(got), a @ b, rtol=2e-4, atol=2e-4)


def test_matmul_rejects_untileable():
    a = jnp.zeros((33, 32))
    b = jnp.zeros((32, 32))
    with pytest.raises(AssertionError):
        matmul(a, b, bm=32, bn=32, bk=32)


# ---------------------------------------------------------------------------
# linreg
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31),
    n=st.integers(2, 200),
    d=st.integers(1, 64),
    pad_blocks=st.integers(0, 2),
)
def test_linreg_kernel_vs_ref(seed, n, d, pad_blocks):
    theta, x, y, _ = _problem(seed, n, d)
    g_ref = np.asarray(ref.linreg_grad(theta, x, y))
    l_ref = float(ref.linreg_loss(theta, x, y))
    # pad to a multiple of some divisor-based block
    bn = _block(n, seed % 7)
    pad = pad_blocks * bn
    xp = np.vstack([x, np.zeros((pad, d), np.float32)])
    yp = np.concatenate([y, np.zeros(pad, np.float32)])
    g, l = linreg_grad_loss(theta, xp, yp, block_n=bn)
    scale = max(1.0, float(np.abs(g_ref).max()))
    assert_allclose(np.asarray(g), g_ref, rtol=1e-4, atol=1e-4 * scale)
    assert_allclose(float(l[0]), l_ref, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# logistic
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31),
    n=st.integers(2, 200),
    d=st.integers(1, 64),
    pad_blocks=st.integers(0, 2),
    lam=st.floats(0.0, 1.0),
)
def test_logreg_kernel_vs_ref(seed, n, d, pad_blocks, lam):
    theta, x, y, _ = _problem(seed, n, d, labels="pm1")
    g_ref = np.asarray(ref.logreg_grad(theta, x, y, lam))
    l_ref = float(ref.logreg_loss(theta, x, y, lam))
    bn = _block(n, seed % 7)
    pad = pad_blocks * bn
    xp = np.vstack([x, np.zeros((pad, d), np.float32)])
    yp = np.concatenate([y, np.zeros(pad, np.float32)])
    mask = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
    g, l = logreg_grad_loss(
        theta, xp, yp, mask, np.float32([lam]), block_n=bn
    )
    assert_allclose(np.asarray(g), g_ref, rtol=1e-4, atol=1e-4)
    assert_allclose(float(l[0]), l_ref, rtol=1e-4, atol=1e-3)


def test_logreg_padding_changes_nothing():
    """The mask must make padded and unpadded results identical."""
    theta, x, y, _ = _problem(0, 64, 8, labels="pm1")
    lam = np.float32([0.01])
    mask = np.ones(64, np.float32)
    g0, l0 = logreg_grad_loss(theta, x, y, mask, lam, block_n=64)
    xp = np.vstack([x, np.zeros((64, 8), np.float32)])
    yp = np.concatenate([y, np.zeros(64, np.float32)])
    mp = np.concatenate([mask, np.zeros(64, np.float32)])
    g1, l1 = logreg_grad_loss(theta, xp, yp, mp, lam, block_n=64)
    assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-6)
    assert_allclose(np.asarray(l0), np.asarray(l1), rtol=1e-6)


# ---------------------------------------------------------------------------
# lasso
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31),
    n=st.integers(2, 200),
    d=st.integers(1, 64),
    lam=st.floats(0.0, 2.0),
)
def test_lasso_kernel_vs_ref(seed, n, d, lam):
    theta, x, y, _ = _problem(seed, n, d)
    g_ref = np.asarray(ref.lasso_subgrad(theta, x, y, lam))
    l_ref = float(ref.lasso_loss(theta, x, y, lam))
    bn = _block(n, seed % 5)
    g, l = lasso_grad_loss(theta, x, y, np.float32([lam]), block_n=bn)
    scale = max(1.0, float(np.abs(g_ref).max()))
    assert_allclose(np.asarray(g), g_ref, rtol=1e-4, atol=1e-4 * scale)
    assert_allclose(float(l[0]), l_ref, rtol=1e-4, atol=1e-3)


def test_lasso_sign_zero_is_zero():
    """sign(0) must contribute no subgradient."""
    d = 4
    theta = np.zeros(d, np.float32)
    x = np.zeros((8, d), np.float32)
    y = np.zeros(8, np.float32)
    g, l = lasso_grad_loss(theta, x, y, np.float32([5.0]), block_n=8)
    assert_allclose(np.asarray(g), np.zeros(d), atol=0)
    assert float(l[0]) == 0.0


# ---------------------------------------------------------------------------
# neural network
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31),
    n=st.integers(2, 120),
    d=st.integers(1, 32),
    h=st.integers(1, 30),
    pad_blocks=st.integers(0, 1),
    lam=st.floats(0.0, 0.1),
)
def test_nn_kernel_vs_ref(seed, n, d, h, pad_blocks, lam):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    theta = (0.5 * rng.standard_normal(ref.nn_dim(d, h))).astype(np.float32)
    g_ref = np.asarray(ref.nn_grad(theta, x, y, lam, h=h))
    l_ref = float(ref.nn_loss(theta, x, y, lam, h=h))

    w1, b1, w2, b2 = ref.nn_unpack(jnp.asarray(theta), d, h)
    bn = _block(n, seed % 5)
    pad = pad_blocks * bn
    xp = np.vstack([x, np.zeros((pad, d), np.float32)])
    yp = np.concatenate([y, np.zeros(pad, np.float32)])
    mask = np.concatenate([np.ones(n, np.float32), np.zeros(pad, np.float32)])
    gw1, gb1, gw2, gb2, loss = nn_grad_loss(
        w1, b1, w2, np.float32([float(b2)]), xp, yp, mask,
        np.float32([lam]), block_n=bn,
    )
    got = np.concatenate(
        [np.asarray(gw1).reshape(-1), np.asarray(gb1), np.asarray(gw2),
         np.asarray(gb2)]
    )
    scale = max(1.0, float(np.abs(g_ref).max()))
    assert_allclose(got, g_ref, rtol=5e-4, atol=5e-4 * scale)
    assert_allclose(float(loss[0]), l_ref, rtol=5e-4, atol=1e-3)


def test_nn_mask_blocks_padded_rows():
    """Without the mask, padded rows would push σ(b1)·w2+b2 into the grad."""
    d, h, n = 3, 5, 16
    rng = np.random.default_rng(1)
    theta = rng.standard_normal(ref.nn_dim(d, h)).astype(np.float32)
    w1, b1, w2, b2 = ref.nn_unpack(jnp.asarray(theta), d, h)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    ones = np.ones(n, np.float32)
    base = nn_grad_loss(w1, b1, w2, np.float32([float(b2)]), x, y, ones,
                        np.float32([0.0]), block_n=16)
    xp = np.vstack([x, np.zeros((16, d), np.float32)])
    yp = np.concatenate([y, np.zeros(16, np.float32)])
    mp = np.concatenate([ones, np.zeros(16, np.float32)])
    padded = nn_grad_loss(w1, b1, w2, np.float32([float(b2)]), xp, yp, mp,
                          np.float32([0.0]), block_n=16)
    for a, b in zip(base, padded):
        assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
