#!/usr/bin/env python3
"""Diff two BENCH_<target>.json files (see rust/src/bench/mod.rs).

Usage: bench_diff.py BASELINE.json CURRENT.json

Compares median_ns on every row the two files share and prints a
markdown table (suitable for $GITHUB_STEP_SUMMARY).  Rows whose
current median exceeds 2x the baseline are flagged loudly; rows
present in only one file are listed but never flagged.

Population-scale rows (scale_pop_*, wire_loadgen_pop*) are
first-class: compared and flagged like every timing row, with one
unit quirk -- *_rss_kib rows carry raw peak-RSS KiB in the median_ns
slot (the row name is the unit), so they render as MiB, not time.

Always exits 0: shared-runner noise makes a hard gate flaky, so this
is a warn-only step -- the signal is the table in the CI summary, not
the exit code.
"""

import json
import sys

REGRESSION_FACTOR = 2.0


def load(path):
    try:
        with open(path, "r", encoding="utf-8") as f:
            rows = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench_diff: could not read {path}: {e}")
        return None
    return {r["name"]: float(r["median_ns"]) for r in rows}


def fmt_ns(ns):
    if ns >= 1e6:
        return f"{ns / 1e6:.2f} ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.2f} us"
    return f"{ns:.0f} ns"


def fmt_value(name, v):
    """Render a row's median in its actual unit (see module doc)."""
    if name.endswith("_rss_kib"):
        return f"{v / 1024:.1f} MiB"
    return fmt_ns(v)


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip())
        return 0
    base = load(argv[1])
    cur = load(argv[2])
    if base is None or cur is None:
        print("bench_diff: skipping comparison (see above)")
        return 0

    shared = [n for n in cur if n in base]
    regressions = []
    print("### Bench diff vs baseline")
    print()
    print("| bench | baseline | current | ratio | |")
    print("|---|---:|---:|---:|---|")
    for name in shared:
        b, c = base[name], cur[name]
        ratio = c / b if b > 0 else float("inf")
        flag = ""
        if ratio > REGRESSION_FACTOR:
            flag = f"**>{REGRESSION_FACTOR:g}x REGRESSION**"
            regressions.append((name, ratio))
        print(
            f"| {name} | {fmt_value(name, b)} | {fmt_value(name, c)} "
            f"| {ratio:.2f}x | {flag} |"
        )
    print()

    only_base = sorted(n for n in base if n not in cur)
    only_cur = sorted(n for n in cur if n not in base)
    if only_base:
        print(f"rows only in baseline ({len(only_base)}): "
              + ", ".join(only_base))
    if only_cur:
        print(f"rows only in current ({len(only_cur)}): "
              + ", ".join(only_cur))

    if regressions:
        print()
        print(f"WARNING: {len(regressions)} row(s) regressed "
              f">{REGRESSION_FACTOR:g}x vs the checked-in baseline:")
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x")
        print("(warn-only: update BENCH_hotpath.json at the repo root "
              "if the new cost is intentional)")
    else:
        print(f"\nno >{REGRESSION_FACTOR:g}x regressions on "
              f"{len(shared)} shared rows")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
