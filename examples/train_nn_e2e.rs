//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Federated training of the paper's neural network (1 hidden layer,
//! 30 sigmoid units) on the ijcnn1 workload with M = 9 workers:
//!
//!   L1/L2  the worker gradient is the fused Pallas kernel inside the
//!          jax graph, AOT-lowered by `make artifacts` to HLO text;
//!   runtime  rust loads + compiles it through PJRT (CPU) — Python is
//!          not running anywhere in this binary;
//!   L3     the threaded coordinator (one OS thread per worker) runs
//!          CHB vs HB for 500 rounds and logs the loss curve.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_nn_e2e
//! ```
//!
//! Writes results/e2e/{CHB,HB}.csv; the run is recorded in
//! EXPERIMENTS.md §End-to-end.

use std::path::Path;

use chb_fed::coordinator::{run_threaded, RunConfig};
use chb_fed::experiments::Problem;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::runtime::PjrtRuntime;
use chb_fed::tasks::TaskKind;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    let data = Path::new("data");
    let rounds: usize = std::env::var("E2E_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);

    let mut rt = PjrtRuntime::new(artifacts)?;
    println!(
        "PJRT platform: {} — executing AOT Pallas artifacts, no Python",
        rt.platform()
    );

    // the paper's ijcnn1 NN protocol: λ = 1/49990, α = 0.02, ε₁ = 0.01
    let ds = chb_fed::data::registry::load("ijcnn1", data)?.standardized();
    let shards = chb_fed::data::partition::split_even(&ds, 9);
    let problem =
        Problem::from_shards(TaskKind::Nn, "ijcnn1", shards, 1.0 / 49_990.0);
    let alpha = 0.02f64.min(0.5 / problem.l_global);
    println!(
        "problem: NN 1×30 on ijcnn1 — M=9, θ∈ℝ^{}, L≈{:.3}, α={alpha:.4}",
        problem.dim(),
        problem.l_global
    );

    let params = MethodParams::new(alpha).with_beta(0.4).with_epsilon1(0.01);
    let mut summary = Vec::new();
    for method in [Method::Chb, Method::Hb] {
        let t0 = std::time::Instant::now();
        let workers = problem.pjrt_workers(&mut rt)?;
        let cfg = RunConfig::new(method, params, rounds);
        let trace = run_threaded(workers, &cfg, problem.theta0());
        let secs = t0.elapsed().as_secs_f64();
        chb_fed::metrics::csv::write_trace(
            Path::new("results/e2e").join(format!("{}.csv", trace.method)).as_path(),
            &trace,
            0.0,
        )?;
        println!(
            "\n{} — {rounds} rounds in {secs:.1}s ({:.1} rounds/s)",
            trace.method,
            rounds as f64 / secs
        );
        println!("  loss curve (every {} rounds):", (rounds / 10).max(1));
        for s in trace.iters.iter().step_by((rounds / 10).max(1)) {
            println!(
                "    k={:<4} f={:<12.6} ‖∇‖²={:<12.6e} comms={}",
                s.k, s.loss, s.agg_grad_sq, s.comms_cum
            );
        }
        summary.push((
            trace.method.clone(),
            trace.total_comms(),
            trace.final_loss(),
            trace.iters.last().map_or(f64::NAN, |s| s.agg_grad_sq),
        ));
    }

    println!("\n=== end-to-end summary (ijcnn1 NN, {rounds} rounds) ===");
    println!("{:<5} {:>8} {:>14} {:>14}", "", "comms", "final loss", "final ‖∇‖²");
    for (m, c, l, g) in &summary {
        println!("{:<5} {:>8} {:>14.6} {:>14.4e}", m, c, l, g);
    }
    let (chb, hb) = (&summary[0], &summary[1]);
    println!(
        "\nCHB used {:.0}% of HB's communications at comparable loss \
         ({:.6} vs {:.6}).",
        100.0 * chb.1 as f64 / hb.1 as f64,
        chb.2,
        hb.2
    );
    Ok(())
}
