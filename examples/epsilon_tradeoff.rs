//! The communication–iteration trade-off knob (paper Fig. 11), driven
//! through the public API: sweep ε₁ over four decades on the
//! synthetic logistic problem and print the frontier.
//!
//! ```bash
//! cargo run --release --example epsilon_tradeoff
//! ```

use chb_fed::coordinator::{run_serial, RunConfig, StopRule};
use chb_fed::experiments::figures::synth_logreg_problem;
use chb_fed::optim::{Method, MethodParams};

fn main() {
    let problem = synth_logreg_problem(0xE7, 0.001);
    let f_star = problem.f_star().expect("strongly convex");
    let alpha = 1.0 / problem.l_global;
    println!(
        "synthetic logistic (M=9, common L_m=4): α=1/L={alpha:.5}, \
         target obj err 1e-8\n"
    );
    println!(
        "{:>22} {:>8} {:>8} {:>12}",
        "ε₁", "comms", "iters", "comm/iter"
    );

    // ε₁ = 0 is exactly classical HB; the sweep shows the paper's
    // "tune ε₁ for a favorable trade-off" claim (§II).
    for c in [0.0, 0.001, 0.01, 0.1, 1.0, 10.0] {
        let mut params = MethodParams::new(alpha).with_beta(0.4);
        params = if c == 0.0 {
            params.with_epsilon1(0.0)
        } else {
            params.with_epsilon1_scaled(c, problem.m_workers())
        };
        let cfg = RunConfig::new(Method::Chb, params, 5_000)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
        let mut ws = problem.rust_workers();
        let t = run_serial(&mut ws, &cfg, problem.theta0());
        let label = if c == 0.0 {
            "0 (≡ HB)".to_string()
        } else {
            format!("{c}/(α²M²)")
        };
        println!(
            "{label:>22} {:>8} {:>8} {:>12.2}",
            t.total_comms(),
            t.iterations(),
            t.total_comms() as f64 / t.iterations().max(1) as f64
        );
    }
    println!(
        "\nSmall ε₁ ⇒ HB-like (every worker transmits); larger ε₁ buys \
         communications with iterations until convergence degrades."
    );
}
