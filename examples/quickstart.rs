//! Quickstart: the whole public API in one screen.
//!
//! Builds the paper's synthetic linear-regression problem (9 workers,
//! increasing smoothness constants), runs all four methods, and prints
//! the communications-vs-iterations comparison that is the paper's
//! headline claim.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use chb_fed::coordinator::{run_serial, RunConfig, StopRule};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::tasks::TaskKind;

fn main() {
    // 1. A federated problem: M = 9 workers, each with 50 samples of
    //    50 features, worker m's smoothness constant L_m = (1.3^m)².
    let l_m = synthetic::increasing_l(9);
    let per_worker = synthetic::per_worker_rescaled(42, 9, 50, 50, &l_m);
    let problem =
        Problem::from_worker_datasets(TaskKind::LinReg, "synth", &per_worker, 0.0);
    let f_star = problem.f_star().expect("convex task has an optimum");
    println!(
        "problem: linear regression, M={}, d={}, L={:.2}, f*={:.4}",
        problem.m_workers(),
        problem.dim(),
        problem.l_global,
        f_star
    );

    // 2. The paper's parameter protocol: α = 1/L, β = 0.4,
    //    ε₁ = 0.1/(α²M²), stop at objective error 1e-8.
    let alpha = 1.0 / problem.l_global;
    let params = MethodParams::new(alpha)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, problem.m_workers());

    // 3. Run GD, HB, LAG (censoring GD) and CHB (this paper).
    println!("\n{:<6} {:>8} {:>8}   (target err 1e-8)", "method", "comms", "iters");
    for method in Method::ALL {
        let cfg = RunConfig::new(method, params, 2_000)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
        let mut workers = problem.rust_workers();
        let trace = run_serial(&mut workers, &cfg, problem.theta0());
        println!(
            "{:<6} {:>8} {:>8}",
            trace.method,
            trace.total_comms(),
            trace.iterations()
        );
    }
    println!(
        "\nCHB should match HB's iteration count at a fraction of its \
         communications — the paper's headline result."
    );
}
