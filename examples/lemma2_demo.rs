//! Lemma 2 as a live demonstration: workers whose smoothness constant
//! satisfies L_m² ≤ ε₁ transmit at most k/2 times in k iterations.
//!
//! Builds a 9-worker linear regression where the first workers are
//! very smooth and the last are not, runs CHB, and checks the bound
//! worker by worker against `theory::lemma2_bound`.
//!
//! ```bash
//! cargo run --release --example lemma2_demo
//! ```

//! Caveat demonstrated here too: Lemma 2 is a statement about exact
//! arithmetic.  Once a run reaches f64 machine precision the computed
//! δ∇ is cancellation noise and no longer bounded by L_m‖Δθ‖, so the
//! demo (like the paper's experiments) stops at a finite objective
//! error rather than running to the bitter end.

use chb_fed::coordinator::{run_serial, RunConfig, StopRule};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::tasks::TaskKind;
use chb_fed::theory;

fn main() {
    let m = 9;
    let k = 150;
    // smoothness schedule spanning the Lemma-2 threshold
    let l_m: Vec<f64> = (0..m).map(|i| 0.05 * 3.0f64.powi(i as i32)).collect();
    let per_worker = synthetic::per_worker_rescaled(0x1EA, m, 50, 30, &l_m);
    let problem =
        Problem::from_worker_datasets(TaskKind::LinReg, "lemma2", &per_worker, 0.0);

    let alpha = 1.0 / problem.l_global;
    let params = MethodParams::new(alpha)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, m);
    let eps1 = params.epsilon1;
    println!("CHB, {k} iterations, ε₁ = {eps1:.4}\n");
    println!(
        "{:>3} {:>12} {:>10} {:>6} {:>8} {:>9}",
        "m", "L_m", "L_m²≤ε₁", "S_m", "bound", "holds"
    );

    // stop well above machine precision (see module docs)
    let f_star = problem.f_star().expect("convex");
    let cfg = RunConfig::new(Method::Chb, params, k)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-9 });
    let mut workers = problem.rust_workers();
    let trace = run_serial(&mut workers, &cfg, problem.theta0());

    let bound = theory::lemma2_bound(trace.iterations());
    let mut all_hold = true;
    for (i, &s_m) in trace.per_worker_comms.iter().enumerate() {
        let applies = theory::lemma2_applies(problem.l_m[i], eps1);
        let holds = !applies || s_m <= bound;
        all_hold &= holds;
        println!(
            "{i:>3} {:>12.4} {:>10} {s_m:>6} {:>8} {:>9}",
            problem.l_m[i],
            applies,
            if applies { bound.to_string() } else { "—".into() },
            if applies { holds.to_string() } else { "n/a".into() },
        );
    }
    assert!(all_hold, "Lemma 2 violated!");
    println!("\nLemma 2 holds for every qualifying worker. ✓");
}
