//! `cargo bench --bench backends` — gradient-backend latency:
//! pure-rust f64 vs PJRT (AOT Pallas artifact), per worker call.
//!
//! This is the L1/L2-vs-L3 boundary measurement in EXPERIMENTS.md
//! §Perf: how much does routing the worker gradient through the XLA
//! executable cost relative to the in-process implementation, and
//! where is the break-even shape.

use std::path::Path;

use chb_fed::bench::{black_box, header, Bencher};
use chb_fed::coordinator::GradientBackend;
use chb_fed::data::{partition, registry};
use chb_fed::runtime::PjrtRuntime;
use chb_fed::tasks::{self, TaskKind};

fn main() {
    header("backends");
    let b = Bencher::default();
    let Ok(mut rt) = PjrtRuntime::new(Path::new("artifacts")) else {
        println!("(artifacts missing — run `make artifacts`; rust-only run)");
        bench_rust_only(&b);
        return;
    };
    println!("PJRT platform: {}", rt.platform());

    for (task, dataset) in [
        (TaskKind::LinReg, "synth"),
        (TaskKind::LogReg, "synth"),
        (TaskKind::LinReg, "ijcnn1"),
        (TaskKind::Nn, "ijcnn1"),
    ] {
        let spec = registry::spec(dataset).unwrap();
        let ds = registry::load(dataset, Path::new("data")).unwrap();
        let shards = partition::split_even(&ds, spec.workers);
        let shard = &shards[0];
        let lam = 0.001 / spec.workers as f64;

        let obj = tasks::build_objective(task, shard, lam);
        let dim = obj.dim();
        let theta: Vec<f64> = (0..dim).map(|i| (i % 5) as f64 * 0.01).collect();
        let mut ws = tasks::TaskWorkspace::default();
        let mut grad = vec![0.0; dim];
        b.run(&format!("rust {} {dataset}", task.name()), |_| {
            black_box(obj.grad_loss_into(black_box(&theta), &mut ws, &mut grad));
        });

        let meta = rt.manifest().find(task, dataset).unwrap().clone();
        let mut pjrt = rt.worker_backend(&meta, shard, lam).unwrap();
        b.run(&format!("pjrt {} {dataset}", task.name()), |_| {
            black_box(pjrt.grad_loss_into(black_box(&theta), &mut grad));
        });
    }
}

fn bench_rust_only(b: &Bencher) {
    let ds = registry::load("synth", Path::new("data")).unwrap();
    let shards = partition::split_even(&ds, 9);
    let obj = tasks::build_objective(TaskKind::LinReg, &shards[0], 0.0);
    let theta = vec![0.01; obj.dim()];
    let mut ws = tasks::TaskWorkspace::default();
    let mut grad = vec![0.0; obj.dim()];
    b.run("rust linreg synth", |_| {
        black_box(obj.grad_loss_into(black_box(&theta), &mut ws, &mut grad));
    });
}
