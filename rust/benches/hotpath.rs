//! `cargo bench --bench hotpath` — the L3 §Perf microbenches.
//!
//! Measures the per-round cost components on the two shapes that
//! matter (d = 50 synthetic; d = 784 MNIST-class) so EXPERIMENTS.md
//! §Perf can separate coordinator overhead from gradient compute, and
//! pins the two PR-level perf claims directly:
//!
//! * fused single-pass gradient vs the two-pass gemv + gemv_t baseline
//!   (`linreg grad fused` / `linreg grad two-pass` rows), and
//! * sparse O(k) server folds vs dense O(d) folds
//!   (`server fold … sparse` / `… dense` rows).
//!
//! Every result also lands in `BENCH_hotpath.json` (written to the
//! working directory — `rust/` under cargo), machine-readable so the
//! perf trajectory is tracked PR-over-PR.  Pass `-- --smoke` for the
//! CI-sized profile: the same bench list minus the M = 1000 scaling
//! rows, the population-scale rows clamped to M = 10⁴, minimal sample
//! counts.

use std::sync::Arc;

use chb_fed::bench::{black_box, header, BenchResult, Bencher};
use chb_fed::compress::{Payload, TopK};
use chb_fed::coordinator::{run_rayon, run_serial, RunConfig, Server, Worker};
use chb_fed::data::partition::shard_whole;
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg::{self, Matrix};
use chb_fed::net::{dense_delta_bits, sparse_delta_bits};
use chb_fed::optim::{GradDiffCensor, Method, MethodParams, NeverCensor};
use chb_fed::rng::Xoshiro256;
use chb_fed::tasks::{build_objective, TaskKind};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    header(if smoke { "hotpath (smoke)" } else { "hotpath" });
    let micro = if smoke {
        Bencher { warmup_iters: 2, samples: 5, iters_per_sample: 20 }
    } else {
        Bencher::micro()
    };
    let std_b = if smoke { Bencher::quick() } else { Bencher::default() };
    let quick = Bencher::quick();
    let mut all: Vec<BenchResult> = Vec::new();

    // -- linalg primitives ------------------------------------------------
    let mut rng = Xoshiro256::new(1);
    for d in [50usize, 784] {
        let x = rng.gaussian_vec(d);
        let y = rng.gaussian_vec(d);
        all.push(micro.run(&format!("dot d={d}"), |_| {
            black_box(linalg::dot(black_box(&x), black_box(&y)));
        }));
    }
    for (n, d) in [(50usize, 50usize), (768, 784)] {
        let mut m = Matrix::zeros(n, d);
        for v in &mut m.data {
            *v = rng.next_gaussian();
        }
        let theta = rng.gaussian_vec(d);
        let y = rng.gaussian_vec(n);
        let mut out = vec![0.0; n];
        let mut g = vec![0.0; d];
        all.push(micro.run(&format!("gemv {n}x{d}"), |_| {
            m.gemv(black_box(&theta), &mut out);
        }));
        all.push(micro.run(&format!("gemv_t {n}x{d}"), |_| {
            m.gemv_t_into(black_box(&out), &mut g);
        }));
        // the PR-level claim: one row sweep instead of two.  The
        // two-pass body is exactly what the pre-fusion gradient did —
        // gemv, subtract y, gemv_t — streaming X twice per round.
        all.push(micro.run(&format!("linreg grad two-pass {n}x{d}"), |_| {
            m.gemv(black_box(&theta), &mut out);
            for (r, yv) in out.iter_mut().zip(&y) {
                *r -= yv;
            }
            m.gemv_t_into(&out, &mut g);
            black_box(&g);
        }));
        all.push(micro.run(&format!("linreg grad fused {n}x{d}"), |_| {
            g.fill(0.0);
            black_box(m.fused_residual_grad(
                black_box(&theta),
                &y,
                &mut out,
                &mut g,
            ));
        }));
        // minibatch-vs-full gradient kernel: the row-subset sweep the
        // stochastic regime runs (data::batch draws, here a fixed
        // quarter-shard set) against the full fused sweep above — the
        // per-round compute saving censored SGD buys
        {
            use chb_fed::data::batch::{BatchSampler, BatchSchedule};
            let b = (n / 4).max(1);
            let sched = BatchSchedule::Minibatch {
                size: b,
                seed: 0xB47C,
                replace: false,
            };
            let mut sampler = BatchSampler::new(sched, 0, n);
            let rows: Vec<u32> = sampler.draw(1).unwrap().to_vec();
            all.push(micro.run(
                &format!("linreg grad minibatch b={b} {n}x{d}"),
                |_| {
                    g.fill(0.0);
                    black_box(m.fused_residual_grad_rows(
                        black_box(&theta),
                        &y,
                        &rows,
                        &mut out,
                        &mut g,
                    ));
                },
            ));
        }
    }

    // -- worker round (gradient + censor decision) ------------------------
    for (name, n, d) in [("synth", 50usize, 50usize), ("mnist-class", 768, 784)]
    {
        let mut r = Xoshiro256::new(7);
        let ds = synthetic::gaussian_pm1(&mut r, n, d);
        let shard = shard_whole(&ds);
        let obj = build_objective(TaskKind::LinReg, &shard, 0.0);
        let mut worker = Worker::new(
            0,
            Box::new(chb_fed::coordinator::RustBackend::new(obj)),
        );
        let censor = GradDiffCensor { epsilon1: 1.0 };
        let theta = r.gaussian_vec(d);
        // θ is fixed, so this row censors from round 2 on — it times
        // gradient + censor decision (the steady-state skip round)
        all.push(std_b.run(&format!("worker round linreg {name}"), |k| {
            black_box(worker.round(black_box(&theta), 1.0, &censor, k + 1));
        }));
        // dense always-transmit row: the apples-to-apples partner of
        // the top-32 row below (gradient + dense payload + arena)
        let obj = build_objective(TaskKind::LinReg, &shard, 0.0);
        let mut worker = Worker::new(
            0,
            Box::new(chb_fed::coordinator::RustBackend::new(obj)),
        );
        all.push(std_b.run(
            &format!("worker round linreg dense-tx {name}"),
            |k| {
                black_box(worker.round(
                    black_box(&theta),
                    1.0,
                    &NeverCensor,
                    k + 1,
                ));
            },
        ));
        // minibatch worker round: quarter-shard gradient subset plus
        // the full-shard measurement-side loss pass — the steady-state
        // stochastic-regime round, against the dense-tx row above
        {
            use chb_fed::data::batch::BatchSchedule;
            let obj = build_objective(TaskKind::LinReg, &shard, 0.0);
            let mut worker = Worker::new(
                0,
                Box::new(chb_fed::coordinator::RustBackend::new(obj)),
            )
            .with_batching(BatchSchedule::Minibatch {
                size: (n / 4).max(1),
                seed: 0xB47C,
                replace: false,
            });
            all.push(std_b.run(
                &format!("worker round linreg minibatch-tx {name}"),
                |k| {
                    black_box(worker.round(
                        black_box(&theta),
                        1.0,
                        &NeverCensor,
                        k + 1,
                    ));
                },
            ));
        }
        // same round through the sparse top-k uplink: compress_into
        // writes into the worker's arena, no per-round allocation.
        // NeverCensor, not the ε₁ rule: θ is fixed here, so once the
        // decoded payloads telescope to the exact gradient the delta
        // is zero and a censoring worker would skip — every timed
        // round must actually run the compress path.
        let obj = build_objective(TaskKind::LinReg, &shard, 0.0);
        let mut worker = Worker::new(
            0,
            Box::new(chb_fed::coordinator::RustBackend::new(obj)),
        )
        .with_compressor(Arc::new(TopK { k: 32 }));
        all.push(std_b.run(
            &format!("worker round linreg top-32 {name}"),
            |k| {
                black_box(worker.round(
                    black_box(&theta),
                    1.0,
                    &NeverCensor,
                    k + 1,
                ));
            },
        ));
    }

    // -- SIMD dispatch: detected backend vs forced scalar -----------------
    // Same kernel, same inputs, only the dispatch table differs — the
    // [avx2]/[neon] vs [scalar] row pairs are the SIMD speedup claim
    // (bit-identical results, pinned by tests/simd_equivalence.rs).
    {
        use chb_fed::compress::{CodecScratch, Compressor, PackedInt};
        use chb_fed::linalg::simd::{self, Backend};

        let detected = simd::active();
        let backends: Vec<Backend> = if detected == Backend::Scalar {
            vec![Backend::Scalar]
        } else {
            vec![detected, Backend::Scalar]
        };
        let (n, d) = (768usize, 784usize);
        let mut r = Xoshiro256::new(21);
        let mut mx = Matrix::zeros(n, d);
        for v in &mut mx.data {
            *v = r.next_gaussian();
        }
        let theta = r.gaussian_vec(d);
        let yv = r.gaussian_vec(n);
        let mask = vec![1.0; n];
        let mut resid = vec![0.0; n];
        let mut grad = vec![0.0; d];
        let xvec = r.gaussian_vec(d);
        let sparse_idx: Vec<u32> =
            (0..32u32).map(|j| j * (d as u32 / 32)).collect();
        let sparse_val = r.gaussian_vec(32);
        let mut fold = vec![0.0; d];
        let delta = r.gaussian_vec(d);
        let int8 = PackedInt { bits: 8 };
        let mut scratch = CodecScratch::default();
        let mut slot = Payload::default();
        for backend in backends {
            simd::set_active(backend);
            let tag = backend.label();
            all.push(micro.run(
                &format!("fused_residual_grad {n}x{d} [{tag}]"),
                |_| {
                    grad.fill(0.0);
                    black_box(mx.fused_residual_grad(
                        black_box(&theta),
                        &yv,
                        &mut resid,
                        &mut grad,
                    ));
                },
            ));
            all.push(micro.run(
                &format!("fused_coeff_grad {n}x{d} [{tag}]"),
                |_| {
                    grad.fill(0.0);
                    black_box(mx.fused_coeff_grad(
                        black_box(&theta),
                        &mask,
                        |_, z| (z * z, z),
                        &mut grad,
                    ));
                },
            ));
            all.push(micro.run(&format!("axpy fold d={d} [{tag}]"), |_| {
                linalg::axpy(black_box(0.125), &xvec, &mut fold);
            }));
            all.push(micro.run(
                &format!("axpy_sparse fold k=32 d={d} [{tag}]"),
                |_| {
                    linalg::axpy_sparse(
                        black_box(0.125),
                        &sparse_idx,
                        &sparse_val,
                        &mut fold,
                    );
                },
            ));
            all.push(micro.run(&format!("int8 pack d={d} [{tag}]"), |_| {
                black_box(int8.compress_into(
                    black_box(&delta),
                    &mut scratch,
                    &mut slot,
                ));
            }));
            int8.compress_into(&delta, &mut scratch, &mut slot);
            all.push(micro.run(&format!("int8 unpack d={d} [{tag}]"), |_| {
                slot.fold_into(black_box(&mut fold));
            }));
        }
        simd::set_active(detected);
    }

    // -- codec pack/unpack ladder, d = 784 --------------------------------
    // One row pair per ladder rung (the wire-bits column is what the
    // ladder ablation's bits-to-target divides by).
    {
        use chb_fed::compress::{
            CodecScratch, Compressor, ErrorFeedback, NoCompression,
            PackedFp16, PackedFp32, PackedInt,
        };
        let mut r = Xoshiro256::new(33);
        let delta = r.gaussian_vec(784);
        let mut y = vec![0.0; 784];
        let codecs: [(&str, Box<dyn Compressor>); 5] = [
            ("f64", Box::new(NoCompression)),
            ("fp32", Box::new(PackedFp32)),
            ("fp16", Box::new(PackedFp16)),
            ("int8", Box::new(PackedInt { bits: 8 })),
            ("int8-ef", Box::new(ErrorFeedback(PackedInt { bits: 8 }))),
        ];
        for (label, codec) in &codecs {
            let mut scratch = CodecScratch::default();
            let mut slot = Payload::default();
            all.push(micro.run(&format!("codec pack {label} d=784"), |_| {
                black_box(codec.compress_into(
                    black_box(&delta),
                    &mut scratch,
                    &mut slot,
                ));
            }));
            codec.compress_into(&delta, &mut scratch, &mut slot);
            all.push(micro.run(&format!("codec unpack {label} d=784"), |_| {
                slot.fold_into(black_box(&mut y));
            }));
        }
    }

    // -- server fold (aggregate + update), d = 784: dense vs sparse -------
    {
        let d = 784;
        let k_sparse = 32usize;
        let params = MethodParams::new(1e-3).with_beta(0.4);
        let mut r = Xoshiro256::new(9);
        let dense_rounds: Vec<_> = (0..9)
            .map(|w| chb_fed::coordinator::WorkerRound {
                worker: w,
                decision: chb_fed::optim::CensorDecision::Transmit,
                delta: Arc::new(Payload::Dense(r.gaussian_vec(d))),
                loss: 1.0,
                delta_sq: 1.0,
                bits: dense_delta_bits(d),
                batch_frac: 1.0,
            })
            .collect();
        let sparse_rounds: Vec<_> = (0..9)
            .map(|w| {
                let idx: Vec<u32> = (0..k_sparse)
                    .map(|j| (j * d / k_sparse) as u32)
                    .collect();
                chb_fed::coordinator::WorkerRound {
                    worker: w,
                    decision: chb_fed::optim::CensorDecision::Transmit,
                    delta: Arc::new(Payload::Sparse {
                        idx,
                        val: r.gaussian_vec(k_sparse),
                    }),
                    loss: 1.0,
                    delta_sq: 1.0,
                    bits: sparse_delta_bits(k_sparse),
                    batch_frac: 1.0,
                }
            })
            .collect();
        let mut server = Server::new(Method::Chb, &params, vec![0.0; d]);
        all.push(std_b.run("server fold M=9 d=784 dense", |_| {
            black_box(server.apply_round(black_box(&dense_rounds)));
        }));
        let mut server = Server::new(Method::Chb, &params, vec![0.0; d]);
        all.push(std_b.run(
            &format!("server fold M=9 d=784 sparse k={k_sparse}"),
            |_| {
                black_box(server.apply_round(black_box(&sparse_rounds)));
            },
        ));
    }

    // -- method grid: K-step local descent round --------------------------
    // One worker round under MethodSpec::LocalSteps K=4 — K fused
    // gradient sweeps plus the local heavy-ball recursion — against
    // the single-sweep `worker round` rows above.  NeverCensor so
    // every timed round runs the full local sweep.
    {
        use chb_fed::coordinator::LocalStepCfg;
        let mut r = Xoshiro256::new(41);
        let ds = synthetic::gaussian_pm1(&mut r, 768, 784);
        let shard = shard_whole(&ds);
        let obj = build_objective(TaskKind::LinReg, &shard, 0.0);
        let mut worker = Worker::new(
            0,
            Box::new(chb_fed::coordinator::RustBackend::new(obj)),
        )
        .with_local_steps(LocalStepCfg {
            k_local: 4,
            alpha: 1e-3,
            beta: 0.4,
        });
        let theta = r.gaussian_vec(784);
        all.push(std_b.run("method_localsteps_round", |k| {
            black_box(worker.round(
                black_box(&theta),
                1.0,
                &NeverCensor,
                k + 1,
            ));
        }));
    }

    // -- end-to-end rounds ------------------------------------------------
    let problem = {
        let l_m = synthetic::increasing_l(9);
        let per_worker = synthetic::per_worker_rescaled(3, 9, 50, 50, &l_m);
        Problem::from_worker_datasets(TaskKind::LinReg, "synth", &per_worker, 0.0)
    };
    let params = MethodParams::new(1.0 / problem.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, 9);
    all.push(std_b.run("100 CHB rounds M=9 d=50 (serial)", |_| {
        let cfg = RunConfig::new(Method::Chb, params, 100);
        let mut ws = problem.rust_workers();
        black_box(run_serial(&mut ws, &cfg, problem.theta0()));
    }));

    // -- round-pipeline scaling: serial vs rayon pool ---------------------
    // M ∈ {10, 100, 1000} simulated workers, small shards (10×20) so
    // the pool dispatch — not the gradient math — dominates at large M.
    // Worker construction is inside the timed body (fresh censor state
    // per run); both pools pay it identically, so the serial/rayon
    // *ratio* is the scaling signal reported in EXPERIMENTS.md §Perf.
    let m_list: &[usize] = if smoke { &[10, 100] } else { &[10, 100, 1000] };
    for &m in m_list {
        let l_m: Vec<f64> =
            (0..m).map(|i| 1.0 + (i % 7) as f64 * 0.5).collect();
        let per_worker =
            synthetic::per_worker_rescaled(0x5CA1E, m, 10, 20, &l_m);
        let scale_problem = Problem::from_worker_datasets(
            TaskKind::LinReg,
            "scale",
            &per_worker,
            0.0,
        );
        let params = MethodParams::new(1.0 / scale_problem.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, params, 20);
        let b = if smoke || m >= 1000 { &quick } else { &std_b };
        all.push(b.run(&format!("20 CHB rounds M={m} d=20 (serial)"), |_| {
            let mut ws = scale_problem.rust_workers();
            black_box(run_serial(&mut ws, &cfg, scale_problem.theta0()));
        }));
        all.push(b.run(&format!("20 CHB rounds M={m} d=20 (rayon)"), |_| {
            black_box(run_rayon(
                scale_problem.rust_workers(),
                &cfg,
                scale_problem.theta0(),
            ));
        }));
    }

    // -- population-scale rounds: cohort engine at M clients --------------
    // The PR-level scale claim: per-simulated-round cost of the
    // population engine at M ∈ {10⁴, 10⁵, 10⁶} clients (smoke clamps
    // to 10⁴), cohort 256, against 8 Arc-shared base shards — round
    // cost tracks the cohort, not the population.  Each M also emits a
    // scale_pop_m*_rss_kib row carrying the process peak RSS (VmHWM;
    // KiB in the ns slots — the name is the unit), the O(model +
    // cohort + M·8B) memory claim in machine-readable form.
    {
        use chb_fed::coordinator::{
            AsyncConfig, EngineKind, PopulationSpec,
        };
        use chb_fed::spec::{ParamSpec, RunSpec, Session};
        let base_m = 8usize;
        let l_m = synthetic::increasing_l(base_m);
        let per_worker =
            synthetic::per_worker_rescaled(0xCA11, base_m, 32, 64, &l_m);
        let pop_problem = Problem::from_worker_datasets(
            TaskKind::LinReg,
            "scale",
            &per_worker,
            0.0,
        );
        let m_list: &[u64] = if smoke {
            &[10_000]
        } else {
            &[10_000, 100_000, 1_000_000]
        };
        for &clients in m_list {
            let cohort = 256u64.min(clients);
            let rounds = 10usize;
            // the population objective sums one gradient per client:
            // α scales with 1/(M/W · L) or the run diverges
            let mult = clients.div_ceil(base_m as u64);
            let alpha = 1.0 / (mult as f64 * pop_problem.l_global);
            let spec = RunSpec {
                params: ParamSpec {
                    alpha: Some(alpha),
                    ..ParamSpec::default()
                },
                engine: EngineKind::Async(AsyncConfig::default()),
                population: Some(PopulationSpec {
                    clients,
                    cohort,
                    seed: 0xCA11,
                }),
                iters: rounds,
                lambda: 0.0,
                ..RunSpec::new(TaskKind::LinReg, "scale")
            };
            let reps = if clients >= 1_000_000 { 1 } else { 3 };
            let mut times = Vec::with_capacity(reps);
            for _ in 0..reps {
                let session =
                    Session::from_parts(spec.clone(), pop_problem.clone())
                        .expect("scale spec rejected");
                let t0 = std::time::Instant::now();
                let report = session.run();
                times.push(t0.elapsed().as_secs_f64() / rounds as f64);
                black_box(report.trace.final_loss());
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let r = BenchResult {
                name: format!("scale_pop_m{clients}_cohort{cohort}_round"),
                samples: reps,
                iters: reps * rounds,
                median: times[times.len() / 2],
                mad: 0.0,
                min: times[0],
                max: times[times.len() - 1],
            };
            println!("{}", r.report());
            all.push(r);
            if let Some(kib) = chb_fed::util::mem::peak_rss_kib() {
                // ×1e-9 so write_json's ns conversion lands the raw
                // KiB count in the median_ns slot
                let v = kib as f64 * 1e-9;
                all.push(BenchResult {
                    name: format!("scale_pop_m{clients}_rss_kib"),
                    samples: 1,
                    iters: 1,
                    median: v,
                    mad: 0.0,
                    min: v,
                    max: v,
                });
                println!(
                    "{:<44} {:>12.1} MiB peak RSS",
                    format!("scale_pop_m{clients}_rss_kib"),
                    kib as f64 / 1024.0
                );
            }
        }
    }

    // -- machine-readable report ------------------------------------------
    let out = std::path::Path::new("BENCH_hotpath.json");
    chb_fed::bench::write_json(out, &all).expect("write BENCH_hotpath.json");
    println!("\nwrote {} ({} entries)", out.display(), all.len());
}
