//! `cargo bench --bench hotpath` — the L3 §Perf microbenches.
//!
//! Measures the per-round cost components on the two shapes that
//! matter (d = 50 synthetic; d = 784 MNIST-class) so EXPERIMENTS.md
//! §Perf can separate coordinator overhead from gradient compute.

use chb_fed::bench::{black_box, header, Bencher};
use chb_fed::coordinator::{run_rayon, run_serial, RunConfig, Server, Worker};
use chb_fed::data::partition::shard_whole;
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg::{self, Matrix};
use chb_fed::optim::{GradDiffCensor, Method, MethodParams};
use chb_fed::rng::Xoshiro256;
use chb_fed::tasks::{build_objective, TaskKind};

fn main() {
    header("hotpath");
    let micro = Bencher::micro();
    let std = Bencher::default();

    // -- linalg primitives ------------------------------------------------
    let mut rng = Xoshiro256::new(1);
    for d in [50usize, 784] {
        let x = rng.gaussian_vec(d);
        let y = rng.gaussian_vec(d);
        micro.run(&format!("dot d={d}"), |_| {
            black_box(linalg::dot(black_box(&x), black_box(&y)));
        });
    }
    for (n, d) in [(50usize, 50usize), (768, 784)] {
        let mut m = Matrix::zeros(n, d);
        for v in &mut m.data {
            *v = rng.next_gaussian();
        }
        let theta = rng.gaussian_vec(d);
        let mut out = vec![0.0; n];
        let mut g = vec![0.0; d];
        micro.run(&format!("gemv {n}x{d}"), |_| {
            m.gemv(black_box(&theta), &mut out);
        });
        micro.run(&format!("gemv_t {n}x{d}"), |_| {
            m.gemv_t_into(black_box(&out), &mut g);
        });
    }

    // -- worker round (gradient + censor decision) ------------------------
    for (name, n, d) in [("synth", 50usize, 50usize), ("mnist-class", 768, 784)] {
        let mut r = Xoshiro256::new(7);
        let ds = synthetic::gaussian_pm1(&mut r, n, d);
        let shard = shard_whole(&ds);
        let obj = build_objective(TaskKind::LinReg, &shard, 0.0);
        let mut worker = Worker::new(
            0,
            Box::new(chb_fed::coordinator::RustBackend::new(obj)),
        );
        let censor = GradDiffCensor { epsilon1: 1.0 };
        let theta = r.gaussian_vec(d);
        std.run(&format!("worker round linreg {name}"), |k| {
            black_box(worker.round(black_box(&theta), 1.0, &censor, k + 1));
        });
    }

    // -- server fold (aggregate + update), d = 784 ------------------------
    {
        let d = 784;
        let params = MethodParams::new(1e-3).with_beta(0.4);
        let mut server = Server::new(Method::Chb, &params, vec![0.0; d]);
        let mut r = Xoshiro256::new(9);
        let rounds: Vec<_> = (0..9)
            .map(|w| chb_fed::coordinator::WorkerRound {
                worker: w,
                decision: chb_fed::optim::CensorDecision::Transmit,
                delta: r.gaussian_vec(d),
                loss: 1.0,
                delta_sq: 1.0,
                bits: 64 * d as u64,
            })
            .collect();
        std.run("server fold M=9 d=784", |_| {
            black_box(server.apply_round(black_box(&rounds)));
        });
    }

    // -- end-to-end rounds ------------------------------------------------
    let problem = {
        let l_m = synthetic::increasing_l(9);
        let per_worker = synthetic::per_worker_rescaled(3, 9, 50, 50, &l_m);
        Problem::from_worker_datasets(TaskKind::LinReg, "synth", &per_worker, 0.0)
    };
    let params = MethodParams::new(1.0 / problem.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, 9);
    std.run("100 CHB rounds M=9 d=50 (serial)", |_| {
        let cfg = RunConfig::new(Method::Chb, params, 100);
        let mut ws = problem.rust_workers();
        black_box(run_serial(&mut ws, &cfg, problem.theta0()));
    });

    // -- round-pipeline scaling: serial vs rayon pool ---------------------
    // M ∈ {10, 100, 1000} simulated workers, small shards (10×20) so
    // the pool dispatch — not the gradient math — dominates at large M.
    // Worker construction is inside the timed body (fresh censor state
    // per run); both pools pay it identically, so the serial/rayon
    // *ratio* is the scaling signal reported in EXPERIMENTS.md §Perf.
    let quick = Bencher::quick();
    for m in [10usize, 100, 1000] {
        let l_m: Vec<f64> =
            (0..m).map(|i| 1.0 + (i % 7) as f64 * 0.5).collect();
        let per_worker =
            synthetic::per_worker_rescaled(0x5CA1E, m, 10, 20, &l_m);
        let scale_problem = Problem::from_worker_datasets(
            TaskKind::LinReg,
            "scale",
            &per_worker,
            0.0,
        );
        let params = MethodParams::new(1.0 / scale_problem.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, params, 20);
        let b = if m >= 1000 { &quick } else { &std };
        b.run(&format!("20 CHB rounds M={m} d=20 (serial)"), |_| {
            let mut ws = scale_problem.rust_workers();
            black_box(run_serial(&mut ws, &cfg, scale_problem.theta0()));
        });
        b.run(&format!("20 CHB rounds M={m} d=20 (rayon)"), |_| {
            black_box(run_rayon(
                scale_problem.rust_workers(),
                &cfg,
                scale_problem.theta0(),
            ));
        });
    }
}
