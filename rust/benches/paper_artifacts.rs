//! `cargo bench --bench paper_artifacts` — regenerates every paper
//! table and figure end-to-end and reports wall-clock per artifact.
//!
//! One bench per DESIGN.md §5 row.  Uses the quick profile unless
//! CHB_FULL=1 (paper-scale budgets).  Results land in
//! `results-bench/` so a bench run leaves the same CSVs as
//! `chb-fed exp all`.

use std::path::Path;

use chb_fed::bench::{header, Bencher};
use chb_fed::experiments::{ablations, figures, tables};

fn main() {
    header("paper_artifacts");
    let out = Path::new("results-bench");
    let data = Path::new("data");
    let quick = std::env::var("CHB_FULL").map_or(true, |v| v != "1");
    let b = Bencher { warmup_iters: 0, samples: 1, iters_per_sample: 1 };

    macro_rules! art {
        ($name:literal, $f:expr) => {
            b.run($name, |_| {
                $f(out, data, quick).expect(concat!($name, " failed"));
            });
        };
    }

    art!("bench_fig1", figures::fig1);
    art!("bench_fig2", figures::fig2);
    art!("bench_fig3", figures::fig3);
    art!("bench_fig4", figures::fig4);
    art!("bench_fig5", figures::fig5);
    art!("bench_fig6", figures::fig6);
    art!("bench_fig7", figures::fig7);
    art!("bench_fig8", figures::fig8);
    art!("bench_fig9", figures::fig9);
    art!("bench_fig10", figures::fig10);
    art!("bench_fig11", figures::fig11);
    art!("bench_fig12", figures::fig12);
    art!("bench_table1", tables::table1);
    art!("bench_table2", tables::table2);
    art!("bench_table3", tables::table3);
    b.run("bench_ablations", |_| {
        ablations::all(out, quick).expect("ablations failed");
    });
}
