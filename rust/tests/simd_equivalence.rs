//! The SIMD dispatch layer's load-bearing invariant: **every backend
//! is bit-identical to the scalar reference** — on random shapes,
//! random values, and misaligned subslices — so runtime dispatch can
//! never perturb a pinned trace.
//!
//! Two layers of pinning:
//!
//! * property tests against explicit `Backend::kernels()` handles
//!   (no global state touched → safe under the parallel test runner);
//! * one end-to-end test that *forces* each available backend via
//!   `simd::set_active` and re-runs a full CHB trace, asserting the
//!   whole trace is bitwise unchanged.  Forcing the global mid-test
//!   is safe precisely because of the invariant the other tests pin.

use chb_fed::coordinator::{run_serial, RunConfig};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg::simd::{self, scalar, Backend};
use chb_fed::linalg::Matrix;
use chb_fed::metrics::Trace;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::tasks::TaskKind;
use chb_fed::testing::prop::{self, Gen};

fn gen_vec(g: &mut Gen, n: usize) -> Vec<f64> {
    (0..n).map(|_| g.gaussian() * 3.0).collect()
}

#[test]
fn dot_and_axpy_match_scalar_bitwise_on_random_shapes() {
    let backends = simd::available();
    prop::check("simd dot/axpy ≡ scalar", 120, |g| {
        // random length AND random offset: exercises every lane-tail
        // split and every alignment the loadu/storeu paths can see
        let n = g.usize_in(0..=257);
        let off = g.usize_in(0..=3).min(n);
        let x_full = gen_vec(g, n);
        let y_full = gen_vec(g, n);
        let a = g.f64_signed(4.0);
        let (x, y) = (&x_full[off..], &y_full[off..]);
        for &b in &backends {
            let k = b.kernels();
            chb_fed::assert_prop!(
                k.dot(x, y).to_bits() == scalar::dot(x, y).to_bits(),
                "dot {} n={n} off={off}",
                b.label()
            );
            let mut ya = y.to_vec();
            let mut yb = y.to_vec();
            k.axpy(a, x, &mut ya);
            scalar::axpy(a, x, &mut yb);
            for (u, v) in ya.iter().zip(&yb) {
                chb_fed::assert_prop!(
                    u.to_bits() == v.to_bits(),
                    "axpy {} n={n} off={off}",
                    b.label()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn converts_and_quantize_match_scalar_bitwise() {
    let backends = simd::available();
    prop::check("simd cvt/quant ≡ scalar", 120, |g| {
        let n = g.usize_in(0..=257);
        let off = g.usize_in(0..=3).min(n);
        let src_full = gen_vec(g, n);
        let src = &src_full[off..];
        let m = src.len();
        let inv_scale = g.f64_in(0.1, 100.0);
        let levels = ((1u64 << g.usize_in(1..=31)) - 1) as f64;
        for &b in &backends {
            let k = b.kernels();
            let mut da = vec![0u32; m];
            let mut db = vec![0u32; m];
            k.cvt_f64_to_f32_bits(src, &mut da);
            scalar::cvt_f64_to_f32_bits(src, &mut db);
            chb_fed::assert_prop!(
                da == db,
                "cvt pack {} n={m}",
                b.label()
            );
            let mut fa = gen_vec(g, m);
            let mut fb = fa.clone();
            let a = g.f64_signed(2.0);
            k.cvt_f32_bits_axpy(a, &da, &mut fa);
            scalar::cvt_f32_bits_axpy(a, &db, &mut fb);
            for (u, v) in fa.iter().zip(&fb) {
                chb_fed::assert_prop!(
                    u.to_bits() == v.to_bits(),
                    "cvt fold {} n={m}",
                    b.label()
                );
            }
            let mut qa = vec![0.0; m];
            let mut qb = vec![0.0; m];
            k.quantize_clamped(src, inv_scale, levels, &mut qa);
            scalar::quantize_clamped(src, inv_scale, levels, &mut qb);
            for (u, v) in qa.iter().zip(&qb) {
                chb_fed::assert_prop!(
                    u.to_bits() == v.to_bits(),
                    "quant {} n={m} levels={levels}",
                    b.label()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn quantize_handles_nonfinite_identically_across_backends() {
    // NaN/±inf coordinates (a diverged worker) must produce the same
    // bit patterns on every backend — maxpd/minpd second-operand
    // semantics are part of the pinned contract
    let src = vec![
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        0.0,
        -0.0,
        2.5,
        -2.5,
        1e308,
    ];
    for &b in &simd::available() {
        let k = b.kernels();
        let mut qa = vec![0.0; src.len()];
        let mut qb = vec![0.0; src.len()];
        k.quantize_clamped(&src, 1.0, 7.0, &mut qa);
        scalar::quantize_clamped(&src, 1.0, 7.0, &mut qb);
        for (j, (u, v)) in qa.iter().zip(&qb).enumerate() {
            assert_eq!(
                u.to_bits(),
                v.to_bits(),
                "{} coord {j}: {u} vs {v}",
                b.label()
            );
        }
    }
}

#[test]
fn fused_gradient_kernels_are_backend_independent() {
    let backends = simd::available();
    prop::check("fused kernels ≡ across backends", 30, |g| {
        let n = g.usize_in(1..=40);
        let d = g.usize_in(1..=24);
        let mut x = Matrix::zeros(n, d);
        for v in &mut x.data {
            *v = g.gaussian();
        }
        let theta = gen_vec(g, d);
        let y = gen_vec(g, n);
        let prev = simd::active();
        let mut reference: Option<(f64, Vec<f64>)> = None;
        for &b in &backends {
            simd::set_active(b);
            let mut resid = vec![0.0; n];
            let mut grad = vec![0.0; d];
            let loss = x.fused_residual_grad(&theta, &y, &mut resid, &mut grad);
            match &reference {
                None => reference = Some((loss, grad)),
                Some((l0, g0)) => {
                    chb_fed::assert_prop!(
                        loss.to_bits() == l0.to_bits(),
                        "loss differs on {}",
                        b.label()
                    );
                    for (u, v) in grad.iter().zip(g0) {
                        chb_fed::assert_prop!(
                            u.to_bits() == v.to_bits(),
                            "grad differs on {}",
                            b.label()
                        );
                    }
                }
            }
        }
        simd::set_active(prev);
        Ok(())
    });
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss differs at k={}",
            x.k
        );
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² differs at k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms at k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits at k={}", x.k);
    }
}

/// End-to-end: the same CHB run, re-executed with each available
/// backend forced, produces the identical trace bit for bit — the
/// invariant that lets `CHB_FORCE_SCALAR=1` CI legs share every pinned
/// expectation with the SIMD legs.
#[test]
fn full_chb_trace_is_bitwise_backend_independent() {
    let m = 4usize;
    let l_m: Vec<f64> =
        (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let per_worker = synthetic::per_worker_rescaled(0x51D3, m, 12, 8, &l_m);
    let p = Problem::from_worker_datasets(
        TaskKind::LinReg,
        "simd-equiv",
        &per_worker,
        0.0,
    );
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, m);
    let cfg = RunConfig::new(Method::Chb, params, 40);
    let prev = simd::active();
    let mut reference: Option<(Backend, Trace)> = None;
    for &b in &simd::available() {
        simd::set_active(b);
        let mut ws = p.rust_workers();
        let t = run_serial(&mut ws, &cfg, p.theta0());
        match &reference {
            None => reference = Some((b, t)),
            Some((b0, t0)) => assert_traces_identical(
                t0,
                &t,
                &format!("{} vs {}", b0.label(), b.label()),
            ),
        }
    }
    simd::set_active(prev);
}
