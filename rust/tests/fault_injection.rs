//! Fault-plan properties: seeded worker crash/rejoin schedules and
//! server kill/restore points must never bend the protocol's
//! invariants.
//!
//!   1. eq. (5) telescopes under arbitrary crash schedules: a down
//!      worker is a carried stale term, so ∇ᵏ == Σ_m ∇f_m(θ̂_m) holds
//!      at every horizon.
//!   2. rejoining workers transmit uncensored on their first round
//!      back, re-syncing θ̂ before censored reporting restarts.
//!   3. the same `FaultPlan` seed reproduces the same trace, bit for
//!      bit, across the serial/threaded/rayon engines and across
//!      reruns.
//!   4. a server killed at any schedule of steps and restored from its
//!      last checkpoint replays to a final trace bit-identical to the
//!      kill-free run, in both the sync and async engines.
//!   5. the async engine's telescope bookkeeping balances under
//!      crashes *and* uplink drops: Σ transmitted = applied + dropped
//!      + in-flight.

use std::sync::Arc;

use chb_fed::checkpoint::CheckpointPolicy;
use chb_fed::coordinator::{
    run_async_with_rules, run_rayon, run_serial, run_threaded, AsyncConfig,
    ComputeModel, EngineKind, FaultPlan, RunConfig, Server,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg;
use chb_fed::metrics::Trace;
use chb_fed::net::LatencyModel;
use chb_fed::optim::{CensorDecision, Method, MethodParams};
use chb_fed::spec::{EpsilonSpec, ParamSpec, RunSpec, Session};
use chb_fed::tasks::TaskKind;
use chb_fed::testing::prop::{self, Gen};

fn gen_problem(g: &mut Gen) -> Problem {
    let m = g.usize_in(2..=6);
    let d = g.usize_in(2..=12);
    let n = g.usize_in(4..=30);
    let l_m: Vec<f64> = (0..m).map(|_| g.f64_in(0.5, 20.0)).collect();
    let per_worker =
        synthetic::per_worker_rescaled(g.seed ^ 0xFA17, m, n, d, &l_m);
    Problem::from_worker_datasets(TaskKind::LinReg, "fault", &per_worker, 0.0)
}

fn gen_plan(g: &mut Gen) -> FaultPlan {
    FaultPlan {
        crash_prob: g.f64_in(0.1, 0.5),
        down_rounds: g.usize_in(1..=3),
        seed: g.usize_in(0..=1 << 30) as u64,
        server_kills: Vec::new(),
    }
}

fn assert_traces_bitwise(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits k={}", x.k);
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² k={}",
            x.k
        );
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
    assert_eq!(a.participants, b.participants, "{what}: participants");
    assert_eq!(a.fault_downs, b.fault_downs, "{what}: fault_downs");
    assert_eq!(a.fault_rejoins, b.fault_rejoins, "{what}: fault_rejoins");
}

/// Invariant 1 + 2, mirrored at the worker level so server and worker
/// state stay inspectable: under an arbitrary seeded crash schedule
/// the aggregate telescopes to Σ_m last-transmitted, and every rejoin
/// round transmits (the forced re-sync is never censored away).
#[test]
fn crash_schedules_preserve_the_telescope() {
    prop::check("crash telescope", 25, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        let plan = gen_plan(g);
        let params = MethodParams::new(g.f64_in(0.1, 0.8) / p.l_global)
            .with_beta(g.f64_in(0.0, 0.6))
            .with_epsilon1_scaled(g.f64_in(0.01, 1.0), m);
        let iters = g.usize_in(2..=40);
        // mirror the engine loop exactly (full participation): down ⇒
        // observe-only, first round back ⇒ forced uncensored transmit
        let censor =
            chb_fed::optim::method::build_censor_rule(Method::Chb, &params);
        let mut server =
            Server::new(Method::Chb, &params, p.theta0());
        let mut workers = p.rust_workers();
        let mut downs = 0usize;
        let mut rejoins = 0usize;
        for k in 1..=iters {
            let step_sq = server.theta_step_sq();
            let theta = server.theta.clone();
            let rounds: Vec<_> = workers
                .iter_mut()
                .map(|w| {
                    if plan.down(w.id, k) {
                        downs += 1;
                        w.observe(&theta)
                    } else if plan.rejoin(w.id, k) {
                        rejoins += 1;
                        let r = w.round_forced(
                            &theta,
                            step_sq,
                            censor.as_ref(),
                            k,
                        );
                        chb_fed::assert_prop!(
                            r.decision == CensorDecision::Transmit,
                            "rejoin round at k={k} was censored"
                        );
                        r
                    } else {
                        w.round(&theta, step_sq, censor.as_ref(), k)
                    }
                })
                .collect();
            server.apply_round(&rounds);
        }
        // eq. (5): ∇ᵏ == Σ_m last_transmitted_m, crashes or not
        let mut expect = vec![0.0; server.dim()];
        for w in &workers {
            linalg::axpy(1.0, w.last_transmitted(), &mut expect);
        }
        let diff = expect
            .iter()
            .zip(&server.agg_grad)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let scale = linalg::norm2(&expect).max(1.0);
        chb_fed::assert_prop!(
            diff <= 1e-9 * scale,
            "crashes broke the telescope: {diff:.3e} (scale {scale:.3e})"
        );
        // the engine counts the same events the mirror does
        let cfg = RunConfig::new(Method::Chb, params, iters)
            .with_faults(plan.clone());
        let mut ws = p.rust_workers();
        let t = run_serial(&mut ws, &cfg, p.theta0());
        chb_fed::assert_prop!(
            t.fault_downs == downs && t.fault_rejoins == rejoins,
            "engine counted ({}, {}) fault events, mirror saw ({downs}, {rejoins})",
            t.fault_downs,
            t.fault_rejoins
        );
        Ok(())
    });
}

/// Invariant 3: one seed, one trace — across reruns and across the
/// three synchronous engines.
#[test]
fn fault_schedule_is_deterministic_across_engines() {
    prop::check("fault determinism", 10, |g| {
        let p = gen_problem(g);
        let plan = gen_plan(g);
        let params = MethodParams::new(g.f64_in(0.2, 0.8) / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, p.m_workers());
        let iters = g.usize_in(4..=30);
        let cfg = RunConfig::new(Method::Chb, params, iters)
            .with_comm_map()
            .with_faults(plan);
        let mut ws = p.rust_workers();
        let a = run_serial(&mut ws, &cfg, p.theta0());
        let mut ws = p.rust_workers();
        let a2 = run_serial(&mut ws, &cfg, p.theta0());
        assert_traces_bitwise(&a, &a2, "serial rerun");
        let b = run_threaded(p.rust_workers(), &cfg, p.theta0());
        assert_traces_bitwise(&a, &b, "threaded");
        let c = run_rayon(p.rust_workers(), &cfg, p.theta0());
        assert_traces_bitwise(&a, &c, "rayon");
        Ok(())
    });
}

/// A crash window of `down_rounds` rounds shows up in the trace: the
/// engine's counters are populated and every down round is matched by
/// at most one later rejoin.
#[test]
fn fault_counters_are_populated_and_consistent() {
    let p = {
        let l_m: Vec<f64> = (0..4).map(|i| (1.0 + 0.3 * i as f64)).collect();
        let per_worker = synthetic::per_worker_rescaled(0xFA, 4, 16, 6, &l_m);
        Problem::from_worker_datasets(TaskKind::LinReg, "fault", &per_worker, 0.0)
    };
    let plan = FaultPlan {
        crash_prob: 0.5,
        down_rounds: 2,
        seed: 0xFA17,
        server_kills: Vec::new(),
    };
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg =
        RunConfig::new(Method::Chb, params, 30).with_faults(plan.clone());
    let mut ws = p.rust_workers();
    let t = run_serial(&mut ws, &cfg, p.theta0());
    assert!(t.fault_downs > 0, "crash_prob 0.5 over 30 rounds hit nobody");
    assert!(t.fault_rejoins > 0, "nobody ever rejoined");
    // a rejoin is the first active round after a down window, so there
    // can never be more rejoins than distinct down windows
    assert!(
        t.fault_rejoins <= t.fault_downs,
        "{} rejoins from {} down rounds",
        t.fault_rejoins,
        t.fault_downs
    );
    // fault-free control: same config minus the plan transmits from
    // round 1 with zero counters
    let cfg0 = RunConfig::new(Method::Chb, cfg.params, 30);
    let mut ws = p.rust_workers();
    let t0 = run_serial(&mut ws, &cfg0, p.theta0());
    assert_eq!(t0.fault_downs, 0);
    assert_eq!(t0.fault_rejoins, 0);
}

/// Invariant 4, sync engines: server kills at arbitrary points — with
/// or without a checkpoint policy backing the recovery image — replay
/// to the kill-free trace bitwise.
#[test]
fn server_kill_replay_matches_kill_free_run_sync() {
    let p = {
        let l_m: Vec<f64> = (0..4).map(|i| (1.0 + 0.4 * i as f64)).collect();
        let per_worker = synthetic::per_worker_rescaled(0x51, 4, 14, 7, &l_m);
        Problem::from_worker_datasets(TaskKind::LinReg, "fault", &per_worker, 0.0)
    };
    let base = RunSpec {
        params: ParamSpec {
            alpha: Some(1.0 / p.l_global),
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters: 18,
        record_comm_map: true,
        ..RunSpec::new(TaskKind::LinReg, "fault")
    };
    let crash = FaultPlan {
        crash_prob: 0.25,
        down_rounds: 2,
        seed: 0xFA17,
        server_kills: Vec::new(),
    };
    for engine in
        [EngineKind::Serial, EngineKind::Threaded, EngineKind::Rayon { threads: 2 }]
    {
        let name = engine.name();
        let free = RunSpec {
            engine,
            faults: crash.clone(),
            ..base.clone()
        };
        let baseline =
            Session::from_parts(free.clone(), p.clone()).unwrap().run().trace;
        // kills replayed from the implicit pre-loop recovery image
        let killed = RunSpec {
            faults: FaultPlan {
                server_kills: vec![4, 11],
                ..crash.clone()
            },
            ..free.clone()
        };
        let t = Session::from_parts(killed.clone(), p.clone())
            .unwrap()
            .run()
            .trace;
        assert_traces_bitwise(&baseline, &t, &format!("{name} kill, no ckpt"));
        // kills replayed from a real checkpoint taken mid-run
        let dir = std::env::temp_dir().join(format!(
            "chb_fault_kill_{}_{name}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let t = Session::from_parts(killed, p.clone())
            .unwrap()
            .with_checkpoints(CheckpointPolicy::new(3, &dir))
            .run_checked()
            .unwrap()
            .trace;
        assert_traces_bitwise(&baseline, &t, &format!("{name} kill + ckpt"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Invariant 4, async engine: a server kill mid-virtual-time restores
/// the entire event world (queue, stations, compute RNG streams) and
/// replays to the kill-free outcome bitwise.
#[test]
fn server_kill_replay_matches_kill_free_run_async() {
    let p = {
        let l_m: Vec<f64> = (0..4).map(|i| (1.0 + 0.4 * i as f64)).collect();
        let per_worker = synthetic::per_worker_rescaled(0x52, 4, 14, 7, &l_m);
        Problem::from_worker_datasets(TaskKind::LinReg, "fault", &per_worker, 0.0)
    };
    let acfg = AsyncConfig {
        compute: ComputeModel::Pareto { scale_us: 700.0, shape: 1.5, seed: 0xA5 },
        latency: LatencyModel { fixed_us: 120.0, per_kib_us: 16.0 },
        max_staleness: Some(3),
    };
    let base = RunSpec {
        params: ParamSpec {
            alpha: Some(1.0 / p.l_global),
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters: 20,
        engine: EngineKind::Async(acfg),
        ..RunSpec::new(TaskKind::LinReg, "fault")
    };
    let crash = FaultPlan {
        crash_prob: 0.2,
        down_rounds: 1,
        seed: 0xFA18,
        server_kills: Vec::new(),
    };
    let free = RunSpec { faults: crash.clone(), ..base.clone() };
    let baseline = Session::from_parts(free, p.clone()).unwrap().run();
    let killed = RunSpec {
        faults: FaultPlan { server_kills: vec![5, 13], ..crash },
        ..base
    };
    let report = Session::from_parts(killed, p.clone()).unwrap().run();
    assert_traces_bitwise(
        &baseline.trace,
        &report.trace,
        "async kill replay",
    );
    let (a, b) = (
        baseline.async_summary.expect("async bookkeeping"),
        report.async_summary.expect("async bookkeeping"),
    );
    for i in 0..a.agg_grad.len() {
        assert_eq!(
            a.agg_grad[i].to_bits(),
            b.agg_grad[i].to_bits(),
            "agg_grad[{i}] after kill replay"
        );
    }
    assert_eq!(a.vclock_us.to_bits(), b.vclock_us.to_bits(), "vclock");
}

/// Invariant 5: the async engine's conservation law holds under
/// crashes and uplink drops together — every transmitted delta is
/// folded, dropped, or still in flight, and nothing is double-counted.
#[test]
fn async_telescope_balances_under_crashes_and_drops() {
    prop::check("async fault telescope", 10, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        let params = MethodParams::new(g.f64_in(0.2, 0.8) / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, params, g.usize_in(5..=25))
            .with_drops(g.f64_in(0.0, 0.3), g.usize_in(0..=1 << 30) as u64)
            .with_faults(gen_plan(g));
        let acfg = AsyncConfig {
            compute: ComputeModel::Pareto {
                scale_us: g.f64_in(100.0, 2_000.0),
                shape: g.f64_in(1.1, 3.0),
                seed: g.usize_in(0..=1 << 30) as u64,
            },
            latency: LatencyModel {
                fixed_us: g.f64_in(0.0, 500.0),
                per_kib_us: g.f64_in(0.0, 32.0),
            },
            max_staleness: None,
        };
        let censor: Arc<dyn chb_fed::optim::CensorRule> = Arc::from(
            chb_fed::optim::method::build_censor_rule(Method::Chb, &cfg.params),
        );
        let server = Server::new(Method::Chb, &cfg.params, p.theta0());
        let mut workers = p.rust_workers();
        let out = run_async_with_rules(
            &mut workers,
            &cfg,
            &acfg,
            server,
            censor,
            "CHB-async",
        );
        // the fold accumulator is the aggregate, bit for bit
        for i in 0..out.agg_grad.len() {
            chb_fed::assert_prop!(
                out.agg_grad[i].to_bits() == out.applied_sum[i].to_bits(),
                "agg_grad[{i}] != applied_sum[{i}]"
            );
        }
        // conservation: Σ_m last-transmitted == applied + dropped +
        // in-flight (each worker's transmitted deltas telescope to its
        // θ̂ reference, wherever each delta physically ended up)
        let dim = out.agg_grad.len();
        let mut lhs = vec![0.0; dim];
        for w in &workers {
            linalg::axpy(1.0, w.last_transmitted(), &mut lhs);
        }
        let mut scale = 1.0f64;
        let mut diff = 0.0f64;
        for i in 0..dim {
            let rhs =
                out.applied_sum[i] + out.dropped_sum[i] + out.inflight_sum[i];
            diff = diff.max((lhs[i] - rhs).abs());
            scale = scale.max(lhs[i].abs());
        }
        chb_fed::assert_prop!(
            diff <= 1e-9 * scale,
            "conservation broke under faults+drops: {diff:.3e} (scale {scale:.3e})"
        );
        Ok(())
    });
}
