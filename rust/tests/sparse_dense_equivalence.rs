//! Integration: the sparse payload pipeline is an exact drop-in for
//! the dense one.  A run whose workers uplink `TopK` (which emits
//! `Payload::Sparse` and folds in O(k) via `linalg::axpy_sparse`) must
//! be bit-identical to the same run with `DenseDecoded(TopK)` (same
//! codec, dense O(d) decode + fold) — on all four paper tasks, across
//! the serial / threaded / rayon pools, and through the async engine's
//! degenerate (synchronous-equivalent) regime.  Also pins the eq. (5)
//! telescope under sparse folds: server Σ folded payloads ≡ Σ worker
//! decoded deltas.

use std::sync::Arc;

use chb_fed::compress::{
    Compressor, DenseDecoded, ErrorFeedback, PackedFp16, PackedFp32,
    PackedInt, TopK, TopKInt,
};
use chb_fed::coordinator::{
    run_async_detailed, run_rayon, run_serial, run_threaded, AsyncConfig,
    RunConfig,
    Server, Worker,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg;
use chb_fed::metrics::Trace;
use chb_fed::net::LatencyModel;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::tasks::TaskKind;

/// Small instance of one paper task: M = 4 workers, 12×8 shards.
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> =
        (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0xF0 + match task {
        TaskKind::LinReg => 1,
        TaskKind::LogReg => 2,
        TaskKind::Lasso => 3,
        TaskKind::Nn => 4,
    };
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "sparse-equiv", &per_worker, lam)
}

fn workers_with(p: &Problem, codec: Arc<dyn Compressor>) -> Vec<Worker> {
    p.rust_workers()
        .into_iter()
        .map(|w| w.with_compressor(Arc::clone(&codec)))
        .collect()
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss differs at k={}",
            x.k
        );
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² differs at k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms at k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits at k={}", x.k);
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.participants, b.participants, "{what}: participants");
}

fn params_for(p: &Problem, task: TaskKind) -> (MethodParams, usize) {
    let iters = if task == TaskKind::Nn { 15 } else { 40 };
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    (params, iters)
}

#[test]
fn sparse_topk_matches_dense_decoded_topk_on_all_four_tasks() {
    for task in
        [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
    {
        let p = problem_for(task);
        let (params, iters) = params_for(&p, task);
        let cfg = RunConfig::new(Method::Chb, params, iters);
        // k < d so the sparsifier is genuinely lossy
        let k = 3;
        let mut sparse_ws = workers_with(&p, Arc::new(TopK { k }));
        let sparse = run_serial(&mut sparse_ws, &cfg, p.theta0());
        let mut dense_ws =
            workers_with(&p, Arc::new(DenseDecoded(TopK { k })));
        let dense = run_serial(&mut dense_ws, &cfg, p.theta0());
        let name = task.name();
        assert_traces_identical(&sparse, &dense, &format!("{name} s-vs-d"));
        // worker θ̂ bookkeeping is also bit-identical across the two
        // payload representations
        for (a, b) in sparse_ws.iter().zip(&dense_ws) {
            for (x, y) in
                a.last_transmitted().iter().zip(b.last_transmitted())
            {
                assert_eq!(x.to_bits(), y.to_bits(), "{name}: θ̂ drifted");
            }
        }
    }
}

/// ARCHITECTURE.md invariant 3, extended to the packed codecs: a run
/// whose workers uplink `Payload::Packed` (decoded on the fly inside
/// the fold) must be bit-identical to the same run through
/// `DenseDecoded<C>` (materialized dense decode, O(d) fold) — for
/// every packed scheme, including the error-feedback wrapper, on all
/// four paper tasks.
#[test]
fn packed_codecs_match_dense_decoded_on_all_four_tasks() {
    let codecs: Vec<(
        &str,
        Arc<dyn Compressor>,
        Arc<dyn Compressor>,
    )> = vec![
        (
            "fp32",
            Arc::new(PackedFp32),
            Arc::new(DenseDecoded(PackedFp32)),
        ),
        (
            "fp16",
            Arc::new(PackedFp16),
            Arc::new(DenseDecoded(PackedFp16)),
        ),
        (
            "int8",
            Arc::new(PackedInt { bits: 8 }),
            Arc::new(DenseDecoded(PackedInt { bits: 8 })),
        ),
        (
            "int8-ef",
            Arc::new(ErrorFeedback(PackedInt { bits: 8 })),
            Arc::new(DenseDecoded(ErrorFeedback(PackedInt { bits: 8 }))),
        ),
    ];
    for task in
        [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
    {
        let p = problem_for(task);
        let (params, iters) = params_for(&p, task);
        let cfg = RunConfig::new(Method::Chb, params, iters);
        for (label, packed, densified) in &codecs {
            let mut packed_ws = workers_with(&p, Arc::clone(packed));
            let a = run_serial(&mut packed_ws, &cfg, p.theta0());
            let mut dense_ws = workers_with(&p, Arc::clone(densified));
            let b = run_serial(&mut dense_ws, &cfg, p.theta0());
            let what = format!("{} {label} packed-vs-dense", task.name());
            assert_traces_identical(&a, &b, &what);
            for (wa, wb) in packed_ws.iter().zip(&dense_ws) {
                for (x, y) in
                    wa.last_transmitted().iter().zip(wb.last_transmitted())
                {
                    assert_eq!(x.to_bits(), y.to_bits(), "{what}: θ̂ drifted");
                }
            }
        }
    }
}

/// The sparse+packed hybrid: a run whose workers uplink `TopKInt`
/// (top-k support, `bits`-wide quantized values, `32 + (32+bits)·nnz`
/// on the wire) must match its `DenseDecoded` form bit for bit on all
/// four tasks — and every transmitted delta must charge exactly the
/// hybrid wire-size formula.
#[test]
fn topk_int_hybrid_matches_dense_decoded_on_all_four_tasks() {
    let (k, bits) = (3usize, 8u32);
    for task in
        [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
    {
        let p = problem_for(task);
        let (params, iters) = params_for(&p, task);
        let cfg = RunConfig::new(Method::Chb, params, iters);
        let mut sparse_ws = workers_with(&p, Arc::new(TopKInt { k, bits }));
        let sparse = run_serial(&mut sparse_ws, &cfg, p.theta0());
        let mut dense_ws =
            workers_with(&p, Arc::new(DenseDecoded(TopKInt { k, bits })));
        let dense = run_serial(&mut dense_ws, &cfg, p.theta0());
        let name = task.name();
        assert_traces_identical(&sparse, &dense, &format!("{name} hybrid"));
        for (a, b) in sparse_ws.iter().zip(&dense_ws) {
            for (x, y) in
                a.last_transmitted().iter().zip(b.last_transmitted())
            {
                assert_eq!(x.to_bits(), y.to_bits(), "{name}: θ̂ drifted");
            }
        }
        // the accounting pin: k < d, so every transmit is exactly the
        // scale header plus (index + value) bits per kept coordinate
        let per_tx = 32 + (32 + bits as u64) * k as u64;
        let mut prev_bits = 0u64;
        for s in &sparse.iters {
            assert_eq!(
                s.bits_cum - prev_bits,
                s.comms_round as u64 * per_tx,
                "{name}: hybrid wire-size formula at k={}",
                s.k
            );
            prev_bits = s.bits_cum;
        }
    }
}

#[test]
fn sparse_payloads_are_pool_independent() {
    for task in [TaskKind::LinReg, TaskKind::Nn] {
        let p = problem_for(task);
        let (params, iters) = params_for(&p, task);
        let cfg = RunConfig::new(Method::Chb, params, iters);
        let codec: Arc<dyn Compressor> = Arc::new(TopK { k: 3 });
        let mut ws = workers_with(&p, Arc::clone(&codec));
        let serial = run_serial(&mut ws, &cfg, p.theta0());
        let threaded =
            run_threaded(workers_with(&p, Arc::clone(&codec)), &cfg, p.theta0());
        let rayon =
            run_rayon(workers_with(&p, Arc::clone(&codec)), &cfg, p.theta0());
        let name = task.name();
        assert_traces_identical(&serial, &threaded, &format!("{name} threaded"));
        assert_traces_identical(&serial, &rayon, &format!("{name} rayon"));
    }
}

#[test]
fn degenerate_async_folds_sparse_payloads_identically_to_serial() {
    let task = TaskKind::LinReg;
    let p = problem_for(task);
    let (params, iters) = params_for(&p, task);
    let cfg = RunConfig::new(Method::Chb, params, iters);
    let codec: Arc<dyn Compressor> = Arc::new(TopK { k: 3 });
    let mut ws = workers_with(&p, Arc::clone(&codec));
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    let acfg = AsyncConfig {
        latency: LatencyModel::zero(),
        ..AsyncConfig::default()
    };
    let mut ws = workers_with(&p, codec);
    let a = run_async_detailed(&mut ws, &cfg, &acfg, p.theta0()).trace;
    assert_traces_identical(&serial, &a, "async degenerate sparse");
}

#[test]
fn sparse_folds_preserve_the_telescoping_aggregate() {
    let p = problem_for(TaskKind::LinReg);
    let m = p.m_workers();
    let params = MethodParams::new(0.8 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, m);
    let censor =
        chb_fed::optim::method::build_censor_rule(Method::Chb, &params);
    let mut server = Server::new(Method::Chb, &params, p.theta0());
    let mut workers = workers_with(&p, Arc::new(TopK { k: 2 }));
    for k in 1..=50 {
        let step_sq = server.theta_step_sq();
        let theta = server.theta.clone();
        let rounds: Vec<_> = workers
            .iter_mut()
            .map(|w| w.round(&theta, step_sq, censor.as_ref(), k))
            .collect();
        server.apply_round(&rounds);
    }
    // eq. (5) with sparse payloads: the server aggregate still equals
    // Σ_m (worker m's decoded-delta bookkeeping).  The two sides fold
    // the identical additions in different orders (round-major vs
    // worker-major), so the comparison is to f64 round-off — the same
    // tolerance the dense telescope property test uses.
    let dim = server.dim();
    let mut expect = vec![0.0; dim];
    for w in &workers {
        linalg::axpy(1.0, w.last_transmitted(), &mut expect);
    }
    let scale = linalg::norm2(&expect).max(1.0);
    for i in 0..dim {
        assert!(
            (expect[i] - server.agg_grad[i]).abs() <= 1e-9 * scale,
            "telescope broke at coord {i}: {} vs {}",
            expect[i],
            server.agg_grad[i]
        );
    }
}
