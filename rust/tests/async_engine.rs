//! Integration: the asynchronous discrete-event engine.
//!
//! 1. **Degenerate reduction** — zero network latency + a uniform
//!    compute model collapse the event order to synchronous rounds, so
//!    the async engine must reproduce the serial engine bit-for-bit on
//!    all four paper tasks (the ISSUE's acceptance criterion).
//! 2. **Staleness semantics** — a property test that the server
//!    aggregate equals Σ applied deltas (and that the decoded-delta
//!    bookkeeping balances against every worker's θ̂ state) under
//!    arbitrary arrival orderings, heterogeneity, latencies, and
//!    uplink drops.

use chb_fed::coordinator::{
    run_async_detailed, run_serial, AsyncConfig, ComputeModel,
    RunConfig, StopRule,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg;
use chb_fed::metrics::Trace;
use chb_fed::net::LatencyModel;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::tasks::TaskKind;
use chb_fed::testing::prop::{self, Gen};

/// Small instance of one paper task (same fixture as
/// `engine_equivalence.rs`).
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> = (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0xE0 + match task {
        TaskKind::LinReg => 1,
        TaskKind::LogReg => 2,
        TaskKind::Lasso => 3,
        TaskKind::Nn => 4,
    };
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "equiv", &per_worker, lam)
}

/// Zero latency + uniform compute: the degenerate async configuration.
fn degenerate() -> AsyncConfig {
    AsyncConfig {
        compute: ComputeModel::Uniform { us: 1_000.0 },
        latency: LatencyModel::zero(),
        max_staleness: None,
    }
}

/// Optimizer-trajectory equality (vclock intentionally excluded: the
/// engines define it differently — round latency vs event time).
fn assert_trajectories_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss differs at k={}",
            x.k
        );
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² differs at k={}",
            x.k
        );
        assert_eq!(
            x.step_sq.to_bits(),
            y.step_sq.to_bits(),
            "{what}: step differs at k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms at k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits at k={}", x.k);
        assert_eq!(y.stale_max, 0, "{what}: staleness at k={}", x.k);
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
    assert_eq!(a.participants, b.participants, "{what}: participants");
}

#[test]
fn degenerate_async_is_bit_identical_to_serial_on_all_four_tasks() {
    for task in [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn] {
        let p = problem_for(task);
        let iters = if task == TaskKind::Nn { 15 } else { 30 };
        let params = MethodParams::new(1.0 / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, p.m_workers());
        let cfg = RunConfig::new(Method::Chb, params, iters).with_comm_map();

        let mut ws = p.rust_workers();
        let serial = run_serial(&mut ws, &cfg, p.theta0());
        let mut ws = p.rust_workers();
        let a = run_async_detailed(&mut ws, &cfg, &degenerate(), p.theta0())
            .trace;
        assert_trajectories_identical(&serial, &a, task.name());
        // and zero staleness everywhere, by degeneracy
        assert_eq!(a.max_staleness(), 0, "{}: staleness", task.name());
    }
}

#[test]
fn degenerate_async_stop_rule_fires_identically() {
    let p = problem_for(TaskKind::LinReg);
    let f_star = p.f_star().expect("convex");
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 5_000)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
    let mut ws = p.rust_workers();
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    assert!(serial.iterations() < 5_000, "stop rule never fired");
    let mut ws = p.rust_workers();
    let a = run_async_detailed(&mut ws, &cfg, &degenerate(), p.theta0()).trace;
    assert_trajectories_identical(&serial, &a, "early-stop async");
}

#[test]
fn degenerate_async_matches_serial_under_drops_too() {
    // drop decisions consume the seeded stream in worker-id order in
    // both engines (per round = per batch), so even failure injection
    // reproduces exactly in the degenerate configuration
    let p = problem_for(TaskKind::LinReg);
    let params = MethodParams::new(0.5 / p.l_global)
        .with_beta(0.2)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 80)
        .with_comm_map()
        .with_drops(0.2, 0xD20);
    let mut ws = p.rust_workers();
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    let mut ws = p.rust_workers();
    let a = run_async_detailed(&mut ws, &cfg, &degenerate(), p.theta0()).trace;
    assert_trajectories_identical(&serial, &a, "drops async");
}

/// Random small linreg problem (mirrors `prop_invariants.rs`).
fn gen_problem(g: &mut Gen) -> Problem {
    let m = g.usize_in(2..=6);
    let d = g.usize_in(2..=10);
    let n = g.usize_in(4..=24);
    let l_m: Vec<f64> = (0..m).map(|_| g.f64_in(0.5, 20.0)).collect();
    let per_worker =
        synthetic::per_worker_rescaled(g.seed ^ 0x9E38, m, n, d, &l_m);
    Problem::from_worker_datasets(TaskKind::LinReg, "prop", &per_worker, 0.0)
}

#[test]
fn aggregate_equals_applied_deltas_under_arbitrary_orderings_and_drops() {
    prop::check("async telescope", 25, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        // conservative α: stale folds shrink the stability margin, and
        // a divergent run would turn the identity check into NaN − NaN
        let params = MethodParams::new(g.f64_in(0.02, 0.1) / p.l_global)
            .with_beta(g.f64_in(0.0, 0.4))
            .with_epsilon1_scaled(g.f64_in(0.01, 1.0), m);
        let iters = g.usize_in(1..=60);
        let drop_prob = *g.choose(&[0.0, 0.15, 0.4]);
        let cfg = RunConfig::new(Method::Chb, params, iters)
            .with_drops(drop_prob, g.seed ^ 0xD0);
        let acfg = AsyncConfig {
            compute: ComputeModel::Pareto {
                scale_us: g.f64_in(100.0, 2_000.0),
                shape: g.f64_in(1.2, 5.0),
                seed: g.seed ^ 0xC0,
            },
            latency: LatencyModel {
                fixed_us: g.f64_in(0.0, 1_000.0),
                per_kib_us: g.f64_in(0.0, 50.0),
            },
            max_staleness: *g.choose(&[None, Some(0), Some(3), Some(25)]),
        };
        let mut ws = p.rust_workers();
        let out = run_async_detailed(&mut ws, &cfg, &acfg, p.theta0());

        // (a) the server aggregate IS the fold sum, bit for bit: the
        // same deltas were added in the same order
        let dim = out.agg_grad.len();
        for i in 0..dim {
            chb_fed::assert_prop!(
                out.agg_grad[i].to_bits() == out.applied_sum[i].to_bits(),
                "aggregate != applied fold sum at coord {i}"
            );
        }

        // (b) decoded-delta bookkeeping balances: every transmitted
        // delta is folded, dropped, or still in flight — so the
        // workers' Σ θ̂ state equals those three sums combined, no
        // matter how arrivals interleaved
        let mut last_tx = vec![0.0; dim];
        for w in ws.iter() {
            linalg::axpy(1.0, w.last_transmitted(), &mut last_tx);
        }
        let mut rhs = out.agg_grad.clone();
        linalg::axpy(1.0, &out.dropped_sum, &mut rhs);
        linalg::axpy(1.0, &out.inflight_sum, &mut rhs);
        let scale = linalg::norm2(&last_tx).max(1.0);
        for i in 0..dim {
            chb_fed::assert_prop!(
                (last_tx[i] - rhs[i]).abs() <= 1e-9 * scale,
                "telescope broke at coord {i}: θ̂ sum {} vs folded+dropped+inflight {}",
                last_tx[i],
                rhs[i]
            );
        }

        // (c) staleness telemetry is consistent: folds ≤ attempts, and
        // comms_cum counts exactly the folded deltas
        let folds: usize =
            out.trace.worker_staleness.iter().map(|s| s.folds).sum();
        chb_fed::assert_prop!(
            folds == out.trace.total_comms(),
            "telemetry folds {folds} != delivered comms {}",
            out.trace.total_comms()
        );
        let attempts: usize = out.trace.per_worker_comms.iter().sum();
        chb_fed::assert_prop!(
            folds <= attempts,
            "folded {folds} > attempted {attempts}"
        );
        Ok(())
    });
}

#[test]
fn full_chb_trace_is_identical_under_chb_force_heap() {
    // the CHB_FORCE_HEAP escape hatch swaps the EventQueue backend
    // (radix wheel → BinaryHeap) under a non-degenerate configuration:
    // heavy-tailed compute, real latency, a staleness bound.  The
    // entire event history — and therefore the whole trace, virtual
    // clock included — must be bit-identical, which is the contract
    // that makes the wheel a safe default at 10⁶ clients.
    let p = problem_for(TaskKind::LinReg);
    let params = MethodParams::new(0.1 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 60).with_comm_map();
    let acfg = AsyncConfig {
        compute: ComputeModel::Pareto {
            scale_us: 800.0,
            shape: 1.8,
            seed: 0xBEEF,
        },
        latency: LatencyModel { fixed_us: 350.0, per_kib_us: 12.0 },
        max_staleness: Some(5),
    };
    let mut ws = p.rust_workers();
    let wheel = run_async_detailed(&mut ws, &cfg, &acfg, p.theta0()).trace;
    std::env::set_var("CHB_FORCE_HEAP", "1");
    let mut ws = p.rust_workers();
    let heap = run_async_detailed(&mut ws, &cfg, &acfg, p.theta0()).trace;
    std::env::remove_var("CHB_FORCE_HEAP");
    // full comparison by hand: assert_trajectories_identical pins
    // stale_max == 0, which only holds in the degenerate configuration
    assert_eq!(wheel.iterations(), heap.iterations(), "iteration count");
    for (x, y) in wheel.iters.iter().zip(&heap.iters) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "loss k={}", x.k);
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "‖∇‖² k={}",
            x.k
        );
        assert_eq!(x.step_sq.to_bits(), y.step_sq.to_bits(), "step k={}", x.k);
        assert_eq!(
            x.vclock_us.to_bits(),
            y.vclock_us.to_bits(),
            "virtual clock k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "comms k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "bits k={}", x.k);
        assert_eq!(x.stale_max, y.stale_max, "staleness k={}", x.k);
    }
    assert_eq!(wheel.per_worker_comms, heap.per_worker_comms, "S_m");
    assert_eq!(wheel.comm_map, heap.comm_map, "comm map");
    // sanity: the run was actually non-degenerate (staleness occurred)
    assert!(wheel.max_staleness() > 0, "configuration was degenerate");
}

#[test]
fn max_staleness_bounds_consecutive_censored_rounds() {
    // with the bound at S, no worker may ever censor more than S
    // completions in a row: folds ≥ completions / (S + 1) per worker
    let p = problem_for(TaskKind::LinReg);
    let m = p.m_workers();
    let s = 3usize;
    let params = MethodParams::new(0.2 / p.l_global)
        .with_beta(0.2)
        // absurdly aggressive censoring: without the bound, workers
        // would go silent for the whole run after k = 1
        .with_epsilon1(1e12);
    let cfg = RunConfig::new(Method::Chb, params, 200);
    let acfg = AsyncConfig {
        compute: ComputeModel::Uniform { us: 1_000.0 },
        latency: LatencyModel::zero(),
        max_staleness: Some(s),
    };
    let mut ws = p.rust_workers();
    let trace = run_async_detailed(&mut ws, &cfg, &acfg, p.theta0()).trace;
    // degenerate schedule: every worker completes once per server step
    for (id, (&attempts, stats)) in trace
        .per_worker_comms
        .iter()
        .zip(&trace.worker_staleness)
        .enumerate()
    {
        let completions = trace.iterations();
        let min_tx = completions / (s + 1);
        assert!(
            attempts >= min_tx,
            "worker {id}: {attempts} transmissions < forced floor {min_tx}"
        );
        assert_eq!(stats.folds, attempts, "worker {id}: drops were off");
    }
    // and the bound actually binds: aggressive ε₁ means ~1 in (S+1)
    // completions transmits, far below one per round
    assert!(trace.total_comms() < m * trace.iterations());
}
