//! Wire-protocol pins (ARCHITECTURE.md invariant 6 and the frame
//! contract behind it):
//!
//!   1. the frame codec is **byte-frozen**: a golden `Round` frame
//!      stored as a fixture must round-trip bit-exactly in both
//!      directions, so any accidental codec change (field order, CRC
//!      polynomial, hex width) fails loudly instead of silently
//!      splitting deployed versions.
//!   2. damaged input fails with a **typed error before any state is
//!      touched** — truncation, bad magic, version bumps, unknown
//!      kinds, bit flips in body or CRC all name their failure, and
//!      [`Frame::take`] drains exactly one damaged frame so the stream
//!      recovers at the next boundary (bad magic is stream-fatal).
//!   3. with zero chaos, a loopback wire run is **bit-identical to the
//!      in-process serial engine** on all four paper tasks.
//!   4. duplicate/delay chaos never perturbs a trace (seq-based
//!      idempotence), and a seeded lossy chaos mix reproduces the same
//!      trace bit for bit across reruns.
//!   5. server kills replay over the wire to the kill-free trace, with
//!      and without a real mid-run checkpoint backing the recovery.

use std::path::PathBuf;
use std::sync::Arc;

use chb_fed::checkpoint::CheckpointPolicy;
use chb_fed::coordinator::{
    run_with_rules_ctx, EngineKind, FaultPlan, RunConfig, RunContext, Server,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::metrics::Trace;
use chb_fed::optim::{CensorRule, Method, MethodParams};
use chb_fed::spec::{EpsilonSpec, ParamSpec, RunSpec, Session};
use chb_fed::tasks::TaskKind;
use chb_fed::util::json::Json;
use chb_fed::wire::frame::{
    parse_round, round_body, Frame, FrameKind, WireError,
};
use chb_fed::wire::{
    run_client, ChaosSpec, ClientConfig, Listener, WireConfig, WirePool,
};

/// The golden frame: kind=Round, round=5, seq=9, θ=[1.0, −0.5],
/// step_sq=0.1, active, not forced, acked=4.  160 bytes total.
fn golden_bytes() -> Vec<u8> {
    let hex: String = include_str!("fixtures/wire_golden.hex")
        .chars()
        .filter(|c| c.is_ascii_hexdigit())
        .collect();
    (0..hex.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&hex[i..i + 2], 16).unwrap())
        .collect()
}

/// Rebuild the golden frame from the codec API.
fn golden_frame() -> Frame {
    let theta = Json::Str("3ff0000000000000bfe0000000000000".into());
    Frame::new(FrameKind::Round, 5, 9, round_body(&theta, 0.1, true, false, 4))
}

#[test]
fn golden_round_frame_is_byte_exact_both_directions() {
    let bytes = golden_bytes();
    assert_eq!(bytes.len(), 160, "fixture length");
    assert_eq!(
        golden_frame().encode(),
        bytes,
        "encoder drifted from the golden fixture"
    );
    let f = Frame::decode(&bytes).expect("golden frame must decode");
    assert_eq!(f.kind, FrameKind::Round);
    assert_eq!((f.round, f.seq), (5, 9));
    let msg = parse_round(&f.body).expect("golden body must parse");
    assert_eq!(msg.theta.len(), 2);
    assert_eq!(msg.theta[0].to_bits(), 1.0f64.to_bits());
    assert_eq!(msg.theta[1].to_bits(), (-0.5f64).to_bits());
    assert_eq!(msg.step_sq.to_bits(), 0.1f64.to_bits());
    assert!(msg.active, "golden frame is an active round");
    assert!(!msg.force, "golden frame is not a forced resync");
    assert_eq!(msg.acked, 4);
}

#[test]
fn damaged_frames_yield_typed_errors_before_any_state() {
    let bytes = golden_bytes();
    // shorter than the smallest possible frame
    assert!(matches!(
        Frame::decode(&bytes[..20]),
        Err(WireError::Truncated { .. })
    ));
    // body cut off mid-payload: the error names what is missing
    match Frame::decode(&bytes[..100]) {
        Err(WireError::Truncated { need, got }) => {
            assert_eq!((need, got), (160, 100));
        }
        other => panic!("want Truncated, got {other:?}"),
    }
    // corrupted magic
    let mut b = bytes.clone();
    b[0] = b'X';
    assert!(matches!(Frame::decode(&b), Err(WireError::BadMagic(_))));
    // a future protocol version is rejected, not misparsed
    let mut b = bytes.clone();
    b[4] = 2;
    match Frame::decode(&b) {
        Err(WireError::Version { got }) => assert_eq!(got, 2),
        other => panic!("want Version, got {other:?}"),
    }
    // unknown frame kind
    let mut b = bytes.clone();
    b[6] = 99;
    assert!(matches!(Frame::decode(&b), Err(WireError::BadKind(99))));
    // a single flipped body bit trips the CRC
    let mut b = bytes.clone();
    b[40] ^= 0x01;
    assert!(matches!(Frame::decode(&b), Err(WireError::Crc { .. })));
    // as does a flipped bit in the CRC trailer itself
    let mut b = bytes.clone();
    let n = b.len();
    b[n - 1] ^= 0x80;
    assert!(matches!(Frame::decode(&b), Err(WireError::Crc { .. })));
}

#[test]
fn take_drains_one_damaged_frame_and_recovers_at_the_next() {
    let good = golden_bytes();
    let mut bad = good.clone();
    bad[40] ^= 0x04; // body damage → CRC mismatch, framing intact
    let mut buf = Vec::new();
    buf.extend_from_slice(&bad);
    buf.extend_from_slice(&good);
    match Frame::take(&mut buf) {
        Err(WireError::Crc { .. }) => {}
        other => panic!("want Crc, got {other:?}"),
    }
    assert_eq!(buf.len(), good.len(), "damaged frame must be drained");
    let f = Frame::take(&mut buf)
        .expect("second frame is intact")
        .expect("second frame is complete");
    assert_eq!((f.kind, f.round, f.seq), (FrameKind::Round, 5, 9));
    assert!(buf.is_empty(), "good frame fully consumed");
    assert!(
        Frame::take(&mut buf).unwrap().is_none(),
        "empty buffer means no frame yet, not an error"
    );
    // a partial prefix of a valid frame is also just "not yet"
    let mut buf = good[..50].to_vec();
    assert!(Frame::take(&mut buf).unwrap().is_none());
    assert_eq!(buf.len(), 50, "partial frames stay buffered");
    // bad magic is stream-fatal: framing is lost, no resync possible
    let mut buf = good.clone();
    buf[1] = 0;
    assert!(matches!(Frame::take(&mut buf), Err(WireError::BadMagic(_))));
}

// ---------------------------------------------------------------- //
// engine-level pins: loopback wire runs vs. the in-process serial  //
// ---------------------------------------------------------------- //

/// Small instance of one paper task (the `spec_session` pattern).
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> =
        (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0x31BE
        + match task {
            TaskKind::LinReg => 1,
            TaskKind::LogReg => 2,
            TaskKind::Lasso => 3,
            TaskKind::Nn => 4,
        };
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "wire", &per_worker, lam)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("chb_wire_proto_{}", std::process::id()))
        .join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Full bitwise trace comparison: every column of every round, plus
/// the per-worker and fault bookkeeping.
fn assert_traces_bitwise(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.method, b.method, "{what}: method label");
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(x.k, y.k, "{what}: round index");
        let k = x.k;
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss k={k}");
        assert_eq!(x.comms_round, y.comms_round, "{what}: comms_round k={k}");
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms_cum k={k}");
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² k={k}"
        );
        assert_eq!(
            x.step_sq.to_bits(),
            y.step_sq.to_bits(),
            "{what}: step_sq k={k}"
        );
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits_cum k={k}");
        assert_eq!(
            x.vclock_us.to_bits(),
            y.vclock_us.to_bits(),
            "{what}: vclock k={k}"
        );
        assert_eq!(x.stale_max, y.stale_max, "{what}: stale_max k={k}");
        assert_eq!(
            x.batch_frac.to_bits(),
            y.batch_frac.to_bits(),
            "{what}: batch_frac k={k}"
        );
        assert_eq!(x.epoch.to_bits(), y.epoch.to_bits(), "{what}: epoch k={k}");
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.participants, b.participants, "{what}: participants");
    assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
    assert_eq!(a.fault_downs, b.fault_downs, "{what}: fault_downs");
    assert_eq!(a.fault_rejoins, b.fault_rejoins, "{what}: fault_rejoins");
}

fn wire_spec(
    p: &Problem,
    task: TaskKind,
    iters: usize,
    engine: EngineKind,
) -> RunSpec {
    RunSpec {
        params: ParamSpec {
            alpha: Some(1.0 / p.l_global),
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters,
        record_comm_map: true,
        lambda: p.lambda_global(),
        engine,
        ..RunSpec::new(task, "wire")
    }
}

fn run(spec: &RunSpec, p: &Problem) -> Trace {
    Session::from_parts(spec.clone(), p.clone()).unwrap().run().trace
}

/// Invariant 6: with zero chaos and full participation, the loopback
/// wire deployment — real sockets, real frames, real client threads —
/// is bit-identical to the in-process serial engine on every task.
#[test]
fn loopback_wire_is_bit_identical_to_serial_on_all_tasks() {
    let tasks =
        [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn];
    for task in tasks {
        let p = problem_for(task);
        let serial = run(&wire_spec(&p, task, 16, EngineKind::Serial), &p);
        let wire = run(
            &wire_spec(&p, task, 16, EngineKind::Wire(WireConfig::default())),
            &p,
        );
        assert_traces_bitwise(&serial, &wire, &format!("{task:?} wire"));
    }
}

/// The two-direction bit ledger: in a zero-chaos, full-participation
/// loopback run the trace's cumulative uplink and downlink bit
/// columns equal the exact sum of model/delta payload bits carried by
/// the delivered wire frames, as counted frame-by-frame on the server
/// side (`WireStats::payload_bits_up` / `payload_bits_down`).  Full
/// participation matters: the pool sends a `Round` frame to every
/// connected worker, while the trace charges scheduled workers only —
/// under `Participation::Full` the two populations coincide.
#[test]
fn loopback_bit_ledgers_match_the_frames_exactly() {
    for task in
        [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
    {
        let p = problem_for(task);
        let m = p.m_workers();
        let params = MethodParams::new(1.0 / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, params, 12);
        let censor: Arc<dyn CensorRule> = Arc::from(
            chb_fed::optim::method::build_censor_rule(Method::Chb, &params),
        );
        let (listener, addr) =
            Listener::bind_loopback().expect("bind loopback");
        let handles: Vec<_> = p
            .rust_workers()
            .into_iter()
            .map(|mut w| {
                let censor = Arc::clone(&censor);
                let ccfg = ClientConfig::loopback(addr.clone(), m);
                std::thread::spawn(move || {
                    run_client(&mut w, censor, &ccfg)
                        .expect("loopback client failed");
                })
            })
            .collect();
        let server = Server::new(Method::Chb, &params, p.theta0());
        let dim = server.dim();
        let mut pool =
            WirePool::new(listener, m, dim, WireConfig::default(), None)
                .expect("wire handshake");
        let trace = run_with_rules_ctx(
            &mut pool,
            &cfg,
            server,
            Arc::clone(&censor),
            "CHB",
            "wire",
            &RunContext::default(),
        )
        .expect("loopback run failed");
        let stats = pool.stats();
        pool.shutdown();
        for h in handles {
            h.join().expect("loopback client panicked");
        }
        let name = task.name();
        assert!(
            trace.total_uplink_bits() > 0,
            "{name}: no uplink traffic — the ledger check is vacuous"
        );
        assert_eq!(
            stats.payload_bits_up,
            trace.total_uplink_bits(),
            "{name}: uplink ledger vs delivered Transmit frames"
        );
        // downlink: one 64·d Round frame per worker per round
        assert_eq!(
            trace.total_downlink_bits(),
            (trace.iterations() * m * 64 * dim) as u64,
            "{name}: free-downlink formula"
        );
        assert_eq!(
            stats.payload_bits_down,
            trace.total_downlink_bits(),
            "{name}: downlink ledger vs delivered Round frames"
        );
    }
}

/// Duplicated and delayed frames are absorbed by seq-based duplicate
/// suppression and patient reads — the folded trace cannot tell they
/// ever happened.
#[test]
fn duplicate_and_delay_chaos_never_perturb_the_trace() {
    let task = TaskKind::LinReg;
    let p = problem_for(task);
    let clean = run(
        &wire_spec(&p, task, 16, EngineKind::Wire(WireConfig::default())),
        &p,
    );
    let noisy_cfg = WireConfig {
        chaos: ChaosSpec {
            duplicate: 0.4,
            delay_prob: 0.2,
            delay_ms: 1,
            ..ChaosSpec::default()
        },
        ..WireConfig::default()
    };
    let noisy = run(&wire_spec(&p, task, 16, EngineKind::Wire(noisy_cfg)), &p);
    assert_traces_bitwise(&clean, &noisy, "duplicate/delay chaos");
}

/// Lossy chaos (drops + corruptions) exercises retransmits, CRC
/// rejection, and rollback/commit — and because every chaos action is
/// a pure function of (seed, link, round, attempt), two runs of the
/// same spec produce bit-identical traces.
#[test]
fn seeded_lossy_chaos_is_deterministic_across_reruns() {
    let task = TaskKind::LogReg;
    let p = problem_for(task);
    let wcfg = WireConfig {
        round_deadline_ms: 600,
        chaos: ChaosSpec {
            drop: 0.12,
            duplicate: 0.1,
            corrupt: 0.08,
            seed: 0xD1CE,
            ..ChaosSpec::default()
        },
        ..WireConfig::default()
    };
    let spec = wire_spec(&p, task, 14, EngineKind::Wire(wcfg));
    let a = run(&spec, &p);
    let b = run(&spec, &p);
    assert_traces_bitwise(&a, &b, "seeded lossy chaos rerun");
}

/// Invariant 4 over the wire: a server killed mid-run and restored —
/// from the implicit pre-loop image or from a real checkpoint — pushes
/// `Restore` frames to every client and replays to the kill-free
/// trace, bit for bit, with worker crash/rejoin chaos running too.
#[test]
fn server_kill_replay_matches_kill_free_wire_run() {
    let task = TaskKind::LinReg;
    let p = problem_for(task);
    let crash = FaultPlan {
        crash_prob: 0.25,
        down_rounds: 2,
        seed: 0xFA17,
        server_kills: Vec::new(),
    };
    let engine = EngineKind::Wire(WireConfig::default());
    let base = RunSpec {
        faults: crash.clone(),
        ..wire_spec(&p, task, 18, engine)
    };
    let baseline = run(&base, &p);
    let killed = RunSpec {
        faults: FaultPlan { server_kills: vec![4, 11], ..crash },
        ..base.clone()
    };
    // kills replayed from the implicit pre-loop recovery image
    let t = run(&killed, &p);
    assert_traces_bitwise(&baseline, &t, "wire kill, no ckpt");
    // kills replayed from a real checkpoint taken mid-run
    let dir = tmp_dir("kill");
    let t = Session::from_parts(killed, p.clone())
        .unwrap()
        .with_checkpoints(CheckpointPolicy::new(3, &dir))
        .run_checked()
        .unwrap()
        .trace;
    assert_traces_bitwise(&baseline, &t, "wire kill + ckpt");
    let _ = std::fs::remove_dir_all(&dir);
}
