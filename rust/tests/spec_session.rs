//! Integration: the declarative spec layer is a lossless façade over
//! the legacy builder paths.
//!
//! * spec-built runs are bit-identical to direct
//!   `RunConfig`/`AsyncConfig` assembly on all four paper tasks ×
//!   all four engines (the equivalence-test pattern);
//! * `RunSpec → json → RunSpec` is exact (property test over random
//!   specs);
//! * the manifest format is pinned by a golden fixture;
//! * a run replayed from its emitted `manifest.json` reproduces the
//!   original trace bit-for-bit.

use chb_fed::coordinator::{
    run_async_detailed, run_rayon, run_serial, run_threaded, AsyncConfig,
    ComputeModel, EngineKind, Participation, RunConfig,
};
use chb_fed::data::batch::BatchSchedule;
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::metrics::Trace;
use chb_fed::net::{DownlinkSpec, LatencyModel};
use chb_fed::optim::{Method, MethodParams, MethodSpec};
use chb_fed::spec::{
    CensorSpec, CodecSpec, DropSpec, EpsilonSpec, ParamSpec, Registry,
    RunSpec, Session, StopSpec,
};
use chb_fed::tasks::TaskKind;
use chb_fed::testing::prop;

/// Small instance of one paper task: M = 4 workers, 12×8 shards
/// (the `engine_equivalence` pattern).
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> = (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0x5EC + match task {
        TaskKind::LinReg => 1,
        TaskKind::LogReg => 2,
        TaskKind::Lasso => 3,
        TaskKind::Nn => 4,
    };
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "spec-equiv", &per_worker, lam)
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss differs at k={}",
            x.k
        );
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² differs at k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms at k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits at k={}", x.k);
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
    assert_eq!(a.participants, b.participants, "{what}: participants");
}

fn degenerate_async() -> AsyncConfig {
    AsyncConfig {
        compute: ComputeModel::Uniform { us: 1_000.0 },
        latency: LatencyModel::zero(),
        max_staleness: None,
    }
}

/// One spec per (task, engine); the legacy trace assembled by hand.
#[test]
fn spec_runs_are_bit_identical_to_legacy_builders() {
    for task in [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn] {
        let p = problem_for(task);
        let iters = if task == TaskKind::Nn { 12 } else { 25 };
        let alpha = 1.0 / p.l_global;
        let params = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, p.m_workers());
        let cfg = RunConfig::new(Method::Chb, params, iters).with_comm_map();
        let spec = RunSpec {
            params: ParamSpec {
                alpha: Some(alpha),
                beta: 0.4,
                epsilon: EpsilonSpec::Scaled { c: 0.1 },
            },
            iters,
            record_comm_map: true,
            lambda: p.lambda_global(),
            ..RunSpec::new(task, "spec-equiv")
        };
        let name = task.name();

        let mut ws = p.rust_workers();
        let legacy = run_serial(&mut ws, &cfg, p.theta0());
        let by_spec = Session::from_parts(spec.clone(), p.clone())
            .unwrap()
            .run()
            .trace;
        assert_traces_identical(&legacy, &by_spec, &format!("{name} serial"));
        assert_eq!(by_spec.method, "CHB");

        let legacy = run_threaded(p.rust_workers(), &cfg, p.theta0());
        let by_spec = Session::from_parts(
            RunSpec { engine: EngineKind::Threaded, ..spec.clone() },
            p.clone(),
        )
        .unwrap()
        .run()
        .trace;
        assert_traces_identical(&legacy, &by_spec, &format!("{name} threaded"));

        let legacy = run_rayon(p.rust_workers(), &cfg, p.theta0());
        let by_spec = Session::from_parts(
            RunSpec {
                engine: EngineKind::Rayon { threads: 0 },
                ..spec.clone()
            },
            p.clone(),
        )
        .unwrap()
        .run()
        .trace;
        assert_traces_identical(&legacy, &by_spec, &format!("{name} rayon"));

        let mut ws = p.rust_workers();
        let legacy =
            run_async_detailed(&mut ws, &cfg, &degenerate_async(), p.theta0());
        let report = Session::from_parts(
            RunSpec {
                engine: EngineKind::Async(degenerate_async()),
                ..spec.clone()
            },
            p.clone(),
        )
        .unwrap()
        .run();
        assert_traces_identical(
            &legacy.trace,
            &report.trace,
            &format!("{name} async"),
        );
        assert_eq!(report.trace.method, "CHB-async");
        let summary = report.async_summary.expect("async bookkeeping");
        for i in 0..summary.agg_grad.len() {
            assert_eq!(
                summary.agg_grad[i].to_bits(),
                legacy.agg_grad[i].to_bits(),
                "{name} async agg_grad[{i}]"
            );
        }
    }
}

/// Sampling + drops + stop rule through the spec path: the remaining
/// RunConfig axes match the hand-assembled run exactly.
#[test]
fn spec_covers_sampling_drops_and_stop_rules() {
    let p = problem_for(TaskKind::LinReg);
    let alpha = 0.5 / p.l_global;
    let f_star = p.f_star().unwrap();
    let part = Participation::UniformSample { frac: 0.6, seed: 0xFEED };
    let params = MethodParams::new(alpha)
        .with_beta(0.3)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 400)
        .with_comm_map()
        .with_participation(part)
        .with_drops(0.1, 0xD20)
        .with_stop(chb_fed::coordinator::StopRule::ObjErrBelow {
            f_star,
            tol: 1e-7,
        });
    let mut ws = p.rust_workers();
    let legacy = run_serial(&mut ws, &cfg, p.theta0());
    let spec = RunSpec {
        params: ParamSpec {
            alpha: Some(alpha),
            beta: 0.3,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters: 400,
        participation: part,
        drops: DropSpec { prob: 0.1, seed: 0xD20 },
        stop: StopSpec::ObjErr { tol: 1e-7, f_star: Some(f_star) },
        record_comm_map: true,
        ..RunSpec::new(TaskKind::LinReg, "spec-equiv")
    };
    let by_spec = Session::from_parts(spec, p.clone()).unwrap().run().trace;
    assert_traces_identical(&legacy, &by_spec, "sampling+drops+stop");
}

/// A stop rule with `f_star: None` resolves against the problem's
/// high-accuracy minimizer — same trace as passing it explicitly.
#[test]
fn stop_rule_f_star_resolves_from_the_problem() {
    let p = problem_for(TaskKind::LinReg);
    let f_star = p.f_star().unwrap();
    let base = RunSpec {
        iters: 2_000,
        stop: StopSpec::ObjErr { tol: 1e-8, f_star: None },
        ..RunSpec::new(TaskKind::LinReg, "spec-equiv")
    };
    let auto =
        Session::from_parts(base.clone(), p.clone()).unwrap().run().trace;
    let explicit = Session::from_parts(
        RunSpec {
            stop: StopSpec::ObjErr { tol: 1e-8, f_star: Some(f_star) },
            ..base
        },
        p.clone(),
    )
    .unwrap()
    .run()
    .trace;
    assert!(auto.iterations() < 2_000, "stop rule never fired");
    assert_traces_identical(&auto, &explicit, "resolved f*");
}

fn random_spec(g: &mut prop::Gen) -> RunSpec {
    let seed_cap = 1u64 << 40; // well inside the 2^53-exact range
    let seed = |g: &mut prop::Gen| g.usize_in(0..=seed_cap as usize) as u64;
    let task = *g.choose(&[
        TaskKind::LinReg,
        TaskKind::LogReg,
        TaskKind::Lasso,
        TaskKind::Nn,
    ]);
    let classic =
        |g: &mut prop::Gen| *g.choose(&[Method::Chb, Method::Hb, Method::Lag, Method::Gd]);
    let method = match g.usize_in(0..=3) {
        0 => MethodSpec::Classic(classic(g)),
        1 => MethodSpec::Nesterov { censored: g.bool() },
        2 => MethodSpec::LocalSteps {
            base: classic(g),
            k_local: g.usize_in(1..=16),
        },
        _ => MethodSpec::CensoredAdam {
            beta1: g.f64_in(0.0, 1.0),
            beta2: g.f64_in(0.0, 1.0),
            eps: g.f64_in(1e-12, 1.0),
            amsgrad: g.bool(),
        },
    };
    let engine = match g.usize_in(0..=3) {
        0 => EngineKind::Serial,
        1 => EngineKind::Threaded,
        2 => EngineKind::Rayon { threads: g.usize_in(0..=8) },
        _ => EngineKind::Async(AsyncConfig {
            compute: if g.bool() {
                ComputeModel::Uniform { us: g.f64_in(1.0, 5_000.0) }
            } else {
                ComputeModel::Pareto {
                    scale_us: g.f64_in(1.0, 5_000.0),
                    shape: g.f64_in(0.5, 4.0),
                    seed: seed(g),
                }
            },
            latency: LatencyModel {
                fixed_us: g.f64_in(0.0, 1_000.0),
                per_kib_us: g.f64_in(0.0, 64.0),
            },
            max_staleness: if g.bool() {
                Some(g.usize_in(0..=64))
            } else {
                None
            },
        }),
    };
    RunSpec {
        label: if g.bool() {
            Some(format!("label-{}", g.usize_in(0..=9_999)))
        } else {
            None
        },
        lambda: g.f64_in(0.0, 1.0),
        method,
        params: ParamSpec {
            alpha: if g.bool() { Some(g.f64_in(1e-6, 2.0)) } else { None },
            beta: g.f64_in(0.0, 1.0),
            epsilon: if g.bool() {
                EpsilonSpec::Scaled { c: g.f64_in(0.0, 10.0) }
            } else {
                EpsilonSpec::Absolute { eps: g.f64_in(0.0, 10.0) }
            },
        },
        censor: match g.usize_in(0..=5) {
            0 => CensorSpec::MethodDefault,
            1 => CensorSpec::Never,
            2 => CensorSpec::Absolute { tau: g.f64_in(0.0, 100.0) },
            3 => CensorSpec::Periodic { period: g.usize_in(0..=16) },
            4 => CensorSpec::Decaying {
                tau0: g.f64_in(0.0, 100.0),
                rho: g.f64_in(0.01, 1.0),
            },
            _ => CensorSpec::VarianceScaled,
        },
        engine,
        participation: match g.usize_in(0..=2) {
            0 => Participation::Full,
            1 => Participation::UniformSample {
                frac: g.f64_in(0.01, 1.0),
                seed: seed(g),
            },
            _ => Participation::Straggler {
                timeout: g.f64_in(0.0, 4.0),
                seed: seed(g),
            },
        },
        batch: match g.usize_in(0..=2) {
            0 => BatchSchedule::Full,
            1 => BatchSchedule::Minibatch {
                size: g.usize_in(1..=256),
                seed: seed(g),
                replace: g.bool(),
            },
            _ => BatchSchedule::GrowingBatch {
                size0: g.usize_in(1..=64),
                growth: g.f64_in(1.0, 2.0),
                seed: seed(g),
            },
        },
        codec: match g.usize_in(0..=6) {
            0 => CodecSpec::None,
            1 => CodecSpec::Quantizer { bits: g.usize_in(2..=32) as u32 },
            2 => CodecSpec::TopK { k: g.usize_in(1..=512) },
            3 => CodecSpec::Fp32 { error_feedback: g.bool() },
            4 => CodecSpec::Fp16 { error_feedback: g.bool() },
            5 => CodecSpec::Int {
                bits: g.usize_in(2..=32) as u32,
                error_feedback: g.bool(),
            },
            _ => CodecSpec::TopKInt {
                k: g.usize_in(1..=512),
                bits: g.usize_in(2..=32) as u32,
            },
        },
        downlink: match g.usize_in(0..=3) {
            0 => DownlinkSpec::None,
            1 => DownlinkSpec::Fp32 { error_feedback: g.bool() },
            2 => DownlinkSpec::Fp16 { error_feedback: g.bool() },
            _ => DownlinkSpec::Int {
                bits: g.usize_in(2..=32) as u32,
                error_feedback: g.bool(),
            },
        },
        iters: g.usize_in(1..=100_000),
        stop: match g.usize_in(0..=2) {
            0 => StopSpec::MaxIters,
            1 => StopSpec::ObjErr {
                tol: g.f64_in(1e-12, 1.0),
                f_star: if g.bool() {
                    Some(g.f64_signed(100.0))
                } else {
                    None
                },
            },
            _ => StopSpec::AggGrad { tol: g.f64_in(1e-12, 1.0) },
        },
        drops: DropSpec { prob: g.f64_in(0.0, 1.0), seed: seed(g) },
        record_comm_map: g.bool(),
        ..RunSpec::new(task, "prop")
    }
}

/// `spec → json → spec` is exact for arbitrary (even invalid) specs —
/// serialization must not depend on validity.
#[test]
fn json_round_trip_is_exact() {
    prop::check("spec json round trip", 300, |g| {
        let spec = random_spec(g);
        let text = spec.to_json_string();
        let back = RunSpec::from_json_str(&text)
            .map_err(|e| format!("decode failed: {e}\n{text}"))?;
        chb_fed::assert_prop!(
            back == spec,
            "round trip changed the spec:\n{spec:?}\nvs\n{back:?}"
        );
        // and the serialized form is a fixed point
        chb_fed::assert_prop!(
            back.to_json_string() == text,
            "second serialization differs"
        );
        Ok(())
    });
}

/// The manifest format itself is pinned: the default spec must encode
/// to exactly the checked-in fixture (key order, indentation, number
/// formatting), and the fixture must decode back to the same spec.
#[test]
fn golden_manifest_fixture() {
    let golden = include_str!("fixtures/manifest_golden.json");
    let spec = RunSpec::new(TaskKind::LinReg, "synth");
    assert_eq!(
        spec.to_json_string() + "\n",
        golden,
        "manifest encoding drifted — if intentional, bump SPEC_VERSION \
         and regenerate the fixture"
    );
    assert_eq!(RunSpec::from_json_str(golden).unwrap(), spec);
}

/// End to end: run from a spec against the registry, write the result
/// directory, reread its manifest.json, rerun — bit-identical traces
/// on all four tasks.  (The "synth"-named registry entries fall back
/// to deterministic stand-ins, so no data files are needed.)
#[test]
fn manifest_replay_reproduces_the_trace() {
    let tmp = std::env::temp_dir().join(format!(
        "chb_spec_replay_{}",
        std::process::id()
    ));
    let registry = Registry::new(&tmp.join("data"), &tmp.join("artifacts"));
    for task in [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn] {
        let spec = RunSpec {
            iters: 8,
            record_comm_map: true,
            ..RunSpec::new(task, "synth")
        };
        let report = Session::from_spec(&spec, &registry).unwrap().run();
        let dir = tmp.join("run").join(task.name());
        std::fs::create_dir_all(&dir).unwrap();
        report.write_artifacts(&dir, 0.0).unwrap();

        let manifest =
            std::fs::read_to_string(dir.join("manifest.json")).unwrap();
        let replayed_spec = RunSpec::from_json_str(&manifest).unwrap();
        assert_eq!(replayed_spec, spec, "{}: manifest drift", task.name());
        let replay =
            Session::from_spec(&replayed_spec, &registry).unwrap().run();
        assert_traces_identical(
            &report.trace,
            &replay.trace,
            &format!("{} replay", task.name()),
        );
        // the emitted trace CSV exists under the documented name
        assert!(dir.join(report.trace_filename()).exists());
    }
    let _ = std::fs::remove_dir_all(&tmp);
}

/// The codec axis through the spec layer matches hand-attached
/// compressors (uplink bits included).
#[test]
fn spec_codec_matches_hand_attached_compressor() {
    use chb_fed::compress::TopK;
    use std::sync::Arc;
    let p = problem_for(TaskKind::LinReg);
    let alpha = 1.0 / p.l_global;
    let params = MethodParams::new(alpha)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 30);
    let codec = Arc::new(TopK { k: 3 });
    let mut ws: Vec<_> = p
        .rust_workers()
        .into_iter()
        .map(|w| w.with_compressor(codec.clone()))
        .collect();
    let legacy = run_serial(&mut ws, &cfg, p.theta0());
    let spec = RunSpec {
        params: ParamSpec {
            alpha: Some(alpha),
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        codec: CodecSpec::TopK { k: 3 },
        iters: 30,
        ..RunSpec::new(TaskKind::LinReg, "spec-equiv")
    };
    let by_spec = Session::from_parts(spec, p.clone()).unwrap().run().trace;
    assert_traces_identical(&legacy, &by_spec, "top-k codec");
}
