//! Property tests for the bit-packed codecs (`compress::packed`):
//!
//! 1. fp32 pack→unpack is *exact* on f32-representable inputs; fp16
//!    likewise on half-representable inputs.
//! 2. the n-bit integer pack stays within one quantization level of
//!    the input on every coordinate, for every width.
//! 3. error feedback telescopes: over any round sequence,
//!    Σ decoded + final residual ≡ Σ true deltas (up to f64 rounding
//!    of the running sums).
//! 4. wire-bit accounting matches `net::packed_delta_bits` for every
//!    scheme and dimension.

use chb_fed::compress::{
    CodecScratch, Compressor, ErrorFeedback, PackedFp16, PackedFp32,
    PackedInt, Payload,
};
use chb_fed::linalg;
use chb_fed::net::packed_delta_bits;
use chb_fed::testing::prop;

fn f16_snap(v: f64) -> f64 {
    // round-trip through the codec itself to land exactly on a half
    // value; the property then demands the second trip is lossless
    let one = PackedFp16.compress(&[v]);
    one.decoded.to_dense(1)[0]
}

#[test]
fn fp32_pack_unpack_is_exact_on_f32_values() {
    prop::check("fp32 roundtrip exact", 60, |g| {
        let d = g.usize_in(1..=300);
        let v: Vec<f64> = (0..d)
            .map(|_| f64::from((g.f64_signed(1e6)) as f32))
            .collect();
        let out = PackedFp32.compress(&v);
        chb_fed::assert_prop!(
            out.bits == packed_delta_bits(32, 0, d),
            "bits {} for d={d}",
            out.bits
        );
        let dec = out.decoded.to_dense(d);
        for (j, (a, b)) in v.iter().zip(&dec).enumerate() {
            chb_fed::assert_prop!(
                a.to_bits() == b.to_bits(),
                "coord {j}: {a} vs {b}"
            );
        }
        Ok(())
    });
}

#[test]
fn fp16_pack_unpack_is_exact_on_half_values() {
    prop::check("fp16 roundtrip exact", 60, |g| {
        let d = g.usize_in(1..=300);
        let v: Vec<f64> =
            (0..d).map(|_| f16_snap(g.f64_signed(100.0))).collect();
        let out = PackedFp16.compress(&v);
        chb_fed::assert_prop!(
            out.bits == packed_delta_bits(16, 0, d),
            "bits {} for d={d}",
            out.bits
        );
        let dec = out.decoded.to_dense(d);
        for (j, (a, b)) in v.iter().zip(&dec).enumerate() {
            chb_fed::assert_prop!(
                a.to_bits() == b.to_bits(),
                "coord {j}: {a} vs {b}"
            );
        }
        Ok(())
    });
}

#[test]
fn int_pack_stays_within_one_level_everywhere() {
    prop::check("int pack bound", 60, |g| {
        let d = g.usize_in(1..=300);
        let bits = g.usize_in(2..=32) as u32;
        let v = g.vec_f64(d, 10.0);
        let c = PackedInt { bits };
        let out = c.compress(&v);
        chb_fed::assert_prop!(
            out.bits == packed_delta_bits(bits, 32, d),
            "bits {} for bits={bits} d={d}",
            out.bits
        );
        let dec = out.decoded.to_dense(d);
        let maxabs = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        let levels = ((1u64 << (bits - 1)) - 1) as f64;
        // one full level of slack, plus headroom for the reciprocal-
        // multiply rounding at high widths
        let bound = (maxabs / levels) * (1.0 + 1e-9) + 1e-300;
        for (j, (a, b)) in v.iter().zip(&dec).enumerate() {
            chb_fed::assert_prop!(
                (a - b).abs() <= bound,
                "coord {j}: |{a} - {b}| > {bound} (bits={bits})"
            );
        }
        Ok(())
    });
}

#[test]
fn error_feedback_telescopes_for_every_inner_codec() {
    prop::check("EF telescope", 40, |g| {
        let d = g.usize_in(1..=64);
        let rounds = g.usize_in(1..=30);
        let which = g.usize_in(0..=2);
        let codec: Box<dyn Compressor> = match which {
            0 => Box::new(ErrorFeedback(PackedFp32)),
            1 => Box::new(ErrorFeedback(PackedFp16)),
            _ => Box::new(ErrorFeedback(PackedInt {
                bits: g.usize_in(2..=16) as u32,
            })),
        };
        let mut scratch = CodecScratch::default();
        let mut out = Payload::default();
        let mut sum_true = vec![0.0; d];
        let mut sum_dec = vec![0.0; d];
        let mut mag = 0.0f64;
        for _ in 0..rounds {
            let delta = g.vec_f64(d, 5.0);
            mag = mag.max(delta.iter().fold(0.0f64, |m, v| m.max(v.abs())));
            linalg::axpy(1.0, &delta, &mut sum_true);
            codec.compress_into(&delta, &mut scratch, &mut out);
            out.fold_into(&mut sum_dec);
        }
        let res = scratch.residual();
        let scale = (mag * rounds as f64).max(1.0);
        for j in 0..d {
            let lhs = sum_dec[j] + res[j];
            chb_fed::assert_prop!(
                (lhs - sum_true[j]).abs() <= 1e-9 * scale,
                "codec {which} coord {j}: {lhs} vs {} (scale {scale})",
                sum_true[j]
            );
        }
        Ok(())
    });
}

#[test]
fn packed_payload_shape_survives_dimension_changes() {
    // the same scratch + slot reused across different dimensions must
    // stay correct (capacity reuse may not leak stale words)
    let c = ErrorFeedback(PackedInt { bits: 6 });
    let mut scratch = CodecScratch::default();
    let mut out = Payload::default();
    for &d in &[64usize, 5, 130, 1, 64] {
        let v: Vec<f64> = (0..d).map(|j| (j as f64) - d as f64 / 3.0).collect();
        c.compress_into(&v, &mut scratch, &mut out);
        assert_eq!(out.nnz(), d);
        assert!(out.fits(d));
        assert!(!out.fits(d + 1));
        let dec = out.to_dense(d);
        let maxabs = v.iter().fold(0.0f64, |m, x| m.max(x.abs()));
        for (a, b) in v.iter().zip(&dec) {
            // EF residual is bounded by one level of the *corrected*
            // vector, whose magnitude ≤ 2·maxabs in steady state
            assert!(
                (a - b).abs() <= 3.0 * maxabs / 31.0 + 1e-12,
                "d={d}: {a} vs {b}"
            );
        }
    }
}
