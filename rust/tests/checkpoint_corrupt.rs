//! Corrupt-checkpoint hardening: damaged, truncated, version-skewed,
//! or mismatched checkpoint files must surface as **typed**
//! [`CheckpointError`]s — never panics, and never a half-mutated run
//! (a resume validates the whole image before touching any state).
//!
//! The checkpoint format itself is pinned by
//! `tests/fixtures/checkpoint_golden.json`: the fixture must encode
//! byte-for-byte from a known [`Checkpoint`] value and decode back to
//! it, exactly like the manifest golden fixture.

use chb_fed::checkpoint::{
    Checkpoint, CheckpointError, CheckpointPolicy, LinkState, NetState,
    ServerState, WorkerState, CHECKPOINT_VERSION,
};
use chb_fed::coordinator::{
    run_serial, run_with_rules_ctx, EngineKind, RunConfig, RunContext,
    SerialPool, Server,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::metrics::{IterStat, Trace};
use chb_fed::optim::{Method, MethodParams};
use chb_fed::spec::{RunSpec, Session};
use chb_fed::tasks::TaskKind;

const GOLDEN: &str = include_str!("fixtures/checkpoint_golden.json");

/// The value the golden fixture encodes: a 2-round serial run, M = 1,
/// d = 2, with hand-picked bit patterns that are easy to audit in the
/// hex encoding (1.0 = 3ff0…, 2.0 = 4000…, 0.5 = 3fe0…).
fn golden_checkpoint() -> Checkpoint {
    let stat = |k: usize, loss: f64, comms_cum: usize, step_sq: f64,
                bits_cum: u64, epoch: f64| IterStat {
        k,
        loss,
        comms_round: 1,
        comms_cum,
        agg_grad_sq: 2.0,
        step_sq,
        bits_cum,
        down_bits_cum: bits_cum,
        vclock_us: 0.0,
        stale_max: 0,
        batch_frac: 1.0,
        epoch,
    };
    Checkpoint {
        version: CHECKPOINT_VERSION,
        spec_hash: Some(0xdead_beef),
        engine: "serial".into(),
        k: 2,
        dim: 2,
        server: ServerState {
            theta: vec![1.0, 2.0],
            theta_prev: vec![0.5, 0.5],
            agg_grad: vec![1.0, -1.0],
            k: 2,
        },
        workers: vec![WorkerState {
            id: 0,
            last_tx: vec![1.0, -1.0],
            transmissions: 2,
            residual: Vec::new(),
        }],
        schedule_rng: Some([1, 2, 3, 4]),
        net: NetState {
            rng: [0xa, 0xb, 0xc, 0xd],
            dropped: 0,
            sim_clock_us: 0.0,
            up: vec![LinkState { messages: 2, bytes: 64 }],
            down: vec![LinkState { messages: 2, bytes: 128 }],
        },
        trace: Trace {
            method: "CHB".into(),
            iters: vec![
                stat(1, 1.5, 1, 0.0, 128, 1.0),
                stat(2, 0.5, 2, 0.25, 256, 2.0),
            ],
            per_worker_comms: vec![2],
            participants: vec![1, 1],
            comm_map: vec![vec![true], vec![true]],
            worker_staleness: Vec::new(),
            fault_downs: 0,
            fault_rejoins: 0,
        },
        async_state: None,
    }
}

/// The format pin: encode == fixture bytes, decode == value, and the
/// decoded value re-encodes to the identical text.
#[test]
fn golden_checkpoint_fixture() {
    let cp = golden_checkpoint();
    assert_eq!(
        cp.to_json_string(),
        GOLDEN,
        "checkpoint encoding drifted — if intentional, bump \
         CHECKPOINT_VERSION and regenerate the fixture"
    );
    let back = Checkpoint::from_json_str(GOLDEN).unwrap();
    assert_eq!(back.to_json_string(), GOLDEN, "decode→encode not a fixed point");
    assert_eq!(back.version, CHECKPOINT_VERSION);
    assert_eq!(back.spec_hash, Some(0xdead_beef));
    assert_eq!(back.engine, "serial");
    assert_eq!((back.k, back.dim, back.num_workers()), (2, 2, 1));
    assert_eq!(back.server.theta, vec![1.0, 2.0]);
    assert_eq!(back.server.agg_grad, vec![1.0, -1.0]);
    assert_eq!(back.workers[0].transmissions, 2);
    assert_eq!(back.net.up[0].bytes, 64);
    assert_eq!(back.trace.iters.len(), 2);
    assert_eq!(back.trace.iters[1].bits_cum, 256);
    assert!(back.async_state.is_none());
}

/// Pre-downlink checkpoints (no `down_bits_cum` column) still decode:
/// the counter back-fills to zero rather than failing the strict key
/// check, so old images resume under the new trace schema.
#[test]
fn checkpoints_without_downlink_column_decode_with_zeros() {
    let legacy = GOLDEN.replace(
        "      \"down_bits_cum\": \"00000000000000800000000000000100\",\n",
        "",
    );
    assert!(legacy != GOLDEN, "pattern not found");
    let back = Checkpoint::from_json_str(&legacy).unwrap();
    assert_eq!(back.trace.iters.len(), 2);
    assert!(back.trace.iters.iter().all(|s| s.down_bits_cum == 0));
    // re-encoding emits the column explicitly (zeros)
    assert!(back
        .to_json_string()
        .contains("\"down_bits_cum\": \"00000000000000000000000000000000\""));
}

/// Truncation anywhere yields a typed parse error, never a panic.
#[test]
fn truncated_files_are_typed_parse_errors() {
    for cut in [1, 10, GOLDEN.len() / 3, GOLDEN.len() / 2, GOLDEN.len() - 2] {
        match Checkpoint::from_json_str(&GOLDEN[..cut]) {
            Err(CheckpointError::Parse(_)) => {}
            other => panic!(
                "truncation at {cut} gave {:?}, expected Parse",
                other.map(|_| "Ok")
            ),
        }
    }
}

/// A flipped bit inside a hex word (here: a hex digit knocked out of
/// the alphabet, and a word knocked off the 16-digit grid) is caught
/// by the strict hex codec as Corrupt.
#[test]
fn bit_flips_in_hex_payloads_are_corrupt_errors() {
    // damage one hex digit of server.agg_grad
    let bad = GOLDEN.replacen(
        "3ff0000000000000bff0000000000000",
        "3fz0000000000000bff0000000000000",
        1,
    );
    assert!(bad != GOLDEN, "pattern not found");
    assert!(matches!(
        Checkpoint::from_json_str(&bad),
        Err(CheckpointError::Corrupt(_))
    ));
    // damage the hex grid: a residual that is not a multiple of 16
    let bad = GOLDEN.replace("\"residual\": \"\"", "\"residual\": \"00\"");
    assert!(bad != GOLDEN, "pattern not found");
    assert!(matches!(
        Checkpoint::from_json_str(&bad),
        Err(CheckpointError::Corrupt(_))
    ));
    // damage a vector length: theta loses one element (len != dim)
    let bad = GOLDEN.replacen(
        "\"theta\": \"3ff00000000000004000000000000000\"",
        "\"theta\": \"3ff0000000000000\"",
        1,
    );
    assert!(bad != GOLDEN, "pattern not found");
    assert!(matches!(
        Checkpoint::from_json_str(&bad),
        Err(CheckpointError::Corrupt(_))
    ));
}

/// Version skew is rejected first — even when the rest of the file is
/// garbage, the error is Version, so upgrade messages stay honest.
#[test]
fn version_bump_is_rejected_before_anything_else() {
    let bumped = GOLDEN.replace("\"version\": 1", "\"version\": 2");
    match Checkpoint::from_json_str(&bumped) {
        Err(CheckpointError::Version { found: 2, expected }) => {
            assert_eq!(expected, CHECKPOINT_VERSION);
        }
        other => panic!("expected Version, got {:?}", other.map(|_| "Ok")),
    }
    // version gate fires before any payload validation
    let bumped_and_corrupt = bumped.replacen(
        "3ff0000000000000bff0000000000000",
        "zzzz000000000000bff0000000000000",
        1,
    );
    assert!(matches!(
        Checkpoint::from_json_str(&bumped_and_corrupt),
        Err(CheckpointError::Version { .. })
    ));
}

/// Unknown and missing keys are Corrupt — the decoder is strict in
/// both directions.
#[test]
fn unknown_and_missing_keys_are_corrupt_errors() {
    let extra =
        GOLDEN.replace("\"version\": 1", "\"version\": 1,\n  \"zzz\": 0");
    assert!(matches!(
        Checkpoint::from_json_str(&extra),
        Err(CheckpointError::Corrupt(_))
    ));
    let missing = GOLDEN.replace(
        "  \"schedule_rng\": [\n    \"0000000000000001\",\n    \
         \"0000000000000002\",\n    \"0000000000000003\",\n    \
         \"0000000000000004\"\n  ],\n",
        "",
    );
    assert!(missing != GOLDEN, "pattern not found");
    assert!(matches!(
        Checkpoint::from_json_str(&missing),
        Err(CheckpointError::Corrupt(_))
    ));
    // internal inconsistency: server.k disagrees with checkpoint k
    let skewed = GOLDEN.replacen("\"k\": 2", "\"k\": 3", 1);
    assert!(matches!(
        Checkpoint::from_json_str(&skewed),
        Err(CheckpointError::Corrupt(_))
    ));
}

fn problem(seed: u64, m: usize, d: usize) -> Problem {
    let l_m: Vec<f64> = (0..m).map(|i| 1.0 + 0.5 * i as f64).collect();
    let per_worker = synthetic::per_worker_rescaled(seed, m, 14, d, &l_m);
    Problem::from_worker_datasets(TaskKind::LinReg, "corrupt", &per_worker, 0.0)
}

/// Write a real checkpoint through a session run, for resume tests.
fn real_checkpoint(p: &Problem, spec: &RunSpec, dir: &std::path::Path) -> Checkpoint {
    Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .with_checkpoints(CheckpointPolicy::new(5, dir))
        .run_checked()
        .unwrap();
    Checkpoint::load(&dir.join("checkpoint.json")).unwrap()
}

/// Resume-time identity checks are typed: a different manifest is
/// SpecMismatch, a different engine kind is Engine, a different
/// parameter dimension is Dimension, a different worker count is
/// Corrupt — each detected before any state is restored.
#[test]
fn mismatched_resume_targets_are_typed_errors() {
    let p = problem(0xC0, 4, 8);
    let dir = std::env::temp_dir()
        .join(format!("chb_ckpt_corrupt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = RunSpec { iters: 12, ..RunSpec::new(TaskKind::LinReg, "corrupt") };
    let cp = real_checkpoint(&p, &spec, &dir);

    // different manifest (iters changed) → SpecMismatch
    let other = RunSpec { iters: 16, ..spec.clone() };
    let err = Session::from_parts(other, p.clone())
        .unwrap()
        .resuming_from(cp.clone())
        .run_checked()
        .unwrap_err();
    assert!(matches!(err, CheckpointError::SpecMismatch { .. }), "{err}");

    // different engine kind (hash check bypassed) → Engine
    let mut anon = cp.clone();
    anon.spec_hash = None;
    let threaded = RunSpec { engine: EngineKind::Threaded, ..spec.clone() };
    let err = Session::from_parts(threaded, p.clone())
        .unwrap()
        .resuming_from(anon)
        .run_checked()
        .unwrap_err();
    match err {
        CheckpointError::Engine { found, expected } => {
            assert_eq!((found.as_str(), expected.as_str()), ("serial", "threaded"));
        }
        other => panic!("expected Engine, got {other}"),
    }

    // same manifest, different problem dimension → Dimension
    let p10 = problem(0xC1, 4, 10);
    let err = Session::from_parts(spec.clone(), p10)
        .unwrap()
        .resuming_from(cp.clone())
        .run_checked()
        .unwrap_err();
    match err {
        CheckpointError::Dimension { found, expected } => {
            assert_eq!((found, expected), (8, 10));
        }
        other => panic!("expected Dimension, got {other}"),
    }

    // same manifest and dimension, different worker count → Corrupt
    let p3 = problem(0xC2, 3, 8);
    let err = Session::from_parts(spec, p3)
        .unwrap()
        .resuming_from(cp)
        .run_checked()
        .unwrap_err();
    assert!(matches!(err, CheckpointError::Corrupt(_)), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A failed resume mutates nothing: the same worker set, after the
/// typed error, still reproduces the baseline trace bit-for-bit.
#[test]
fn failed_resume_leaves_engine_state_untouched() {
    let p = problem(0xC3, 4, 8);
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 15);
    let mut ws = p.rust_workers();
    let baseline = run_serial(&mut ws, &cfg, p.theta0());

    let mut ws2 = p.rust_workers();
    let censor: std::sync::Arc<dyn chb_fed::optim::CensorRule> = std::sync::Arc::from(
        chb_fed::optim::method::build_censor_rule(Method::Chb, &params),
    );
    // golden checkpoint: engine matches, dimension (2 vs 8) does not
    let ctx = RunContext {
        resume: Some(golden_checkpoint()),
        ..RunContext::default()
    };
    let err = run_with_rules_ctx(
        &mut SerialPool::new(&mut ws2),
        &cfg,
        Server::new(Method::Chb, &params, p.theta0()),
        censor,
        "CHB",
        "serial",
        &ctx,
    )
    .unwrap_err();
    assert!(matches!(err, CheckpointError::Dimension { .. }), "{err}");
    for w in &ws2 {
        assert_eq!(w.transmissions, 0, "failed resume touched worker state");
        assert!(
            w.last_transmitted().iter().all(|&x| x == 0.0),
            "failed resume touched a censor reference"
        );
    }
    // the untouched workers replay the baseline exactly
    let rerun = run_serial(&mut ws2, &cfg, p.theta0());
    assert_eq!(baseline.iterations(), rerun.iterations());
    for (a, b) in baseline.iters.iter().zip(&rerun.iters) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "k={}", a.k);
        assert_eq!(a.comms_cum, b.comms_cum, "k={}", a.k);
        assert_eq!(a.bits_cum, b.bits_cum, "k={}", a.k);
    }
}
