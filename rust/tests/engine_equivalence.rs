//! Integration: the three worker pools (serial / threaded / rayon)
//! are interchangeable execution backends for the one `RoundEngine`
//! pipeline — bit-identical traces on all four paper tasks under full
//! participation, and a seeded sampling schedule reproduces exactly
//! across engines.

use chb_fed::coordinator::{
    run_async_detailed, run_rayon, run_serial, run_threaded, AsyncConfig,
    EngineKind, Participation, RayonPool, RoundEngine, RunConfig, StopRule,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::metrics::Trace;
use chb_fed::net::LatencyModel;
use chb_fed::optim::{Method, MethodParams, MethodSpec};
use chb_fed::spec::{EpsilonSpec, ParamSpec, RunSpec, Session};
use chb_fed::tasks::TaskKind;

/// Small instance of one paper task: M = 4 workers, 12×8 shards.
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> = (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0xE0 + match task {
        TaskKind::LinReg => 1,
        TaskKind::LogReg => 2,
        TaskKind::Lasso => 3,
        TaskKind::Nn => 4,
    }; // distinct data draw per task
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "equiv", &per_worker, lam)
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss differs at k={}",
            x.k
        );
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² differs at k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms at k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits at k={}", x.k);
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
    assert_eq!(a.participants, b.participants, "{what}: participants");
}

#[test]
fn pools_are_bit_identical_on_all_four_tasks() {
    for task in [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn] {
        let p = problem_for(task);
        let iters = if task == TaskKind::Nn { 15 } else { 30 };
        let params = MethodParams::new(1.0 / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, p.m_workers());
        let cfg = RunConfig::new(Method::Chb, params, iters).with_comm_map();

        let mut ws = p.rust_workers();
        let serial = run_serial(&mut ws, &cfg, p.theta0());
        let threaded = run_threaded(p.rust_workers(), &cfg, p.theta0());
        let rayon = run_rayon(p.rust_workers(), &cfg, p.theta0());
        let name = task.name();
        assert_traces_identical(&serial, &threaded, &format!("{name} threaded"));
        assert_traces_identical(&serial, &rayon, &format!("{name} rayon"));

        // force a genuinely multi-threaded rayon pool even on 1-core
        // CI machines (available_parallelism there would give 1)
        let rayon3 =
            RoundEngine::new(RayonPool::with_threads(p.rust_workers(), 3))
                .run(&cfg, p.theta0());
        assert_traces_identical(&serial, &rayon3, &format!("{name} rayon×3"));
    }
}

/// [`assert_traces_identical`] plus the downlink ledger column.
fn assert_traces_identical_with_downlink(a: &Trace, b: &Trace, what: &str) {
    assert_traces_identical(a, b, what);
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.down_bits_cum, y.down_bits_cum,
            "{what}: downlink bits differ at k={}",
            x.k
        );
    }
}

/// ARCHITECTURE.md invariant 7: the spec-layer method grid in its
/// degenerate corner — `MethodSpec::Classic` with the default free
/// downlink (`DownlinkSpec::None`) — is bit-identical to the legacy
/// `run_*` entry points on all four paper tasks, across serial /
/// threaded / rayon and the degenerate async regime, and both sides
/// charge the legacy 64·d downlink bits per scheduled worker.
#[test]
fn classic_grid_with_free_downlink_matches_legacy_entry_points() {
    for task in [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn] {
        let p = problem_for(task);
        let iters = if task == TaskKind::Nn { 12 } else { 25 };
        let params = MethodParams::new(1.0 / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, p.m_workers());
        let cfg = RunConfig::new(Method::Chb, params, iters);
        let run_grid = |engine: EngineKind| {
            let spec = RunSpec {
                method: MethodSpec::Classic(Method::Chb),
                params: ParamSpec {
                    alpha: Some(1.0 / p.l_global),
                    beta: 0.4,
                    epsilon: EpsilonSpec::Scaled { c: 0.1 },
                },
                iters,
                lambda: p.lambda_global(),
                engine,
                ..RunSpec::new(task, "equiv")
            };
            Session::from_parts(spec, p.clone())
                .expect("degenerate grid spec must validate")
                .run()
                .trace
        };
        let name = task.name();

        let mut ws = p.rust_workers();
        let serial = run_serial(&mut ws, &cfg, p.theta0());
        assert_traces_identical_with_downlink(
            &serial,
            &run_grid(EngineKind::Serial),
            &format!("{name} grid serial"),
        );
        assert_traces_identical_with_downlink(
            &run_threaded(p.rust_workers(), &cfg, p.theta0()),
            &run_grid(EngineKind::Threaded),
            &format!("{name} grid threaded"),
        );
        assert_traces_identical_with_downlink(
            &run_rayon(p.rust_workers(), &cfg, p.theta0()),
            &run_grid(EngineKind::Rayon { threads: 0 }),
            &format!("{name} grid rayon"),
        );
        let acfg = AsyncConfig {
            latency: LatencyModel::zero(),
            ..AsyncConfig::default()
        };
        let mut ws = p.rust_workers();
        let legacy_async =
            run_async_detailed(&mut ws, &cfg, &acfg, p.theta0()).trace;
        assert_traces_identical_with_downlink(
            &legacy_async,
            &run_grid(EngineKind::Async(acfg)),
            &format!("{name} grid async"),
        );

        // with downlink = none the ledger is exactly the legacy free
        // broadcast: 64·d bits to each of the M scheduled workers
        let (m, d) = (p.m_workers() as u64, p.dim() as u64);
        for (i, s) in serial.iters.iter().enumerate() {
            assert_eq!(
                s.down_bits_cum,
                (i as u64 + 1) * m * 64 * d,
                "{name}: free-downlink formula at k={}",
                s.k
            );
        }
    }
}

#[test]
fn stop_rules_fire_identically_across_pools() {
    let p = problem_for(TaskKind::LinReg);
    let f_star = p.f_star().expect("convex");
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 5_000)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
    let mut ws = p.rust_workers();
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    assert!(serial.iterations() < 5_000, "stop rule never fired");
    let threaded = run_threaded(p.rust_workers(), &cfg, p.theta0());
    let rayon = run_rayon(p.rust_workers(), &cfg, p.theta0());
    assert_traces_identical(&serial, &threaded, "early-stop threaded");
    assert_traces_identical(&serial, &rayon, "early-stop rayon");
}

#[test]
fn seeded_sampling_reproduces_exactly_across_engines() {
    let p = problem_for(TaskKind::LinReg);
    let m = p.m_workers();
    let params = MethodParams::new(0.5 / p.l_global)
        .with_beta(0.3)
        .with_epsilon1_scaled(0.1, m);
    let part = Participation::UniformSample { frac: 0.6, seed: 0xFEED };
    let cfg = RunConfig::new(Method::Chb, params, 60)
        .with_comm_map()
        .with_participation(part);

    let mut ws = p.rust_workers();
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    let mut ws = p.rust_workers();
    let serial2 = run_serial(&mut ws, &cfg, p.theta0());
    assert_traces_identical(&serial, &serial2, "sampling rerun");

    let threaded = run_threaded(p.rust_workers(), &cfg, p.theta0());
    let rayon = run_rayon(p.rust_workers(), &cfg, p.theta0());
    assert_traces_identical(&serial, &threaded, "sampling threaded");
    assert_traces_identical(&serial, &rayon, "sampling rayon");

    // the schedule itself: round(0.6·4) = 2 scheduled every round,
    // and transmissions only ever come from scheduled workers
    assert_eq!(serial.participants.len(), serial.iterations());
    assert!(serial.participants.iter().all(|&n| n == 2));
    for (s, &n) in serial.iters.iter().zip(&serial.participants) {
        assert!(s.comms_round <= n, "k={}: {} > {n}", s.k, s.comms_round);
    }
}

#[test]
fn straggler_schedule_reproduces_exactly_across_engines() {
    let p = problem_for(TaskKind::LinReg);
    let params = MethodParams::new(0.3 / p.l_global)
        .with_beta(0.2)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let part = Participation::Straggler { timeout: 1.0, seed: 42 };
    let cfg = RunConfig::new(Method::Chb, params, 60)
        .with_comm_map()
        .with_participation(part);
    let mut ws = p.rust_workers();
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    let threaded = run_threaded(p.rust_workers(), &cfg, p.theta0());
    let rayon = run_rayon(p.rust_workers(), &cfg, p.theta0());
    assert_traces_identical(&serial, &threaded, "straggler threaded");
    assert_traces_identical(&serial, &rayon, "straggler rayon");
    let m = p.m_workers();
    assert!(serial.participants.iter().all(|&n| (1..=m).contains(&n)));
}
