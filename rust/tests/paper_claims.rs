//! Integration: the paper's qualitative claims must hold on this
//! implementation (the "shape" DESIGN.md §5 commits to).
//!
//! These are end-to-end runs through Problem → coordinator → metrics,
//! asserting orderings rather than absolute numbers.

use chb_fed::coordinator::StopRule;
use chb_fed::experiments::figures::{synth_linreg_problem, synth_logreg_problem};
use chb_fed::experiments::runner::{run_all_methods, run_method, Protocol};
use chb_fed::metrics::Trace;
use chb_fed::optim::Method;
use chb_fed::theory;

fn by_method<'a>(traces: &'a [Trace], name: &str) -> &'a Trace {
    traces.iter().find(|t| t.method == name).unwrap()
}

/// §IV headline: at equal target accuracy CHB uses the fewest
/// communications; HB/CHB need fewer iterations than GD/LAG.
#[test]
fn chb_wins_communications_at_equal_accuracy() {
    for problem in [synth_linreg_problem(7), synth_logreg_problem(7, 0.001)] {
        let f_star = problem.f_star().unwrap();
        let proto = Protocol::paper_default(1.0 / problem.l_global, 5_000)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
        let traces = run_all_methods(&problem, &proto);
        let (chb, hb) = (by_method(&traces, "CHB"), by_method(&traces, "HB"));
        let (lag, gd) = (by_method(&traces, "LAG"), by_method(&traces, "GD"));

        // every method reached the target
        for t in &traces {
            assert!(
                t.final_loss() - f_star < 1e-8,
                "{} did not converge: {:.3e}",
                t.method,
                t.final_loss() - f_star
            );
        }
        // comms ordering (the paper's Table I/II pattern)
        assert!(chb.total_comms() < hb.total_comms(), "CHB ≥ HB comms");
        assert!(chb.total_comms() < lag.total_comms(), "CHB ≥ LAG comms");
        assert!(chb.total_comms() < gd.total_comms(), "CHB ≥ GD comms");
        assert!(lag.total_comms() < gd.total_comms(), "LAG ≥ GD comms");
        // momentum methods need fewer iterations
        assert!(chb.iterations() < lag.iterations(), "CHB ≥ LAG iters");
        assert!(hb.iterations() < gd.iterations(), "HB ≥ GD iters");
        // CHB iterations within 35% of HB (paper: "almost the same")
        assert!(
            (chb.iterations() as f64) < 1.35 * hb.iterations() as f64,
            "CHB iters {} vs HB {}",
            chb.iterations(),
            hb.iterations()
        );
    }
}

/// Fig. 1: workers with smaller L_m transmit less frequently in CHB.
#[test]
fn smooth_workers_transmit_less() {
    let problem = synth_linreg_problem(11);
    let proto = Protocol::paper_default(1.0 / problem.l_global, 24);
    let trace = run_method(&problem, Method::Chb, &proto, true);
    let s = &trace.per_worker_comms;
    // L_m increases with worker index; transmissions must trend up.
    // (Monotone in the large; allow local ties/jitter of 1.)
    assert!(
        s[8] > s[0] && s[8] >= s[4] && s[4] >= s[0],
        "no trend: {s:?}"
    );
    // Lemma 2 for qualifying workers
    let eps1 = proto.params(problem.m_workers()).epsilon1;
    let bound = theory::lemma2_bound(trace.iterations());
    for (m, &count) in s.iter().enumerate() {
        if theory::lemma2_applies(problem.l_m[m], eps1) {
            assert!(
                count <= bound,
                "worker {m}: S_m={count} > {bound} with L_m²≤ε₁"
            );
        }
    }
}

/// Fig. 11: increasing ε₁ monotonically reduces communications until
/// convergence degrades (iterations rise).
#[test]
fn epsilon_sweep_trades_comms_for_iterations() {
    let problem = synth_logreg_problem(13, 0.001);
    let f_star = problem.f_star().unwrap();
    let mut comms = Vec::new();
    let mut iters = Vec::new();
    for c in [0.01, 0.1, 1.0] {
        let mut proto = Protocol::paper_default(1.0 / problem.l_global, 5_000)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
        proto.eps_c = c;
        let t = run_method(&problem, Method::Chb, &proto, false);
        assert!(t.final_loss() - f_star < 1e-8, "ε₁={c} did not converge");
        comms.push(t.total_comms());
        iters.push(t.iterations());
    }
    assert!(comms[1] < comms[0], "ε₁↑ should cut comms: {comms:?}");
    assert!(
        iters[2] > iters[1],
        "large ε₁ should cost iterations: {iters:?}"
    );
}

/// Theorem 1: under the (55) setting, the measured per-iteration
/// contraction of the objective error is at least the predicted
/// (1 − c) — i.e. the theory is a valid (conservative) bound.
#[test]
fn theorem1_rate_bounds_measured_rate() {
    let problem = synth_linreg_problem(17);
    let l = problem.l_global;
    // strong-convexity constant: smallest eigenvalue of the total
    // Gram; bound from below via f's quadratic along coordinates —
    // use a conservative μ = L/1e4 (rate prediction shrinks with μ,
    // so any μ ≤ μ_true keeps the bound valid).
    let mu = l / 1e4;
    let delta = 0.1;
    let choice = theory::ParamChoice::theorem1_setting(l, mu, delta, 9);
    assert!(choice.satisfies_lemma1(l, 9));
    let c = choice.contraction(l, mu, 9);
    assert!((c - theory::theorem1_rate(l, mu, delta)).abs() < 1e-9);

    let f_star = problem.f_star().unwrap();
    let proto = Protocol {
        alpha: choice.alpha,
        beta: choice.beta,
        eps_abs: Some(choice.epsilon1),
        eps_c: 0.0,
        max_iters: 400,
        stop: StopRule::ObjErrBelow { f_star, tol: 1e-9 },
        participation: chb_fed::coordinator::Participation::Full,
        engine: chb_fed::coordinator::EngineKind::Serial,
    };
    let t = run_method(&problem, Method::Chb, &proto, false);
    // measured contraction over the run must beat (1 − c)
    let first = t.iters.first().unwrap().loss - f_star;
    let last = t.final_loss() - f_star;
    let k = t.iterations() as f64;
    let measured = (last / first).powf(1.0 / k); // geometric mean factor
    assert!(
        measured <= 1.0 - c + 1e-12,
        "measured factor {measured} worse than predicted {}",
        1.0 - c
    );
}

/// Fig. 12: CHB's averaged per-communication descent dominates LAG's.
#[test]
fn chb_per_comm_descent_beats_lag() {
    let problem = synth_logreg_problem(19, 0.001);
    let f_star = problem.f_star().unwrap();
    let f0 = chb_fed::experiments::fstar::objective(&problem, &problem.theta0());
    let proto = Protocol::paper_default(1.0 / problem.l_global, 3_000)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
    let chb = run_method(&problem, Method::Chb, &proto, false);
    let lag = run_method(&problem, Method::Lag, &proto, false);
    let last = |t: &Trace| t.per_comm_descent(f0).last().unwrap().2;
    assert!(
        last(&chb) > last(&lag),
        "CHB {:.4e} vs LAG {:.4e}",
        last(&chb),
        last(&lag)
    );
}
