//! Integration: the gradient-sampling layer (`data::batch`).
//!
//! * `BatchSchedule::Full` is bit-identical to the legacy
//!   (pre-batching) path on all four paper tasks across
//!   serial / threaded / rayon / degenerate-async — pinning the seed
//!   traces through the batch-indexed kernel refactor.
//! * Minibatch index streams are a pure function of
//!   (worker, seed, k): the same stochastic run reproduces exactly
//!   across every pool and the degenerate async engine, regardless of
//!   thread interleaving.
//! * The stochastic regime's bookkeeping (batch_frac / epoch columns)
//!   and its headline economics (censored minibatch CHB spends fewer
//!   uplink bits to a fixed accuracy than uncensored minibatch SGD)
//!   hold on a small synthetic instance.

use std::sync::Arc;

use chb_fed::coordinator::{
    run_async_detailed, run_rayon, run_serial, run_threaded, run_with_rules,
    AsyncConfig, Participation, RunConfig, SerialPool, Server,
};
use chb_fed::data::batch::{BatchSampler, BatchSchedule};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::metrics::Trace;
use chb_fed::net::LatencyModel;
use chb_fed::optim::{
    CensorRule, DecayingCensor, GdRule, HeavyBallRule, Method, MethodParams,
    NeverCensor,
};
use chb_fed::tasks::TaskKind;

/// Small instance of one paper task: M = 4 workers, 12×8 shards.
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> = (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0xBA + match task {
        TaskKind::LinReg => 1,
        TaskKind::LogReg => 2,
        TaskKind::Lasso => 3,
        TaskKind::Nn => 4,
    };
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "batch-equiv", &per_worker, lam)
}

fn degenerate_async() -> AsyncConfig {
    AsyncConfig { latency: LatencyModel::zero(), ..AsyncConfig::default() }
}

fn assert_traces_identical(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(
            x.loss.to_bits(),
            y.loss.to_bits(),
            "{what}: loss differs at k={}",
            x.k
        );
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² differs at k={}",
            x.k
        );
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms at k={}", x.k);
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits at k={}", x.k);
        assert_eq!(
            x.batch_frac.to_bits(),
            y.batch_frac.to_bits(),
            "{what}: batch_frac at k={}",
            x.k
        );
        assert_eq!(
            x.epoch.to_bits(),
            y.epoch.to_bits(),
            "{what}: epoch at k={}",
            x.k
        );
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.participants, b.participants, "{what}: participants");
}

#[test]
fn full_schedule_is_bit_identical_to_legacy_on_all_tasks_and_engines() {
    for task in
        [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
    {
        let p = problem_for(task);
        let iters = if task == TaskKind::Nn { 12 } else { 25 };
        let params = MethodParams::new(1.0 / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, p.m_workers());
        let cfg = RunConfig::new(Method::Chb, params, iters);
        let name = task.name();

        // the legacy path: workers with no sampler at all
        let mut ws = p.rust_workers();
        let legacy = run_serial(&mut ws, &cfg, p.theta0());
        // the full-batch *schedule* must be the same thing, bit for bit
        let mut ws = p.rust_workers_batched(BatchSchedule::Full);
        let full_serial = run_serial(&mut ws, &cfg, p.theta0());
        assert_traces_identical(&legacy, &full_serial, &format!("{name} serial"));
        let full_threaded = run_threaded(
            p.rust_workers_batched(BatchSchedule::Full),
            &cfg,
            p.theta0(),
        );
        assert_traces_identical(&legacy, &full_threaded, &format!("{name} threaded"));
        let full_rayon = run_rayon(
            p.rust_workers_batched(BatchSchedule::Full),
            &cfg,
            p.theta0(),
        );
        assert_traces_identical(&legacy, &full_rayon, &format!("{name} rayon"));
        let mut ws = p.rust_workers_batched(BatchSchedule::Full);
        let full_async =
            run_async_detailed(&mut ws, &cfg, &degenerate_async(), p.theta0())
                .trace;
        assert_traces_identical(&legacy, &full_async, &format!("{name} async"));

        // and the new columns read as the deterministic regime
        for (i, s) in legacy.iters.iter().enumerate() {
            assert_eq!(s.batch_frac, 1.0, "{name}: batch_frac k={}", s.k);
            assert!(
                (s.epoch - (i + 1) as f64).abs() < 1e-12,
                "{name}: epoch k={} is {}",
                s.k,
                s.epoch
            );
        }
    }
}

#[test]
fn minibatch_traces_reproduce_exactly_across_engines() {
    // the property behind the reproducibility claim: index streams are
    // a pure function of (worker, seed, k), so no pool interleaving —
    // and not even the async engine's event order — can perturb them
    let p = problem_for(TaskKind::LinReg);
    let schedule =
        BatchSchedule::Minibatch { size: 4, seed: 0xFEED, replace: false };
    let params = MethodParams::new(0.5 / p.l_global)
        .with_beta(0.3)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 40);

    let mut ws = p.rust_workers_batched(schedule);
    let serial = run_serial(&mut ws, &cfg, p.theta0());
    let mut ws = p.rust_workers_batched(schedule);
    let serial2 = run_serial(&mut ws, &cfg, p.theta0());
    assert_traces_identical(&serial, &serial2, "minibatch rerun");

    let threaded = run_threaded(p.rust_workers_batched(schedule), &cfg, p.theta0());
    assert_traces_identical(&serial, &threaded, "minibatch threaded");
    let rayon = run_rayon(p.rust_workers_batched(schedule), &cfg, p.theta0());
    assert_traces_identical(&serial, &rayon, "minibatch rayon");
    let mut ws = p.rust_workers_batched(schedule);
    let degenerate =
        run_async_detailed(&mut ws, &cfg, &degenerate_async(), p.theta0())
            .trace;
    assert_traces_identical(&serial, &degenerate, "minibatch degenerate-async");

    // a different draw seed genuinely changes the run
    let other = BatchSchedule::Minibatch { size: 4, seed: 0xFEE0, replace: false };
    let mut ws = p.rust_workers_batched(other);
    let reseeded = run_serial(&mut ws, &cfg, p.theta0());
    assert!(
        serial
            .iters
            .iter()
            .zip(&reseeded.iters)
            .any(|(a, b)| a.loss.to_bits() != b.loss.to_bits()),
        "re-seeded minibatch run was bit-identical — sampler ignored the seed?"
    );
}

#[test]
fn minibatch_draws_ignore_sampler_history() {
    // per-(worker, seed, k) purity, stated directly on the sampler:
    // drawing rounds out of order (as async arrival patterns do)
    // yields the same index set per k as drawing them in order
    let schedule =
        BatchSchedule::Minibatch { size: 5, seed: 0xD1CE, replace: false };
    let mut in_order = BatchSampler::new(schedule, 3, 24);
    let mut shuffled = BatchSampler::new(schedule, 3, 24);
    let forward: Vec<Vec<u32>> =
        (1..=8).map(|k| in_order.draw(k).unwrap().to_vec()).collect();
    for k in [8usize, 2, 5, 1, 7, 3, 6, 4] {
        assert_eq!(
            shuffled.draw(k).unwrap(),
            &forward[k - 1][..],
            "draw at k={k} depended on draw order"
        );
    }
}

#[test]
fn batch_frac_and_epoch_columns_track_the_schedule() {
    let p = problem_for(TaskKind::LinReg);
    let params = MethodParams::new(0.5 / p.l_global)
        .with_beta(0.3)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 30);
    // fixed minibatch: 4 of 12 rows ⇒ frac 1/3 every round
    let mini = BatchSchedule::Minibatch { size: 4, seed: 1, replace: false };
    let mut ws = p.rust_workers_batched(mini);
    let t = run_serial(&mut ws, &cfg, p.theta0());
    for (i, s) in t.iters.iter().enumerate() {
        assert!((s.batch_frac - 1.0 / 3.0).abs() < 1e-12, "k={}", s.k);
        assert!(
            (s.epoch - (i + 1) as f64 / 3.0).abs() < 1e-9,
            "epoch k={} is {}",
            s.k,
            s.epoch
        );
    }
    // growing batch: fraction is non-decreasing and saturates at 1
    let grow = BatchSchedule::GrowingBatch { size0: 2, growth: 1.5, seed: 2 };
    let mut ws = p.rust_workers_batched(grow);
    let t = run_serial(&mut ws, &cfg, p.theta0());
    for w in t.iters.windows(2) {
        assert!(w[1].batch_frac >= w[0].batch_frac - 1e-12);
    }
    assert_eq!(t.iters.last().unwrap().batch_frac, 1.0, "never saturated");
}

#[test]
fn observers_do_not_dilute_batch_frac_or_epoch() {
    // partial participation: unscheduled workers observe (no gradient)
    // and must be excluded from the batch_frac mean, while the epoch
    // column advances by Σ fractions / M
    let p = problem_for(TaskKind::LinReg);
    let mini = BatchSchedule::Minibatch { size: 4, seed: 3, replace: false };
    let params = MethodParams::new(0.3 / p.l_global)
        .with_beta(0.2)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let part = Participation::UniformSample { frac: 0.5, seed: 9 };
    let cfg = RunConfig::new(Method::Chb, params, 20)
        .with_participation(part);
    let mut ws = p.rust_workers_batched(mini);
    let t = run_serial(&mut ws, &cfg, p.theta0());
    // M = 4, frac 0.5 ⇒ 2 scheduled per round, each visiting 4 of 12
    // rows: batch_frac reads the schedule's 1/3, epoch advances by
    // 2·(1/3)/4 = 1/6 per round
    for (i, s) in t.iters.iter().enumerate() {
        assert!(
            (s.batch_frac - 1.0 / 3.0).abs() < 1e-12,
            "k={}: batch_frac {} diluted by observers",
            s.k,
            s.batch_frac
        );
        assert!(
            (s.epoch - (i + 1) as f64 / 6.0).abs() < 1e-9,
            "k={}: epoch {}",
            s.k,
            s.epoch
        );
    }
}

#[test]
fn minibatch_loss_column_reports_the_full_shard() {
    // at k = 1 every regime evaluates the same θ⁰, so the reported
    // global loss must agree bitwise between full-batch and minibatch
    // runs even though their gradients differ
    let p = problem_for(TaskKind::LogReg);
    let params = MethodParams::new(0.5 / p.l_global)
        .with_beta(0.3)
        .with_epsilon1_scaled(0.1, p.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 1);
    let mut ws = p.rust_workers();
    let full = run_serial(&mut ws, &cfg, p.theta0());
    let mini = BatchSchedule::Minibatch { size: 3, seed: 9, replace: false };
    let mut ws = p.rust_workers_batched(mini);
    let batched = run_serial(&mut ws, &cfg, p.theta0());
    assert_eq!(
        full.iters[0].loss.to_bits(),
        batched.iters[0].loss.to_bits(),
        "batched round must report the full-shard loss"
    );
}

#[test]
fn censored_minibatch_chb_beats_uncensored_minibatch_sgd_on_bits() {
    // the ablation_stochastic headline, pinned small: same batch size,
    // same step size — momentum + the CSGD decreasing threshold reach
    // the accuracy target with fewer uplink bits than plain SGD
    let p = problem_for(TaskKind::LinReg);
    let f_star = p.f_star().expect("convex");
    let theta0 = p.theta0();
    let f0 = chb_fed::experiments::fstar::objective(&p, &theta0);
    let target = f_star + 0.1 * (f0 - f_star);
    let alpha = 0.5 / p.l_global;
    let iters = 400;
    let rho = 1e-6f64.powf(1.0 / iters as f64);
    let schedule =
        BatchSchedule::Minibatch { size: 4, seed: 0xB47C, replace: false };

    // τ₀ anchored to the initial gradient energy, as in the ablation
    let tau0 = 0.1 * (f0 - f_star) * p.l_global;

    let bits_to_target = |rule: Box<dyn chb_fed::optim::ServerRule>,
                          censor: Arc<dyn CensorRule>,
                          label: &str|
     -> (u64, bool) {
        let mut workers = p.rust_workers_batched(schedule);
        let cfg = RunConfig::new(Method::Chb, MethodParams::new(0.0), iters);
        let t = run_with_rules(
            &mut SerialPool::new(&mut workers),
            &cfg,
            Server::with_rule(rule, theta0.clone()),
            censor,
            label,
        );
        match t.iters.iter().find(|s| s.loss <= target) {
            Some(s) => (s.bits_cum, true),
            None => (t.iters.last().map_or(u64::MAX, |s| s.bits_cum), false),
        }
    };

    let (sgd_bits, sgd_hit) = bits_to_target(
        Box::new(GdRule { alpha }),
        Arc::new(NeverCensor),
        "sgd-mini",
    );
    let (chb_bits, chb_hit) = bits_to_target(
        Box::new(HeavyBallRule::new(alpha, 0.4, p.dim())),
        Arc::new(DecayingCensor { tau0, rho }),
        "chb-mini",
    );
    assert!(chb_hit, "censored minibatch CHB never reached the target");
    assert!(sgd_hit, "uncensored minibatch SGD never reached the target");
    assert!(
        chb_bits < sgd_bits,
        "censored CHB spent {chb_bits} bits vs SGD's {sgd_bits}"
    );
}
