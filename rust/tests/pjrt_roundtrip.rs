//! Integration: the PJRT backend (AOT Pallas artifacts) must compute
//! the same gradients/losses as the pure-rust f64 backend, and a full
//! federated run through PJRT must track the rust-backend run.
//!
//! Requires `make artifacts` (skips with a message otherwise —
//! integration environments without jax still pass the rest).

use std::path::Path;

use chb_fed::coordinator::{run_serial, GradientBackend, RunConfig};
use chb_fed::data::{partition, registry};
use chb_fed::experiments::Problem;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::runtime::PjrtRuntime;
use chb_fed::tasks::{self, TaskKind};

fn artifact_dir() -> Option<&'static Path> {
    if !cfg!(feature = "pjrt") {
        // the hermetic default build stubs PjrtRuntime (its constructor
        // always errors), so these tests can only run with the feature
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/manifest.json missing (run `make artifacts`)");
        None
    }
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

#[test]
fn pjrt_gradients_match_rust_backend_on_synth() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = PjrtRuntime::new(dir).expect("pjrt runtime");
    let ds = registry::load("synth", Path::new("data")).unwrap();
    let shards = partition::split_even(&ds, 9);
    let lam = 0.001 / 9.0;

    for task in [TaskKind::LinReg, TaskKind::LogReg] {
        let meta = rt.manifest().find(task, "synth").unwrap().clone();
        for (i, shard) in shards.iter().enumerate().take(3) {
            let mut pjrt = rt.worker_backend(&meta, shard, lam).unwrap();
            let obj = tasks::build_objective(task, shard, lam);
            let mut ws = tasks::TaskWorkspace::default();
            let dim = obj.dim();
            // a few distinct iterates, including non-trivial ones
            for scale in [0.0, 0.1, -0.5] {
                let theta: Vec<f64> =
                    (0..dim).map(|j| scale * ((j % 7) as f64 - 3.0) / 3.0).collect();
                let mut g_rust = vec![0.0; dim];
                let l_rust = obj.grad_loss_into(&theta, &mut ws, &mut g_rust);
                let mut g_pjrt = vec![0.0; dim];
                let l_pjrt = pjrt.grad_loss_into(&theta, &mut g_pjrt);
                let gscale = g_rust
                    .iter()
                    .fold(1.0f64, |m, v| m.max(v.abs()));
                assert!(
                    max_abs_diff(&g_rust, &g_pjrt) < 1e-4 * gscale,
                    "{} worker {i} scale {scale}: grad mismatch {:.3e}",
                    task.name(),
                    max_abs_diff(&g_rust, &g_pjrt)
                );
                assert!(
                    (l_rust - l_pjrt).abs() < 1e-3 * l_rust.abs().max(1.0),
                    "{} worker {i}: loss {l_rust} vs {l_pjrt}",
                    task.name()
                );
            }
        }
    }
}

#[test]
fn pjrt_federated_run_tracks_rust_run() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = PjrtRuntime::new(dir).expect("pjrt runtime");
    let problem =
        Problem::from_registry(TaskKind::LinReg, "synth", Path::new("data"), 0.0)
            .unwrap();
    let proto_alpha = 1.0 / problem.l_global;
    let params = MethodParams::new(proto_alpha)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, problem.m_workers());
    let cfg = RunConfig::new(Method::Chb, params, 40);

    let mut rust_ws = problem.rust_workers();
    let rust_trace = run_serial(&mut rust_ws, &cfg, problem.theta0());
    let mut pjrt_ws = problem.pjrt_workers(&mut rt).unwrap();
    let pjrt_trace = run_serial(&mut pjrt_ws, &cfg, problem.theta0());

    assert_eq!(rust_trace.iterations(), pjrt_trace.iterations());
    // f32 artifacts vs f64 backend: trajectories agree to f32 noise;
    // after 40 iterations losses must still be within 0.1% relative
    // and the comm pattern should be near-identical.
    for (a, b) in rust_trace.iters.iter().zip(&pjrt_trace.iters) {
        let rel = (a.loss - b.loss).abs() / a.loss.abs().max(1e-9);
        assert!(rel < 1e-3, "k={}: rust {} vs pjrt {}", a.k, a.loss, b.loss);
    }
    let comm_gap = (rust_trace.total_comms() as i64
        - pjrt_trace.total_comms() as i64)
        .unsigned_abs() as usize;
    assert!(
        comm_gap <= rust_trace.total_comms() / 10 + 4,
        "comm divergence: rust {} vs pjrt {}",
        rust_trace.total_comms(),
        pjrt_trace.total_comms()
    );
}
