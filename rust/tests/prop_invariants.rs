//! Property-based invariants of the coordinator (testing::prop
//! driver; proptest is not on this image).
//!
//! These pin the identities the paper's correctness rests on:
//!   1. eq. (5) telescopes: the server aggregate always equals
//!      Σ_m ∇f_m(θ̂_m) over the workers' last-transmitted state.
//!   2. ε₁ = 0 ⇒ CHB ≡ HB and LAG ≡ GD, bit for bit.
//!   3. comm accounting: comms_cum = Σ per-round; per-worker
//!      S_m sums match; censored methods never transmit more than M·K.
//!   4. serial, threaded, and rayon engines agree bit-for-bit.
//!   5. Lemma 1 (Lyapunov monotone descent) under the closed-form
//!      (43) parameter choice, away from machine precision.
//!   6. participation schedules are deterministic in (policy, seed)
//!      and engine-independent; straggler-as-skip keeps the eq. (5)
//!      telescope exact.
//!   7. the fused single-pass gradient kernels are bit-identical to
//!      the two-pass (gemv + gemv_t) composition they replace, over
//!      random shapes.
//!   8. the radix-wheel EventQueue backend pops in the exact total
//!      `(time, rank, worker, seq)` order of the BinaryHeap reference
//!      — bitwise, including same-instant batches — and its
//!      checkpoint image (entries_ordered + counters, the PR 7
//!      format) is backend-independent and restores mid-drain.

use chb_fed::coordinator::{
    run_rayon, run_serial, run_threaded, Participation, RunConfig, Schedule,
    StopRule,
};
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::linalg;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::tasks::TaskKind;
use chb_fed::testing::prop::{self, Gen};
use chb_fed::theory::{LyapunovTracker, ParamChoice};

/// Random small linreg problem.
fn gen_problem(g: &mut Gen) -> Problem {
    let m = g.usize_in(2..=6);
    let d = g.usize_in(2..=12);
    let n = g.usize_in(4..=30);
    let l_m: Vec<f64> = (0..m).map(|_| g.f64_in(0.5, 20.0)).collect();
    let per_worker =
        synthetic::per_worker_rescaled(g.seed ^ 0x9E37, m, n, d, &l_m);
    Problem::from_worker_datasets(TaskKind::LinReg, "prop", &per_worker, 0.0)
}

#[test]
fn aggregate_telescopes_to_sum_of_last_transmitted() {
    prop::check("aggregate telescopes", 40, |g| {
        let p = gen_problem(g);
        let params = MethodParams::new(g.f64_in(0.1, 1.0) / p.l_global)
            .with_beta(g.f64_in(0.0, 0.8))
            .with_epsilon1_scaled(g.f64_in(0.01, 1.0), p.m_workers());
        let iters = g.usize_in(1..=40);
        // run manually so we can inspect worker state at the end
        let censor = chb_fed::optim::method::build_censor_rule(Method::Chb, &params);
        let mut server =
            chb_fed::coordinator::Server::new(Method::Chb, &params, p.theta0());
        let mut workers = p.rust_workers();
        for k in 1..=iters {
            let step_sq = server.theta_step_sq();
            let theta = server.theta.clone();
            let rounds: Vec<_> = workers
                .iter_mut()
                .map(|w| w.round(&theta, step_sq, censor.as_ref(), k))
                .collect();
            server.apply_round(&rounds);
        }
        // eq. (5) invariant: ∇ᵏ == Σ_m last_transmitted_m
        let dim = server.dim();
        let mut expect = vec![0.0; dim];
        for w in &workers {
            linalg::axpy(1.0, w.last_transmitted(), &mut expect);
        }
        let diff = expect
            .iter()
            .zip(&server.agg_grad)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let scale = linalg::norm2(&expect).max(1.0);
        chb_fed::assert_prop!(
            diff <= 1e-9 * scale,
            "aggregate drifted from telescoped sum: {diff:.3e} (scale {scale:.3e})"
        );
        Ok(())
    });
}

#[test]
fn fused_residual_grad_is_bitwise_equal_to_two_pass_composition() {
    prop::check("fused ≡ gemv∘sub∘gemv_t", 60, |g| {
        let n = g.usize_in(1..=48);
        let d = g.usize_in(1..=24);
        let mut x = linalg::Matrix::zeros(n, d);
        for v in &mut x.data {
            *v = g.gaussian();
        }
        // exercise the r == 0 skip path: zero out some rows
        for i in 0..n {
            if g.bool() && g.bool() {
                x.row_mut(i).fill(0.0);
            }
        }
        let theta = g.vec_f64(d, 3.0);
        let mut y = g.vec_f64(n, 3.0);
        for (i, yv) in y.iter_mut().enumerate() {
            if x.row(i).iter().all(|&v| v == 0.0) {
                *yv = 0.0; // zero rows get zero labels → r = 0 exactly
            }
        }
        // two-pass reference: stream X twice
        let mut resid_ref = vec![0.0; n];
        x.gemv(&theta, &mut resid_ref);
        for (r, yv) in resid_ref.iter_mut().zip(&y) {
            *r -= yv;
        }
        let mut grad_ref = vec![0.0; d];
        x.gemv_t_into(&resid_ref, &mut grad_ref);
        let loss_ref: f64 =
            0.5 * resid_ref.iter().map(|r| r * r).sum::<f64>();
        // fused: one sweep
        let mut resid = vec![0.0; n];
        let mut grad = vec![0.0; d];
        let loss = x.fused_residual_grad(&theta, &y, &mut resid, &mut grad);
        for i in 0..n {
            chb_fed::assert_prop!(
                resid[i].to_bits() == resid_ref[i].to_bits(),
                "resid[{i}]: fused {} vs two-pass {}",
                resid[i],
                resid_ref[i]
            );
        }
        for j in 0..d {
            chb_fed::assert_prop!(
                grad[j].to_bits() == grad_ref[j].to_bits(),
                "grad[{j}]: fused {} vs two-pass {}",
                grad[j],
                grad_ref[j]
            );
        }
        // loss accumulates in row order both ways (0.5·Σr² vs Σ½r²
        // differ by one final multiply on the same sum)
        chb_fed::assert_prop!(
            loss.to_bits() == loss_ref.to_bits(),
            "loss: fused {loss} vs two-pass {loss_ref}"
        );
        Ok(())
    });
}

#[test]
fn fused_coeff_grad_is_bitwise_equal_to_unfused_sweep() {
    prop::check("fused coeff ≡ per-row dot + rank-1", 40, |g| {
        let n = g.usize_in(1..=40);
        let d = g.usize_in(1..=16);
        let mut x = linalg::Matrix::zeros(n, d);
        for v in &mut x.data {
            *v = g.gaussian();
        }
        let theta = g.vec_f64(d, 2.0);
        let mask: Vec<f64> =
            (0..n).map(|_| if g.bool() { 1.0 } else { 0.0 }).collect();
        let y: Vec<f64> =
            (0..n).map(|_| if g.bool() { 1.0 } else { -1.0 }).collect();
        // unfused reference with the logistic coefficient map
        let mut grad_ref = vec![0.0; d];
        let mut loss_ref = 0.0;
        for i in 0..n {
            if mask[i] == 0.0 {
                continue;
            }
            let z = linalg::dot(x.row(i), &theta);
            let margin = y[i] * z;
            loss_ref += chb_fed::tasks::log1pexp(-margin);
            let c = -y[i] * chb_fed::tasks::sigmoid(-margin);
            if c != 0.0 {
                for j in 0..d {
                    grad_ref[j] += c * x.row(i)[j];
                }
            }
        }
        let mut grad = vec![0.0; d];
        let loss = x.fused_coeff_grad(
            &theta,
            &mask,
            |i, z| {
                let margin = y[i] * z;
                (
                    chb_fed::tasks::log1pexp(-margin),
                    -y[i] * chb_fed::tasks::sigmoid(-margin),
                )
            },
            &mut grad,
        );
        chb_fed::assert_prop!(
            loss.to_bits() == loss_ref.to_bits(),
            "loss: {loss} vs {loss_ref}"
        );
        for j in 0..d {
            chb_fed::assert_prop!(
                grad[j].to_bits() == grad_ref[j].to_bits(),
                "grad[{j}]: {} vs {}",
                grad[j],
                grad_ref[j]
            );
        }
        Ok(())
    });
}

#[test]
fn epsilon_zero_collapses_to_classical_methods() {
    prop::check("ε₁=0 ⇒ CHB≡HB, LAG≡GD", 25, |g| {
        let p = gen_problem(g);
        let params = MethodParams::new(g.f64_in(0.1, 1.0) / p.l_global)
            .with_beta(g.f64_in(0.1, 0.6))
            .with_epsilon1(0.0);
        let iters = g.usize_in(5..=30);
        for (censored, classical) in [(Method::Chb, Method::Hb), (Method::Lag, Method::Gd)] {
            let cfg_a = RunConfig::new(censored, params, iters);
            let cfg_b = RunConfig::new(classical, params, iters);
            let mut ws = p.rust_workers();
            let a = run_serial(&mut ws, &cfg_a, p.theta0());
            let mut ws = p.rust_workers();
            let b = run_serial(&mut ws, &cfg_b, p.theta0());
            for (x, y) in a.iters.iter().zip(&b.iters) {
                chb_fed::assert_prop!(
                    x.loss.to_bits() == y.loss.to_bits(),
                    "{} vs {} diverged at k={}: {} vs {}",
                    censored.name(),
                    classical.name(),
                    x.k,
                    x.loss,
                    y.loss
                );
            }
        }
        Ok(())
    });
}

#[test]
fn communication_accounting_is_consistent() {
    prop::check("comm accounting", 30, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        let params = MethodParams::new(g.f64_in(0.2, 1.0) / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(g.f64_in(0.01, 2.0), m);
        let iters = g.usize_in(2..=50);
        let cfg = RunConfig::new(Method::Chb, params, iters).with_comm_map();
        let mut ws = p.rust_workers();
        let t = run_serial(&mut ws, &cfg, p.theta0());

        // cumulative == running sum of per-round
        let mut cum = 0;
        for s in &t.iters {
            cum += s.comms_round;
            chb_fed::assert_prop!(
                s.comms_cum == cum,
                "k={}: comms_cum {} != running sum {cum}",
                s.k,
                s.comms_cum
            );
            chb_fed::assert_prop!(
                s.comms_round <= m,
                "k={}: {} transmissions from {m} workers",
                s.k,
                s.comms_round
            );
        }
        // per-worker sums match the total
        let by_worker: usize = t.per_worker_comms.iter().sum();
        chb_fed::assert_prop!(
            by_worker == t.total_comms(),
            "per-worker sum {by_worker} != total {}",
            t.total_comms()
        );
        // comm map agrees with both
        let by_map: usize = t
            .comm_map
            .iter()
            .map(|row| row.iter().filter(|&&b| b).count())
            .sum();
        chb_fed::assert_prop!(by_map == t.total_comms(), "map {} != total", by_map);
        // everyone transmits at k=1 (θ̂⁰ = 0 convention)
        chb_fed::assert_prop!(
            t.iters[0].comms_round == m,
            "k=1 transmitted {} != M={m}",
            t.iters[0].comms_round
        );
        Ok(())
    });
}

#[test]
fn serial_threaded_and_rayon_engines_agree() {
    prop::check("serial == threaded == rayon", 12, |g| {
        let p = gen_problem(g);
        let params = MethodParams::new(g.f64_in(0.2, 1.0) / p.l_global)
            .with_beta(g.f64_in(0.0, 0.6))
            .with_epsilon1_scaled(0.1, p.m_workers());
        let iters = g.usize_in(2..=40);
        let cfg = RunConfig::new(Method::Chb, params, iters).with_comm_map();
        let mut ws = p.rust_workers();
        let a = run_serial(&mut ws, &cfg, p.theta0());
        for (other, which) in [
            (run_threaded(p.rust_workers(), &cfg, p.theta0()), "threaded"),
            (run_rayon(p.rust_workers(), &cfg, p.theta0()), "rayon"),
        ] {
            chb_fed::assert_prop!(
                a.iterations() == other.iterations(),
                "{which}: iter count"
            );
            for (x, y) in a.iters.iter().zip(&other.iters) {
                chb_fed::assert_prop!(
                    x.loss.to_bits() == y.loss.to_bits()
                        && x.comms_cum == y.comms_cum,
                    "k={}: serial ({}, {}) vs {which} ({}, {})",
                    x.k,
                    x.loss,
                    x.comms_cum,
                    y.loss,
                    y.comms_cum
                );
            }
            chb_fed::assert_prop!(
                a.comm_map == other.comm_map,
                "{which}: comm maps differ"
            );
            chb_fed::assert_prop!(
                a.participants == other.participants,
                "{which}: participant counts differ"
            );
        }
        Ok(())
    });
}

#[test]
fn sampled_participation_is_deterministic_across_engines() {
    prop::check("sampling determinism", 10, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        let frac = g.f64_in(0.25, 1.0);
        let seed = g.usize_in(0..=1 << 30) as u64;
        let params = MethodParams::new(g.f64_in(0.1, 0.4) / p.l_global)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let iters = g.usize_in(2..=30);
        let cfg = RunConfig::new(Method::Chb, params, iters)
            .with_comm_map()
            .with_participation(Participation::UniformSample { frac, seed });
        let mut ws = p.rust_workers();
        let a = run_serial(&mut ws, &cfg, p.theta0());
        let mut ws = p.rust_workers();
        let a2 = run_serial(&mut ws, &cfg, p.theta0());
        chb_fed::assert_prop!(
            a.comm_map == a2.comm_map && a.participants == a2.participants,
            "same (frac, seed) rerun produced a different schedule"
        );
        let b = run_threaded(p.rust_workers(), &cfg, p.theta0());
        let c = run_rayon(p.rust_workers(), &cfg, p.theta0());
        for (other, which) in [(&b, "threaded"), (&c, "rayon")] {
            chb_fed::assert_prop!(
                a.participants == other.participants
                    && a.comm_map == other.comm_map,
                "{which}: schedule differs from serial"
            );
            for (x, y) in a.iters.iter().zip(&other.iters) {
                chb_fed::assert_prop!(
                    x.loss.to_bits() == y.loss.to_bits(),
                    "{which}: final θ path diverged at k={}",
                    x.k
                );
            }
        }
        // schedule shape: exactly clamp(round(frac·M), 1, M) per round,
        // and only scheduled workers ever transmit
        let want = ((frac * m as f64).round() as usize).clamp(1, m);
        chb_fed::assert_prop!(
            a.participants.iter().all(|&n| n == want),
            "expected {want} participants/round, got {:?}",
            a.participants
        );
        for (s, &n) in a.iters.iter().zip(&a.participants) {
            chb_fed::assert_prop!(
                s.comms_round <= n,
                "k={}: {} transmissions from {n} scheduled",
                s.k,
                s.comms_round
            );
        }
        Ok(())
    });
}

#[test]
fn straggler_skip_preserves_aggregate_telescope() {
    prop::check("straggler telescope", 15, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        let params = MethodParams::new(g.f64_in(0.1, 0.4) / p.l_global)
            .with_beta(g.f64_in(0.0, 0.5))
            .with_epsilon1_scaled(g.f64_in(0.01, 1.0), m);
        let iters = g.usize_in(1..=30);
        let timeout = g.f64_in(0.2, 2.5);
        let seed = g.usize_in(0..=1 << 30) as u64;
        // mirror the engine loop so server + worker state stay
        // inspectable at the end
        let censor =
            chb_fed::optim::method::build_censor_rule(Method::Chb, &params);
        let mut server =
            chb_fed::coordinator::Server::new(Method::Chb, &params, p.theta0());
        let mut schedule =
            Schedule::new(Participation::Straggler { timeout, seed });
        let mut workers = p.rust_workers();
        for k in 1..=iters {
            let active = schedule.active_set(k, m);
            chb_fed::assert_prop!(
                active.iter().any(|&a| a),
                "k={k}: empty round"
            );
            let step_sq = server.theta_step_sq();
            let theta = server.theta.clone();
            let rounds: Vec<_> = workers
                .iter_mut()
                .map(|w| {
                    if active[w.id] {
                        w.round(&theta, step_sq, censor.as_ref(), k)
                    } else {
                        w.observe(&theta)
                    }
                })
                .collect();
            server.apply_round(&rounds);
        }
        // eq. (5) must telescope even when stragglers miss rounds:
        // ∇ᵏ == Σ_m last_transmitted_m exactly as under full
        // participation (a skipped round is just a carried stale term)
        let dim = server.dim();
        let mut expect = vec![0.0; dim];
        for w in &workers {
            linalg::axpy(1.0, w.last_transmitted(), &mut expect);
        }
        let diff = expect
            .iter()
            .zip(&server.agg_grad)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let scale = linalg::norm2(&expect).max(1.0);
        chb_fed::assert_prop!(
            diff <= 1e-9 * scale,
            "straggler rounds broke the telescope: {diff:.3e} (scale {scale:.3e})"
        );
        Ok(())
    });
}

#[test]
fn event_queue_wheel_matches_heap_pop_order_bitwise() {
    use chb_fed::net::EventQueue;
    prop::check("wheel ≡ heap pop order", 50, |g| {
        let mut wheel = EventQueue::with_wheel();
        let mut heap = EventQueue::with_heap();
        // a handful of shared anchor instants force same-instant
        // batches, where only (rank, worker, seq) breaks the tie
        let mut anchors: Vec<f64> =
            (0..4).map(|_| g.f64_in(0.0, 50_000.0)).collect();
        anchors.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let ops = g.usize_in(20..=300);
        // pushes must stay at/after the popped front (virtual time
        // never flows backwards), so track the drained clock
        let mut clock = 0.0f64;
        for _ in 0..ops {
            if g.bool() || wheel.is_empty() {
                let t = if g.bool() {
                    // same-instant batch: identical f64, not just close
                    clock.max(anchors[g.usize_in(0..=3)])
                } else {
                    clock + g.f64_in(0.0, 10_000.0)
                };
                let rank = g.usize_in(0..=2) as u8;
                let worker = g.usize_in(0..=9);
                let payload = g.usize_in(0..=1 << 30) as u64;
                wheel.push(t, rank, worker, payload);
                heap.push(t, rank, worker, payload);
            } else {
                let (kw, pw) = wheel.pop().expect("wheel non-empty");
                let (kh, ph) = heap.pop().expect("heap tracks wheel");
                chb_fed::assert_prop!(
                    kw.time_us.to_bits() == kh.time_us.to_bits()
                        && kw.rank == kh.rank
                        && kw.worker == kh.worker
                        && kw.seq() == kh.seq()
                        && pw == ph,
                    "pop diverged: wheel ({}, {}, {}, {}) p={pw} vs \
                     heap ({}, {}, {}, {}) p={ph}",
                    kw.time_us,
                    kw.rank,
                    kw.worker,
                    kw.seq(),
                    kh.time_us,
                    kh.rank,
                    kh.worker,
                    kh.seq()
                );
                clock = kw.time_us;
            }
            chb_fed::assert_prop!(
                wheel.len() == heap.len(),
                "length diverged: wheel {} vs heap {}",
                wheel.len(),
                heap.len()
            );
            // peek agrees with peek, bitwise
            match (wheel.peek(), heap.peek()) {
                (None, None) => {}
                (Some(a), Some(b)) => chb_fed::assert_prop!(
                    a.time_us.to_bits() == b.time_us.to_bits()
                        && a.rank == b.rank
                        && a.worker == b.worker
                        && a.seq() == b.seq(),
                    "peek diverged"
                ),
                _ => chb_fed::assert_prop!(false, "peek presence diverged"),
            }
        }
        // full drain: identical tail, then both empty
        let dw = wheel.drain_ordered();
        let dh = heap.drain_ordered();
        chb_fed::assert_prop!(dw.len() == dh.len(), "drain lengths differ");
        for ((ka, pa), (kb, pb)) in dw.iter().zip(&dh) {
            chb_fed::assert_prop!(
                ka.time_us.to_bits() == kb.time_us.to_bits()
                    && ka.rank == kb.rank
                    && ka.worker == kb.worker
                    && ka.seq() == kb.seq()
                    && pa == pb,
                "drained tails diverged"
            );
        }
        Ok(())
    });
}

#[test]
fn event_queue_checkpoint_image_is_backend_independent_and_restores() {
    use chb_fed::net::EventQueue;
    prop::check("queue checkpoint round-trip", 30, |g| {
        let mut wheel = EventQueue::with_wheel();
        let mut heap = EventQueue::with_heap();
        let anchor = g.f64_in(0.0, 10_000.0);
        let n = g.usize_in(5..=120);
        for _ in 0..n {
            let t = if g.bool() { anchor } else { g.f64_in(0.0, 30_000.0) };
            let rank = g.usize_in(0..=2) as u8;
            let worker = g.usize_in(0..=9);
            let payload = g.usize_in(0..=1 << 30) as u64;
            wheel.push(t, rank, worker, payload);
            heap.push(t, rank, worker, payload);
        }
        // drain part-way, as a mid-run checkpoint would find the queue
        let drain = g.usize_in(0..=n / 2);
        for _ in 0..drain {
            wheel.pop();
            heap.pop();
        }
        // the PR 7 capture — entries_ordered + counters — must be
        // identical across backends: a checkpoint carries no backend
        // identity
        let ew: Vec<_> = wheel
            .entries_ordered()
            .into_iter()
            .map(|(k, p)| (k, *p))
            .collect();
        let eh: Vec<_> = heap
            .entries_ordered()
            .into_iter()
            .map(|(k, p)| (k, *p))
            .collect();
        chb_fed::assert_prop!(
            ew.len() == eh.len(),
            "capture sizes differ: {} vs {}",
            ew.len(),
            eh.len()
        );
        for ((ka, pa), (kb, pb)) in ew.iter().zip(&eh) {
            chb_fed::assert_prop!(
                ka.time_us.to_bits() == kb.time_us.to_bits()
                    && ka.rank == kb.rank
                    && ka.worker == kb.worker
                    && ka.seq() == kb.seq()
                    && pa == pb,
                "checkpoint images differ between backends"
            );
        }
        chb_fed::assert_prop!(
            wheel.counters() == heap.counters(),
            "counters differ: {:?} vs {:?}",
            wheel.counters(),
            heap.counters()
        );
        // restore (onto the default backend) and finish the drain:
        // the restored queue must pop exactly what the originals do
        let (seq, last) = wheel.counters();
        let mut restored = EventQueue::restore(ew, seq, last);
        loop {
            let r = restored.pop();
            let w = wheel.pop();
            match (r, w) {
                (None, None) => break,
                (Some((kr, pr)), Some((kw, pw))) => chb_fed::assert_prop!(
                    kr.time_us.to_bits() == kw.time_us.to_bits()
                        && kr.rank == kw.rank
                        && kr.worker == kw.worker
                        && kr.seq() == kw.seq()
                        && pr == pw,
                    "restored queue diverged from the original"
                ),
                _ => {
                    chb_fed::assert_prop!(false, "restored length diverged");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn lemma1_lyapunov_descends_under_condition_43() {
    prop::check("Lemma 1 descent", 15, |g| {
        let p = gen_problem(g);
        let m = p.m_workers();
        let l = p.l_global;
        // closed-form (43) choice with conservative fractions
        let alpha = g.f64_in(0.3, 0.9) / l;
        let choice = ParamChoice::closed_form_43(l, alpha, 1.0, 0.5, 0.5, m);
        chb_fed::assert_prop!(choice.satisfies_lemma1(l, m), "choice inadmissible");
        let params = MethodParams::new(choice.alpha)
            .with_beta(choice.beta)
            .with_epsilon1(choice.epsilon1);
        let f_star = p.f_star().expect("convex");
        // stop far from machine precision: Lemma 1 is exact-arithmetic
        let cfg = RunConfig::new(Method::Chb, params, 300)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-8 });
        let mut ws = p.rust_workers();
        let t = run_serial(&mut ws, &cfg, p.theta0());

        let mut tracker = LyapunovTracker::new(choice.eta1, f_star);
        // 𝕃(θᵏ) uses ‖θᵏ − θ^{k−1}‖², which is step_sq of round k−1
        let mut prev_step_sq = 0.0;
        for s in &t.iters {
            tracker.record(s.loss, prev_step_sq);
            prev_step_sq = s.step_sq;
        }
        let viol = tracker.violation_fraction(1e-9);
        chb_fed::assert_prop!(
            viol == 0.0,
            "Lyapunov increased on {:.1}% of steps",
            viol * 100.0
        );
        Ok(())
    });
}
