//! Checkpoint/restore round trips: a run resumed from a checkpoint at
//! round k is **bit-identical** to the uninterrupted run — trace
//! columns, uplink bit accounting, comm maps, and the emitted CSV
//! bytes — on all four paper tasks × all four engines, and across the
//! state-heavy configurations (minibatch sampling, top-k sparsifier,
//! int8 error feedback, staleness-bounded censoring, drops +
//! participation sampling).
//!
//! Also pinned here: writing checkpoints never perturbs a run (the
//! checkpointed and checkpoint-free traces are bitwise equal), because
//! serializing state draws from no run RNG.

use std::path::{Path, PathBuf};

use chb_fed::checkpoint::{Checkpoint, CheckpointPolicy};
use chb_fed::coordinator::{
    run_engine_with_rules_ctx, AsyncConfig, ComputeModel, EngineKind,
    Participation, RunConfig, RunContext, Server,
};
use chb_fed::data::batch::BatchSchedule;
use chb_fed::data::synthetic;
use chb_fed::experiments::Problem;
use chb_fed::metrics::{csv, Trace};
use chb_fed::net::LatencyModel;
use chb_fed::optim::{Method, MethodParams};
use chb_fed::spec::{
    CensorSpec, CodecSpec, DropSpec, EpsilonSpec, ParamSpec, RunSpec, Session,
};
use chb_fed::tasks::TaskKind;

/// Small instance of one paper task (the `spec_session` pattern).
fn problem_for(task: TaskKind) -> Problem {
    let (m, n, d) = (4usize, 12usize, 8usize);
    let l_m: Vec<f64> = (0..m).map(|i| (1.0 + 0.4 * i as f64).powi(2)).collect();
    let seed = 0xC4E + match task {
        TaskKind::LinReg => 1,
        TaskKind::LogReg => 2,
        TaskKind::Lasso => 3,
        TaskKind::Nn => 4,
    };
    let per_worker = synthetic::per_worker_rescaled(seed, m, n, d, &l_m);
    let lam = match task {
        TaskKind::Lasso => 0.05,
        TaskKind::LogReg | TaskKind::Nn => 0.01,
        TaskKind::LinReg => 0.0,
    };
    Problem::from_worker_datasets(task, "ckpt", &per_worker, lam)
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("chb_ckpt_resume_{}", std::process::id()))
        .join(tag);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Full bitwise trace comparison: every column of every round, plus
/// the per-worker and fault bookkeeping.
fn assert_traces_bitwise(a: &Trace, b: &Trace, what: &str) {
    assert_eq!(a.method, b.method, "{what}: method label");
    assert_eq!(a.iterations(), b.iterations(), "{what}: iteration count");
    for (x, y) in a.iters.iter().zip(&b.iters) {
        assert_eq!(x.k, y.k, "{what}: round index");
        let k = x.k;
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss k={k}");
        assert_eq!(x.comms_round, y.comms_round, "{what}: comms_round k={k}");
        assert_eq!(x.comms_cum, y.comms_cum, "{what}: comms_cum k={k}");
        assert_eq!(
            x.agg_grad_sq.to_bits(),
            y.agg_grad_sq.to_bits(),
            "{what}: ‖∇‖² k={k}"
        );
        assert_eq!(
            x.step_sq.to_bits(),
            y.step_sq.to_bits(),
            "{what}: step_sq k={k}"
        );
        assert_eq!(x.bits_cum, y.bits_cum, "{what}: bits_cum k={k}");
        assert_eq!(
            x.vclock_us.to_bits(),
            y.vclock_us.to_bits(),
            "{what}: vclock k={k}"
        );
        assert_eq!(x.stale_max, y.stale_max, "{what}: stale_max k={k}");
        assert_eq!(
            x.batch_frac.to_bits(),
            y.batch_frac.to_bits(),
            "{what}: batch_frac k={k}"
        );
        assert_eq!(
            x.epoch.to_bits(),
            y.epoch.to_bits(),
            "{what}: epoch k={k}"
        );
    }
    assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: S_m");
    assert_eq!(a.participants, b.participants, "{what}: participants");
    assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
    assert_eq!(
        a.worker_staleness.len(),
        b.worker_staleness.len(),
        "{what}: staleness rows"
    );
    for (i, (x, y)) in
        a.worker_staleness.iter().zip(&b.worker_staleness).enumerate()
    {
        assert_eq!(
            (x.folds, x.max, x.sum),
            (y.folds, y.max, y.sum),
            "{what}: staleness worker {i}"
        );
    }
    assert_eq!(a.fault_downs, b.fault_downs, "{what}: fault_downs");
    assert_eq!(a.fault_rejoins, b.fault_rejoins, "{what}: fault_rejoins");
}

/// The emitted trace CSVs must be byte-identical too — resume is a
/// contract on the artifacts, not just the in-memory structs.
fn assert_csv_bytes_equal(a: &Trace, b: &Trace, dir: &Path, what: &str) {
    let pa = dir.join("a.csv");
    let pb = dir.join("b.csv");
    csv::write_trace(&pa, a, 0.0).unwrap();
    csv::write_trace(&pb, b, 0.0).unwrap();
    let ba = std::fs::read(&pa).unwrap();
    let bb = std::fs::read(&pb).unwrap();
    assert!(ba == bb, "{what}: trace CSV bytes differ");
}

fn pareto_async() -> AsyncConfig {
    AsyncConfig {
        compute: ComputeModel::Pareto {
            scale_us: 800.0,
            shape: 1.6,
            seed: 0xA57,
        },
        latency: LatencyModel { fixed_us: 150.0, per_kib_us: 20.0 },
        max_staleness: None,
    }
}

/// Run `spec` three ways — checkpoint-free, checkpointing every
/// `every` rounds, and resumed from the written checkpoint — and
/// require all three traces bitwise equal.
fn roundtrip_spec(spec: &RunSpec, p: &Problem, every: usize, what: &str) {
    let dir = tmp_dir(&what.replace(' ', "_"));
    let plain =
        Session::from_parts(spec.clone(), p.clone()).unwrap().run().trace;
    let ckpt = Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .with_checkpoints(CheckpointPolicy::new(every, &dir))
        .run_checked()
        .unwrap()
        .trace;
    assert_traces_bitwise(&plain, &ckpt, &format!("{what}: ckpt-write run"));
    let cp = Checkpoint::load(&dir.join("checkpoint.json")).unwrap();
    assert!(
        cp.k >= every && cp.k < spec.iters,
        "{what}: checkpoint at k={} (every={every}, iters={})",
        cp.k,
        spec.iters
    );
    let resumed = Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .resuming_from(cp)
        .run_checked()
        .unwrap()
        .trace;
    assert_traces_bitwise(&plain, &resumed, &format!("{what}: resume"));
    assert_csv_bytes_equal(&plain, &resumed, &dir, what);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Resume ≡ uninterrupted on all four paper tasks × all four engines.
#[test]
fn resume_is_bit_identical_on_all_tasks_and_engines() {
    for task in [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
    {
        let p = problem_for(task);
        let base = RunSpec {
            params: ParamSpec {
                alpha: Some(1.0 / p.l_global),
                beta: 0.4,
                epsilon: EpsilonSpec::Scaled { c: 0.1 },
            },
            iters: 24,
            record_comm_map: true,
            lambda: p.lambda_global(),
            ..RunSpec::new(task, "ckpt")
        };
        let engines = [
            EngineKind::Serial,
            EngineKind::Threaded,
            EngineKind::Rayon { threads: 2 },
            EngineKind::Async(pareto_async()),
        ];
        for engine in engines {
            let name = engine.name();
            let spec = RunSpec { engine, ..base.clone() };
            roundtrip_spec(
                &spec,
                &p,
                9,
                &format!("{} {name}", task.name()),
            );
        }
    }
}

/// Resume from *every* interior round k, not just a convenient
/// midpoint: a truncated run checkpointed at its own final round k,
/// then resumed to the full horizon, reproduces the uninterrupted
/// trace bitwise (sync engine family; engines are pinned bit-identical
/// to each other elsewhere).
#[test]
fn resume_from_every_round_matches_uninterrupted() {
    let p = problem_for(TaskKind::LinReg);
    let iters = 10usize;
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());
    // drops + sampled participation so the net and schedule RNG
    // streams genuinely carry state across the checkpoint boundary
    let cfg = RunConfig::new(Method::Chb, params, iters)
        .with_comm_map()
        .with_participation(Participation::UniformSample {
            frac: 0.75,
            seed: 0x5A11,
        })
        .with_drops(0.15, 0xD09);
    let censor = chb_fed::optim::method::build_censor_rule(Method::Chb, &params);
    let censor: std::sync::Arc<dyn chb_fed::optim::CensorRule> =
        std::sync::Arc::from(censor);
    let run = |cfg: &RunConfig, ctx: &RunContext| {
        let server = Server::new(cfg.method, &cfg.params, p.theta0());
        run_engine_with_rules_ctx(
            &EngineKind::Serial,
            p.rust_workers(),
            cfg,
            server,
            std::sync::Arc::clone(&censor),
            "CHB",
            ctx,
        )
        .map(|out| out.trace)
    };
    let baseline = run(&cfg, &RunContext::default()).unwrap();
    for k in 1..iters {
        let dir = tmp_dir(&format!("every_round_{k}"));
        // truncated run: stops after round k, checkpointing exactly there
        let truncated = RunConfig { max_iters: k, ..cfg.clone() };
        let ctx = RunContext {
            checkpoint: Some(CheckpointPolicy::new(k, &dir)),
            ..RunContext::default()
        };
        run(&truncated, &ctx).unwrap();
        let cp = Checkpoint::load(&dir.join("checkpoint.json")).unwrap();
        assert_eq!(cp.k, k, "truncated run checkpointed at the wrong round");
        let ctx = RunContext { resume: Some(cp), ..RunContext::default() };
        let resumed = run(&cfg, &ctx).unwrap();
        assert_traces_bitwise(&baseline, &resumed, &format!("resume@k={k}"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// The state-heavy configurations round-trip too: minibatch sampler,
/// top-k sparsifier, int8 error-feedback residuals, drops + sampling,
/// and the staleness-bounded censor in the async engine.
#[test]
fn resume_covers_minibatch_topk_int8ef_and_staleness_censor() {
    let p = problem_for(TaskKind::LinReg);
    let base = RunSpec {
        params: ParamSpec {
            alpha: Some(1.0 / p.l_global),
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters: 20,
        record_comm_map: true,
        ..RunSpec::new(TaskKind::LinReg, "ckpt")
    };
    // minibatch sampling: batch cursors are recomputed from
    // (worker, seed, k), so nothing in the checkpoint may drift
    let spec = RunSpec {
        batch: BatchSchedule::Minibatch { size: 4, seed: 0xB1, replace: false },
        censor: CensorSpec::VarianceScaled,
        ..base.clone()
    };
    roundtrip_spec(&spec, &p, 7, "minibatch");
    // top-k sparse uplink payloads
    let spec = RunSpec {
        codec: CodecSpec::TopK { k: 3 },
        engine: EngineKind::Threaded,
        ..base.clone()
    };
    roundtrip_spec(&spec, &p, 7, "topk");
    // int8 + error feedback: the per-worker residual must survive the
    // checkpoint boundary bit-for-bit
    let spec = RunSpec {
        codec: CodecSpec::Int { bits: 8, error_feedback: true },
        engine: EngineKind::Rayon { threads: 2 },
        ..base.clone()
    };
    roundtrip_spec(&spec, &p, 7, "int8-ef");
    // drops + sampled participation through the spec layer
    let spec = RunSpec {
        drops: DropSpec { prob: 0.2, seed: 0xD06 },
        participation: Participation::UniformSample {
            frac: 0.6,
            seed: 0xFACE,
        },
        ..base.clone()
    };
    roundtrip_spec(&spec, &p, 7, "drops-sampling");
    // staleness-bounded censor in the async engine: the per-worker
    // consecutive-skip counters live in the checkpoint's async section
    let spec = RunSpec {
        engine: EngineKind::Async(AsyncConfig {
            max_staleness: Some(2),
            ..pareto_async()
        }),
        ..base.clone()
    };
    roundtrip_spec(&spec, &p, 7, "staleness-censor");
}

/// A checkpoint written under the (default) timer-wheel event queue
/// resumes on the binary-heap backend — and vice versa — continuing
/// bit-identically.  The async section of the PR 7 format stores the
/// queue as an ordered entry list plus (seq, last_popped_us) counters,
/// so `CHB_FORCE_HEAP` may flip between write and resume without
/// perturbing a single trace bit.
///
/// (Setting the env var while sibling tests run concurrently is
/// harmless: the backends are pinned identical by contract, so a
/// sibling transiently constructing a heap-backed queue produces the
/// same results.)
#[test]
fn resume_crosses_event_queue_backends_bit_identically() {
    let p = problem_for(TaskKind::LinReg);
    let spec = RunSpec {
        params: ParamSpec {
            alpha: Some(1.0 / p.l_global),
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters: 24,
        record_comm_map: true,
        engine: EngineKind::Async(pareto_async()),
        ..RunSpec::new(TaskKind::LinReg, "ckpt")
    };
    let plain =
        Session::from_parts(spec.clone(), p.clone()).unwrap().run().trace;
    // checkpoint written by the wheel...
    let dir = tmp_dir("cross_backend_wheel");
    Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .with_checkpoints(CheckpointPolicy::new(9, &dir))
        .run_checked()
        .unwrap();
    let cp = Checkpoint::load(&dir.join("checkpoint.json")).unwrap();
    // ...resumed on the heap, and a second checkpoint written by the
    // heap while the override is in force
    std::env::set_var("CHB_FORCE_HEAP", "1");
    let on_heap = Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .resuming_from(cp)
        .run_checked()
        .unwrap()
        .trace;
    let dir2 = tmp_dir("cross_backend_heap");
    Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .with_checkpoints(CheckpointPolicy::new(9, &dir2))
        .run_checked()
        .unwrap();
    std::env::remove_var("CHB_FORCE_HEAP");
    // ...whose image resumes back on the wheel
    let cp2 = Checkpoint::load(&dir2.join("checkpoint.json")).unwrap();
    let on_wheel = Session::from_parts(spec.clone(), p.clone())
        .unwrap()
        .resuming_from(cp2)
        .run_checked()
        .unwrap()
        .trace;
    assert_traces_bitwise(&plain, &on_heap, "wheel ckpt → heap resume");
    assert_traces_bitwise(&plain, &on_wheel, "heap ckpt → wheel resume");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&dir2);
}

/// A checkpoint file is a faithful serialization: load(save(cp))
/// re-encodes to the identical text, on a checkpoint produced by a
/// real run (not a hand-rolled fixture).
#[test]
fn checkpoint_file_round_trips_textually() {
    let p = problem_for(TaskKind::LogReg);
    let dir = tmp_dir("textual_roundtrip");
    let spec = RunSpec {
        iters: 12,
        record_comm_map: true,
        codec: CodecSpec::Int { bits: 8, error_feedback: true },
        ..RunSpec::new(TaskKind::LogReg, "ckpt")
    };
    Session::from_parts(spec, p)
        .unwrap()
        .with_checkpoints(CheckpointPolicy::new(5, &dir))
        .run_checked()
        .unwrap();
    let path = dir.join("checkpoint.json");
    let text = std::fs::read_to_string(&path).unwrap();
    let cp = Checkpoint::from_json_str(&text).unwrap();
    assert_eq!(cp.to_json_string(), text, "re-encode drifted from the file");
    let _ = std::fs::remove_dir_all(&dir);
}
