//! chb-fed — CLI launcher for the CHB federated-learning runtime.
//!
//! ```text
//! chb-fed exp <id>            regenerate one paper artifact
//!                             (fig1…fig12, table1…table3, ablations, all)
//! chb-fed run                 one federated run with explicit knobs
//! chb-fed list                datasets, artifacts, experiments
//! chb-fed check-theory        evaluate Lemma-1/Theorem-1 conditions
//! ```
//!
//! Common options: --out results --data data --full (paper-scale
//! iteration budgets; default is the quick profile sized for this
//! 1-core image) --verbose

use std::path::Path;

use anyhow::{bail, Context, Result};

use chb_fed::coordinator::{
    run_async_detailed, run_rayon, run_serial, run_threaded, AsyncConfig,
    ComputeModel, Participation, RunConfig, StopRule,
};
use chb_fed::data::batch::BatchSchedule;
use chb_fed::net::LatencyModel;
use chb_fed::experiments::{ablations, figures, tables, Problem};
use chb_fed::optim::Method;
use chb_fed::runtime::PjrtRuntime;
use chb_fed::tasks::TaskKind;
use chb_fed::util::cli::Args;
use chb_fed::util::logging;

const USAGE: &str = "\
chb-fed — Censored Heavy Ball federated learning (paper reproduction)

USAGE:
  chb-fed exp <id> [--out DIR] [--data DIR] [--full]
      ids: fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig11
           fig12 table1 table2 table3 ablations all
  chb-fed run --task T --dataset D [--method M] [--alpha A] [--beta B]
              [--eps-c C | --eps-abs E] [--iters N] [--lambda L]
              [--backend rust|pjrt] [--engine serial|threaded|rayon|async]
              [--participation full|sample|straggler] [--sample-frac F]
              [--timeout T] [--part-seed S]
              [--batch-schedule full|minibatch|growing] [--batch-size B]
              [--batch-seed S] [--batch-growth G] [--batch-replace]
              [--compute-model uniform|pareto] [--compute-us US]
              [--pareto-shape A] [--compute-seed S] [--max-staleness S]
              [--net-fixed-us F] [--net-per-kib-us P]
              [--artifacts DIR] [--out DIR] [--data DIR]
      stochastic regime: --batch-schedule minibatch draws --batch-size
      rows per worker per round (per-worker seeded streams, without
      replacement unless --batch-replace); growing starts at
      --batch-size and multiplies by --batch-growth each round until
      the full shard (CSGD-style variance control).  Loss is still
      reported over the full shard; the trace gains batch_frac and
      epoch columns.  rust backend only.
      async engine: virtual-clock discrete-event simulation; workers
      draw per-round compute times (uniform, or Pareto heavy tails),
      messages order through the latency model, and the server folds
      deltas as they arrive (stale).  --max-staleness S bounds each
      worker's consecutive censored rounds; --iters counts server
      steps.  Zero latency + uniform compute reproduces --engine
      serial exactly.
  chb-fed list [--data DIR] [--artifacts DIR]
  chb-fed check-theory --l L --mu MU [--m M] [--delta D]

FLAGS:
  --full      paper-scale budgets (default: quick profile)
  --verbose   debug logging
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(
        argv,
        &["full", "verbose", "help", "comm-map", "batch-replace"],
    )?;
    if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "exp" => cmd_exp(&args),
        "run" => cmd_run(&args),
        "list" => cmd_list(&args),
        "check-theory" => cmd_theory(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("exp: missing experiment id")?
        .as_str();
    let out = Path::new(args.get_or("out", "results"));
    let data = Path::new(args.get_or("data", "data"));
    let quick = !args.flag("full");
    let run_one = |id: &str| -> Result<()> {
        let t = chb_fed::util::timer::Timer::quiet();
        let r = match id {
            "fig1" => figures::fig1(out, data, quick),
            "fig2" => figures::fig2(out, data, quick),
            "fig3" => figures::fig3(out, data, quick),
            "fig4" => figures::fig4(out, data, quick),
            "fig5" => figures::fig5(out, data, quick),
            "fig6" => figures::fig6(out, data, quick),
            "fig7" => figures::fig7(out, data, quick),
            "fig8" => figures::fig8(out, data, quick),
            "fig9" => figures::fig9(out, data, quick),
            "fig10" => figures::fig10(out, data, quick),
            "fig11" => figures::fig11(out, data, quick),
            "fig12" => figures::fig12(out, data, quick),
            "table1" => tables::table1(out, data, quick),
            "table2" => tables::table2(out, data, quick),
            "table3" => tables::table3(out, data, quick),
            "ablations" => ablations::all(out, quick),
            other => bail!("unknown experiment {other:?}"),
        };
        println!("[{id}: {:.1}s]", t.elapsed_secs());
        r
    };
    if id == "all" {
        for id in [
            "fig1", "fig2", "fig3", "fig11", "fig12", "table1", "table2",
            "fig4", "fig5", "fig6", "fig7", "table3", "fig8", "fig9",
            "fig10", "ablations",
        ] {
            run_one(id)?;
        }
        Ok(())
    } else {
        run_one(id)
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    // --config file.toml provides defaults; explicit flags override.
    let cfg_file = match args.get("config") {
        Some(path) => chb_fed::util::config::Config::load(Path::new(path))?,
        None => chb_fed::util::config::Config::default(),
    };
    let pick = |key: &str, dflt: &str| -> String {
        args.get(key)
            .map(str::to_string)
            .or_else(|| cfg_file.str(&format!("run.{key}")).map(str::to_string))
            .unwrap_or_else(|| dflt.to_string())
    };
    let pick_num = |key: &str| -> Option<f64> {
        args.get(key)
            .and_then(|s| s.parse().ok())
            .or_else(|| cfg_file.num(&format!("run.{key}")))
    };

    let task = TaskKind::parse(&pick("task", "linreg"))
        .context("bad task (linreg|logreg|lasso|nn)")?;
    let dataset = pick("dataset", "synth");
    let dataset = dataset.as_str();
    let data_s = pick("data", "data");
    let data = Path::new(&data_s);
    let lam = pick_num("lambda").unwrap_or(0.001);
    let problem = Problem::from_registry(task, dataset, data, lam)?;

    let alpha = pick_num("alpha").unwrap_or(1.0 / problem.l_global);
    let beta = pick_num("beta").unwrap_or(0.4);
    let iters = pick_num("iters").unwrap_or(500.0) as usize;
    let method = Method::parse(&pick("method", "chb"))
        .context("bad method (gd|hb|lag|chb)")?;
    let mut params = chb_fed::optim::MethodParams::new(alpha).with_beta(beta);
    params = match pick_num("eps-abs") {
        Some(e) => params.with_epsilon1(e),
        None => params.with_epsilon1_scaled(
            pick_num("eps-c").unwrap_or(0.1),
            problem.m_workers(),
        ),
    };
    // config-file aware like every other run.* option
    let part_seed = match args
        .get("part-seed")
        .or_else(|| cfg_file.str("run.part-seed"))
    {
        Some(s) => s
            .parse::<u64>()
            .with_context(|| format!("--part-seed {s:?}"))?,
        None => 0x5EED,
    };
    let participation = match pick("participation", "full").as_str() {
        "full" => Participation::Full,
        "sample" => Participation::UniformSample {
            frac: pick_num("sample-frac").unwrap_or(0.5),
            seed: part_seed,
        },
        "straggler" => Participation::Straggler {
            timeout: pick_num("timeout").unwrap_or(1.5),
            seed: part_seed,
        },
        other => bail!("bad --participation {other:?} (full|sample|straggler)"),
    };
    let mut cfg = RunConfig::new(method, params, iters)
        .with_stop(StopRule::MaxIters)
        .with_participation(participation);
    if args.flag("comm-map") {
        cfg = cfg.with_comm_map();
    }

    // gradient-sampling schedule (data::batch): full is the paper's
    // deterministic regime and the bit-pinned default.  All four
    // knobs are config-file aware like every other run.* option.
    let batch_size = pick_num("batch-size").unwrap_or(32.0) as usize;
    let batch_seed = match args
        .get("batch-seed")
        .or_else(|| cfg_file.str("run.batch-seed"))
    {
        Some(s) => s
            .parse::<u64>()
            .with_context(|| format!("--batch-seed {s:?}"))?,
        None => 0xB47C,
    };
    let schedule = match pick("batch-schedule", "full").as_str() {
        "full" => BatchSchedule::Full,
        "minibatch" => BatchSchedule::Minibatch {
            size: batch_size.max(1),
            seed: batch_seed,
            replace: args.flag("batch-replace"),
        },
        "growing" => {
            let growth = pick_num("batch-growth").unwrap_or(1.05);
            if !growth.is_finite() || growth < 1.0 {
                bail!("--batch-growth must be ≥ 1, got {growth}");
            }
            BatchSchedule::GrowingBatch {
                size0: batch_size.max(1),
                growth,
                seed: batch_seed,
            }
        }
        other => bail!(
            "bad --batch-schedule {other:?} (full|minibatch|growing)"
        ),
    };

    println!(
        "run: {} on {} — M={} d={} L={:.4e} α={alpha:.4e} β={beta} ε₁={:.4e} \
         backend={} engine={} participation={} batch={}",
        method.name(),
        dataset,
        problem.m_workers(),
        problem.dim(),
        problem.l_global,
        params.epsilon1,
        args.get_or("backend", "rust"),
        args.get_or("engine", "serial"),
        participation.name(),
        schedule.name(),
    );

    // backend decides where gradients come from; engine decides where
    // workers execute — one RoundEngine pipeline underneath either way
    let workers = match args.get_or("backend", "rust") {
        "rust" => problem.rust_workers_batched(schedule),
        "pjrt" => {
            if schedule != BatchSchedule::Full {
                bail!(
                    "--backend pjrt evaluates the full AOT shard per \
                     round; minibatch schedules need --backend rust"
                );
            }
            let mut rt =
                PjrtRuntime::new(Path::new(args.get_or("artifacts", "artifacts")))?;
            println!("PJRT platform: {}", rt.platform());
            problem.pjrt_workers(&mut rt)?
        }
        other => bail!("bad --backend {other:?}"),
    };
    let trace = match args.get_or("engine", "serial") {
        "serial" => {
            let mut ws = workers;
            run_serial(&mut ws, &cfg, problem.theta0())
        }
        "threaded" => run_threaded(workers, &cfg, problem.theta0()),
        "rayon" => run_rayon(workers, &cfg, problem.theta0()),
        "async" => {
            if participation != Participation::Full {
                bail!(
                    "--engine async runs full participation by \
                     construction; drop --participation"
                );
            }
            let compute_us: f64 = args.get_parse_or("compute-us", 1_000.0)?;
            if compute_us.is_nan() || compute_us <= 0.0 {
                bail!("--compute-us must be > 0, got {compute_us}");
            }
            let compute = match args.get_or("compute-model", "uniform") {
                "uniform" => ComputeModel::Uniform { us: compute_us },
                "pareto" => {
                    let shape: f64 = args.get_parse_or("pareto-shape", 2.0)?;
                    if shape.is_nan() || shape <= 0.0 {
                        bail!("--pareto-shape must be > 0, got {shape}");
                    }
                    ComputeModel::Pareto {
                        scale_us: compute_us,
                        shape,
                        seed: args.get_parse_or("compute-seed", 0x0A57u64)?,
                    }
                }
                other => bail!(
                    "bad --compute-model {other:?} (uniform|pareto)"
                ),
            };
            let default_lat = LatencyModel::default();
            let fixed_us: f64 =
                args.get_parse_or("net-fixed-us", default_lat.fixed_us)?;
            let per_kib_us: f64 =
                args.get_parse_or("net-per-kib-us", default_lat.per_kib_us)?;
            if !fixed_us.is_finite()
                || !per_kib_us.is_finite()
                || fixed_us < 0.0
                || per_kib_us < 0.0
            {
                bail!(
                    "--net-fixed-us/--net-per-kib-us must be finite and \
                     ≥ 0, got {fixed_us}/{per_kib_us}"
                );
            }
            let acfg = AsyncConfig {
                compute,
                latency: LatencyModel { fixed_us, per_kib_us },
                max_staleness: args.get_parse::<usize>("max-staleness")?,
            };
            let mut ws = workers;
            let out = run_async_detailed(&mut ws, &cfg, &acfg, problem.theta0());
            println!(
                "async: virtual clock {:.1} ms, max staleness {}",
                out.vclock_us / 1e3,
                out.trace.max_staleness()
            );
            out.trace
        }
        other => bail!("bad --engine {other:?} (serial|threaded|rayon|async)"),
    };

    let f_star = problem.f_star().unwrap_or(0.0);
    let out = Path::new(args.get_or("out", "results"));
    chb_fed::metrics::csv::write_trace(
        &out.join("run").join(format!(
            "{}_{}_{}.csv",
            task.name(),
            dataset,
            trace.method
        )),
        &trace,
        f_star,
    )?;
    if !trace.worker_staleness.is_empty() {
        chb_fed::metrics::csv::write_staleness(
            &out.join("run").join(format!(
                "{}_{}_{}_staleness.csv",
                task.name(),
                dataset,
                trace.method
            )),
            &trace,
        )?;
    }
    let last = trace.iters.last().context("empty trace")?;
    println!(
        "done: {} iters, {} comms, mean participants {:.1}, \
         final f−f* = {:.6e}, ‖∇‖² = {:.6e}",
        trace.iterations(),
        trace.total_comms(),
        trace.mean_participants(),
        last.loss - f_star,
        last.agg_grad_sq
    );
    println!("per-worker transmissions: {:?}", trace.per_worker_comms);
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    println!("datasets (data dir: {}):", args.get_or("data", "data"));
    for s in chb_fed::data::registry::SPECS {
        println!(
            "  {:<12} n={:<6} d={:<4} workers={} ",
            s.name, s.n, s.d, s.workers
        );
    }
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    match chb_fed::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("\nartifacts ({}):", dir.display());
            for a in &m.artifacts {
                println!(
                    "  {:<20} n_pad={:<6} d={:<4} θ-dim={}",
                    a.name, a.n_pad, a.d, a.theta_dim
                );
            }
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    println!(
        "\nexperiments: fig1..fig12, table1..table3, ablations, all \
         (chb-fed exp <id>)"
    );
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let l = args.get_parse_or("l", 10.0)?;
    let mu = args.get_parse_or("mu", 1.0)?;
    let m = args.get_parse_or("m", 9usize)?;
    let delta = args.get_parse_or("delta", 0.1)?;
    let p = chb_fed::theory::ParamChoice::theorem1_setting(l, mu, delta, m);
    println!("Theorem-1 setting (55) for L={l}, μ={mu}, M={m}, δ={delta}:");
    println!("  α  = {:.6e}", p.alpha);
    println!("  β  = {:.6e}", p.beta);
    println!("  ε₁ = {:.6e}", p.epsilon1);
    println!("  η₁ = {:.6e}", p.eta1);
    let ok = p.satisfies_lemma1(l, m);
    println!("  Lemma-1 conditions (10)–(12) with σ₀,σ₁ > 0: {ok}");
    let c = p.contraction(l, mu, m);
    println!(
        "  contraction c = {c:.6e} (eq. 17 predicts {:.6e})",
        chb_fed::theory::theorem1_rate(l, mu, delta)
    );
    println!(
        "  iteration complexity to 1e-6: {:.1} (eq. 59)",
        chb_fed::theory::chb_iteration_complexity(l, mu, delta, 1e-6)
    );
    Ok(())
}
