//! chb-fed — CLI launcher for the CHB federated-learning runtime.
//!
//! ```text
//! chb-fed exp <id>            regenerate one paper artifact
//!                             (fig1…fig12, table1…table3, ablations, all)
//! chb-fed run                 one federated run (flags → RunSpec → Session)
//! chb-fed run --spec FILE     replay a run from a manifest
//! chb-fed artifact            kick-tires pipeline: run every example
//!                             spec, index results by manifest hash
//! chb-fed list                datasets, artifacts, experiments
//! chb-fed check-theory        evaluate Lemma-1/Theorem-1 conditions
//! ```
//!
//! Every `run` is described by a `spec::RunSpec`: flags assemble one,
//! `--spec FILE` loads one, `--dump-spec` prints the resolved spec
//! instead of running, and every completed run writes `manifest.json`
//! next to its trace CSVs — so any result directory is rerunnable
//! from a single file.
//!
//! Common options: --out results --data data --full (paper-scale
//! iteration budgets; default is the quick profile sized for this
//! 1-core image) --verbose

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use chb_fed::checkpoint::{atomic_write, fnv1a64, Checkpoint, CheckpointPolicy};
use chb_fed::coordinator::{
    AsyncConfig, ComputeModel, EngineKind, FaultPlan, Participation,
    PopulationSpec,
};
use chb_fed::data::batch::BatchSchedule;
use chb_fed::experiments::{ablations, figures, tables};
use chb_fed::net::{DownlinkSpec, LatencyModel};
use chb_fed::optim::MethodSpec;
use chb_fed::spec::{
    BackendKind, CensorSpec, CodecSpec, DropSpec, EpsilonSpec, ParamSpec,
    Registry, RunSpec, Session,
};
use chb_fed::tasks::TaskKind;
use chb_fed::util::cli::Args;
use chb_fed::util::json::Json;
use chb_fed::util::logging;
use chb_fed::wire::{run_loadgen, LoadgenConfig, TransportSpec, WireConfig};

const USAGE: &str = "\
chb-fed — Censored Heavy Ball federated learning (paper reproduction)

USAGE:
  chb-fed exp <id> [--out DIR] [--data DIR] [--full]
      ids: fig1 fig2 fig3 fig4 fig5 fig6 fig7 fig8 fig9 fig10 fig11
           fig12 table1 table2 table3 ablations ablation-methods all
  chb-fed run [--spec FILE] [--dump-spec]
              [--task T] [--dataset D] [--method M] [--alpha A] [--beta B]
              [--local-steps K]
              [--eps-c C | --eps-abs E] [--iters N] [--lambda L]
              [--backend rust|pjrt]
              [--engine serial|threaded|rayon|async|wire] [--threads N]
              [--participation full|sample|straggler] [--sample-frac F]
              [--timeout T] [--part-seed S]
              [--batch-schedule full|minibatch|growing] [--batch-size B]
              [--batch-seed S] [--batch-growth G] [--batch-replace]
              [--censor method-default|never|absolute|periodic|decaying|
                        variance-scaled]
              [--censor-tau T] [--censor-period P] [--censor-tau0 T]
              [--censor-rho R]
              [--compress none|quant|topk|fp32|fp16|int|topk-int]
              [--quant-bits B] [--topk-k K] [--error-feedback]
              [--downlink-compress none|fp32|fp16|int] [--downlink-bits B]
              [--downlink-error-feedback]
              [--drop-prob P] [--drop-seed S] [--label NAME] [--comm-map]
              [--compute-model uniform|pareto] [--compute-us US]
              [--pareto-shape A] [--compute-seed S] [--max-staleness S]
              [--net-fixed-us F] [--net-per-kib-us P]
              [--quorum Q] [--round-deadline-ms MS] [--heartbeat-ms MS]
              [--retry-max N] [--retry-base-ms MS] [--retry-jitter-seed S]
              [--chaos-drop P] [--chaos-delay-prob P] [--chaos-delay-ms MS]
              [--chaos-duplicate P] [--chaos-corrupt P]
              [--chaos-partition P] [--chaos-seed S]
              [--checkpoint-every N] [--checkpoint-dir DIR]
              [--resume FILE]
              [--fault-prob P] [--fault-down R] [--fault-seed S]
              [--server-kill K1,K2,...]
              [--artifacts DIR] [--out DIR] [--data DIR]
      Flags assemble a RunSpec (the typed, serializable run
      description); --spec FILE loads one instead (combining --spec
      with run flags is an error), and --dump-spec validates + prints
      the spec JSON without running.  Every run writes manifest.json
      next to its trace CSVs: rerun any result directory with
      `chb-fed run --spec <dir>/manifest.json`.
      stochastic regime: --batch-schedule minibatch draws --batch-size
      rows per worker per round (per-worker seeded streams, without
      replacement unless --batch-replace); growing starts at
      --batch-size and multiplies by --batch-growth each round until
      the full shard (CSGD-style variance control).  Loss is still
      reported over the full shard; the trace gains batch_frac and
      epoch columns.  rust backend only.
      packed codecs: fp32/fp16 uplink bit-packed narrowed fields
      (32/16 bits per coordinate); int uplinks --quant-bits-wide
      integer levels plus one f32 scale header; topk-int keeps the
      --topk-k largest coordinates and packs the survivors to
      --quant-bits-wide levels (32 + (32+bits)·nnz on the wire).
      --error-feedback carries each round's rounding error into the
      next uplink (per-worker residual), recovering target accuracy at
      a fraction of the bits — see EXPERIMENTS.md §Codecs.
      method grid: --method also accepts nag/cnag (Nesterov server
      rule), local-steps (each worker runs K censored heavy-ball
      steps between uplinks; --local-steps K composes with any classic
      base, default K=4), and censored-adam/cadam (server-side Adam on
      the censored aggregate).  See EXPERIMENTS.md §Methods.
      downlink codec: --downlink-compress meters the broadcast
      direction (fp32/fp16/int --downlink-bits levels, optional
      --downlink-error-feedback server-side residual); every trace and
      manifest then carries downlink_bits_cum next to the uplink
      column.  none (default) keeps the legacy free-f64 broadcast and
      is bit-identical to pre-downlink runs.
      async engine: virtual-clock discrete-event simulation; workers
      draw per-round compute times (uniform, or Pareto heavy tails),
      messages order through the latency model, and the server folds
      deltas as they arrive (stale).  --max-staleness S bounds each
      worker's consecutive censored rounds; --iters counts server
      steps.  Zero latency + uniform compute reproduces --engine
      serial exactly.
      checkpointing: --checkpoint-every N atomically writes
      checkpoint.json every N server steps (into --checkpoint-dir,
      default the run's output directory); --resume FILE restores a
      run from a checkpoint and continues it bit-identically to the
      uninterrupted run.  Checkpointing never changes the trace.
      wire engine: the same round protocol over loopback sockets —
      one in-process server, one client thread per worker, a
      versioned CRC-framed codec.  With zero chaos the trace is
      bit-identical to --engine serial.  --chaos-* inject seeded
      drop/delay/duplicate/corrupt/partition faults on the data
      plane; --quorum Q folds a round once Q reports arrive after
      --round-deadline-ms (missing workers are folded as skips and
      forced to re-sync uncensored next round).
      fault injection: --fault-prob P crashes each (worker, round)
      with seeded probability P for --fault-down rounds (down workers
      observe only; their first round back transmits uncensored to
      re-sync the censor reference); --server-kill kills the server
      after the listed rounds and restores it from its latest
      checkpoint — the replayed trace is bit-identical to the
      kill-free run.  The plan serializes into manifest.json.
  chb-fed serve --bind tcp:HOST:PORT|uds:PATH [run flags | --spec FILE]
      standalone coordinator daemon: bind the transport, wait for all
      M `chb-fed worker` processes to dial in, then drive the round
      protocol over the wire.  The spec's engine must be wire (pass
      --engine wire, or a wire-engine manifest).  Writes the usual run
      artifacts plus wire_stats.csv (chaos/retry/quorum counters) into
      <out>/serve/.  A killed server restarted with --resume picks the
      cohort back up from its latest checkpoint; clients keep redialing
      and re-sync via a forced uncensored transmit.
  chb-fed worker --id N --connect tcp:HOST:PORT|uds:PATH
                 [run flags | --spec FILE]
      one cohort member: rebuild worker N's shard from the same spec
      the server runs (both sides derive identical data — only frames
      cross the wire), dial the coordinator, and serve censored
      uplinks until the server says Bye.  Dial and mid-run failures
      reconnect with seeded exponential backoff.
  chb-fed loadgen [--preset cohort-10k|cohort-100k] [--population M]
                  [--workers M] [--rounds R] [--dim D]
                  [--chaos-drop P] [--chaos-delay-prob P]
                  [--chaos-delay-ms MS] [--chaos-duplicate P]
                  [--chaos-corrupt P] [--chaos-seed S]
                  [--bench-out FILE]
      closed-loop wire throughput harness: M concurrent loopback
      clients against one in-process server, reporting rounds/sec,
      fold throughput, and p50/p99 round latency.  --bench-out merges
      two rows (wire_loadgen_*_round, *_round_p99) into a
      BENCH_hotpath.json-style file for tools/bench_diff.py.
      --preset drives the population cohort shapes: the clients stand
      in for one sampled cohort out of a 10k/100k-device population
      (wire fan-in per round is the cohort, never the population), and
      the bench rows rename to wire_loadgen_pop*_cohort*_d*_round.
      Explicit --workers/--rounds/--dim/--population override the
      preset.
  chb-fed scale [--clients M] [--cohort C] [--rounds R] [--dim D]
                [--base-workers W] [--seed S] [--rss-budget-mb MB]
                [--bench-out FILE]
      population-scale benchmark: M simulated clients (default 10^6)
      with per-round cohorts of C through the discrete-event cohort
      engine on a synthetic linreg population (W base shards,
      Arc-shared; client c holds shard c mod W).  Reports simulated
      rounds/sec, uplink/censor counts, and peak RSS (VmHWM), proving
      server memory stays O(model + cohort + M·8B), not O(M·d).
      --bench-out merges scale_pop_m*_cohort*_round and
      scale_pop_m*_rss_kib rows into a BENCH_hotpath.json-style file;
      --rss-budget-mb exits nonzero when peak RSS exceeds the budget
      (the CI scale-smoke assertion).
  chb-fed artifact [--smoke] [--specs DIR] [--out DIR] [--data DIR]
                   [--artifacts DIR] [--full]
      the kick-tires pipeline: runs every spec in examples/specs/
      (or --specs DIR), indexes each result directory by its manifest
      hash into <out>/store/<hash>/, and writes store/index.json,
      store/summary.csv, and store/REPORT.md.  --smoke clamps every
      spec to ≤ 25 iterations (the CI profile); --full additionally
      regenerates the paper figures/tables at paper-scale budgets.
  chb-fed list [--data DIR] [--artifacts DIR]
  chb-fed check-theory --l L --mu MU [--m M] [--delta D]

FLAGS:
  --full      paper-scale budgets (default: quick profile)
  --verbose   debug logging
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "full",
            "verbose",
            "help",
            "comm-map",
            "batch-replace",
            "dump-spec",
            "error-feedback",
            "downlink-error-feedback",
            "smoke",
        ],
    )?;
    if args.flag("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    if args.flag("help") || args.positional.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    match args.positional[0].as_str() {
        "exp" => cmd_exp(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "loadgen" => cmd_loadgen(&args),
        "scale" => cmd_scale(&args),
        "artifact" => cmd_artifact(&args),
        "list" => cmd_list(&args),
        "check-theory" => cmd_theory(&args),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }?;
    // strict accounting: anything not consumed above is a typo or an
    // option that does not apply to the chosen command/engine
    args.finish()
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args
        .positional
        .get(1)
        .context("exp: missing experiment id")?
        .as_str();
    let out = Path::new(args.get_or("out", "results"));
    let data = Path::new(args.get_or("data", "data"));
    let quick = !args.flag("full");
    // all options are read by now — reject typos *before* hour-scale
    // driver runs, not after
    args.finish()?;
    if id == "all" {
        for id in ALL_EXPERIMENTS {
            run_experiment(id, out, data, quick)?;
        }
        Ok(())
    } else {
        run_experiment(id, out, data, quick)
    }
}

/// Every paper artifact, in dependency-friendly order (the `exp all`
/// and `artifact --full` sweep).
const ALL_EXPERIMENTS: &[&str] = &[
    "fig1", "fig2", "fig3", "fig11", "fig12", "table1", "table2", "fig4",
    "fig5", "fig6", "fig7", "table3", "fig8", "fig9", "fig10", "ablations",
];

/// Run one paper figure/table driver (shared by `exp` and the full
/// `artifact` profile).
fn run_experiment(
    id: &str,
    out: &Path,
    data: &Path,
    quick: bool,
) -> Result<()> {
    let t = chb_fed::util::timer::Timer::quiet();
    let r = match id {
        "fig1" => figures::fig1(out, data, quick),
        "fig2" => figures::fig2(out, data, quick),
        "fig3" => figures::fig3(out, data, quick),
        "fig4" => figures::fig4(out, data, quick),
        "fig5" => figures::fig5(out, data, quick),
        "fig6" => figures::fig6(out, data, quick),
        "fig7" => figures::fig7(out, data, quick),
        "fig8" => figures::fig8(out, data, quick),
        "fig9" => figures::fig9(out, data, quick),
        "fig10" => figures::fig10(out, data, quick),
        "fig11" => figures::fig11(out, data, quick),
        "fig12" => figures::fig12(out, data, quick),
        "table1" => tables::table1(out, data, quick),
        "table2" => tables::table2(out, data, quick),
        "table3" => tables::table3(out, data, quick),
        "ablations" => ablations::all(out, quick),
        "ablation-methods" => ablations::methods(out, quick),
        other => bail!("unknown experiment {other:?}"),
    };
    println!("[{id}: {:.1}s]", t.elapsed_secs());
    r
}

/// Assemble a [`RunSpec`] from CLI flags (with `--config` file
/// defaults) — the flags→spec half of `cmd_run`.
fn spec_from_flags(args: &Args) -> Result<RunSpec> {
    // --config file.toml provides defaults; explicit flags override.
    let cfg_file = match args.get("config") {
        Some(path) => chb_fed::util::config::Config::load(Path::new(path))?,
        None => chb_fed::util::config::Config::default(),
    };
    let pick = |key: &str, dflt: &str| -> String {
        args.get(key)
            .map(str::to_string)
            .or_else(|| cfg_file.str(&format!("run.{key}")).map(str::to_string))
            .unwrap_or_else(|| dflt.to_string())
    };
    // malformed numbers are hard errors, never silent defaults (the
    // strict-CLI rule: a typo must not change the run)
    let pick_num = |key: &str| -> Result<Option<f64>> {
        match args.get(key) {
            Some(s) => Ok(Some(
                s.parse::<f64>().with_context(|| format!("--{key} {s:?}"))?,
            )),
            None => Ok(cfg_file.num(&format!("run.{key}"))),
        }
    };
    let pick_opt = |key: &str| -> Option<String> {
        args.get(key)
            .map(str::to_string)
            .or_else(|| cfg_file.str(&format!("run.{key}")).map(str::to_string))
    };
    let pick_seed = |key: &str, dflt: u64| -> Result<u64> {
        match args.get(key).or_else(|| cfg_file.str(&format!("run.{key}"))) {
            Some(s) => {
                s.parse::<u64>().with_context(|| format!("--{key} {s:?}"))
            }
            None => Ok(dflt),
        }
    };

    let task = TaskKind::parse(&pick("task", "linreg"))
        .context("bad task (linreg|logreg|lasso|nn)")?;
    let mut method = MethodSpec::parse(&pick("method", "chb")).context(
        "bad method (gd|hb|lag|chb|nag|cnag|local-steps|censored-adam)",
    )?;
    if let Some(k) = pick_num("local-steps")? {
        // wraps the parsed classic base (or overrides the default K of
        // --method local-steps); the adaptive/Nesterov rules have no
        // local-descent analogue on this grid
        method = match method {
            MethodSpec::Classic(base)
            | MethodSpec::LocalSteps { base, .. } => {
                MethodSpec::LocalSteps { base, k_local: k as usize }
            }
            _ => bail!("--local-steps only composes with gd|hb|lag|chb"),
        };
    }
    let params = ParamSpec {
        alpha: pick_num("alpha")?,
        beta: pick_num("beta")?.unwrap_or(0.4),
        epsilon: match pick_num("eps-abs")? {
            Some(eps) => EpsilonSpec::Absolute { eps },
            None => EpsilonSpec::Scaled { c: pick_num("eps-c")?.unwrap_or(0.1) },
        },
    };

    let part_seed = pick_seed("part-seed", 0x5EED)?;
    let participation = match pick("participation", "full").as_str() {
        "full" => Participation::Full,
        "sample" => Participation::UniformSample {
            frac: pick_num("sample-frac")?.unwrap_or(0.5),
            seed: part_seed,
        },
        "straggler" => Participation::Straggler {
            timeout: pick_num("timeout")?.unwrap_or(1.5),
            seed: part_seed,
        },
        other => bail!("bad --participation {other:?} (full|sample|straggler)"),
    };

    // gradient-sampling schedule (data::batch): full is the paper's
    // deterministic regime and the bit-pinned default
    let batch_size = pick_num("batch-size")?.unwrap_or(32.0) as usize;
    let batch_seed = pick_seed("batch-seed", 0xB47C)?;
    let batch = match pick("batch-schedule", "full").as_str() {
        "full" => BatchSchedule::Full,
        "minibatch" => BatchSchedule::Minibatch {
            size: batch_size.max(1),
            seed: batch_seed,
            replace: args.flag("batch-replace"),
        },
        "growing" => BatchSchedule::GrowingBatch {
            size0: batch_size.max(1),
            growth: pick_num("batch-growth")?.unwrap_or(1.05),
            seed: batch_seed,
        },
        other => {
            bail!("bad --batch-schedule {other:?} (full|minibatch|growing)")
        }
    };

    let censor = match pick("censor", "method-default").as_str() {
        "method-default" => CensorSpec::MethodDefault,
        "never" => CensorSpec::Never,
        "absolute" => CensorSpec::Absolute {
            tau: pick_num("censor-tau")?.unwrap_or(1.0),
        },
        "periodic" => CensorSpec::Periodic {
            period: pick_num("censor-period")?.unwrap_or(2.0) as usize,
        },
        "decaying" => CensorSpec::Decaying {
            tau0: pick_num("censor-tau0")?.unwrap_or(1.0),
            rho: pick_num("censor-rho")?.unwrap_or(0.99),
        },
        "variance-scaled" => CensorSpec::VarianceScaled,
        other => bail!(
            "bad --censor {other:?} (method-default|never|absolute|\
             periodic|decaying|variance-scaled)"
        ),
    };

    let error_feedback = args.flag("error-feedback");
    let codec = match pick("compress", "none").as_str() {
        "none" => CodecSpec::None,
        "quant" => CodecSpec::Quantizer {
            bits: pick_num("quant-bits")?.unwrap_or(8.0) as u32,
        },
        "topk" => {
            CodecSpec::TopK { k: pick_num("topk-k")?.unwrap_or(25.0) as usize }
        }
        "fp32" => CodecSpec::Fp32 { error_feedback },
        "fp16" => CodecSpec::Fp16 { error_feedback },
        "int" => CodecSpec::Int {
            bits: pick_num("quant-bits")?.unwrap_or(8.0) as u32,
            error_feedback,
        },
        "topk-int" => CodecSpec::TopKInt {
            k: pick_num("topk-k")?.unwrap_or(25.0) as usize,
            bits: pick_num("quant-bits")?.unwrap_or(8.0) as u32,
        },
        other => bail!(
            "bad --compress {other:?} \
             (none|quant|topk|fp32|fp16|int|topk-int)"
        ),
    };

    // broadcast-direction codec: default keeps the downlink free in
    // f64 (the paper's accounting and the bit-pinned legacy path)
    let downlink_ef = args.flag("downlink-error-feedback");
    let downlink = match pick("downlink-compress", "none").as_str() {
        "none" => DownlinkSpec::None,
        "fp32" => DownlinkSpec::Fp32 { error_feedback: downlink_ef },
        "fp16" => DownlinkSpec::Fp16 { error_feedback: downlink_ef },
        "int" => DownlinkSpec::Int {
            bits: pick_num("downlink-bits")?.unwrap_or(8.0) as u32,
            error_feedback: downlink_ef,
        },
        other => {
            bail!("bad --downlink-compress {other:?} (none|fp32|fp16|int)")
        }
    };

    let engine = match pick("engine", "serial").as_str() {
        "serial" => EngineKind::Serial,
        "threaded" => EngineKind::Threaded,
        "rayon" => EngineKind::Rayon {
            threads: pick_num("threads")?.unwrap_or(0.0) as usize,
        },
        "async" => {
            let compute_us = pick_num("compute-us")?.unwrap_or(1_000.0);
            let compute = match pick("compute-model", "uniform").as_str() {
                "uniform" => ComputeModel::Uniform { us: compute_us },
                "pareto" => ComputeModel::Pareto {
                    scale_us: compute_us,
                    shape: pick_num("pareto-shape")?.unwrap_or(2.0),
                    seed: pick_seed("compute-seed", 0x0A57)?,
                },
                other => {
                    bail!("bad --compute-model {other:?} (uniform|pareto)")
                }
            };
            let default_lat = LatencyModel::default();
            EngineKind::Async(AsyncConfig {
                compute,
                latency: LatencyModel {
                    fixed_us: pick_num("net-fixed-us")?
                        .unwrap_or(default_lat.fixed_us),
                    per_kib_us: pick_num("net-per-kib-us")?
                        .unwrap_or(default_lat.per_kib_us),
                },
                max_staleness: args
                    .get_parse::<usize>("max-staleness")?
                    .or_else(|| {
                        cfg_file.num("run.max-staleness").map(|v| v as usize)
                    }),
            })
        }
        "wire" => {
            let mut wcfg = WireConfig::default();
            if let Some(v) = pick_num("quorum")? {
                wcfg.quorum = v as usize;
            }
            if let Some(v) = pick_num("round-deadline-ms")? {
                wcfg.round_deadline_ms = v as u32;
            }
            if let Some(v) = pick_num("heartbeat-ms")? {
                wcfg.heartbeat_ms = v as u32;
            }
            if let Some(v) = pick_num("retry-max")? {
                wcfg.retry.max_attempts = v as u32;
            }
            if let Some(v) = pick_num("retry-base-ms")? {
                wcfg.retry.base_ms = v as u32;
            }
            wcfg.retry.jitter_seed =
                pick_seed("retry-jitter-seed", wcfg.retry.jitter_seed)?;
            if let Some(v) = pick_num("chaos-drop")? {
                wcfg.chaos.drop = v;
            }
            if let Some(v) = pick_num("chaos-delay-prob")? {
                wcfg.chaos.delay_prob = v;
            }
            if let Some(v) = pick_num("chaos-delay-ms")? {
                wcfg.chaos.delay_ms = v as u32;
            }
            if let Some(v) = pick_num("chaos-duplicate")? {
                wcfg.chaos.duplicate = v;
            }
            if let Some(v) = pick_num("chaos-corrupt")? {
                wcfg.chaos.corrupt = v;
            }
            if let Some(v) = pick_num("chaos-partition")? {
                wcfg.chaos.partition = v;
            }
            wcfg.chaos.seed = pick_seed("chaos-seed", wcfg.chaos.seed)?;
            EngineKind::Wire(wcfg)
        }
        other => bail!(
            "bad --engine {other:?} (serial|threaded|rayon|async|wire)"
        ),
    };

    let backend = match pick("backend", "rust").as_str() {
        "rust" => BackendKind::Rust,
        "pjrt" => BackendKind::Pjrt,
        other => bail!("bad --backend {other:?} (rust|pjrt)"),
    };

    // fault injection (spec semantics, serialized into manifest.json;
    // default = no faults, in which case the key is omitted entirely)
    let server_kills = match pick_opt("server-kill") {
        None => Vec::new(),
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.parse::<usize>()
                    .with_context(|| format!("--server-kill {t:?}"))
            })
            .collect::<Result<Vec<_>>>()?,
    };
    let faults = FaultPlan {
        crash_prob: pick_num("fault-prob")?.unwrap_or(0.0),
        down_rounds: pick_num("fault-down")?.unwrap_or(1.0) as usize,
        seed: pick_seed("fault-seed", 0xFA17)?,
        server_kills,
    };

    Ok(RunSpec {
        label: pick_opt("label"),
        lambda: pick_num("lambda")?.unwrap_or(0.001),
        method,
        params,
        censor,
        engine,
        participation,
        batch,
        codec,
        downlink,
        backend,
        iters: pick_num("iters")?.unwrap_or(500.0) as usize,
        drops: DropSpec {
            prob: pick_num("drop-prob")?.unwrap_or(0.0),
            seed: pick_seed("drop-seed", 0)?,
        },
        faults,
        record_comm_map: args.flag("comm-map"),
        ..RunSpec::new(task, &pick("dataset", "synth"))
    })
}

/// `--spec FILE` replays a manifest verbatim (run flags next to it are
/// rejected by the strict accounting in dispatch()); otherwise flags
/// assemble the spec.  Shared by `run`, `serve`, and `worker`.
fn load_spec(args: &Args) -> Result<RunSpec> {
    let spec = match args.get("spec") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read spec {path}"))?;
            RunSpec::from_json_str(&text)
                .with_context(|| format!("decode spec {path}"))?
        }
        None => spec_from_flags(args)?,
    };
    spec.validate()?;
    Ok(spec)
}

fn cmd_run(args: &Args) -> Result<()> {
    let out = Path::new(args.get_or("out", "results")).join("run");
    let registry = Registry::new(
        Path::new(args.get_or("data", "data")),
        Path::new(args.get_or("artifacts", "artifacts")),
    );
    let spec = load_spec(args)?;
    if args.flag("dump-spec") {
        println!("{}", spec.to_json_string());
        return Ok(());
    }
    // checkpoint/resume are environmental (they never change the
    // trace), so they are flags, not spec fields
    let ckpt_every = args.get_parse::<usize>("checkpoint-every")?;
    let ckpt_dir = args.get("checkpoint-dir").map(PathBuf::from);
    let resume_path = args.get("resume").map(str::to_string);
    if ckpt_dir.is_some() && ckpt_every.is_none() {
        bail!("--checkpoint-dir needs --checkpoint-every");
    }
    // every option has been consumed by now — fail on typo'd or
    // inapplicable flags *before* the run executes and writes artifacts
    args.finish()?;

    let mut session = Session::from_spec(&spec, &registry)?;
    if let Some(every) = ckpt_every {
        let dir = ckpt_dir.unwrap_or_else(|| out.clone());
        session = session.with_checkpoints(CheckpointPolicy::new(every, dir));
    }
    if let Some(path) = resume_path {
        let cp = Checkpoint::load(Path::new(&path))
            .with_context(|| format!("load checkpoint {path}"))?;
        println!("resume: {} from round {} ({})", path, cp.k, cp.engine);
        session = session.resuming_from(cp);
    }
    let params = session.params();
    println!(
        "run: {} on {} — M={} d={} L={:.4e} α={:.4e} β={} ε₁={:.4e} \
         backend={} engine={} participation={} batch={} censor={} codec={}",
        spec.method.name(),
        spec.dataset,
        session.problem().m_workers(),
        session.problem().dim(),
        session.problem().l_global,
        params.alpha,
        params.beta,
        params.epsilon1,
        spec.backend.name(),
        spec.engine.name(),
        spec.participation.name(),
        spec.batch.name(),
        spec.censor.name(),
        spec.codec.name(),
    );
    // resolve f* before the session consumes the problem (obj-err
    // column of the trace CSV; 0 for the nonconvex NN)
    let f_star = session.problem().f_star().unwrap_or(0.0);

    let report = session.run_checked()?;
    if let Some(a) = &report.async_summary {
        println!(
            "async: virtual clock {:.1} ms, max staleness {}",
            a.vclock_us / 1e3,
            report.trace.max_staleness()
        );
    }
    report.write_artifacts(&out, f_star)?;
    let trace = &report.trace;
    let last = trace.iters.last().context("empty trace")?;
    println!(
        "done: {} iters, {} comms, mean participants {:.1}, \
         final f−f* = {:.6e}, ‖∇‖² = {:.6e}",
        trace.iterations(),
        trace.total_comms(),
        trace.mean_participants(),
        last.loss - f_star,
        last.agg_grad_sq
    );
    println!("per-worker transmissions: {:?}", trace.per_worker_comms);
    println!(
        "manifest: {} (rerun with: chb-fed run --spec <that file>)",
        out.join("manifest.json").display()
    );
    Ok(())
}

/// `chb-fed serve`: the coordinator daemon half of a multi-process
/// deployment.  Supports the same checkpoint/resume flags as `run`,
/// which is how a killed server resumes a cohort mid-run.
fn cmd_serve(args: &Args) -> Result<()> {
    let out = Path::new(args.get_or("out", "results")).join("serve");
    let registry = Registry::new(
        Path::new(args.get_or("data", "data")),
        Path::new(args.get_or("artifacts", "artifacts")),
    );
    let bind = args
        .get("bind")
        .context("serve: missing --bind tcp:HOST:PORT | uds:PATH")?;
    let transport = TransportSpec::parse(bind).map_err(anyhow::Error::msg)?;
    let spec = load_spec(args)?;
    let ckpt_every = args.get_parse::<usize>("checkpoint-every")?;
    let ckpt_dir = args.get("checkpoint-dir").map(PathBuf::from);
    let resume_path = args.get("resume").map(str::to_string);
    if ckpt_dir.is_some() && ckpt_every.is_none() {
        bail!("--checkpoint-dir needs --checkpoint-every");
    }
    args.finish()?;

    let mut session = Session::from_spec(&spec, &registry)?;
    if let Some(every) = ckpt_every {
        let dir = ckpt_dir.unwrap_or_else(|| out.clone());
        session = session.with_checkpoints(CheckpointPolicy::new(every, dir));
    }
    if let Some(path) = resume_path {
        let cp = Checkpoint::load(Path::new(&path))
            .with_context(|| format!("load checkpoint {path}"))?;
        println!("resume: {} from round {} ({})", path, cp.k, cp.engine);
        session = session.resuming_from(cp);
    }
    let m = session.problem().m_workers();
    let f_star = session.problem().f_star().unwrap_or(0.0);
    println!(
        "serve: {} on {} at {transport} — waiting for {m} workers",
        spec.method.name(),
        spec.dataset,
    );
    let (report, stats) = session.serve(&transport)?;
    report.write_artifacts(&out, f_star)?;
    let stats_path = out.join("wire_stats.csv");
    atomic_write(&stats_path, &stats.to_csv())
        .with_context(|| format!("write {}", stats_path.display()))?;
    let trace = &report.trace;
    let last = trace.iters.last().context("empty trace")?;
    println!(
        "serve done: {} rounds, {} comms, final loss {:.6e} \
         (retries={} quorum_skips={} reconnects={})",
        trace.iterations(),
        trace.total_comms(),
        last.loss,
        stats.retries,
        stats.quorum_skips,
        stats.reconnects,
    );
    println!("artifacts: {}", out.display());
    Ok(())
}

/// `chb-fed worker`: one cohort member of a multi-process deployment.
fn cmd_worker(args: &Args) -> Result<()> {
    let registry = Registry::new(
        Path::new(args.get_or("data", "data")),
        Path::new(args.get_or("artifacts", "artifacts")),
    );
    let id = args
        .get_parse::<usize>("id")?
        .context("worker: missing --id N")?;
    let connect = args
        .get("connect")
        .context("worker: missing --connect tcp:HOST:PORT | uds:PATH")?;
    let transport =
        TransportSpec::parse(connect).map_err(anyhow::Error::msg)?;
    let spec = load_spec(args)?;
    args.finish()?;

    let session = Session::from_spec(&spec, &registry)?;
    println!("worker {id}: dialing {transport}");
    let stats = session.worker(id, &transport)?;
    println!(
        "worker {id} done: {} rounds, {} commits, {} rollbacks, \
         {} retransmits, {} reconnects",
        stats.rounds,
        stats.commits,
        stats.rollbacks,
        stats.retransmits,
        stats.reconnects,
    );
    Ok(())
}

/// `chb-fed loadgen`: the closed-loop wire throughput harness.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let mut cfg = LoadgenConfig::default();
    // presets model the population cohort shapes: C concurrent wire
    // clients stand in for one sampled cohort out of M devices — a
    // population server's per-round fan-in is the cohort, so that is
    // what the wire must sustain.  Explicit flags override below.
    match args.get("preset") {
        None => {}
        Some("cohort-10k") => {
            cfg.population = 10_000;
            cfg.workers = 100;
            cfg.rounds = 40;
            cfg.dim = 64;
        }
        Some("cohort-100k") => {
            cfg.population = 100_000;
            cfg.workers = 128;
            cfg.rounds = 30;
            cfg.dim = 64;
        }
        Some(other) => {
            bail!("bad --preset {other:?} (cohort-10k|cohort-100k)")
        }
    }
    if let Some(v) = args.get_parse::<u64>("population")? {
        cfg.population = v;
    }
    if let Some(v) = args.get_parse::<usize>("workers")? {
        cfg.workers = v;
    }
    if let Some(v) = args.get_parse::<usize>("rounds")? {
        cfg.rounds = v;
    }
    if let Some(v) = args.get_parse::<usize>("dim")? {
        cfg.dim = v;
    }
    if let Some(v) = args.get_parse::<f64>("chaos-drop")? {
        cfg.wire.chaos.drop = v;
    }
    if let Some(v) = args.get_parse::<f64>("chaos-delay-prob")? {
        cfg.wire.chaos.delay_prob = v;
    }
    if let Some(v) = args.get_parse::<u32>("chaos-delay-ms")? {
        cfg.wire.chaos.delay_ms = v;
    }
    if let Some(v) = args.get_parse::<f64>("chaos-duplicate")? {
        cfg.wire.chaos.duplicate = v;
    }
    if let Some(v) = args.get_parse::<f64>("chaos-corrupt")? {
        cfg.wire.chaos.corrupt = v;
    }
    if let Some(v) = args.get_parse::<u64>("chaos-seed")? {
        cfg.wire.chaos.seed = v;
    }
    let bench_out = args.get("bench-out").map(PathBuf::from);
    args.finish()?;

    let report = run_loadgen(&cfg)?;
    println!("{}", report.summary());
    if let Some(path) = bench_out {
        merge_bench_rows(&path, report.bench_rows())?;
        println!("bench rows merged into {}", path.display());
    }
    Ok(())
}

/// `chb-fed scale`: the population-scale benchmark behind the
/// `scale_*` rows of `BENCH_hotpath.json` — M simulated clients with
/// per-round cohorts of C through the discrete-event cohort engine,
/// measured in simulated rounds/sec and peak RSS.
fn cmd_scale(args: &Args) -> Result<()> {
    let clients = args.get_parse_or("clients", 1_000_000u64)?;
    let cohort = args.get_parse_or("cohort", 256u64)?;
    let rounds = args.get_parse_or("rounds", 20usize)?;
    let dim = args.get_parse_or("dim", 64usize)?;
    let base_m = args.get_parse_or("base-workers", 8usize)?;
    let seed = args.get_parse_or("seed", 0xCA11u64)?;
    let rss_budget_mb = args.get_parse::<u64>("rss-budget-mb")?;
    let bench_out = args.get("bench-out").map(PathBuf::from);
    args.finish()?;

    // synthetic linreg population: W base shards (Arc-shared), client
    // c lazily materializing a worker over shard c mod W — the same
    // construction the Fig. 1/2/3 drivers use, scaled out
    let l_m = chb_fed::data::synthetic::increasing_l(base_m);
    let per_worker = chb_fed::data::synthetic::per_worker_rescaled(
        seed, base_m, 32, dim, &l_m,
    );
    let problem = chb_fed::experiments::Problem::from_worker_datasets(
        TaskKind::LinReg,
        "scale",
        &per_worker,
        0.0,
    );
    // the population objective sums one gradient per client, so its
    // smoothness is ~(M/W)·L_base — α must scale with it or the
    // benchmark diverges at M = 10^6
    let mult = clients.div_ceil(base_m as u64);
    let alpha = 1.0 / (mult as f64 * problem.l_global);
    let spec = RunSpec {
        params: ParamSpec { alpha: Some(alpha), ..Default::default() },
        engine: EngineKind::Async(AsyncConfig {
            compute: ComputeModel::Uniform { us: 1_000.0 },
            latency: LatencyModel::default(),
            max_staleness: None,
        }),
        population: Some(PopulationSpec { clients, cohort, seed }),
        iters: rounds,
        lambda: 0.0,
        ..RunSpec::new(TaskKind::LinReg, "scale")
    };
    println!(
        "scale: {clients} clients, cohort {cohort}, {rounds} rounds, \
         d={dim}, {base_m} base shards, α={alpha:.3e}"
    );
    let session = Session::from_parts(spec, problem)?;
    let t = chb_fed::util::timer::Timer::quiet();
    let report = session.run_checked()?;
    let secs = t.elapsed_secs();

    let done = report.trace.iterations().max(1);
    let per_round_ns = secs * 1e9 / done as f64;
    let summary = report
        .population_summary
        .as_ref()
        .context("population run produced no summary")?;
    let rss_kib = chb_fed::util::mem::peak_rss_kib();
    println!(
        "scale done: {done} rounds in {secs:.2}s ({:.1} rounds/sec), \
         uplinks={} censored={} (censor rate {:.3}), final loss {:.6e}",
        done as f64 / secs.max(1e-9),
        summary.uplinks,
        summary.censored,
        summary.censor_rate(),
        report.trace.final_loss(),
    );
    match rss_kib {
        Some(kib) => println!("peak RSS: {:.1} MiB", kib as f64 / 1024.0),
        None => println!("peak RSS: unavailable (no /proc/self/status)"),
    }
    if let Some(path) = bench_out {
        let row = |name: String, center: f64, samples: f64| {
            let mut o = std::collections::BTreeMap::new();
            o.insert("name".to_string(), Json::Str(name));
            o.insert("median_ns".to_string(), Json::Num(center));
            o.insert("mad_ns".to_string(), Json::Num(0.0));
            o.insert("iters".to_string(), Json::Num(done as f64));
            o.insert("samples".to_string(), Json::Num(samples));
            o.insert("min_ns".to_string(), Json::Num(center));
            o.insert("max_ns".to_string(), Json::Num(center));
            Json::Obj(o)
        };
        let mut rows = vec![row(
            format!("scale_pop_m{clients}_cohort{cohort}_round"),
            per_round_ns,
            done as f64,
        )];
        if let Some(kib) = rss_kib {
            // units abuse by design: the *_rss_kib row carries KiB in
            // the ns slots — the name is the unit
            rows.push(row(
                format!("scale_pop_m{clients}_rss_kib"),
                kib as f64,
                1.0,
            ));
        }
        merge_bench_rows(&path, rows)?;
        println!("bench rows merged into {}", path.display());
    }
    if let Some(budget_mb) = rss_budget_mb {
        let kib = rss_kib
            .context("--rss-budget-mb needs /proc/self/status (Linux only)")?;
        if kib > budget_mb * 1024 {
            bail!(
                "peak RSS {:.1} MiB exceeds the {budget_mb} MiB budget — \
                 population state is no longer O(model + cohort)",
                kib as f64 / 1024.0
            );
        }
        println!("peak RSS within the {budget_mb} MiB budget");
    }
    Ok(())
}

/// Merge bench rows into a `BENCH_hotpath.json`-style array file:
/// rows with the same name are replaced, everything else is kept, and
/// the file is created when absent.  Atomic, so a crash mid-merge
/// never leaves `tools/bench_diff.py` an unparseable file.
fn merge_bench_rows(path: &Path, rows: Vec<Json>) -> Result<()> {
    let mut all: Vec<Json> = match std::fs::read_to_string(path) {
        Ok(text) => Json::parse(&text)
            .with_context(|| format!("parse {}", path.display()))?
            .as_arr()
            .with_context(|| format!("{} is not an array", path.display()))?
            .to_vec(),
        Err(_) => Vec::new(),
    };
    let name_of = |r: &Json| -> Option<String> {
        match r {
            Json::Obj(o) => {
                o.get("name").and_then(|n| n.as_str()).map(str::to_string)
            }
            _ => None,
        }
    };
    let fresh: std::collections::BTreeSet<String> =
        rows.iter().filter_map(&name_of).collect();
    all.retain(|r| name_of(r).is_none_or(|n| !fresh.contains(&n)));
    all.extend(rows);
    atomic_write(path, &(Json::Arr(all).dump_pretty() + "\n"))
        .with_context(|| format!("write {}", path.display()))
}

/// The kick-tires artifact pipeline: run every spec in the examples
/// directory, index each result by its manifest hash, and (in the
/// full profile) regenerate the paper figures/tables.
fn cmd_artifact(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "results"));
    let data = PathBuf::from(args.get_or("data", "data"));
    let specs_dir = PathBuf::from(args.get_or("specs", "examples/specs"));
    let registry = Registry::new(
        &data,
        Path::new(args.get_or("artifacts", "artifacts")),
    );
    let smoke = args.flag("smoke");
    let full = args.flag("full");
    args.finish()?;
    if smoke && full {
        bail!("--smoke and --full are mutually exclusive profiles");
    }

    let mut spec_files: Vec<PathBuf> = std::fs::read_dir(&specs_dir)
        .with_context(|| format!("read specs dir {}", specs_dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "json"))
        .collect();
    spec_files.sort();
    if spec_files.is_empty() {
        bail!("no *.json specs in {}", specs_dir.display());
    }

    let store = out.join("store");
    std::fs::create_dir_all(&store)
        .with_context(|| format!("create {}", store.display()))?;

    let mut index = Vec::new();
    let mut summary = String::from(
        "spec,hash,task,dataset,method,engine,iters,comms,bits_cum,\
         final_loss,seconds\n",
    );
    let mut report_md = String::from(
        "# Artifact store\n\nOne row per example spec; every result \
         directory is keyed by the FNV-1a hash of its exact manifest \
         and rerunnable with `chb-fed run --spec \
         store/<hash>/manifest.json`.\n\n\
         | spec | hash | task | method | engine | iters | comms | bits \
         | final loss |\n\
         |---|---|---|---|---|---|---|---|---|\n",
    );
    for path in &spec_files {
        let stem = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("spec")
            .to_string();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let mut spec = RunSpec::from_json_str(&text)
            .with_context(|| format!("decode {}", path.display()))?;
        if smoke {
            // the CI profile: same specs, clamped budgets — the hash
            // keys the clamped manifest, so smoke and full results
            // never collide in the store
            spec.iters = spec.iters.min(25);
        }
        spec.validate()?;
        let hash = fnv1a64(&spec.to_json_string());
        let dir = store.join(format!("{hash:016x}"));
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let session = Session::from_spec(&spec, &registry)?;
        let f_star = session.problem().f_star().unwrap_or(0.0);
        let t = chb_fed::util::timer::Timer::quiet();
        let report = session
            .run_checked()
            .with_context(|| format!("run {}", path.display()))?;
        let secs = t.elapsed_secs();
        report.write_artifacts(&dir, f_star)?;
        let trace = &report.trace;
        let last = trace.iters.last().context("empty trace")?;
        println!(
            "[{stem}: {} iters, {} comms, {} bits, {secs:.1}s → \
             store/{hash:016x}]",
            trace.iterations(),
            trace.total_comms(),
            last.bits_cum,
        );
        summary.push_str(&format!(
            "{stem},{hash:016x},{},{},{},{},{},{},{},{:.17e},{secs:.3}\n",
            spec.task.name(),
            spec.dataset,
            spec.method.name(),
            spec.engine.name(),
            trace.iterations(),
            trace.total_comms(),
            last.bits_cum,
            last.loss,
        ));
        report_md.push_str(&format!(
            "| {stem} | `{hash:016x}` | {} | {} | {} | {} | {} | {} | \
             {:.6e} |\n",
            spec.task.name(),
            spec.method.name(),
            spec.engine.name(),
            trace.iterations(),
            trace.total_comms(),
            last.bits_cum,
            last.loss,
        ));
        let entry: std::collections::BTreeMap<String, Json> = [
            ("spec".to_string(), Json::Str(stem)),
            ("hash".to_string(), Json::Str(format!("{hash:016x}"))),
            ("dir".to_string(), Json::Str(format!("store/{hash:016x}"))),
            ("task".to_string(), Json::Str(spec.task.name().to_string())),
            ("dataset".to_string(), Json::Str(spec.dataset.clone())),
            (
                "method".to_string(),
                Json::Str(spec.method.name().to_string()),
            ),
            (
                "engine".to_string(),
                Json::Str(spec.engine.name().to_string()),
            ),
            ("iters".to_string(), Json::Num(trace.iterations() as f64)),
            ("comms".to_string(), Json::Num(trace.total_comms() as f64)),
            ("bits_cum".to_string(), Json::Num(last.bits_cum as f64)),
            ("final_loss".to_string(), Json::Num(last.loss)),
        ]
        .into_iter()
        .collect();
        index.push(Json::Obj(entry));
    }
    // the store index is the artifact consumers key on — never leave a
    // torn copy behind if the pipeline dies mid-write
    let index_path = store.join("index.json");
    atomic_write(&index_path, &(Json::Arr(index).dump_pretty() + "\n"))
        .with_context(|| format!("write {}", index_path.display()))?;
    atomic_write(&store.join("summary.csv"), &summary)?;
    atomic_write(&store.join("REPORT.md"), &report_md)?;
    println!(
        "store: {} specs indexed under {}",
        spec_files.len(),
        store.display()
    );

    if full {
        // the real artifact: every paper figure/table at paper-scale
        // budgets, beside the example-spec store
        for id in ALL_EXPERIMENTS {
            run_experiment(id, &out, &data, false)?;
        }
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    println!("datasets (data dir: {}):", args.get_or("data", "data"));
    for s in chb_fed::data::registry::SPECS {
        println!(
            "  {:<12} n={:<6} d={:<4} workers={} ",
            s.name, s.n, s.d, s.workers
        );
    }
    let dir = Path::new(args.get_or("artifacts", "artifacts"));
    match chb_fed::runtime::Manifest::load(dir) {
        Ok(m) => {
            println!("\nartifacts ({}):", dir.display());
            for a in &m.artifacts {
                println!(
                    "  {:<20} n_pad={:<6} d={:<4} θ-dim={}",
                    a.name, a.n_pad, a.d, a.theta_dim
                );
            }
        }
        Err(e) => println!("\nartifacts: unavailable ({e})"),
    }
    println!(
        "\nexperiments: fig1..fig12, table1..table3, ablations, \
         ablation-methods, all (chb-fed exp <id>)"
    );
    Ok(())
}

fn cmd_theory(args: &Args) -> Result<()> {
    let l = args.get_parse_or("l", 10.0)?;
    let mu = args.get_parse_or("mu", 1.0)?;
    let m = args.get_parse_or("m", 9usize)?;
    let delta = args.get_parse_or("delta", 0.1)?;
    let p = chb_fed::theory::ParamChoice::theorem1_setting(l, mu, delta, m);
    println!("Theorem-1 setting (55) for L={l}, μ={mu}, M={m}, δ={delta}:");
    println!("  α  = {:.6e}", p.alpha);
    println!("  β  = {:.6e}", p.beta);
    println!("  ε₁ = {:.6e}", p.epsilon1);
    println!("  η₁ = {:.6e}", p.eta1);
    let ok = p.satisfies_lemma1(l, m);
    println!("  Lemma-1 conditions (10)–(12) with σ₀,σ₁ > 0: {ok}");
    let c = p.contraction(l, mu, m);
    println!(
        "  contraction c = {c:.6e} (eq. 17 predicts {:.6e})",
        chb_fed::theory::theorem1_rate(l, mu, delta)
    );
    println!(
        "  iteration complexity to 1e-6: {:.1} (eq. 59)",
        chb_fed::theory::chb_iteration_complexity(l, mu, delta, 1e-6)
    );
    Ok(())
}
