//! Minimal JSON parser **and serializer** (serde is not on this
//! image) — enough for artifacts/manifest.json, run manifests
//! (`spec::RunSpec`), and result files: objects, arrays, strings,
//! numbers, booleans, null, with full escape handling.
//!
//! [`Json::dump`] / [`Json::dump_pretty`] emit text that
//! [`Json::parse`] reads back to an identical value (round-trip
//! tested): object keys are sorted (`BTreeMap`), numbers use the
//! shortest representation that parses back to the same f64, and
//! non-finite numbers serialize as `null` (JSON has no NaN/∞).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Result};

/// Parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// any JSON number (stored as f64)
    Num(f64),
    /// string with escapes resolved
    Str(String),
    /// array
    Arr(Vec<Json>),
    /// object (sorted keys)
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object member at `key` (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj.str_field("name")?` with a contextual error.
    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))
    }

    /// Convenience: `obj.usize_field("n")?` with a contextual error.
    pub fn usize_field(&self, key: &str) -> Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
    }

    /// Serialize compactly (no whitespace).  Output parses back to an
    /// identical value via [`Json::parse`].
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation — the form the run
    /// manifests are written in, stable enough to diff and to pin as
    /// a golden fixture (keys are sorted, formatting is canonical).
    pub fn dump_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => {
                ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1)))
            }
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&dump_number(*n)),
            Json::Str(s) => dump_string(s, out),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    dump_string(k, out);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

/// Shortest decimal form that parses back to the same f64.  Integral
/// values inside the f64-exact range print without a fraction part
/// (`500`, not `500.0`); non-finite values become `null` (JSON has no
/// NaN/∞ literals).
fn dump_number(n: f64) -> String {
    if !n.is_finite() {
        return "null".to_string();
    }
    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        // 2^53: integers below this are exact in f64
        return format!("{}", n as i64);
    }
    // Rust's {:?} prints the shortest string that round-trips
    format!("{n:?}")
}

fn dump_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (got {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {other:?} at byte {}", self.i),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(text.as_bytes()) {
            self.i += text.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{FFFD}'),
                            );
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] (got {other:?})"),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected , or }} (got {other:?})"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_json() {
        let text = r#"{
            "block_n": 256,
            "artifacts": [
                {"name": "linreg_synth", "n_pad": 50, "d": 50,
                 "args": [{"name": "theta", "shape": [50]}]}
            ]
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.usize_field("block_n").unwrap(), 256);
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].str_field("name").unwrap(), "linreg_synth");
        let args = arts[0].get("args").unwrap().as_arr().unwrap();
        let shape = args[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(50));
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""a\n\"b\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"b\" A"));
    }

    #[test]
    fn numbers_including_exponents() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn dump_round_trips_every_variant() {
        let text = r#"{
            "arr": [1, 2.5, "x", null, true, {"nested": []}],
            "neg": -1.5e-3,
            "int": 500,
            "big": 9e300,
            "esc": "a\n\"b\"\t\\c",
            "empty_obj": {}
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        assert_eq!(Json::parse(&v.dump_pretty()).unwrap(), v);
    }

    #[test]
    fn dump_number_forms() {
        assert_eq!(dump_number(500.0), "500");
        assert_eq!(dump_number(-3.0), "-3");
        assert_eq!(dump_number(0.1), "0.1");
        assert_eq!(dump_number(f64::NAN), "null");
        assert_eq!(dump_number(f64::INFINITY), "null");
        // shortest-round-trip: parse(dump(x)) == x bitwise
        for x in [1.0 / 3.0, 1e-300, 2.0f64.powi(60), 0.30000000000000004] {
            let parsed = Json::parse(&dump_number(x)).unwrap();
            assert_eq!(parsed.as_f64().unwrap().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn dump_pretty_is_stable_and_sorted() {
        let v = Json::parse(r#"{"b": 1, "a": {"z": [1, 2]}}"#).unwrap();
        assert_eq!(
            v.dump_pretty(),
            "{\n  \"a\": {\n    \"z\": [\n      1,\n      2\n    ]\n  },\n  \"b\": 1\n}"
        );
    }

    #[test]
    fn dump_escapes_control_chars() {
        let v = Json::Str("a\u{1}b".into());
        assert_eq!(v.dump(), "\"a\\u0001b\"");
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }
}
