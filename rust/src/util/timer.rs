//! Wallclock timing helpers for the experiment drivers and benches.

use std::time::Instant;

/// Scoped timer: `let _t = Timer::new("phase");` prints on drop.
pub struct Timer {
    label: String,
    start: Instant,
    /// suppress printing (used when the caller only wants elapsed())
    quiet: bool,
}

impl Timer {
    /// Timer that prints "`label`: N.NNNs" when dropped.
    pub fn new(label: &str) -> Self {
        Self { label: label.to_string(), start: Instant::now(), quiet: false }
    }

    /// Timer that never prints (poll `elapsed_secs` instead).
    pub fn quiet() -> Self {
        Self { label: String::new(), start: Instant::now(), quiet: true }
    }

    /// Seconds since construction.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since construction.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
}

impl Drop for Timer {
    fn drop(&mut self) {
        if !self.quiet {
            crate::info!("{}: {:.3}s", self.label, self.elapsed_secs());
        }
    }
}

/// Format a count of seconds compactly (1.23s, 45ms, 12µs).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_nonnegative() {
        let t = Timer::quiet();
        assert!(t.elapsed_secs() >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0025), "2.50ms");
        assert_eq!(fmt_duration(2.5e-6), "2.50µs");
    }
}
