//! Process peak-RSS probe — the memory column of the scale benches.
//!
//! The million-client claims in `BENCH_hotpath.json` are memory
//! claims: the scale rows carry peak resident set size next to
//! rounds/sec so a regression that quietly re-materializes O(M·d)
//! state shows up as numbers, not vibes.  Linux exposes the high-water
//! mark as `VmHWM` in `/proc/self/status`; elsewhere the probe
//! reports `None` and the bench rows simply omit the RSS column.

/// Peak resident set size of this process in KiB (`VmHWM`), or `None`
/// when the platform exposes no `/proc/self/status`.
pub fn peak_rss_kib() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    parse_vm_hwm(&status)
}

/// Extract the `VmHWM` value (KiB) from `/proc/<pid>/status` text.
fn parse_vm_hwm(status: &str) -> Option<u64> {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            // format: "VmHWM:\t  123456 kB"
            return rest
                .split_whitespace()
                .next()
                .and_then(|v| v.parse::<u64>().ok());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_vm_hwm_line() {
        let status = "Name:\tchb-fed\nVmPeak:\t  999 kB\nVmHWM:\t  \
                      123456 kB\nVmRSS:\t  100 kB\n";
        assert_eq!(parse_vm_hwm(status), Some(123_456));
        assert_eq!(parse_vm_hwm("Name:\tchb-fed\n"), None);
    }

    #[test]
    fn probe_reports_a_plausible_value_on_linux() {
        if let Some(kib) = peak_rss_kib() {
            // a test process certainly holds more than 1 MiB and less
            // than 1 TiB resident
            assert!(kib > 1024, "peak RSS {kib} KiB implausibly small");
            assert!(kib < 1 << 30, "peak RSS {kib} KiB implausibly large");
        }
    }
}
