//! TOML-subset config parser (the toml crate is not on this image).
//!
//! Supports the subset the experiment configs use: `[section]`
//! headers, `key = value` with string / number / boolean / inline
//! string-array values, `#` comments.  Keys are addressed as
//! `"section.key"` (top-level keys have no prefix).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// quoted string
    Str(String),
    /// integer or float literal (stored as f64)
    Num(f64),
    /// `true` / `false`
    Bool(bool),
    /// inline array of quoted strings
    StrArr(Vec<String>),
}

/// Flat `section.key → value` map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    /// Parse config text (see the module docs for the subset).
    pub fn parse(text: &str) -> Result<Config> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(body) =
                line.strip_prefix('[').and_then(|s| s.strip_suffix(']'))
            {
                section = body.trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", ln + 1);
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", ln + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            map.insert(
                key,
                parse_value(v.trim())
                    .with_context(|| format!("line {}", ln + 1))?,
            );
        }
        Ok(Config { map })
    }

    /// Parse a config file from disk.
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Config::parse(&text)
    }

    /// Raw value at `"section.key"`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    /// String value at `key` (None on absence or type mismatch).
    pub fn str(&self, key: &str) -> Option<&str> {
        match self.get(key) {
            Some(Value::Str(s)) => Some(s),
            _ => None,
        }
    }

    /// Numeric value at `key` (None on absence or type mismatch).
    pub fn num(&self, key: &str) -> Option<f64> {
        match self.get(key) {
            Some(Value::Num(n)) => Some(*n),
            _ => None,
        }
    }

    /// Boolean value at `key` (None on absence or type mismatch).
    pub fn bool(&self, key: &str) -> Option<bool> {
        match self.get(key) {
            Some(Value::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    /// Numeric value with a default.
    pub fn num_or(&self, key: &str, default: f64) -> f64 {
        self.num(key).unwrap_or(default)
    }

    /// String value with a default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    /// All `"section.key"` keys in sorted order.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string must not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("unterminated string {s:?}");
        };
        return Ok(Value::Str(body.to_string()));
    }
    if let Some(body) = s.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            bail!("unterminated array {s:?}");
        };
        let items: Result<Vec<String>> = body
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| match parse_value(t)? {
                Value::Str(x) => Ok(x),
                other => bail!("array items must be strings, got {other:?}"),
            })
            .collect();
        return Ok(Value::StrArr(items?));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .with_context(|| format!("bad value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
            # experiment config
            name = "fig2"          # trailing comment
            [method]
            alpha = 0.5
            momentum = true
            datasets = ["synth", "ijcnn1"]
        "#;
        let c = Config::parse(text).unwrap();
        assert_eq!(c.str("name"), Some("fig2"));
        assert_eq!(c.num("method.alpha"), Some(0.5));
        assert_eq!(c.bool("method.momentum"), Some(true));
        assert_eq!(
            c.get("method.datasets"),
            Some(&Value::StrArr(vec!["synth".into(), "ijcnn1".into()]))
        );
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(c.str("tag"), Some("a#b"));
    }

    #[test]
    fn defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.num_or("x", 2.5), 2.5);
        assert_eq!(c.str_or("y", "z"), "z");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("x = ").is_err());
        assert!(Config::parse("[]").is_err());
        assert!(Config::parse("a = \"unterminated").is_err());
    }
}
