//! Minimal CLI argument parser (clap is not available on this image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and strict option accounting: every
//! option read through a getter is marked *consumed*, and
//! [`Args::finish`] errors on anything left over.  Historically a
//! typo'd flag (`--itres 500`) was silently treated as a value-taking
//! option — it swallowed the next argument and was then ignored; now
//! it survives parsing but fails `finish()` with a clear message, and
//! a value that itself looks like an option (`--alpha --beta`) is
//! rejected at parse time.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Context, Result};

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// non-option arguments in order of appearance
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// option/flag names a getter has read — `finish()` reports the rest
    consumed: RefCell<BTreeSet<String>>,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{body} needs a value"))?;
                    if v.starts_with("--") {
                        bail!(
                            "--{body} needs a value, got option-like {v:?} \
                             (use --{body}={v} if the value really starts \
                             with --)"
                        );
                    }
                    out.options.insert(body.to_string(), v);
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                bail!("short options not supported: {arg}");
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().insert(name.to_string());
    }

    /// Was the boolean flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        let hit = self.flags.iter().any(|f| f == name);
        if hit {
            self.mark(name);
        }
        hit
    }

    /// Value of option `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        let v = self.options.get(key).map(|s| s.as_str());
        if v.is_some() {
            self.mark(key);
        }
        v
    }

    /// Value of option `--key` with a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse option `--key` into T (None when absent, Err on bad input).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => Ok(Some(
                s.parse::<T>().with_context(|| format!("--{key} {s:?}"))?,
            )),
        }
    }

    /// Parse option `--key` into T with a default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    /// Error unless every given option and flag was consumed by a
    /// getter.  Commands call this after dispatch, so an unknown
    /// option — or a real one that does not apply to the chosen
    /// command/engine (async knobs on `--engine serial`, run flags
    /// next to `--spec`) — fails loudly instead of being ignored.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unused: Vec<String> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k.as_str()))
            .map(|k| format!("--{k}"))
            .collect();
        if unused.is_empty() {
            return Ok(());
        }
        bail!(
            "unknown or unused option(s): {} (unknown options swallow the \
             following argument; check spelling, and check the option \
             applies to this command/engine — see --help)",
            unused.join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(
            argv("run --alpha 0.1 --beta=0.4 --verbose pos1"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["run", "pos1"]);
        assert_eq!(a.get("alpha"), Some("0.1"));
        assert_eq!(a.get("beta"), Some("0.4"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv("--iters 500"), &[]).unwrap();
        assert_eq!(a.get_parse_or::<usize>("iters", 1).unwrap(), 500);
        assert_eq!(a.get_parse_or::<f64>("alpha", 0.5).unwrap(), 0.5);
        assert!(Args::parse(argv("--iters abc"), &[])
            .unwrap()
            .get_parse::<usize>("iters")
            .is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("--alpha"), &[]).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(argv("-- --not-an-option"), &[]).unwrap();
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }

    #[test]
    fn unknown_option_fails_finish() {
        // the historical bug: a typo'd flag swallowed the next token
        // and the run proceeded as if nothing happened
        let a = Args::parse(argv("run --itres 500"), &[]).unwrap();
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--itres"), "{err}");
    }

    #[test]
    fn typod_boolean_flag_fails_finish_instead_of_eating_args() {
        // `--comm-mpa` is not in flag_names, so it grabs "--task"? no:
        // option-like values are rejected at parse time
        assert!(Args::parse(argv("run --comm-mpa --task linreg"), &[
            "comm-map"
        ])
        .is_err());
        // with a non-option token following, it parses but finish()
        // reports it
        let a =
            Args::parse(argv("run --comm-mpa x --task linreg"), &["comm-map"])
                .unwrap();
        assert_eq!(a.get("task"), Some("linreg"));
        assert!(a.finish().unwrap_err().to_string().contains("--comm-mpa"));
    }

    #[test]
    fn unused_declared_option_fails_finish() {
        // a real option that the chosen code path never reads (e.g.
        // async knobs on a sync engine) is reported, not ignored
        let a = Args::parse(argv("run --compute-us 50"), &[]).unwrap();
        assert!(a
            .finish()
            .unwrap_err()
            .to_string()
            .contains("--compute-us"));
    }

    #[test]
    fn unused_flag_fails_finish() {
        let a = Args::parse(argv("run --full"), &["full"]).unwrap();
        assert!(a.finish().unwrap_err().to_string().contains("--full"));
        assert!(a.flag("full"));
        a.finish().unwrap();
    }

    #[test]
    fn option_like_value_rejected_but_eq_form_allowed() {
        assert!(Args::parse(argv("--alpha --beta 3"), &[]).is_err());
        let a = Args::parse(argv("--alpha=--beta"), &[]).unwrap();
        assert_eq!(a.get("alpha"), Some("--beta"));
    }

    #[test]
    fn defaults_do_not_mark_missing_options() {
        let a = Args::parse(argv("--task linreg"), &[]).unwrap();
        // reading an *absent* option with a default must not hide the
        // unused real option
        assert_eq!(a.get_or("dataset", "synth"), "synth");
        assert!(a.finish().is_err());
        assert_eq!(a.get("task"), Some("linreg"));
        a.finish().unwrap();
    }
}
