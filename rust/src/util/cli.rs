//! Minimal CLI argument parser (clap is not available on this image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with typed getters and an automatically assembled
//! usage/help string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    /// non-option arguments in order of appearance
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding the program name).
    /// `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(
        argv: I,
        flag_names: &[&str],
    ) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("--{body} needs a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else if arg.starts_with('-') && arg.len() > 1 {
                bail!("short options not supported: {arg}");
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Was the boolean flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of option `--key`, if given.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Value of option `--key` with a default.
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Parse option `--key` into T (None when absent, Err on bad input).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        match self.get(key) {
            None => Ok(None),
            Some(s) => Ok(Some(
                s.parse::<T>().with_context(|| format!("--{key} {s:?}"))?,
            )),
        }
    }

    /// Parse option `--key` into T with a default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::error::Error + Send + Sync + 'static,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_mixed_forms() {
        let a = Args::parse(
            argv("run --alpha 0.1 --beta=0.4 --verbose pos1"),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["run", "pos1"]);
        assert_eq!(a.get("alpha"), Some("0.1"));
        assert_eq!(a.get("beta"), Some("0.4"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(argv("--iters 500"), &[]).unwrap();
        assert_eq!(a.get_parse_or::<usize>("iters", 1).unwrap(), 500);
        assert_eq!(a.get_parse_or::<f64>("alpha", 0.5).unwrap(), 0.5);
        assert!(Args::parse(argv("--iters abc"), &[])
            .unwrap()
            .get_parse::<usize>("iters")
            .is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(argv("--alpha"), &[]).is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = Args::parse(argv("-- --not-an-option"), &[]).unwrap();
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
