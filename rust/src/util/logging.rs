//! Tiny leveled logger writing to stderr (the log crate facade exists
//! on the image, but a self-contained logger keeps the binary free of
//! global-initializer ordering concerns).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// unrecoverable problems
    Error = 0,
    /// suspicious but non-fatal conditions
    Warn = 1,
    /// normal progress reporting (the default threshold)
    Info = 2,
    /// verbose diagnostics (`--verbose`)
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global threshold: messages above `level` are suppressed.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Would a message at `level` currently be printed?
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Write one message to stderr if `level` passes the threshold
/// (prefer the [`info!`](crate::info)/[`warn_log!`](crate::warn_log)/
/// [`debug_log!`](crate::debug_log) macros).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

/// Log at Info level with `format!` syntax.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            format_args!($($t)*),
        )
    };
}

/// Log at Warn level with `format!` syntax.
#[macro_export]
macro_rules! warn_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            format_args!($($t)*),
        )
    };
}

/// Log at Debug level with `format!` syntax.
#[macro_export]
macro_rules! debug_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            format_args!($($t)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
