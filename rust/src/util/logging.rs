//! Tiny leveled logger writing to stderr (the log crate facade exists
//! on the image, but a self-contained logger keeps the binary free of
//! global-initializer ordering concerns).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            format_args!($($t)*),
        )
    };
}

#[macro_export]
macro_rules! warn_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            format_args!($($t)*),
        )
    };
}

#[macro_export]
macro_rules! debug_log {
    ($($t:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            format_args!($($t)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
