//! Small substrates built from scratch (the image has no clap / serde
//! / toml crates): CLI parsing, a TOML-subset config reader, a JSON
//! parser (for artifacts/manifest.json), logging, and timing.

pub mod cli;
pub mod config;
pub mod json;
pub mod logging;
pub mod mem;
pub mod timer;
