//! # chb-fed — Communication-Efficient Federated Learning with Censored Heavy Ball
//!
//! Production-grade reproduction of Chen, Blum & Sadler,
//! *"Communication-Efficient Federated Learning Using Censored Heavy
//! Ball Descent"* (2022): a server–worker federated runtime in rust
//! (Layer 3) whose per-worker gradients are AOT-compiled JAX/Pallas
//! programs executed through PJRT (Layers 1–2), plus a pure-rust f64
//! backend mirroring the same math.
//!
//! Quick tour (see README.md for the full map, and ARCHITECTURE.md at
//! the repository root for the paper-equation ↔ module correspondence):
//! * [`spec`] — the declarative run layer: one serializable
//!   [`spec::RunSpec`] describes a complete run (method, censor,
//!   engine, participation, batching, compression, drops, stop rule),
//!   one [`spec::Session`] executes it; every run writes a rerunnable
//!   `manifest.json`.
//! * [`optim`] — GD / HB / LAG-WK / CHB update + censor rules (the
//!   paper's Algorithm 1).
//! * [`coordinator`] — the federated round engines (synchronous pools
//!   and the asynchronous discrete-event engine) behind one
//!   [`coordinator::EngineKind`] dispatch, and comm accounting.
//! * [`wire`] — the same round protocol over real sockets: a
//!   `chb-fed serve` daemon, `chb-fed worker` clients, a versioned
//!   CRC-framed codec, seeded chaos injection, and quorum/retry
//!   supervision (loopback runs are bit-identical to serial).
//! * [`checkpoint`] — versioned, atomically-written run snapshots
//!   with bit-identical resume, plus the fault-injection plan
//!   ([`coordinator::FaultPlan`]) they are tested against.
//! * [`runtime`] — PJRT artifact loading/execution.
//! * [`experiments`] — one driver per paper figure/table.
//! * [`theory`] — the paper's parameter conditions (10)–(12), rate
//!   predictions, and Lemma 2 bounds as executable checks.

#![warn(missing_docs)]

pub mod bench;
pub mod checkpoint;
pub mod compress;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod optim;
pub mod rng;
pub mod runtime;
pub mod spec;
pub mod tasks;
pub mod testing;
pub mod theory;
pub mod util;
pub mod wire;
