//! The gradient-sampling subsystem: which rows of its shard a worker
//! visits at round k.
//!
//! The paper validates CHB on deterministic full-shard gradients; its
//! nearest neighbors — CSGD (*Communication-Censored Distributed
//! Stochastic Gradient Descent*, Li et al.) and LAG (Chen et al.) —
//! show the censoring question changes character under stochastic
//! gradients.  This module supplies the sampling side of that regime:
//!
//! * [`BatchSchedule`] — the policy (full shard, fixed-size minibatch
//!   with or without replacement, or a CSGD-style geometrically
//!   growing batch), shared by every worker of a run.
//! * [`BatchSampler`] — one per worker: materializes the policy into
//!   concrete row-index slices, deterministically per
//!   `(worker, seed, k)` and **independent of any pool interleaving
//!   or engine choice** (each draw re-seeds a fresh xoshiro stream
//!   from a hash of the triple, so no sampler state leaks between
//!   rounds).
//!
//! `BatchSchedule::Full` never draws at all — the worker takes the
//! legacy full-shard kernel path, bit-for-bit
//! (`tests/batch_equivalence.rs` pins this across all four tasks and
//! all four engines).

use crate::rng::{SplitMix64, Xoshiro256};

/// Which rows of its shard a worker's gradient visits each round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchSchedule {
    /// the paper's deterministic regime: every real row, every round
    /// (bit-identical to the pre-batching code path)
    Full,
    /// fixed-size minibatch, redrawn every round from a per-worker
    /// seeded stream
    Minibatch {
        /// rows per batch (clamped to `[1, n_real]`)
        size: usize,
        /// master seed for the per-(worker, round) draw streams
        seed: u64,
        /// true: i.i.d. draws (duplicates allowed); false: without
        /// replacement (a uniform `size`-subset)
        replace: bool,
    },
    /// CSGD-style variance control: batch size grows geometrically,
    /// `⌈size₀·growth^(k−1)⌉`, saturating at the full shard (where the
    /// worker falls back to the legacy full-batch kernel)
    GrowingBatch {
        /// batch size at k = 1
        size0: usize,
        /// per-round geometric growth factor (≥ 1)
        growth: f64,
        /// master seed for the per-(worker, round) draw streams
        seed: u64,
    },
}

impl BatchSchedule {
    /// Short label for logs and CSV columns.
    pub fn name(&self) -> &'static str {
        match self {
            BatchSchedule::Full => "full",
            BatchSchedule::Minibatch { .. } => "minibatch",
            BatchSchedule::GrowingBatch { .. } => "growing",
        }
    }

    /// Nominal batch size at round `k` over an `n`-row shard.  Capped
    /// at `n` for without-replacement draws; an i.i.d.
    /// (with-replacement) minibatch may oversample the shard.
    pub fn size_at(&self, k: usize, n: usize) -> usize {
        match *self {
            BatchSchedule::Full => n,
            BatchSchedule::Minibatch { size, replace: true, .. } => {
                size.max(1)
            }
            BatchSchedule::Minibatch { size, replace: false, .. } => {
                size.clamp(1, n.max(1))
            }
            BatchSchedule::GrowingBatch { size0, growth, .. } => {
                let e = k.saturating_sub(1).min(i32::MAX as usize) as i32;
                let s = (size0.max(1) as f64) * growth.powi(e);
                if s >= n as f64 {
                    n
                } else {
                    (s.ceil() as usize).clamp(1, n.max(1))
                }
            }
        }
    }

    /// Fraction of the shard visited at round `k`, clamped to (0, 1]
    /// — the variance proxy
    /// [`crate::optim::censor::VarianceScaledCensor`] scales ε₁ by
    /// (variance compensation saturates at the full batch, so an
    /// oversampling with-replacement draw clamps here even though the
    /// trace's `batch_frac` column reports the raw `|B|/n`).
    pub fn fraction_at(&self, k: usize, n: usize) -> f64 {
        if n == 0 {
            return 1.0;
        }
        (self.size_at(k, n) as f64 / n as f64).min(1.0)
    }
}

/// Hash of the `(seed, worker, k)` triple into one draw-stream seed —
/// three chained SplitMix64 finalizers, so every coordinate fully
/// avalanches and draws are a pure function of the triple.
fn draw_seed(seed: u64, worker: usize, k: usize) -> u64 {
    let a = SplitMix64::new(seed).next_u64();
    let b = SplitMix64::new(a ^ worker as u64).next_u64();
    SplitMix64::new(b ^ k as u64).next_u64()
}

/// One worker's materialized batch stream.
///
/// Owns two reusable index buffers, so steady-state draws allocate
/// nothing.  Each [`BatchSampler::draw`] is deterministic per
/// `(worker, schedule seed, k)` — no state carries between rounds, so
/// an async engine that skips server versions, or a pool that
/// interleaves workers arbitrarily, still reproduces the serial draws
/// exactly.
pub struct BatchSampler {
    schedule: BatchSchedule,
    worker: usize,
    n_rows: usize,
    /// partial-Fisher–Yates scratch (without-replacement draws)
    perm: Vec<u32>,
    /// the drawn batch, ascending (cache-friendly row sweeps)
    idx: Vec<u32>,
}

impl BatchSampler {
    /// Sampler for worker `worker` over an `n_rows`-row shard.
    ///
    /// Panics when a non-full schedule is paired with a backend that
    /// reports no rows (`n_rows == 0`) — there is nothing to sample.
    pub fn new(schedule: BatchSchedule, worker: usize, n_rows: usize) -> Self {
        assert!(
            n_rows > 0 || schedule == BatchSchedule::Full,
            "worker {worker}: a {} schedule needs a row-indexed \
             objective (backend reported 0 rows)",
            schedule.name()
        );
        Self { schedule, worker, n_rows, perm: Vec::new(), idx: Vec::new() }
    }

    /// The schedule this sampler materializes.
    pub fn schedule(&self) -> BatchSchedule {
        self.schedule
    }

    /// Row universe size n_real.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Draw round k's row set.  `None` means "the full shard" — the
    /// caller takes the legacy full-batch kernel path (this is what
    /// makes `Full` bit-identical, and what a saturated
    /// [`BatchSchedule::GrowingBatch`] degenerates to).
    pub fn draw(&mut self, k: usize) -> Option<&[u32]> {
        let (seed, replace) = match self.schedule {
            BatchSchedule::Full => return None,
            BatchSchedule::Minibatch { seed, replace, .. } => (seed, replace),
            BatchSchedule::GrowingBatch { seed, .. } => (seed, false),
        };
        let n = self.n_rows;
        let b = self.schedule.size_at(k, n);
        if b >= n && !replace {
            // a without-replacement draw of all n rows IS the full
            // shard: use the (cheaper, bit-pinned) full kernel
            return None;
        }
        let mut rng = Xoshiro256::new(draw_seed(seed, self.worker, k));
        self.idx.clear();
        if replace {
            for _ in 0..b {
                self.idx.push(rng.next_below(n as u64) as u32);
            }
        } else {
            // identity-reset + partial Fisher–Yates: O(n) per draw,
            // noise next to the O(b·d) gradient it feeds
            self.perm.clear();
            self.perm.extend(0..n as u32);
            for i in 0..b {
                let j = i + rng.next_below((n - i) as u64) as usize;
                self.perm.swap(i, j);
            }
            self.idx.extend_from_slice(&self.perm[..b]);
        }
        self.idx.sort_unstable();
        Some(&self.idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_schedule_never_draws() {
        let mut s = BatchSampler::new(BatchSchedule::Full, 0, 100);
        for k in 1..=5 {
            assert!(s.draw(k).is_none());
        }
        // Full works even with an empty row universe (toy backends)
        let mut s0 = BatchSampler::new(BatchSchedule::Full, 3, 0);
        assert!(s0.draw(1).is_none());
    }

    #[test]
    fn draws_are_a_pure_function_of_worker_seed_and_k() {
        let sched =
            BatchSchedule::Minibatch { size: 8, seed: 0xFEED, replace: false };
        let mut a = BatchSampler::new(sched, 2, 40);
        let mut b = BatchSampler::new(sched, 2, 40);
        // draw in different round orders: results per k must match
        let ka: Vec<Vec<u32>> = [1, 2, 3, 4, 5]
            .iter()
            .map(|&k| a.draw(k).unwrap().to_vec())
            .collect();
        let kb: Vec<Vec<u32>> = [5, 3, 1, 2, 4]
            .iter()
            .map(|&k| b.draw(k).unwrap().to_vec())
            .collect();
        assert_eq!(ka[0], kb[2]); // k = 1
        assert_eq!(ka[1], kb[3]); // k = 2
        assert_eq!(ka[2], kb[1]); // k = 3
        assert_eq!(ka[3], kb[4]); // k = 4
        assert_eq!(ka[4], kb[0]); // k = 5
        // distinct rounds draw distinct sets (overwhelmingly)
        assert_ne!(ka[0], ka[1]);
    }

    #[test]
    fn workers_and_seeds_decorrelate_draws() {
        let sched =
            BatchSchedule::Minibatch { size: 8, seed: 7, replace: false };
        let mut w0 = BatchSampler::new(sched, 0, 64);
        let mut w1 = BatchSampler::new(sched, 1, 64);
        assert_ne!(w0.draw(1).unwrap(), w1.draw(1).unwrap());
        let sched2 =
            BatchSchedule::Minibatch { size: 8, seed: 8, replace: false };
        let mut s2 = BatchSampler::new(sched2, 0, 64);
        let mut s7 = BatchSampler::new(sched, 0, 64);
        assert_ne!(s7.draw(1).unwrap(), s2.draw(1).unwrap());
    }

    #[test]
    fn without_replacement_draws_are_distinct_sorted_in_range() {
        let sched =
            BatchSchedule::Minibatch { size: 10, seed: 3, replace: false };
        let mut s = BatchSampler::new(sched, 1, 25);
        for k in 1..=50 {
            let rows = s.draw(k).unwrap().to_vec();
            assert_eq!(rows.len(), 10);
            assert!(rows.windows(2).all(|w| w[0] < w[1]), "k={k}: {rows:?}");
            assert!(rows.iter().all(|&i| (i as usize) < 25));
        }
    }

    #[test]
    fn with_replacement_allows_duplicates_and_stays_in_range() {
        let sched =
            BatchSchedule::Minibatch { size: 40, seed: 5, replace: true };
        let mut s = BatchSampler::new(sched, 0, 6);
        let mut saw_dup = false;
        for k in 1..=20 {
            let rows = s.draw(k).unwrap();
            assert_eq!(rows.len(), 40);
            assert!(rows.iter().all(|&i| (i as usize) < 6));
            assert!(rows.windows(2).all(|w| w[0] <= w[1]));
            saw_dup |= rows.windows(2).any(|w| w[0] == w[1]);
        }
        assert!(saw_dup, "40 draws from 6 rows never collided");
    }

    #[test]
    fn oversized_minibatch_without_replacement_is_full_batch() {
        let sched =
            BatchSchedule::Minibatch { size: 99, seed: 1, replace: false };
        let mut s = BatchSampler::new(sched, 0, 10);
        assert!(s.draw(1).is_none());
    }

    #[test]
    fn growing_batch_sizes_are_geometric_and_saturate() {
        let sched =
            BatchSchedule::GrowingBatch { size0: 2, growth: 2.0, seed: 9 };
        assert_eq!(sched.size_at(1, 100), 2);
        assert_eq!(sched.size_at(2, 100), 4);
        assert_eq!(sched.size_at(3, 100), 8);
        assert_eq!(sched.size_at(7, 100), 100); // 128 → clamp
        let mut s = BatchSampler::new(sched, 0, 100);
        assert_eq!(s.draw(1).unwrap().len(), 2);
        assert_eq!(s.draw(4).unwrap().len(), 16);
        // saturated: the full-batch kernel takes over
        assert!(s.draw(7).is_none());
        // fraction column tracks the size
        assert!((sched.fraction_at(2, 100) - 0.04).abs() < 1e-15);
        assert!((sched.fraction_at(50, 100) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn huge_growth_exponent_does_not_overflow() {
        let sched =
            BatchSchedule::GrowingBatch { size0: 1, growth: 1.5, seed: 0 };
        // powi on a huge exponent gives +inf; size must clamp to n
        assert_eq!(sched.size_at(usize::MAX, 1_000), 1_000);
    }

    #[test]
    #[should_panic(expected = "row-indexed")]
    fn non_full_schedule_with_no_rows_panics() {
        let sched =
            BatchSchedule::Minibatch { size: 4, seed: 0, replace: false };
        let _ = BatchSampler::new(sched, 0, 0);
    }
}
