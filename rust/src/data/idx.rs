//! IDX (MNIST) binary format parser.
//!
//! Magic: 0x00 0x00 <dtype> <ndims>, big-endian dims, then raw data.
//! Only the u8 dtype (0x08) is needed for MNIST images/labels.

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Matrix;

use super::Dataset;

/// Parsed IDX tensor of u8.
pub struct IdxU8 {
    /// tensor shape (dims[0] = item count)
    pub dims: Vec<usize>,
    /// flattened payload bytes
    pub data: Vec<u8>,
}

/// Read an IDX u8 tensor from any reader.
pub fn parse_u8<R: Read>(mut r: R) -> Result<IdxU8> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("read magic")?;
    if magic[0] != 0 || magic[1] != 0 {
        bail!("bad IDX magic {magic:?}");
    }
    if magic[2] != 0x08 {
        bail!("unsupported IDX dtype 0x{:02x} (want u8)", magic[2]);
    }
    let ndims = magic[3] as usize;
    if ndims == 0 || ndims > 4 {
        bail!("unreasonable IDX ndims {ndims}");
    }
    let mut dims = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        let mut b = [0u8; 4];
        r.read_exact(&mut b).context("read dim")?;
        dims.push(u32::from_be_bytes(b) as usize);
    }
    let total: usize = dims.iter().product();
    let mut data = vec![0u8; total];
    r.read_exact(&mut data).context("read payload")?;
    Ok(IdxU8 { dims, data })
}

/// Combine an images file (n×28×28) and a labels file (n) into a
/// Dataset: pixels scaled to [0,1], labels mapped to ±1 by parity
/// (even digit → +1) to match the binary tasks in the experiments.
pub fn load_mnist(images: &Path, labels: &Path) -> Result<Dataset> {
    let img = parse_u8(
        std::fs::File::open(images)
            .with_context(|| format!("open {}", images.display()))?,
    )?;
    let lab = parse_u8(
        std::fs::File::open(labels)
            .with_context(|| format!("open {}", labels.display()))?,
    )?;
    if img.dims.len() != 3 {
        bail!("images: want 3 dims, got {:?}", img.dims);
    }
    if lab.dims.len() != 1 || lab.dims[0] != img.dims[0] {
        bail!("labels: dims {:?} vs images {:?}", lab.dims, img.dims);
    }
    let n = img.dims[0];
    let d = img.dims[1] * img.dims[2];
    let mut x = Matrix::zeros(n, d);
    for i in 0..n {
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = img.data[i * d + j] as f64 / 255.0;
        }
    }
    let y = lab
        .data
        .iter()
        .map(|&v| if v % 2 == 0 { 1.0 } else { -1.0 })
        .collect();
    Ok(Dataset {
        x,
        y,
        source: format!("{} + {}", images.display(), labels.display()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx_bytes(dims: &[u32], payload: &[u8]) -> Vec<u8> {
        let mut b = vec![0, 0, 0x08, dims.len() as u8];
        for d in dims {
            b.extend_from_slice(&d.to_be_bytes());
        }
        b.extend_from_slice(payload);
        b
    }

    #[test]
    fn parses_vector_and_tensor() {
        let v = parse_u8(&idx_bytes(&[3], &[1, 2, 3])[..]).unwrap();
        assert_eq!(v.dims, vec![3]);
        assert_eq!(v.data, vec![1, 2, 3]);
        let t = parse_u8(&idx_bytes(&[2, 2, 2], &[0; 8])[..]).unwrap();
        assert_eq!(t.dims, vec![2, 2, 2]);
    }

    #[test]
    fn rejects_bad_magic_and_dtype() {
        assert!(parse_u8(&[1, 0, 8, 1, 0, 0, 0, 0][..]).is_err());
        assert!(parse_u8(&[0, 0, 0x0D, 1, 0, 0, 0, 0][..]).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let b = idx_bytes(&[10], &[1, 2, 3]);
        assert!(parse_u8(&b[..]).is_err());
    }
}
