//! Named-dataset registry — the single source of dataset truth.
//!
//! Mirrors python/compile/aot.py `DATASETS` (names, shapes, worker
//! counts) so artifact shapes always match shard shapes.  Each entry
//! loads the genuine file from `data/` when present and otherwise
//! falls back to a deterministic synthetic stand-in of identical shape
//! (DESIGN.md §3 documents each substitution).

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::rng::Xoshiro256;

use super::{idx, libsvm, synthetic, Dataset};

/// Static description of one registry entry (mirror of aot.py).
#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    /// registry key ("synth", "ijcnn1", …)
    pub name: &'static str,
    /// sample count
    pub n: usize,
    /// feature count used by the experiments (after the paper's
    /// min-feature truncation for the §IV-B small datasets)
    pub d: usize,
    /// native feature count of the real file, pre-truncation
    pub d_native: usize,
    /// the paper's worker count M for this dataset
    pub workers: usize,
}

/// All datasets the experiments use; `d` matches aot.py exactly.
pub const SPECS: &[DatasetSpec] = &[
    DatasetSpec { name: "synth", n: 450, d: 50, d_native: 50, workers: 9 },
    DatasetSpec { name: "ijcnn1", n: 49_990, d: 22, d_native: 22, workers: 9 },
    DatasetSpec { name: "mnist", n: 60_000, d: 784, d_native: 784, workers: 9 },
    DatasetSpec { name: "housing", n: 506, d: 8, d_native: 13, workers: 3 },
    DatasetSpec { name: "bodyfat", n: 252, d: 8, d_native: 14, workers: 3 },
    DatasetSpec { name: "abalone", n: 4_177, d: 8, d_native: 8, workers: 3 },
    DatasetSpec { name: "ionosphere", n: 351, d: 14, d_native: 34, workers: 3 },
    DatasetSpec { name: "adult", n: 1_605, d: 14, d_native: 14, workers: 3 },
    DatasetSpec { name: "derm", n: 366, d: 14, d_native: 34, workers: 3 },
];

/// Look a dataset spec up by name.
pub fn spec(name: &str) -> Result<&'static DatasetSpec> {
    SPECS
        .iter()
        .find(|s| s.name == name)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {name:?}"))
}

/// Stable per-dataset seed so stand-ins are reproducible and distinct.
fn seed_for(name: &str) -> u64 {
    // FNV-1a over the name, mixed with a project constant.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h ^ 0xC0FF_EE00_5EED_0001
}

/// Load a dataset by name: real file from `data_dir` when present,
/// deterministic synthetic stand-in otherwise.
pub fn load(name: &str, data_dir: &Path) -> Result<Dataset> {
    let s = spec(name)?;
    if let Some(ds) = try_load_real(s, data_dir)? {
        return Ok(truncate(ds, s));
    }
    Ok(stand_in(s))
}

fn truncate(ds: Dataset, s: &DatasetSpec) -> Dataset {
    if ds.d() > s.d {
        ds.truncate_features(s.d)
    } else {
        ds
    }
}

fn try_load_real(s: &DatasetSpec, dir: &Path) -> Result<Option<Dataset>> {
    if s.name == "mnist" {
        let img: PathBuf = dir.join("train-images-idx3-ubyte");
        let lab: PathBuf = dir.join("train-labels-idx1-ubyte");
        if img.exists() && lab.exists() {
            return Ok(Some(idx::load_mnist(&img, &lab)?));
        }
        return Ok(None);
    }
    // libsvm-format file named after the dataset
    for cand in [dir.join(s.name), dir.join(format!("{}.txt", s.name))] {
        if cand.exists() {
            let ds = libsvm::load(&cand, s.d_native)?;
            if ds.n() == 0 {
                bail!("{}: empty file", cand.display());
            }
            return Ok(Some(ds));
        }
    }
    Ok(None)
}

/// The synthetic stand-in for a registry entry (DESIGN.md §3).
///
/// Raw real-world feature matrices are ill-conditioned (feature
/// scales span decades), which is what makes GD slow, momentum
/// valuable, and gradients anisotropic enough for censoring to pay
/// off.  Every stand-in therefore gets a geometric column scaling
/// (condition ≈ spread² on the Gram matrix) — without it the paper's
/// comparisons collapse (a whitened Gaussian converges in ~10 GD
/// steps and nothing censors).
pub fn stand_in(s: &DatasetSpec) -> Dataset {
    let mut rng = Xoshiro256::new(seed_for(s.name));
    let mut ds = match s.name {
        // class-structured, like digit data
        "mnist" => synthetic::blobs_pm1(&mut rng, s.n, s.d, 10),
        // regression targets for the linreg trio (labels generated
        // *after* the column scaling below, from the scaled features)
        "housing" | "bodyfat" | "abalone" => {
            synthetic::gaussian_pm1(&mut rng, s.n, s.d)
        }
        // ±1-labelled feature clouds for the classification sets
        _ => synthetic::gaussian_pm1(&mut rng, s.n, s.d),
    };
    // Ill-conditioning: Gram condition ≈ spread².  Per-dataset values
    // are chosen so GD's iteration count at α ≈ 1/L lands in the range
    // the paper reports for the real dataset (Table I: ~200 iters on
    // ijcnn1; Table II: 10²–10³ on the UCI sets; Table III: far from
    // converged after 2000 iters on MNIST).
    let spread = match s.name {
        "synth" => 1.0, // the paper defines this one: whitened normal
        "ijcnn1" => 4.0,
        "mnist" => 30.0,
        _ => 8.0,
    };
    if spread > 1.0 {
        synthetic::scale_columns(&mut ds.x, spread);
    }
    if matches!(s.name, "housing" | "bodyfat" | "abalone") {
        // regression labels from the scaled features + noise
        let theta_star: Vec<f64> = rng.gaussian_vec(s.d);
        let scale = 1.0
            / crate::tasks::smoothness::lambda_max_xtx(&ds.x).sqrt().max(1e-12);
        let mut y = vec![0.0; s.n];
        ds.x.gemv(&theta_star, &mut y);
        for v in &mut y {
            *v = *v * scale + 0.05 * rng.next_gaussian();
        }
        ds.y = y;
    }
    ds.source = format!("synthetic {} stand-in ({}x{})", s.name, s.n, s.d);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_spec_loads_with_right_shape() {
        for s in SPECS {
            // skip mnist here (covered separately; it is the slow one)
            if s.name == "mnist" {
                continue;
            }
            let ds = load(s.name, Path::new("/nonexistent")).unwrap();
            assert_eq!(ds.n(), s.n, "{}", s.name);
            assert_eq!(ds.d(), s.d, "{}", s.name);
        }
    }

    #[test]
    fn unknown_name_errors() {
        assert!(load("nope", Path::new(".")).is_err());
    }

    #[test]
    fn stand_ins_are_deterministic_and_distinct() {
        let a = stand_in(spec("ijcnn1").unwrap());
        let b = stand_in(spec("ijcnn1").unwrap());
        assert_eq!(a.x.data[..20], b.x.data[..20]);
        let c = stand_in(spec("derm").unwrap());
        assert_ne!(a.x.data[..5], c.x.data[..5]);
    }

    #[test]
    fn real_file_wins_over_stand_in() {
        let dir = std::env::temp_dir().join("chb_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        // a miniature "derm" in libsvm format — wrong n, but real files win
        std::fs::write(dir.join("derm"), "1 1:1\n-1 2:1\n").unwrap();
        let ds = load("derm", &dir).unwrap();
        assert!(ds.source.contains("derm"));
        assert_eq!(ds.n(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spec_shapes_match_aot_manifest_protocol() {
        use crate::data::padded_n;
        // these pairs are asserted against artifacts/manifest.json by
        // the integration test; here just pin the arithmetic
        let s = spec("ijcnn1").unwrap();
        assert_eq!(padded_n(s.n.div_ceil(s.workers)), 5632);
        let s = spec("synth").unwrap();
        assert_eq!(padded_n(s.n.div_ceil(s.workers)), 50);
    }
}
