//! Shard construction: even split across workers + zero padding.
//!
//! The paper: "All samples are evenly split between nine workers."
//! Each shard is padded to a single common row count so every worker
//! shares one AOT artifact shape (aot.py's `per_worker_padded`).

use std::sync::Arc;

use crate::linalg::Matrix;

use super::{padded_n, Dataset, Shard};

/// Split `ds` evenly across `m` workers; worker i gets rows
/// i, i+m, i+2m, … (round-robin keeps shard row counts within 1 of
/// each other and mixes any ordering in the source file).  All shards
/// are padded to the same `padded_n(ceil(n/m))` rows.
pub fn split_even(ds: &Dataset, m: usize) -> Vec<Shard> {
    assert!(m > 0, "need at least one worker");
    let n = ds.n();
    let d = ds.d();
    let n_max = n.div_ceil(m);
    let n_pad = padded_n(n_max);
    (0..m)
        .map(|w| {
            let rows: Vec<usize> = (w..n).step_by(m).collect();
            let mut x = Matrix::zeros(n_pad, d);
            let mut y = vec![0.0; n_pad];
            let mut mask = vec![0.0; n_pad];
            for (i, &src) in rows.iter().enumerate() {
                x.row_mut(i).copy_from_slice(ds.x.row(src));
                y[i] = ds.y[src];
                mask[i] = 1.0;
            }
            Shard {
                x: Arc::new(x),
                y: Arc::new(y),
                mask: Arc::new(mask),
                n_real: rows.len(),
            }
        })
        .collect()
}

/// A single shard holding the whole dataset, unpadded (tests, M=1).
pub fn shard_whole(ds: &Dataset) -> Shard {
    Shard {
        x: Arc::new(ds.x.clone()),
        y: Arc::new(ds.y.clone()),
        mask: Arc::new(vec![1.0; ds.n()]),
        n_real: ds.n(),
    }
}

/// Wrap pre-partitioned per-worker datasets (the Fig. 1/2 synthetic
/// protocol where each worker's data is generated directly).
pub fn shards_from_datasets(per_worker: &[Dataset]) -> Vec<Shard> {
    per_worker.iter().map(shard_whole).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::rng::Xoshiro256;

    #[test]
    fn split_covers_every_row_exactly_once() {
        let mut rng = Xoshiro256::new(20);
        let ds = synthetic::gaussian_pm1(&mut rng, 103, 4);
        let shards = split_even(&ds, 9);
        assert_eq!(shards.len(), 9);
        let total: usize = shards.iter().map(|s| s.n_real).sum();
        assert_eq!(total, 103);
        // every shard same padded height
        let n_pad = shards[0].n_pad();
        assert!(shards.iter().all(|s| s.n_pad() == n_pad));
        // row-level reconstruction: sum of masked y equals sum of ds.y
        let got: f64 = shards
            .iter()
            .flat_map(|s| s.y.iter().zip(s.mask.iter()).map(|(y, m)| y * m))
            .sum();
        let want: f64 = ds.y.iter().sum();
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn shard_sizes_differ_by_at_most_one() {
        let mut rng = Xoshiro256::new(21);
        let ds = synthetic::gaussian_pm1(&mut rng, 49_990 % 1000, 3);
        let shards = split_even(&ds, 9);
        let min = shards.iter().map(|s| s.n_real).min().unwrap();
        let max = shards.iter().map(|s| s.n_real).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn padding_rows_are_zero_with_zero_mask() {
        let mut rng = Xoshiro256::new(22);
        let ds = synthetic::gaussian_pm1(&mut rng, 10, 3);
        let shards = split_even(&ds, 3);
        for s in &shards {
            for i in s.n_real..s.n_pad() {
                assert_eq!(s.mask[i], 0.0);
                assert_eq!(s.y[i], 0.0);
                assert!(s.x.row(i).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn ijcnn1_shapes_match_aot_manifest() {
        // 49 990 over 9 workers → ceil = 5555 + pad → 5632 (aot.py)
        assert_eq!(padded_n(49_990usize.div_ceil(9)), 5632);
        // mnist: 60 000 / 9 → 6667 → 6912
        assert_eq!(padded_n(60_000usize.div_ceil(9)), 6912);
    }
}
