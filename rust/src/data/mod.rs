//! Dataset substrate: generation, parsing, partitioning.
//!
//! The experiments in the paper use one synthetic protocol and eight
//! "real" datasets.  This image has no network access, so each real
//! dataset has a synthetic stand-in with identical shape and the same
//! per-worker smoothness structure (DESIGN.md §3); if the genuine file
//! is dropped into `data/` (libsvm, idx, or csv format) the registry
//! picks it up instead.
//!
//! Shape protocol (must stay in sync with python/compile/aot.py):
//! an even split of N samples over M workers, each shard zero-padded to
//! `padded_n(ceil(N/M))` rows so every worker shares one artifact shape.

pub mod batch;
pub mod idx;
pub mod libsvm;
pub mod partition;
pub mod registry;
pub mod synthetic;

use std::sync::Arc;

use crate::linalg::Matrix;

/// A labelled dense dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// (n × d) feature matrix
    pub x: Matrix,
    /// n labels (±1 for classification, reals for regression)
    pub y: Vec<f64>,
    /// human-readable provenance ("synthetic ijcnn1 stand-in", file path…)
    pub source: String,
}

impl Dataset {
    /// Sample count n.
    pub fn n(&self) -> usize {
        self.x.rows
    }

    /// Feature count d.
    pub fn d(&self) -> usize {
        self.x.cols
    }

    /// Keep only the first k features (paper §IV-B protocol).
    pub fn truncate_features(&self, k: usize) -> Dataset {
        Dataset {
            x: self.x.truncate_cols(k),
            y: self.y.clone(),
            source: format!("{} (features truncated to {k})", self.source),
        }
    }

    /// Z-score every feature column (standard preprocessing for the
    /// NN task; constant columns become zero).
    pub fn standardized(&self) -> Dataset {
        let (n, d) = (self.n(), self.d());
        let mut x = self.x.clone();
        for j in 0..d {
            let mean =
                (0..n).map(|i| x.get(i, j)).sum::<f64>() / n.max(1) as f64;
            let var = (0..n)
                .map(|i| (x.get(i, j) - mean).powi(2))
                .sum::<f64>()
                / n.max(1) as f64;
            let sd = var.sqrt();
            for i in 0..n {
                let v = x.get(i, j);
                x.set(i, j, if sd > 0.0 { (v - mean) / sd } else { 0.0 });
            }
        }
        Dataset {
            x,
            y: self.y.clone(),
            source: format!("{} (standardized)", self.source),
        }
    }
}

/// One worker's shard: rows padded with zeros up to `n_pad`; `mask[i]`
/// is 1.0 for real rows and 0.0 for padding.
///
/// Storage is `Arc`-shared: task objectives built over a shard
/// (`tasks::build_objective`) reference the same allocation instead of
/// copying it, so at M workers the resident dataset memory is
/// O(Σ n_m·d) once — not once per live objective.  Cloning a `Shard`
/// clones three `Arc`s; use [`Arc::make_mut`] for the rare
/// mutate-a-copy case (tests).
#[derive(Clone, Debug)]
pub struct Shard {
    /// padded (n_pad × d) feature block
    pub x: Arc<Matrix>,
    /// padded labels (0.0 on padding rows)
    pub y: Arc<Vec<f64>>,
    /// 1.0 for real rows, 0.0 for padding
    pub mask: Arc<Vec<f64>>,
    /// genuine sample count before padding
    pub n_real: usize,
}

impl Shard {
    /// Row count after padding (the artifact shape).
    pub fn n_pad(&self) -> usize {
        self.x.rows
    }
}

/// The kernel row-tile; mirrors kernels/common.py DEFAULT_BLOCK_N.
pub const BLOCK_N: usize = 256;

/// Rows after padding to the kernel tile (mirror of model.padded_n).
pub fn padded_n(n: usize) -> usize {
    let block = n.min(BLOCK_N);
    if block == 0 {
        return 0;
    }
    n.div_ceil(block) * block
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padded_n_matches_python_protocol() {
        // small n: block == n, no padding
        assert_eq!(padded_n(50), 50);
        assert_eq!(padded_n(169), 169);
        // large n: round up to multiple of 256
        assert_eq!(padded_n(5555), 5632);
        assert_eq!(padded_n(6667), 6912);
        assert_eq!(padded_n(256), 256);
        assert_eq!(padded_n(257), 512);
        assert_eq!(padded_n(0), 0);
    }
}
