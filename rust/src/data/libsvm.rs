//! LIBSVM sparse-text format parser (ijcnn1, the UCI exports, …).
//!
//! Format: one sample per line, `label idx:val idx:val …` with 1-based
//! feature indices.  Dense-ifies into `Matrix` since every dataset in
//! the paper is small enough (MNIST dense = 188 MB f64, fine).

use std::io::{BufRead, BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Matrix;

use super::Dataset;

/// Parse LIBSVM text from any reader. `d_hint` pre-sizes the feature
/// count; actual max index wins if larger.
pub fn parse<R: Read>(reader: R, d_hint: usize) -> Result<Dataset> {
    let mut labels = Vec::new();
    let mut rows: Vec<Vec<(usize, f64)>> = Vec::new();
    let mut d = d_hint;
    for (lineno, line) in BufReader::new(reader).lines().enumerate() {
        let line = line.context("read line")?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f64 = parts
            .next()
            .with_context(|| format!("line {}: empty", lineno + 1))?
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .with_context(|| format!("line {}: token {tok:?}", lineno + 1))?;
            let idx: usize = idx
                .parse()
                .with_context(|| format!("line {}: index {idx:?}", lineno + 1))?;
            if idx == 0 {
                bail!("line {}: libsvm indices are 1-based", lineno + 1);
            }
            let val: f64 = val
                .parse()
                .with_context(|| format!("line {}: value {val:?}", lineno + 1))?;
            d = d.max(idx);
            feats.push((idx - 1, val));
        }
        labels.push(label);
        rows.push(feats);
    }
    let n = labels.len();
    let mut x = Matrix::zeros(n, d);
    for (i, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            x.set(i, j, v);
        }
    }
    Ok(Dataset { x, y: labels, source: "libsvm".into() })
}

/// Parse a LIBSVM file from disk.
pub fn load(path: &Path, d_hint: usize) -> Result<Dataset> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut ds = parse(f, d_hint)?;
    ds.source = path.display().to_string();
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_lines() {
        let text = "+1 1:0.5 3:-2\n-1 2:1.0\n";
        let ds = parse(text.as_bytes(), 0).unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.get(0, 0), 0.5);
        assert_eq!(ds.x.get(0, 2), -2.0);
        assert_eq!(ds.x.get(1, 1), 1.0);
        assert_eq!(ds.x.get(1, 0), 0.0);
    }

    #[test]
    fn respects_d_hint_and_skips_blank_lines() {
        let text = "\n# comment\n1 1:2.0\n";
        let ds = parse(text.as_bytes(), 5).unwrap();
        assert_eq!(ds.d(), 5);
        assert_eq!(ds.n(), 1);
    }

    #[test]
    fn rejects_zero_index() {
        let text = "1 0:2.0\n";
        assert!(parse(text.as_bytes(), 0).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("abc def".as_bytes(), 0).is_err());
        assert!(parse("1 x:1".as_bytes(), 0).is_err());
        assert!(parse("1 1:zz".as_bytes(), 0).is_err());
    }
}
