//! Synthetic dataset generators implementing the paper's protocols.
//!
//! Paper §IV-A: "randomly generate an independent sequence of labels,
//! each with equal probability of y = ±1 … randomly generate 50
//! independent instances x ∈ ℝ⁵⁰ from a standard normal distribution
//! and use the same approach as [54] to rescale the data to change the
//! value of smoothness constants."
//!
//! The rescale is exact, not approximate: for linear regression the
//! worker smoothness constant is L_m = λ_max(X_mᵀX_m), so scaling X_m
//! by √(L_target / λ_max) sets L_m = L_target up to power-iteration
//! tolerance.  For logistic regression L_m = ¼λ_max(X_mᵀX_m) + λ.

use crate::linalg::Matrix;
use crate::rng::Xoshiro256;
use crate::tasks::smoothness::lambda_max_xtx;

use super::Dataset;

/// Standard-normal features, ±1 labels.
pub fn gaussian_pm1(rng: &mut Xoshiro256, n: usize, d: usize) -> Dataset {
    let mut x = Matrix::zeros(n, d);
    for v in &mut x.data {
        *v = rng.next_gaussian();
    }
    let y = (0..n).map(|_| rng.next_sign()).collect();
    Dataset { x, y, source: format!("synthetic gaussian±1 {n}x{d}") }
}

/// Standard-normal features with real-valued labels y = Xθ* + noise —
/// used for regression stand-ins where ±1 labels would make the
/// objective trivially flat.
pub fn gaussian_regression(
    rng: &mut Xoshiro256,
    n: usize,
    d: usize,
    noise: f64,
) -> Dataset {
    let mut x = Matrix::zeros(n, d);
    for v in &mut x.data {
        *v = rng.next_gaussian();
    }
    let theta_star: Vec<f64> = rng.gaussian_vec(d);
    let mut y = vec![0.0; n];
    x.gemv(&theta_star, &mut y);
    for v in &mut y {
        *v += noise * rng.next_gaussian();
    }
    Dataset { x, y, source: format!("synthetic regression {n}x{d}") }
}

/// Class-structured blobs for the MNIST stand-in: `classes` Gaussian
/// centers, labels ±1 by class parity (even/odd digit).
pub fn blobs_pm1(
    rng: &mut Xoshiro256,
    n: usize,
    d: usize,
    classes: usize,
) -> Dataset {
    let centers: Vec<Vec<f64>> = (0..classes)
        .map(|_| rng.gaussian_vec(d).iter().map(|v| 2.0 * v).collect())
        .collect();
    let mut x = Matrix::zeros(n, d);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let c = rng.next_below(classes as u64) as usize;
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = centers[c][j] + rng.next_gaussian();
        }
        y[i] = if c % 2 == 0 { 1.0 } else { -1.0 };
    }
    Dataset { x, y, source: format!("synthetic blobs {n}x{d} ({classes} classes)") }
}

/// Rescale X so that λ_max(XᵀX) == `target` (exactly, via power
/// iteration).  This is the [54]-style smoothness rescale the paper
/// uses to set each worker's L_m for linear regression.
pub fn rescale_to_lambda_max(x: &mut Matrix, target: f64) {
    let cur = lambda_max_xtx(x);
    if cur > 0.0 {
        x.scale((target / cur).sqrt());
    }
}

/// Geometric per-column scaling: column j gets factor
/// spread^(j/(d−1)).  Raw UCI/ijcnn1/MNIST features span decades of
/// scale, which is what makes the paper's real-data problems
/// ill-conditioned (GD slow, momentum valuable, gradients
/// anisotropic → censoring profitable).  The stand-ins apply this so
/// the *shape* of the comparisons survives the substitution
/// (DESIGN.md §3).
pub fn scale_columns(x: &mut Matrix, spread: f64) {
    let d = x.cols;
    if d < 2 {
        return;
    }
    let scales: Vec<f64> =
        (0..d).map(|j| spread.powf(j as f64 / (d - 1) as f64)).collect();
    for i in 0..x.rows {
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] *= scales[j];
        }
    }
}

/// The Fig. 1/2 protocol: M workers, each with `n_m` standard-normal
/// samples of dimension d and ±1 labels, worker m rescaled so its
/// linear-regression smoothness constant is exactly `l_m[m]`.
/// Returns one Dataset per worker (pre-partitioned by construction).
pub fn per_worker_rescaled(
    seed: u64,
    m_workers: usize,
    n_m: usize,
    d: usize,
    l_m: &[f64],
) -> Vec<Dataset> {
    assert_eq!(l_m.len(), m_workers);
    let mut root = Xoshiro256::new(seed);
    (0..m_workers)
        .map(|m| {
            let mut rng = root.split();
            let mut ds = gaussian_pm1(&mut rng, n_m, d);
            rescale_to_lambda_max(&mut ds.x, l_m[m]);
            ds.source = format!(
                "synthetic worker {m} {n_m}x{d}, L_m={:.4}", l_m[m]
            );
            ds
        })
        .collect()
}

/// Paper Fig. 1/2 smoothness schedule: L_m = (1.3^{m-1})², m = 1..=M.
pub fn increasing_l(m_workers: usize) -> Vec<f64> {
    (0..m_workers)
        .map(|m| {
            let b: f64 = 1.3f64.powi(m as i32);
            b * b
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_pm1() {
        let mut rng = Xoshiro256::new(1);
        let ds = gaussian_pm1(&mut rng, 100, 5);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn rescale_hits_target() {
        let mut rng = Xoshiro256::new(2);
        let mut ds = gaussian_pm1(&mut rng, 60, 10);
        rescale_to_lambda_max(&mut ds.x, 4.0);
        let l = lambda_max_xtx(&ds.x);
        assert!((l - 4.0).abs() < 1e-6, "λ_max={l}");
    }

    #[test]
    fn increasing_l_matches_paper() {
        let l = increasing_l(9);
        assert!((l[0] - 1.0).abs() < 1e-12);
        assert!((l[1] - 1.69).abs() < 1e-12); // (1.3)²
        assert!((l[8] - 1.3f64.powi(8).powi(2)).abs() < 1e-9);
        // strictly increasing
        assert!(l.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn per_worker_shapes_and_smoothness() {
        let l = increasing_l(3);
        let shards = per_worker_rescaled(7, 3, 50, 50, &l);
        assert_eq!(shards.len(), 3);
        for (m, ds) in shards.iter().enumerate() {
            assert_eq!(ds.n(), 50);
            assert_eq!(ds.d(), 50);
            let got = lambda_max_xtx(&ds.x);
            assert!(
                (got - l[m]).abs() < 1e-5 * l[m].max(1.0),
                "worker {m}: λ_max={got} want {}",
                l[m]
            );
        }
    }

    #[test]
    fn blobs_have_both_labels() {
        let mut rng = Xoshiro256::new(3);
        let ds = blobs_pm1(&mut rng, 200, 8, 10);
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 20 && pos < 180, "pos={pos}");
    }

    #[test]
    fn regression_labels_correlate_with_features() {
        let mut rng = Xoshiro256::new(4);
        let ds = gaussian_regression(&mut rng, 500, 10, 0.1);
        // y should have variance ≈ ‖θ*‖² ≈ d, far above the noise
        let var: f64 =
            ds.y.iter().map(|v| v * v).sum::<f64>() / ds.n() as f64;
        assert!(var > 1.0, "var={var}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = per_worker_rescaled(9, 2, 10, 4, &[1.0, 2.0]);
        let b = per_worker_rescaled(9, 2, 10, 4, &[1.0, 2.0]);
        assert_eq!(a[0].x.data, b[0].x.data);
        assert_eq!(a[1].y, b[1].y);
    }
}
