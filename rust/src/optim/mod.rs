//! Optimizer layer: server update rules + worker censor rules.
//!
//! The four algorithms the paper evaluates are compositions of two
//! orthogonal pieces:
//!
//! | algorithm | server update       | censor rule          |
//! |-----------|---------------------|----------------------|
//! | GD        | θ−α∇                | never skip           |
//! | HB        | θ−α∇+β(θ−θ⁻)        | never skip           |
//! | LAG-WK    | θ−α∇                | grad-diff rule (8)   |
//! | CHB       | θ−α∇+β(θ−θ⁻)        | grad-diff rule (8)   |
//!
//! `∇` is always the server's *running aggregate* ∇ᵏ of eq. (5); with
//! censoring off, ∇ᵏ equals the exact gradient and the classical
//! methods fall out — this identity is property-tested.

pub mod adam;
pub mod censor;
pub mod method;
pub mod nesterov;

pub use adam::CensoredAdamRule;
pub use censor::{
    AdaptiveCensor, CensorDecision, CensorRule, DecayingCensor,
    GradDiffCensor, NeverCensor, StalenessBoundedCensor,
    VarianceScaledCensor,
};
pub use method::{Method, MethodParams, MethodSpec};
pub use nesterov::NesterovRule;

use crate::linalg;

/// Server-side parameter update.  Implementations must be pure:
/// everything they need arrives through the arguments so engines can
/// replay rounds deterministically.
pub trait ServerRule: Send {
    /// In-place update of `theta` given the aggregate gradient and the
    /// previous iterate; `theta_prev` is θ^{k-1} on entry and must hold
    /// θ^k on exit (the rule handles the rotation).
    fn step(&mut self, theta: &mut [f64], theta_prev: &mut [f64], agg_grad: &[f64]);

    /// Short label for logs and trace CSVs.
    fn name(&self) -> &'static str;
}

/// Plain gradient descent: θ ← θ − α∇.
pub struct GdRule {
    /// step size α
    pub alpha: f64,
}

impl ServerRule for GdRule {
    fn step(&mut self, theta: &mut [f64], theta_prev: &mut [f64], agg_grad: &[f64]) {
        theta_prev.copy_from_slice(theta);
        linalg::axpy(-self.alpha, agg_grad, theta);
    }

    fn name(&self) -> &'static str {
        "gd"
    }
}

/// Heavy ball: θ ← θ − α∇ + β(θ − θ⁻)   (paper eq. 2 / 4).
pub struct HeavyBallRule {
    /// step size α
    pub alpha: f64,
    /// momentum coefficient β
    pub beta: f64,
    /// scratch for the momentum term (steady-state: no allocation)
    momentum: Vec<f64>,
}

impl HeavyBallRule {
    /// Rule for a `dim`-dimensional iterate with step α, momentum β.
    pub fn new(alpha: f64, beta: f64, dim: usize) -> Self {
        Self { alpha, beta, momentum: vec![0.0; dim] }
    }
}

impl ServerRule for HeavyBallRule {
    fn step(&mut self, theta: &mut [f64], theta_prev: &mut [f64], agg_grad: &[f64]) {
        // momentum = θ^k − θ^{k−1}
        linalg::sub_into(theta, theta_prev, &mut self.momentum);
        theta_prev.copy_from_slice(theta);
        linalg::axpy(-self.alpha, agg_grad, theta);
        linalg::axpy(self.beta, &self.momentum, theta);
    }

    fn name(&self) -> &'static str {
        "hb"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gd_step_is_theta_minus_alpha_grad() {
        let mut rule = GdRule { alpha: 0.1 };
        let mut theta = vec![1.0, 2.0];
        let mut prev = vec![0.0, 0.0];
        rule.step(&mut theta, &mut prev, &[10.0, -10.0]);
        assert_eq!(theta, vec![0.0, 3.0]);
        assert_eq!(prev, vec![1.0, 2.0]);
    }

    #[test]
    fn hb_with_beta_zero_equals_gd() {
        let mut hb = HeavyBallRule::new(0.05, 0.0, 2);
        let mut gd = GdRule { alpha: 0.05 };
        let g = vec![3.0, -1.0];
        let mut th = vec![1.0, 1.0];
        let mut tp = vec![0.5, 0.5];
        let mut th2 = th.clone();
        let mut tp2 = tp.clone();
        hb.step(&mut th, &mut tp, &g);
        gd.step(&mut th2, &mut tp2, &g);
        assert_eq!(th, th2);
    }

    #[test]
    fn hb_momentum_uses_previous_iterate() {
        // θ^k = 2, θ^{k-1} = 1, ∇ = 0, β = 0.4 → θ^{k+1} = 2 + 0.4(2−1)
        let mut hb = HeavyBallRule::new(0.1, 0.4, 1);
        let mut th = vec![2.0];
        let mut tp = vec![1.0];
        hb.step(&mut th, &mut tp, &[0.0]);
        assert!((th[0] - 2.4).abs() < 1e-15);
        assert_eq!(tp, vec![2.0]);
    }

    #[test]
    fn hb_full_update_formula() {
        let (a, b) = (0.2, 0.4);
        let mut hb = HeavyBallRule::new(a, b, 1);
        let (tk, tkm1, g) = (3.0, 2.5, 4.0);
        let mut th = vec![tk];
        let mut tp = vec![tkm1];
        hb.step(&mut th, &mut tp, &[g]);
        let want = tk - a * g + b * (tk - tkm1);
        assert!((th[0] - want).abs() < 1e-15);
    }
}
