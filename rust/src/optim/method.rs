//! Method descriptors: the four paper algorithms as data.
//!
//! A [`Method`] plus [`MethodParams`] fully determines a run; the
//! coordinator materializes the server rule and censor rule from them.

use super::{
    CensorRule, GdRule, GradDiffCensor, HeavyBallRule, NeverCensor, ServerRule,
};

/// The algorithms compared throughout §IV.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// gradient descent [58]
    Gd,
    /// classical heavy ball [57]
    Hb,
    /// LAG-WK, censoring-based GD [54]
    Lag,
    /// this paper
    Chb,
}

impl Method {
    /// The four methods in the paper's table order (CHB, HB, LAG, GD).
    pub const ALL: [Method; 4] = [Method::Chb, Method::Hb, Method::Lag, Method::Gd];

    /// Paper-style label ("CHB", "HB", "LAG", "GD").
    pub fn name(self) -> &'static str {
        match self {
            Method::Gd => "GD",
            Method::Hb => "HB",
            Method::Lag => "LAG",
            Method::Chb => "CHB",
        }
    }

    /// Parse a CLI method name (case-insensitive; "lag-wk" = "lag").
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "gd" => Some(Method::Gd),
            "hb" => Some(Method::Hb),
            "lag" | "lag-wk" => Some(Method::Lag),
            "chb" => Some(Method::Chb),
            _ => None,
        }
    }

    /// Does the server update carry a β(θᵏ − θ^{k−1}) term?
    pub fn uses_momentum(self) -> bool {
        matches!(self, Method::Hb | Method::Chb)
    }

    /// Do workers apply the skip-transmission rule (8)?
    pub fn uses_censoring(self) -> bool {
        matches!(self, Method::Lag | Method::Chb)
    }
}

/// Hyperparameters shared by all four methods.
#[derive(Clone, Copy, Debug)]
pub struct MethodParams {
    /// step size α
    pub alpha: f64,
    /// momentum β (paper default 0.4; ignored by GD/LAG)
    pub beta: f64,
    /// censor threshold ε₁ (ignored by GD/HB)
    pub epsilon1: f64,
}

impl MethodParams {
    /// Step size `alpha` with the paper's defaults (β = 0.4, ε₁ = 0).
    pub fn new(alpha: f64) -> Self {
        Self { alpha, beta: 0.4, epsilon1: 0.0 }
    }

    /// Replace the momentum coefficient (builder form).
    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }

    /// Set a raw censor threshold ε₁ (builder form).
    pub fn with_epsilon1(mut self, epsilon1: f64) -> Self {
        self.epsilon1 = epsilon1;
        self
    }

    /// Paper standard: ε₁ = c/(α²M²).
    pub fn with_epsilon1_scaled(mut self, c: f64, m_workers: usize) -> Self {
        self.epsilon1 =
            super::censor::epsilon1_scaled(c, self.alpha, m_workers);
        self
    }
}

/// Materialize the server rule for (method, params).
pub fn build_server_rule(
    method: Method,
    p: &MethodParams,
    dim: usize,
) -> Box<dyn ServerRule> {
    if method.uses_momentum() {
        Box::new(HeavyBallRule::new(p.alpha, p.beta, dim))
    } else {
        Box::new(GdRule { alpha: p.alpha })
    }
}

/// Materialize the censor rule for (method, params).
pub fn build_censor_rule(method: Method, p: &MethodParams) -> Box<dyn CensorRule> {
    if method.uses_censoring() {
        Box::new(GradDiffCensor { epsilon1: p.epsilon1 })
    } else {
        Box::new(NeverCensor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("lag-wk"), Some(Method::Lag));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn composition_table_matches_paper() {
        assert!(!Method::Gd.uses_momentum() && !Method::Gd.uses_censoring());
        assert!(Method::Hb.uses_momentum() && !Method::Hb.uses_censoring());
        assert!(!Method::Lag.uses_momentum() && Method::Lag.uses_censoring());
        assert!(Method::Chb.uses_momentum() && Method::Chb.uses_censoring());
    }

    #[test]
    fn builders_produce_right_rules() {
        let p = MethodParams::new(0.1).with_epsilon1(1.0);
        assert_eq!(build_server_rule(Method::Chb, &p, 3).name(), "hb");
        assert_eq!(build_server_rule(Method::Lag, &p, 3).name(), "gd");
        assert_eq!(build_censor_rule(Method::Chb, &p).name(), "grad-diff");
        assert_eq!(build_censor_rule(Method::Hb, &p).name(), "never");
    }
}
