//! Method descriptors: the paper algorithms — and the beyond-paper
//! method grid — as data.
//!
//! A [`Method`] plus [`MethodParams`] fully determines a classic run;
//! the coordinator materializes the server rule and censor rule from
//! them.  [`MethodSpec`] is the first-class method *grid* on top: the
//! four classic methods (unchanged bitwise), censored Nesterov, K
//! local steps between uplinks, and a censored-Adam server rule, each
//! a `RunSpec::method` variant with typed validation of incompatible
//! axes (see `spec::RunSpec::validate`).

use super::{
    CensorRule, CensoredAdamRule, GdRule, GradDiffCensor, HeavyBallRule,
    NesterovRule, NeverCensor, ServerRule,
};

/// The algorithms compared throughout §IV.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// gradient descent [58]
    Gd,
    /// classical heavy ball [57]
    Hb,
    /// LAG-WK, censoring-based GD [54]
    Lag,
    /// this paper
    Chb,
}

impl Method {
    /// The four methods in the paper's table order (CHB, HB, LAG, GD).
    pub const ALL: [Method; 4] = [Method::Chb, Method::Hb, Method::Lag, Method::Gd];

    /// Paper-style label ("CHB", "HB", "LAG", "GD").
    pub fn name(self) -> &'static str {
        match self {
            Method::Gd => "GD",
            Method::Hb => "HB",
            Method::Lag => "LAG",
            Method::Chb => "CHB",
        }
    }

    /// Parse a CLI method name (case-insensitive; "lag-wk" = "lag").
    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "gd" => Some(Method::Gd),
            "hb" => Some(Method::Hb),
            "lag" | "lag-wk" => Some(Method::Lag),
            "chb" => Some(Method::Chb),
            _ => None,
        }
    }

    /// Does the server update carry a β(θᵏ − θ^{k−1}) term?
    pub fn uses_momentum(self) -> bool {
        matches!(self, Method::Hb | Method::Chb)
    }

    /// Do workers apply the skip-transmission rule (8)?
    pub fn uses_censoring(self) -> bool {
        matches!(self, Method::Lag | Method::Chb)
    }
}

/// Adam defaults (Kingma & Ba; what the censored-adam variant uses
/// when a spec omits the moment coefficients).
pub const ADAM_BETA1: f64 = 0.9;
/// Second-moment decay default.
pub const ADAM_BETA2: f64 = 0.999;
/// Denominator-stabilizer default.
pub const ADAM_EPS: f64 = 1e-8;

/// Default K for `--method local-steps` when `--local-steps` is not
/// given.
pub const DEFAULT_K_LOCAL: usize = 4;

/// The first-class method grid: what `RunSpec::method` holds.
///
/// `Classic` keeps the four paper methods byte-compatible (manifests
/// encode them as the same plain lowercase string as before); the
/// other variants are beyond-paper compositions that reuse the same
/// censor/uplink/engine machinery:
///
/// * [`MethodSpec::Nesterov`] — the gradient-correction NAG server
///   rule ([`NesterovRule`]), censored or not.
/// * [`MethodSpec::LocalSteps`] — each worker runs `k_local` local
///   GD/HB steps between uplinks and reports the *sum* of the local
///   gradients (so `k_local = 1` reduces bitwise to the base method);
///   censoring applies to the accumulated K-step delta, and epoch
///   accounting advances by K gradient passes per round.
/// * [`MethodSpec::CensoredAdam`] — a server-side bias-corrected Adam
///   step on the lazily-aggregated ∇ᵏ of eq. (5) (the composition of
///   the adaptive-gradient paper), with the grad-diff censor (8)
///   unchanged on the worker side.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MethodSpec {
    /// one of the four paper methods, unchanged bitwise
    Classic(Method),
    /// (censored) Nesterov accelerated gradient, server side
    Nesterov {
        /// apply the grad-diff censor (8)?
        censored: bool,
    },
    /// K local steps of the base method between uplinks
    LocalSteps {
        /// local/server update family (momentum + censor come from it)
        base: Method,
        /// local steps per round (1 = exactly the base method)
        k_local: usize,
    },
    /// server-side Adam on the lazy aggregate, censored uplinks
    CensoredAdam {
        /// first-moment decay β₁
        beta1: f64,
        /// second-moment decay β₂
        beta2: f64,
        /// denominator stabilizer ε
        eps: f64,
        /// AMSGrad variant (monotone second moment)?
        amsgrad: bool,
    },
}

impl From<Method> for MethodSpec {
    fn from(m: Method) -> MethodSpec {
        MethodSpec::Classic(m)
    }
}

impl MethodSpec {
    /// Censored Adam with the standard coefficient defaults.
    pub fn censored_adam() -> MethodSpec {
        MethodSpec::CensoredAdam {
            beta1: ADAM_BETA1,
            beta2: ADAM_BETA2,
            eps: ADAM_EPS,
            amsgrad: false,
        }
    }

    /// K censored-HB local steps (the grid's local-training default).
    pub fn local_steps(k_local: usize) -> MethodSpec {
        MethodSpec::LocalSteps { base: Method::Chb, k_local }
    }

    /// Paper-style label ("CHB", …, "NAG"/"CNAG", "LOCAL", "CADAM").
    pub fn name(&self) -> &'static str {
        match self {
            MethodSpec::Classic(m) => m.name(),
            MethodSpec::Nesterov { censored: false } => "NAG",
            MethodSpec::Nesterov { censored: true } => "CNAG",
            MethodSpec::LocalSteps { .. } => "LOCAL",
            MethodSpec::CensoredAdam { .. } => "CADAM",
        }
    }

    /// Parse a CLI method name: the four classic names plus
    /// `nag`/`cnag`, `local-steps` (K from [`DEFAULT_K_LOCAL`]; the
    /// CLI overrides it with `--local-steps`), and
    /// `censored-adam`/`cadam`.
    pub fn parse(s: &str) -> Option<MethodSpec> {
        if let Some(m) = Method::parse(s) {
            return Some(MethodSpec::Classic(m));
        }
        match s.to_ascii_lowercase().as_str() {
            "nag" => Some(MethodSpec::Nesterov { censored: false }),
            "cnag" => Some(MethodSpec::Nesterov { censored: true }),
            "local-steps" | "local" => {
                Some(MethodSpec::local_steps(DEFAULT_K_LOCAL))
            }
            "censored-adam" | "cadam" => Some(MethodSpec::censored_adam()),
            _ => None,
        }
    }

    /// Does the server update carry a momentum-type term?
    pub fn uses_momentum(&self) -> bool {
        match self {
            MethodSpec::Classic(m) => m.uses_momentum(),
            MethodSpec::Nesterov { .. } => true,
            MethodSpec::LocalSteps { base, .. } => base.uses_momentum(),
            // Adam's preconditioned first moment, not β(θ−θ⁻)
            MethodSpec::CensoredAdam { .. } => false,
        }
    }

    /// Do workers apply the skip-transmission rule (8)?
    pub fn uses_censoring(&self) -> bool {
        match self {
            MethodSpec::Classic(m) => m.uses_censoring(),
            MethodSpec::Nesterov { censored } => *censored,
            MethodSpec::LocalSteps { base, .. } => base.uses_censoring(),
            MethodSpec::CensoredAdam { .. } => true,
        }
    }

    /// Local steps per round (1 for everything but `LocalSteps`).
    pub fn k_local(&self) -> usize {
        match self {
            MethodSpec::LocalSteps { k_local, .. } => (*k_local).max(1),
            _ => 1,
        }
    }

    /// The classic method this spec degenerates to — what legacy
    /// `RunConfig`/`Server` constructors that still take a [`Method`]
    /// receive (the injected rule pair carries the real algorithm).
    pub fn base_method(&self) -> Method {
        match self {
            MethodSpec::Classic(m) => *m,
            MethodSpec::Nesterov { censored: true } => Method::Chb,
            MethodSpec::Nesterov { censored: false } => Method::Hb,
            MethodSpec::LocalSteps { base, .. } => *base,
            MethodSpec::CensoredAdam { .. } => Method::Lag,
        }
    }
}

/// Hyperparameters shared by all four methods.
#[derive(Clone, Copy, Debug)]
pub struct MethodParams {
    /// step size α
    pub alpha: f64,
    /// momentum β (paper default 0.4; ignored by GD/LAG)
    pub beta: f64,
    /// censor threshold ε₁ (ignored by GD/HB)
    pub epsilon1: f64,
}

impl MethodParams {
    /// Step size `alpha` with the paper's defaults (β = 0.4, ε₁ = 0).
    pub fn new(alpha: f64) -> Self {
        Self { alpha, beta: 0.4, epsilon1: 0.0 }
    }

    /// Replace the momentum coefficient (builder form).
    pub fn with_beta(mut self, beta: f64) -> Self {
        self.beta = beta;
        self
    }

    /// Set a raw censor threshold ε₁ (builder form).
    pub fn with_epsilon1(mut self, epsilon1: f64) -> Self {
        self.epsilon1 = epsilon1;
        self
    }

    /// Paper standard: ε₁ = c/(α²M²).
    pub fn with_epsilon1_scaled(mut self, c: f64, m_workers: usize) -> Self {
        self.epsilon1 =
            super::censor::epsilon1_scaled(c, self.alpha, m_workers);
        self
    }
}

/// Materialize the server rule for (method, params).
pub fn build_server_rule(
    method: Method,
    p: &MethodParams,
    dim: usize,
) -> Box<dyn ServerRule> {
    if method.uses_momentum() {
        Box::new(HeavyBallRule::new(p.alpha, p.beta, dim))
    } else {
        Box::new(GdRule { alpha: p.alpha })
    }
}

/// Materialize the censor rule for (method, params).
pub fn build_censor_rule(method: Method, p: &MethodParams) -> Box<dyn CensorRule> {
    if method.uses_censoring() {
        Box::new(GradDiffCensor { epsilon1: p.epsilon1 })
    } else {
        Box::new(NeverCensor)
    }
}

/// Materialize the server rule for a grid method.  `Classic` routes
/// through [`build_server_rule`] unchanged; `LocalSteps` uses its base
/// method's rule (the K-step trajectory lives on the worker).
pub fn build_server_rule_spec(
    spec: &MethodSpec,
    p: &MethodParams,
    dim: usize,
) -> Box<dyn ServerRule> {
    match spec {
        MethodSpec::Classic(m) => build_server_rule(*m, p, dim),
        MethodSpec::Nesterov { .. } => {
            Box::new(NesterovRule::new(p.alpha, p.beta, dim))
        }
        MethodSpec::LocalSteps { base, .. } => build_server_rule(*base, p, dim),
        MethodSpec::CensoredAdam { beta1, beta2, eps, amsgrad } => {
            Box::new(CensoredAdamRule::new(
                p.alpha, *beta1, *beta2, *eps, *amsgrad, dim,
            ))
        }
    }
}

/// Materialize the censor rule for a grid method.
pub fn build_censor_rule_spec(
    spec: &MethodSpec,
    p: &MethodParams,
) -> Box<dyn CensorRule> {
    if spec.uses_censoring() {
        Box::new(GradDiffCensor { epsilon1: p.epsilon1 })
    } else {
        Box::new(NeverCensor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips() {
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("lag-wk"), Some(Method::Lag));
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn composition_table_matches_paper() {
        assert!(!Method::Gd.uses_momentum() && !Method::Gd.uses_censoring());
        assert!(Method::Hb.uses_momentum() && !Method::Hb.uses_censoring());
        assert!(!Method::Lag.uses_momentum() && Method::Lag.uses_censoring());
        assert!(Method::Chb.uses_momentum() && Method::Chb.uses_censoring());
    }

    #[test]
    fn builders_produce_right_rules() {
        let p = MethodParams::new(0.1).with_epsilon1(1.0);
        assert_eq!(build_server_rule(Method::Chb, &p, 3).name(), "hb");
        assert_eq!(build_server_rule(Method::Lag, &p, 3).name(), "gd");
        assert_eq!(build_censor_rule(Method::Chb, &p).name(), "grad-diff");
        assert_eq!(build_censor_rule(Method::Hb, &p).name(), "never");
    }

    #[test]
    fn spec_parse_covers_the_grid() {
        for m in Method::ALL {
            assert_eq!(
                MethodSpec::parse(m.name()),
                Some(MethodSpec::Classic(m))
            );
        }
        assert_eq!(
            MethodSpec::parse("nag"),
            Some(MethodSpec::Nesterov { censored: false })
        );
        assert_eq!(
            MethodSpec::parse("CNAG"),
            Some(MethodSpec::Nesterov { censored: true })
        );
        assert_eq!(
            MethodSpec::parse("local-steps"),
            Some(MethodSpec::LocalSteps {
                base: Method::Chb,
                k_local: DEFAULT_K_LOCAL
            })
        );
        assert_eq!(
            MethodSpec::parse("cadam"),
            Some(MethodSpec::censored_adam())
        );
        assert_eq!(MethodSpec::parse("bogus"), None);
    }

    #[test]
    fn spec_composition_table() {
        assert!(MethodSpec::Classic(Method::Chb).uses_censoring());
        assert!(MethodSpec::Nesterov { censored: true }.uses_censoring());
        assert!(!MethodSpec::Nesterov { censored: false }.uses_censoring());
        assert!(MethodSpec::censored_adam().uses_censoring());
        assert!(!MethodSpec::censored_adam().uses_momentum());
        assert!(MethodSpec::local_steps(4).uses_censoring());
        assert_eq!(MethodSpec::local_steps(4).k_local(), 4);
        assert_eq!(MethodSpec::Classic(Method::Gd).k_local(), 1);
    }

    #[test]
    fn spec_builders_produce_right_rules() {
        let p = MethodParams::new(0.1).with_epsilon1(1.0);
        let d = 3;
        assert_eq!(
            build_server_rule_spec(&MethodSpec::Classic(Method::Chb), &p, d)
                .name(),
            "hb"
        );
        assert_eq!(
            build_server_rule_spec(
                &MethodSpec::Nesterov { censored: true },
                &p,
                d
            )
            .name(),
            "nag"
        );
        assert_eq!(
            build_server_rule_spec(&MethodSpec::local_steps(4), &p, d).name(),
            "hb"
        );
        assert_eq!(
            build_server_rule_spec(&MethodSpec::censored_adam(), &p, d).name(),
            "censored-adam"
        );
        assert_eq!(
            build_censor_rule_spec(&MethodSpec::censored_adam(), &p).name(),
            "grad-diff"
        );
        assert_eq!(
            build_censor_rule_spec(
                &MethodSpec::Nesterov { censored: false },
                &p
            )
            .name(),
            "never"
        );
    }
}
