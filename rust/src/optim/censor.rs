//! Worker-side censor rules — when to *not* transmit.
//!
//! The paper's CHB-skip-transmission condition (eq. 8):
//!
//! ```text
//! skip  ⟺  ‖δ∇_m^k‖² ≤ ε₁ ‖θ^k − θ^{k−1}‖²
//! ```
//!
//! where δ∇_m^k = ∇f_m(θ^k) − ∇f_m(θ̂_m^{k−1}) is the change since the
//! last *transmitted* gradient.  LAG-WK uses the identical rule (the
//! paper: "choose the same skip-transmission condition (8) for CHB and
//! censoring-based GD"), so one implementation serves both.
//!
//! Two beyond-paper variants are provided for the ablation benches:
//! an absolute threshold and a value-censor (LAG-PS-flavored) rule.

/// Verdict for one worker at one iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CensorDecision {
    /// upload δ∇_m^k this round
    Transmit,
    /// stay silent; the server carries the stale term (eq. 5)
    Skip,
}

/// Decide whether worker m transmits at iteration k.
///
/// Inputs are the *squared norms* so engines can reuse the values for
/// metrics without recomputation; `k` lets rules warm up (everyone
/// transmits at k = 1 where θ⁰ = θ¹ makes the RHS zero anyway).
///
/// ```
/// use chb_fed::optim::{CensorDecision, CensorRule, GradDiffCensor};
///
/// // the paper's rule (8): skip iff ‖δ∇‖² ≤ ε₁‖θᵏ − θ^{k−1}‖²
/// let rule = GradDiffCensor { epsilon1: 0.5 };
/// assert_eq!(rule.decide(1.0, 4.0, 3), CensorDecision::Skip);
/// assert_eq!(rule.decide(3.0, 4.0, 3), CensorDecision::Transmit);
/// ```
pub trait CensorRule: Send + Sync {
    /// Verdict for ‖δ∇_m^k‖² = `delta_grad_sq` against the broadcast
    /// scale ‖θᵏ − θ^{k−1}‖² = `theta_step_sq` at iteration `k`.
    fn decide(
        &self,
        delta_grad_sq: f64,
        theta_step_sq: f64,
        k: usize,
    ) -> CensorDecision;

    /// Short label for logs and trace CSVs.
    fn name(&self) -> &'static str;
}

/// Never skip — GD and classical HB.
pub struct NeverCensor;

impl CensorRule for NeverCensor {
    fn decide(&self, _: f64, _: f64, _: usize) -> CensorDecision {
        CensorDecision::Transmit
    }

    fn name(&self) -> &'static str {
        "never"
    }
}

/// The paper's rule (eq. 8) with threshold ε₁.
pub struct GradDiffCensor {
    /// censor threshold ε₁ (paper standard: [`epsilon1_scaled`])
    pub epsilon1: f64,
}

impl CensorRule for GradDiffCensor {
    fn decide(
        &self,
        delta_grad_sq: f64,
        theta_step_sq: f64,
        _k: usize,
    ) -> CensorDecision {
        if delta_grad_sq <= self.epsilon1 * theta_step_sq {
            CensorDecision::Skip
        } else {
            CensorDecision::Transmit
        }
    }

    fn name(&self) -> &'static str {
        "grad-diff"
    }
}

/// Ablation: absolute threshold ‖δ∇‖² ≤ τ (ignores the θ-step scale).
/// Demonstrates why the paper's *relative* rule is the right one: a
/// fixed τ either censors nothing early or everything late.
pub struct AbsoluteCensor {
    /// absolute squared-norm threshold τ
    pub tau: f64,
}

impl CensorRule for AbsoluteCensor {
    fn decide(&self, delta_grad_sq: f64, _: f64, _: usize) -> CensorDecision {
        if delta_grad_sq <= self.tau {
            CensorDecision::Skip
        } else {
            CensorDecision::Transmit
        }
    }

    fn name(&self) -> &'static str {
        "absolute"
    }
}

/// Ablation: transmit at most every `period` iterations regardless of
/// information content (round-robin style baseline).
///
/// Construct through [`PeriodicCensor::new`], which normalizes the
/// degenerate `period = 0` to 1 (transmit every round) once, instead
/// of re-clamping on every [`CensorRule::decide`] call.
pub struct PeriodicCensor {
    period: usize,
}

impl PeriodicCensor {
    /// Rule transmitting whenever k is a multiple of `period`.
    /// `period = 0` is normalized to 1; `period = 1` therefore never
    /// skips (every k is a multiple of 1).
    pub fn new(period: usize) -> Self {
        Self { period: period.max(1) }
    }

    /// The normalized period (≥ 1).
    pub fn period(&self) -> usize {
        self.period
    }
}

impl CensorRule for PeriodicCensor {
    fn decide(&self, _: f64, _: f64, k: usize) -> CensorDecision {
        if k % self.period == 0 {
            CensorDecision::Transmit
        } else {
            CensorDecision::Skip
        }
    }

    fn name(&self) -> &'static str {
        "periodic"
    }
}

/// CSGD-style decreasing threshold (Li et al., *Communication-Censored
/// Distributed Stochastic Gradient Descent*): skip iff
/// ‖δ∇_m^k‖² ≤ τ_k with τ_k = τ₀·ρᵏ, ρ ∈ (0, 1).
///
/// Under minibatch gradients the paper's relative rule (8) misfires: a
/// noisy δ∇ has ‖δ∇‖² inflated by O(σ²/|B|) even at a stationary
/// point, so comparing it against the (shrinking) iterate step either
/// censors nothing or the noise floor triggers spurious uploads
/// forever.  A *decreasing absolute* threshold instead dominates the
/// noise floor early (aggressive censoring while gradients are large
/// and redundant) and vanishes as k → ∞, so late-phase information is
/// never suppressed — the schedule CSGD proves convergent for
/// censored SGD.
pub struct DecayingCensor {
    /// initial threshold τ₀ (scale it to ‖∇f_m(θ⁰)‖² — see
    /// `experiments::ablations::stochastic` for the recipe)
    pub tau0: f64,
    /// per-iteration decay ρ ∈ (0, 1)
    pub rho: f64,
}

impl DecayingCensor {
    /// Threshold τ_k = τ₀·ρᵏ at iteration k.
    pub fn tau_at(&self, k: usize) -> f64 {
        self.tau0 * self.rho.powi(k.min(i32::MAX as usize) as i32)
    }
}

impl CensorRule for DecayingCensor {
    fn decide(&self, delta_grad_sq: f64, _: f64, k: usize) -> CensorDecision {
        if delta_grad_sq <= self.tau_at(k) {
            CensorDecision::Skip
        } else {
            CensorDecision::Transmit
        }
    }

    fn name(&self) -> &'static str {
        "decaying"
    }
}

/// Variance-compensated relative rule for minibatch runs: the paper's
/// eq. (8) with an effective threshold ε₁/ϕ_k, where ϕ_k ∈ (0, 1] is
/// the batch schedule's shard fraction at round k.
///
/// Rationale: with batch fraction ϕ the stochastic δ∇ carries an
/// additive noise term of variance O(1/|B|) ∝ 1/ϕ, so ‖δ∇‖² is
/// inflated by ≈ 1/ϕ relative to the deterministic quantity eq. (8)
/// was designed for.  Dividing ε₁ by ϕ_k restores the intended
/// skip region; at ϕ = 1 the rule reduces exactly to
/// [`GradDiffCensor`].  Composable with
/// [`super::StalenessBoundedCensor`] like any other rule.
pub struct VarianceScaledCensor {
    /// base threshold ε₁ (the full-batch value)
    pub epsilon1: f64,
    /// the run's batch schedule (must match the workers')
    pub schedule: crate::data::batch::BatchSchedule,
    /// reference shard size the fraction is evaluated against
    pub n_rows: usize,
}

impl VarianceScaledCensor {
    /// Effective threshold ε₁/ϕ_k at iteration k.
    pub fn epsilon_at(&self, k: usize) -> f64 {
        let frac = self.schedule.fraction_at(k, self.n_rows).max(1e-12);
        self.epsilon1 / frac
    }
}

impl CensorRule for VarianceScaledCensor {
    fn decide(
        &self,
        delta_grad_sq: f64,
        theta_step_sq: f64,
        k: usize,
    ) -> CensorDecision {
        if delta_grad_sq <= self.epsilon_at(k) * theta_step_sq {
            CensorDecision::Skip
        } else {
            CensorDecision::Transmit
        }
    }

    fn name(&self) -> &'static str {
        "variance-scaled"
    }
}

/// ε₁ = c / (α² M²) — the paper's standard threshold parameterization
/// (used with c = 0.1 almost everywhere, swept in Fig. 11).
pub fn epsilon1_scaled(c: f64, alpha: f64, m_workers: usize) -> f64 {
    c / (alpha * alpha * (m_workers * m_workers) as f64)
}

/// Beyond-paper: adaptive ε₁ — the paper's conclusion leaves "finding
/// an optimal approach to tune ε₁" open.  This rule anneals the
/// threshold geometrically from `eps_hi` toward `eps_lo` over the
/// first `horizon` iterations: aggressive censoring early (when the
/// momentum direction is persistent and per-worker changes are
/// redundant), conservative near convergence (when every residual
/// delta matters for the final digits).
///
/// Interior mutability keeps the [`CensorRule`] trait object shared
/// across workers without threading k through extra state — the rule
/// is a pure function of the iteration index.
pub struct AdaptiveCensor {
    /// threshold at k = 0 (aggressive censoring)
    pub eps_hi: f64,
    /// threshold at k ≥ `horizon` (conservative censoring)
    pub eps_lo: f64,
    /// iterations over which the threshold anneals hi → lo
    pub horizon: usize,
}

impl AdaptiveCensor {
    /// Current threshold at iteration k.
    pub fn epsilon_at(&self, k: usize) -> f64 {
        if self.horizon == 0 || self.eps_hi <= 0.0 {
            return self.eps_lo;
        }
        let t = (k.min(self.horizon) as f64) / self.horizon as f64;
        // geometric interpolation hi → lo
        self.eps_hi * (self.eps_lo.max(1e-300) / self.eps_hi).powf(t)
    }
}

impl CensorRule for AdaptiveCensor {
    fn decide(
        &self,
        delta_grad_sq: f64,
        theta_step_sq: f64,
        k: usize,
    ) -> CensorDecision {
        if delta_grad_sq <= self.epsilon_at(k) * theta_step_sq {
            CensorDecision::Skip
        } else {
            CensorDecision::Transmit
        }
    }

    fn name(&self) -> &'static str {
        "adaptive"
    }
}

/// Staleness-bounded wrapper: apply `inner`, but force a transmit once
/// a worker has censored `max_skips` rounds in a row — the LAG-style
/// "communicate at least every D rounds" bound that keeps every
/// worker's contribution to the eq. (5) aggregate boundedly stale.
/// The async engine builds one per worker when `--max-staleness` is
/// set; with `max_skips = 0` censoring is disabled entirely.
///
/// The consecutive-skip counter is interior state, so **one instance
/// serves exactly one worker** — sharing one instance across workers
/// (e.g. as the single `RoundInput.censor` Arc of the sync engines)
/// is a contract violation: the workers would pool one counter and
/// the bound would fire once per ~S decisions *globally* instead of
/// per worker.  The counter's read-modify-write is a single atomic
/// `fetch_add`, so even misuse never loses updates, but the only
/// supported pattern is per-worker instances (what the async engine
/// builds).
pub struct StalenessBoundedCensor {
    inner: std::sync::Arc<dyn CensorRule>,
    max_skips: usize,
    skips: std::sync::atomic::AtomicUsize,
}

impl StalenessBoundedCensor {
    /// Wrap `inner`, allowing at most `max_skips` consecutive skips.
    pub fn new(
        inner: std::sync::Arc<dyn CensorRule>,
        max_skips: usize,
    ) -> Self {
        Self {
            inner,
            max_skips,
            skips: std::sync::atomic::AtomicUsize::new(0),
        }
    }

    /// Consecutive skips since the last transmission (checkpoint
    /// capture — this counter is the rule's only mutable state).
    pub fn pending_skips(&self) -> usize {
        self.skips.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Restore the consecutive-skip counter from a checkpoint.
    pub fn set_pending_skips(&self, n: usize) {
        self.skips.store(n, std::sync::atomic::Ordering::Relaxed);
    }
}

impl CensorRule for StalenessBoundedCensor {
    fn decide(
        &self,
        delta_grad_sq: f64,
        theta_step_sq: f64,
        k: usize,
    ) -> CensorDecision {
        use std::sync::atomic::Ordering;
        if self.inner.decide(delta_grad_sq, theta_step_sq, k)
            == CensorDecision::Transmit
        {
            self.skips.store(0, Ordering::Relaxed);
            return CensorDecision::Transmit;
        }
        // single atomic RMW: no update is ever lost, even if misused
        // concurrently
        let pending = self.skips.fetch_add(1, Ordering::Relaxed);
        if pending >= self.max_skips {
            // silence budget exhausted: forced refresh
            self.skips.store(0, Ordering::Relaxed);
            CensorDecision::Transmit
        } else {
            CensorDecision::Skip
        }
    }

    fn name(&self) -> &'static str {
        "staleness-bounded"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_diff_rule_matches_eq8() {
        let r = GradDiffCensor { epsilon1: 0.5 };
        // ‖δ∇‖² = 1, ε₁‖Δθ‖² = 0.5·4 = 2 → skip
        assert_eq!(r.decide(1.0, 4.0, 3), CensorDecision::Skip);
        // boundary: equal → skip (the paper's ≤)
        assert_eq!(r.decide(2.0, 4.0, 3), CensorDecision::Skip);
        // above → transmit
        assert_eq!(r.decide(2.0 + 1e-12, 4.0, 3), CensorDecision::Transmit);
    }

    #[test]
    fn zero_theta_step_transmits_unless_grad_unchanged() {
        let r = GradDiffCensor { epsilon1: 10.0 };
        // RHS = 0: any gradient change must be transmitted
        assert_eq!(r.decide(1e-30, 0.0, 2), CensorDecision::Transmit);
        // exactly unchanged gradient may be skipped
        assert_eq!(r.decide(0.0, 0.0, 2), CensorDecision::Skip);
    }

    #[test]
    fn epsilon_zero_reduces_to_classical_method() {
        // ε₁ = 0 ⇒ CHB ≡ HB (paper §II): only exactly-zero δ∇ skips
        let r = GradDiffCensor { epsilon1: 0.0 };
        assert_eq!(r.decide(1e-300, 1e10, 5), CensorDecision::Transmit);
        assert_eq!(r.decide(0.0, 1e10, 5), CensorDecision::Skip);
    }

    #[test]
    fn never_censor_always_transmits() {
        assert_eq!(NeverCensor.decide(0.0, 1e9, 1), CensorDecision::Transmit);
    }

    #[test]
    fn periodic_and_absolute_behave() {
        let p = PeriodicCensor::new(3);
        assert_eq!(p.decide(9.9, 0.0, 3), CensorDecision::Transmit);
        assert_eq!(p.decide(9.9, 0.0, 4), CensorDecision::Skip);
        let a = AbsoluteCensor { tau: 1.0 };
        assert_eq!(a.decide(0.5, 0.0, 1), CensorDecision::Skip);
        assert_eq!(a.decide(1.5, 0.0, 1), CensorDecision::Transmit);
    }

    #[test]
    fn periodic_period_one_never_skips() {
        // regression: period = 1 ⇒ every k is a multiple ⇒ no skips
        let p = PeriodicCensor::new(1);
        for k in 1..=100 {
            assert_eq!(p.decide(9.9, 0.0, k), CensorDecision::Transmit, "k={k}");
        }
    }

    #[test]
    fn periodic_period_zero_normalizes_to_one_in_the_constructor() {
        let p = PeriodicCensor::new(0);
        assert_eq!(p.period(), 1);
        for k in 1..=10 {
            assert_eq!(p.decide(0.0, 0.0, k), CensorDecision::Transmit);
        }
    }

    #[test]
    fn decaying_censor_threshold_shrinks_geometrically() {
        let r = DecayingCensor { tau0: 100.0, rho: 0.5 };
        assert!((r.tau_at(0) - 100.0).abs() < 1e-12);
        assert!((r.tau_at(1) - 50.0).abs() < 1e-12);
        assert!((r.tau_at(5) - 3.125).abs() < 1e-12);
        // same ‖δ∇‖² flips from censored to transmitted as τ decays
        assert_eq!(r.decide(10.0, 0.0, 1), CensorDecision::Skip);
        assert_eq!(r.decide(10.0, 0.0, 5), CensorDecision::Transmit);
        // the θ-step scale is irrelevant (absolute rule)
        assert_eq!(r.decide(10.0, 1e12, 5), CensorDecision::Transmit);
    }

    #[test]
    fn decaying_censor_eventually_stops_censoring_noise() {
        // any fixed noise floor survives only finitely many rounds
        let r = DecayingCensor { tau0: 1.0, rho: 0.9 };
        let noise = 1e-3;
        let k_cross =
            (noise.ln() / 0.9f64.ln()).ceil() as usize;
        assert_eq!(r.decide(noise, 0.0, k_cross + 1), CensorDecision::Transmit);
        assert_eq!(r.decide(noise, 0.0, 1), CensorDecision::Skip);
    }

    #[test]
    fn variance_scaled_censor_reduces_to_grad_diff_at_full_batch() {
        use crate::data::batch::BatchSchedule;
        let v = VarianceScaledCensor {
            epsilon1: 0.5,
            schedule: BatchSchedule::Full,
            n_rows: 100,
        };
        let g = GradDiffCensor { epsilon1: 0.5 };
        for (dgs, tss, k) in
            [(1.0, 4.0, 3), (2.0, 4.0, 3), (2.0 + 1e-12, 4.0, 7)]
        {
            assert_eq!(v.decide(dgs, tss, k), g.decide(dgs, tss, k));
        }
    }

    #[test]
    fn variance_scaled_censor_widens_skip_region_for_small_batches() {
        use crate::data::batch::BatchSchedule;
        let v = VarianceScaledCensor {
            epsilon1: 0.5,
            schedule: BatchSchedule::Minibatch {
                size: 10,
                seed: 0,
                replace: false,
            },
            n_rows: 100,
        };
        // ϕ = 0.1 ⇒ ε_eff = 5: a δ∇ the full-batch rule would upload
        // (2+ε > ε₁·4 = 2) is attributed to minibatch noise and skipped
        assert!((v.epsilon_at(3) - 5.0).abs() < 1e-12);
        assert_eq!(v.decide(2.0 + 1e-9, 4.0, 3), CensorDecision::Skip);
        assert_eq!(v.decide(20.0 + 1e-9, 4.0, 3), CensorDecision::Transmit);
    }

    #[test]
    fn variance_scaled_composes_with_staleness_bound() {
        use crate::data::batch::BatchSchedule;
        let inner = std::sync::Arc::new(VarianceScaledCensor {
            epsilon1: 1e12, // censors everything …
            schedule: BatchSchedule::Minibatch {
                size: 5,
                seed: 0,
                replace: false,
            },
            n_rows: 50,
        });
        let r = StalenessBoundedCensor::new(inner, 2);
        // … until the silence budget forces a refresh
        assert_eq!(r.decide(1.0, 1.0, 1), CensorDecision::Skip);
        assert_eq!(r.decide(1.0, 1.0, 2), CensorDecision::Skip);
        assert_eq!(r.decide(1.0, 1.0, 3), CensorDecision::Transmit);
    }

    #[test]
    fn adaptive_censor_anneals_geometrically() {
        let a = AdaptiveCensor { eps_hi: 100.0, eps_lo: 1.0, horizon: 10 };
        assert!((a.epsilon_at(0) - 100.0).abs() < 1e-12);
        assert!((a.epsilon_at(10) - 1.0).abs() < 1e-12);
        assert!((a.epsilon_at(5) - 10.0).abs() < 1e-9); // geometric midpoint
        // clamps beyond the horizon
        assert!((a.epsilon_at(99) - 1.0).abs() < 1e-12);
        // decisions follow the instantaneous threshold
        assert_eq!(a.decide(50.0, 1.0, 0), CensorDecision::Skip);
        assert_eq!(a.decide(50.0, 1.0, 10), CensorDecision::Transmit);
    }

    #[test]
    fn staleness_bound_forces_transmit_after_max_skips() {
        // inner rule that always censors
        let always_skip = std::sync::Arc::new(AbsoluteCensor { tau: f64::MAX });
        let r = StalenessBoundedCensor::new(always_skip, 2);
        let d = |k| r.decide(1.0, 1.0, k);
        // skip, skip, forced transmit, then the budget resets
        assert_eq!(d(1), CensorDecision::Skip);
        assert_eq!(d(2), CensorDecision::Skip);
        assert_eq!(d(3), CensorDecision::Transmit);
        assert_eq!(d(4), CensorDecision::Skip);
        assert_eq!(d(5), CensorDecision::Skip);
        assert_eq!(d(6), CensorDecision::Transmit);
    }

    #[test]
    fn staleness_bound_zero_disables_censoring() {
        let inner = std::sync::Arc::new(GradDiffCensor { epsilon1: 1e12 });
        let r = StalenessBoundedCensor::new(inner, 0);
        for k in 1..=5 {
            assert_eq!(r.decide(0.5, 1.0, k), CensorDecision::Transmit);
        }
    }

    #[test]
    fn staleness_bound_resets_on_voluntary_transmit() {
        let inner = std::sync::Arc::new(AbsoluteCensor { tau: 1.0 });
        let r = StalenessBoundedCensor::new(inner, 3);
        assert_eq!(r.decide(0.5, 0.0, 1), CensorDecision::Skip);
        // inner says transmit → counter resets
        assert_eq!(r.decide(2.0, 0.0, 2), CensorDecision::Transmit);
        // full budget of 3 skips available again
        assert_eq!(r.decide(0.5, 0.0, 3), CensorDecision::Skip);
        assert_eq!(r.decide(0.5, 0.0, 4), CensorDecision::Skip);
        assert_eq!(r.decide(0.5, 0.0, 5), CensorDecision::Skip);
        assert_eq!(r.decide(0.5, 0.0, 6), CensorDecision::Transmit);
    }

    #[test]
    fn epsilon1_scaling_matches_paper() {
        // ε₁ = 0.1/(α²M²) with α=0.5, M=9
        let e = epsilon1_scaled(0.1, 0.5, 9);
        assert!((e - 0.1 / (0.25 * 81.0)).abs() < 1e-15);
    }
}
