//! Censored Adam: a server-side adaptive step on the lazy aggregate.
//!
//! "Toward Communication Efficient Adaptive Gradient Method" shows
//! censored/lazy aggregation composes with Adam-style preconditioning:
//! workers keep the grad-diff skip rule (8) unchanged, the server keeps
//! the telescoping aggregate ∇ᵏ of eq. (5), and the *update* replaces
//! the heavy-ball step with bias-corrected Adam on ∇ᵏ:
//!
//! ```text
//! m ← β₁ m + (1−β₁) ∇ᵏ         v ← β₂ v + (1−β₂) (∇ᵏ)²
//! θ ← θ − α · (m / (1−β₁ᵗ)) / (√(v̂ / (1−β₂ᵗ)) + ε)
//! ```
//!
//! with `v̂ = max-so-far(v)` when AMSGrad is on, else `v̂ = v`.  The
//! moment vectors are runtime state (not checkpoint-serialized), so the
//! spec layer rejects the combination with checkpoint/restore axes.

use super::ServerRule;

/// Bias-corrected (optionally AMSGrad) Adam as a [`ServerRule`].
pub struct CensoredAdamRule {
    /// step size α
    pub alpha: f64,
    /// first-moment decay β₁
    pub beta1: f64,
    /// second-moment decay β₂
    pub beta2: f64,
    /// denominator stabilizer ε
    pub eps: f64,
    /// monotone second moment (AMSGrad)?
    pub amsgrad: bool,
    t: u64,
    m: Vec<f64>,
    v: Vec<f64>,
    vmax: Vec<f64>,
}

impl CensoredAdamRule {
    /// Rule for a `dim`-dimensional iterate; moments start at zero.
    pub fn new(
        alpha: f64,
        beta1: f64,
        beta2: f64,
        eps: f64,
        amsgrad: bool,
        dim: usize,
    ) -> Self {
        Self {
            alpha,
            beta1,
            beta2,
            eps,
            amsgrad,
            t: 0,
            m: vec![0.0; dim],
            v: vec![0.0; dim],
            vmax: vec![0.0; dim],
        }
    }
}

impl ServerRule for CensoredAdamRule {
    fn step(&mut self, theta: &mut [f64], theta_prev: &mut [f64], agg_grad: &[f64]) {
        // rotate first so theta_step_sq() sees θ^{k+1} − θ^k like the
        // other rules
        theta_prev.copy_from_slice(theta);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..theta.len() {
            let g = agg_grad[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let v = if self.amsgrad {
                if self.v[i] > self.vmax[i] {
                    self.vmax[i] = self.v[i];
                }
                self.vmax[i]
            } else {
                self.v[i]
            };
            let mhat = self.m[i] / bc1;
            let vhat = v / bc2;
            theta[i] -= self.alpha * mhat / (vhat.sqrt() + self.eps);
        }
    }

    fn name(&self) -> &'static str {
        "censored-adam"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_signed_alpha_step() {
        // t=1: m/bc1 = g, v/bc2 = g² → θ −= α·g/(|g|+ε) ≈ α·sign(g)
        let mut rule = CensoredAdamRule::new(0.1, 0.9, 0.999, 1e-8, false, 2);
        let mut th = vec![1.0, -1.0];
        let mut tp = vec![0.0, 0.0];
        rule.step(&mut th, &mut tp, &[4.0, -0.5]);
        assert_eq!(tp, vec![1.0, -1.0]);
        assert!((th[0] - (1.0 - 0.1 * 4.0 / (4.0 + 1e-8))).abs() < 1e-12);
        assert!((th[1] - (-1.0 + 0.1 * 0.5 / (0.5 + 1e-8))).abs() < 1e-12);
    }

    #[test]
    fn zero_gradient_leaves_theta_fixed() {
        let mut rule = CensoredAdamRule::new(0.1, 0.9, 0.999, 1e-8, true, 1);
        let mut th = vec![3.0];
        let mut tp = vec![2.0];
        rule.step(&mut th, &mut tp, &[0.0]);
        assert_eq!(th, vec![3.0]);
        assert_eq!(tp, vec![3.0]);
    }

    #[test]
    fn amsgrad_keeps_monotone_denominator() {
        let mut ams = CensoredAdamRule::new(0.1, 0.9, 0.5, 1e-8, true, 1);
        let mut plain = CensoredAdamRule::new(0.1, 0.9, 0.5, 1e-8, false, 1);
        let (mut th_a, mut tp_a) = (vec![0.0], vec![0.0]);
        let (mut th_p, mut tp_p) = (vec![0.0], vec![0.0]);
        // big gradient then small: AMSGrad's v̂ stays at the big value,
        // so its second step is strictly smaller in magnitude
        for rule_io in [
            (&mut ams, &mut th_a, &mut tp_a),
            (&mut plain, &mut th_p, &mut tp_p),
        ] {
            let (rule, th, tp) = rule_io;
            rule.step(th, tp, &[10.0]);
            rule.step(th, tp, &[0.1]);
        }
        let step_a = (th_a[0] - tp_a[0]).abs();
        let step_p = (th_p[0] - tp_p[0]).abs();
        assert!(step_a < step_p);
    }

    #[test]
    fn descends_a_quadratic() {
        // f(θ) = ½θ², ∇ = θ; 200 Adam steps from θ=5 should land near 0
        let mut rule = CensoredAdamRule::new(0.2, 0.9, 0.999, 1e-8, false, 1);
        let mut th = vec![5.0];
        let mut tp = vec![5.0];
        for _ in 0..200 {
            let g = [th[0]];
            rule.step(&mut th, &mut tp, &g);
        }
        assert!(th[0].abs() < 0.5, "theta = {}", th[0]);
    }
}
