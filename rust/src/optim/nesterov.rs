//! Censored Nesterov accelerated gradient (CNAG) — a beyond-paper
//! extension along the paper's own axis: the censoring rule (8) is
//! agnostic to the *server* update, so any momentum-type method can be
//! censored.  Nesterov momentum evaluates the gradient at the
//! look-ahead point; in the server-side formulation used here the
//! update is
//!
//! ```text
//! θ^{k+1} = θᵏ − α∇ᵏ + β(θᵏ − θ^{k−1}) − αβ(∇ᵏ − ∇^{k−1})
//! ```
//!
//! (the "gradient-correction" form of NAG, which needs no extra
//! broadcast — workers still see only θᵏ).  The ablation
//! `experiments::ablations::nesterov` compares CHB vs censored-NAG.

use crate::linalg;

use super::ServerRule;

/// Server-side Nesterov accelerated gradient (gradient-correction
/// form).
pub struct NesterovRule {
    /// step size α
    pub alpha: f64,
    /// momentum coefficient β
    pub beta: f64,
    momentum: Vec<f64>,
    prev_agg: Vec<f64>,
    have_prev: bool,
}

impl NesterovRule {
    /// Rule for a `dim`-dimensional iterate with step α, momentum β.
    pub fn new(alpha: f64, beta: f64, dim: usize) -> Self {
        Self {
            alpha,
            beta,
            momentum: vec![0.0; dim],
            prev_agg: vec![0.0; dim],
            have_prev: false,
        }
    }
}

impl ServerRule for NesterovRule {
    fn step(&mut self, theta: &mut [f64], theta_prev: &mut [f64], agg_grad: &[f64]) {
        linalg::sub_into(theta, theta_prev, &mut self.momentum);
        theta_prev.copy_from_slice(theta);
        linalg::axpy(-self.alpha, agg_grad, theta);
        linalg::axpy(self.beta, &self.momentum, theta);
        if self.have_prev {
            // −αβ(∇ᵏ − ∇^{k−1})
            for i in 0..theta.len() {
                theta[i] -=
                    self.alpha * self.beta * (agg_grad[i] - self.prev_agg[i]);
            }
        }
        self.prev_agg.copy_from_slice(agg_grad);
        self.have_prev = true;
    }

    fn name(&self) -> &'static str {
        "nag"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_matches_heavy_ball() {
        // with no previous aggregate the correction term is zero
        let mut nag = NesterovRule::new(0.1, 0.4, 2);
        let mut hb = super::super::HeavyBallRule::new(0.1, 0.4, 2);
        let g = vec![1.0, -2.0];
        let (mut t1, mut p1) = (vec![1.0, 2.0], vec![0.5, 1.5]);
        let (mut t2, mut p2) = (t1.clone(), p1.clone());
        nag.step(&mut t1, &mut p1, &g);
        hb.step(&mut t2, &mut p2, &g);
        assert_eq!(t1, t2);
    }

    #[test]
    fn correction_term_applies_from_second_step() {
        let (a, b) = (0.5, 0.5);
        let mut nag = NesterovRule::new(a, b, 1);
        let mut theta = vec![0.0];
        let mut prev = vec![0.0];
        nag.step(&mut theta, &mut prev, &[1.0]); // θ = −0.5
        assert_eq!(theta, vec![-0.5]);
        // second step with ∇ = 2: HB part: −0.5 −0.5·2 + 0.5(−0.5−0)
        // = −1.75; correction −αβ(2−1) = −0.25 ⇒ −2.0
        nag.step(&mut theta, &mut prev, &[2.0]);
        assert!((theta[0] + 2.0).abs() < 1e-15);
    }

    #[test]
    fn nag_converges_faster_than_gd_on_ill_conditioned_quadratic() {
        // f(θ) = ½θᵀdiag(1, 100)θ — classic acceleration test
        let grad = |t: &[f64]| vec![t[0], 100.0 * t[1]];
        let f = |t: &[f64]| 0.5 * (t[0] * t[0] + 100.0 * t[1] * t[1]);
        let run = |rule: &mut dyn ServerRule, iters: usize| {
            let mut theta = vec![1.0, 1.0];
            let mut prev = theta.clone();
            for _ in 0..iters {
                let g = grad(&theta);
                rule.step(&mut theta, &mut prev, &g);
            }
            f(&theta)
        };
        let alpha = 1.0 / 100.0;
        let beta = 0.8;
        let mut nag = NesterovRule::new(alpha, beta, 2);
        let mut gd = super::super::GdRule { alpha };
        let f_nag = run(&mut nag, 150);
        let f_gd = run(&mut gd, 150);
        assert!(f_nag < f_gd * 1e-2, "nag {f_nag} vs gd {f_gd}");
    }
}
