//! Figure drivers — one per paper figure (DESIGN.md §5).
//!
//! Each writes CSVs under `results/<fig>/` with exactly the series the
//! paper plots (objective error vs communications and vs iterations,
//! per-worker comm maps, ε₁/step-size sweeps, per-communication
//! descent) and prints a compact summary.

use std::path::Path;

use anyhow::Result;

use crate::coordinator::StopRule;
use crate::data::synthetic;
use crate::metrics::csv;
use crate::optim::Method;
use crate::tasks::TaskKind;

use super::runner::{self, Protocol};
use super::tables::{self, SuiteEntry};
use super::Problem;

/// The Fig. 1/2 synthetic linear-regression problem: M = 9 workers,
/// 50×50 standard-normal shards, L_m = (1.3^{m−1})².
pub fn synth_linreg_problem(seed: u64) -> Problem {
    let l_m = synthetic::increasing_l(9);
    let per_worker = synthetic::per_worker_rescaled(seed, 9, 50, 50, &l_m);
    Problem::from_worker_datasets(TaskKind::LinReg, "synth", &per_worker, 0.0)
}

/// The Fig. 3 synthetic logistic problem: common smoothness L_m = 4.
/// For logistic regression L_m = ¼λ_max(XᵀX) + λ_m, so each worker's
/// features are rescaled to λ_max = 4(4 − λ_m).
pub fn synth_logreg_problem(seed: u64, lam_global: f64) -> Problem {
    let m = 9;
    let lam_m = lam_global / m as f64;
    let target_lambda_max = 4.0 * (4.0 - lam_m);
    let mut root = crate::rng::Xoshiro256::new(seed);
    let per_worker: Vec<_> = (0..m)
        .map(|i| {
            let mut rng = root.split();
            let mut ds = synthetic::gaussian_pm1(&mut rng, 50, 50);
            synthetic::rescale_to_lambda_max(&mut ds.x, target_lambda_max);
            ds.source = format!("synthetic logreg worker {i}, L_m=4");
            ds
        })
        .collect();
    Problem::from_worker_datasets(TaskKind::LogReg, "synth", &per_worker, lam_global)
}

// ---------------------------------------------------------------------------
// Fig. 1 — per-worker communication pattern, first 24 iterations
// ---------------------------------------------------------------------------

/// Fig. 1 — per-worker communication pattern over the first 24
/// iterations (CHB vs HB) with the Lemma-2 bound check.
pub fn fig1(out_dir: &Path, _data_dir: &Path, _quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xF1);
    let proto = Protocol::paper_default(1.0 / p.l_global, 24);
    for method in [Method::Chb, Method::Hb] {
        let trace = runner::run_method(&p, method, &proto, true);
        csv::write_comm_map(
            &out_dir.join("fig1").join(format!("{}_comm_map.csv", trace.method)),
            &trace,
        )?;
        println!("\nFig.1 {} — transmissions per worker (24 iters):", trace.method);
        for (w, &c) in trace.per_worker_comms.iter().enumerate() {
            let bound = crate::theory::lemma2_bound(24);
            // the Lemma-2 bound only concerns the censored method
            let lm2 = method == Method::Chb
                && crate::theory::lemma2_applies(
                    p.l_m[w],
                    proto.params(p.m_workers()).epsilon1,
                );
            println!(
                "  worker {w}: L_m={:9.4}  S_m={c:2}{}",
                p.l_m[w],
                if lm2 {
                    format!("  (Lemma 2: ≤ {bound}: {})", c <= bound)
                } else {
                    String::new()
                }
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 2 / Fig. 3 — objective error vs comms & iters (synthetic)
// ---------------------------------------------------------------------------

/// Fig. 2 — objective error vs comms/iters, synthetic linreg.
pub fn fig2(out_dir: &Path, _data_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xF1);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 400 } else { 1_000 };
    let proto = Protocol::paper_default(1.0 / p.l_global, iters)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-13 });
    let traces = runner::run_all_methods(&p, &proto);
    runner::write_traces(out_dir, "fig2", &traces, f_star)?;
    runner::print_summary("fig2 (synthetic linreg, increasing L_m)", &p, &traces, f_star);
    Ok(())
}

/// Fig. 3 — objective error vs comms/iters, synthetic logreg.
pub fn fig3(out_dir: &Path, _data_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_logreg_problem(0xF3, 0.001);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 600 } else { 2_000 };
    let proto = Protocol::paper_default(1.0 / p.l_global, iters)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-10 });
    let traces = runner::run_all_methods(&p, &proto);
    runner::write_traces(out_dir, "fig3", &traces, f_star)?;
    runner::print_summary("fig3 (synthetic logreg, common L_m=4)", &p, &traces, f_star);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 4 / Fig. 5 — ijcnn1 (reuse the Table-I suite runs)
// ---------------------------------------------------------------------------

/// Fig. 4 — ijcnn1 linreg + logreg (Table-I suite subset).
pub fn fig4(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries: Vec<SuiteEntry> = tables::table1_suite(data_dir, quick)?
        .into_iter()
        .filter(|e| matches!(e.task, TaskKind::LinReg | TaskKind::LogReg))
        .collect();
    tables::write_suite(out_dir, "fig4", &entries)?;
    tables::print_table("Fig.4 (ijcnn1 linreg + logreg)", &entries, false);
    Ok(())
}

/// Fig. 5 — ijcnn1 lasso + NN (Table-I suite subset).
pub fn fig5(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries: Vec<SuiteEntry> = tables::table1_suite(data_dir, quick)?
        .into_iter()
        .filter(|e| matches!(e.task, TaskKind::Lasso | TaskKind::Nn))
        .collect();
    tables::write_suite(out_dir, "fig5", &entries)?;
    tables::print_table("Fig.5 (ijcnn1 lasso + NN)", &entries, false);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 6 / Fig. 7 — small UCI (Table-II suite)
// ---------------------------------------------------------------------------

/// Fig. 6 — small-UCI linreg + logreg (Table-II suite subset).
pub fn fig6(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries: Vec<SuiteEntry> = tables::table2_suite(data_dir, quick)?
        .into_iter()
        .filter(|e| matches!(e.task, TaskKind::LinReg | TaskKind::LogReg))
        .collect();
    tables::write_suite(out_dir, "fig6", &entries)?;
    tables::print_table("Fig.6 (small UCI linreg + logreg)", &entries, false);
    Ok(())
}

/// Fig. 7 — small-UCI lasso + NN (Table-II suite subset).
pub fn fig7(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries: Vec<SuiteEntry> = tables::table2_suite(data_dir, quick)?
        .into_iter()
        .filter(|e| matches!(e.task, TaskKind::Lasso | TaskKind::Nn))
        .collect();
    tables::write_suite(out_dir, "fig7", &entries)?;
    tables::print_table("Fig.7 (small UCI lasso + NN)", &entries, false);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 8 / Fig. 9 — MNIST (Table-III suite)
// ---------------------------------------------------------------------------

/// Fig. 8 — MNIST linreg + logreg (Table-III suite subset).
pub fn fig8(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries: Vec<SuiteEntry> = tables::table3_suite(data_dir, quick)?
        .into_iter()
        .filter(|e| matches!(e.task, TaskKind::LinReg | TaskKind::LogReg))
        .collect();
    tables::write_suite(out_dir, "fig8", &entries)?;
    tables::print_table("Fig.8 (MNIST linreg + logreg)", &entries, true);
    Ok(())
}

/// Fig. 9 — MNIST lasso + NN (Table-III suite subset).
pub fn fig9(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries: Vec<SuiteEntry> = tables::table3_suite(data_dir, quick)?
        .into_iter()
        .filter(|e| matches!(e.task, TaskKind::Lasso | TaskKind::Nn))
        .collect();
    tables::write_suite(out_dir, "fig9", &entries)?;
    tables::print_table("Fig.9 (MNIST lasso + NN)", &entries, true);
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 10 — step-size study (MNIST linreg)
// ---------------------------------------------------------------------------

/// Paper: same linreg setup, α swept a decade apart (2.2e-7 vs
/// 2.2e-8); shows small α saves comms for the censored methods and
/// the momentum term keeps CHB stable at large α.  Re-expressed as
/// fractions of 1/L for the stand-in: {0.09, 0.9, 1.8}/L.
pub fn fig10(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let cap = Some(if quick { 2_700 } else { 9_000 });
    let iters = if quick { 500 } else { 2_000 };
    let p = tables::registry_problem(TaskKind::LinReg, "mnist", data_dir, 0.0, cap)?;
    let f_star = p.f_star().unwrap();
    // last entry sits above the true 2/λ_max(ΣXᵀX) stability edge
    // (L = Σ_m λ_max is a conservative bound) — the Fig. 10(d) regime
    let fracs = [0.09, 0.9, 1.8, 3.0];
    println!("\nFig.10 (MNIST linreg step-size study), f*={f_star:.6e}");
    for (i, frac) in fracs.iter().enumerate() {
        let alpha = frac / p.l_global;
        let proto = Protocol::paper_default(alpha, iters);
        let traces = runner::run_all_methods(&p, &proto);
        let id = format!("fig10/alpha{i}");
        runner::write_traces(out_dir, &id, &traces, f_star)?;
        println!("α = {frac}/L:");
        for t in &traces {
            println!(
                "  {:<4} comms {:>7}  final err {:.4e}",
                t.method,
                t.total_comms(),
                t.final_loss() - f_star
            );
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 11 — ε₁ sweep (synthetic logreg)
// ---------------------------------------------------------------------------

/// Fig. 11 — the ε₁ comms/accuracy frontier on synthetic logreg.
pub fn fig11(out_dir: &Path, _data_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_logreg_problem(0xF3, 0.001);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 600 } else { 2_000 };
    let alpha = 1.0 / p.l_global;
    println!("\nFig.11 (ε₁ sweep, synthetic logreg), f*={f_star:.6e}");
    // HB reference (ε₁ = 0 limit)
    let hb = runner::run_method(
        &p,
        Method::Hb,
        &Protocol::paper_default(alpha, iters)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-10 }),
        false,
    );
    csv::write_trace(&out_dir.join("fig11").join("HB.csv"), &hb, f_star)?;
    println!("  HB           comms {:>7} iters {:>6}", hb.total_comms(), hb.iterations());
    for (i, c) in [0.01, 0.1, 1.0].iter().enumerate() {
        let mut proto = Protocol::paper_default(alpha, iters)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-10 });
        proto.eps_c = *c;
        let t = runner::run_method(&p, Method::Chb, &proto, false);
        csv::write_trace(
            &out_dir.join("fig11").join(format!("CHB_eps{i}.csv")),
            &t,
            f_star,
        )?;
        println!(
            "  CHB ε₁={c:>5}/(α²M²) comms {:>7} iters {:>6} final err {:.3e}",
            t.total_comms(),
            t.iterations(),
            t.final_loss() - f_star
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Fig. 12 — averaged per-communication descent (synthetic logreg)
// ---------------------------------------------------------------------------

/// Fig. 12 — averaged per-communication descent, CHB vs LAG.
pub fn fig12(out_dir: &Path, _data_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_logreg_problem(0xF3, 0.001);
    let f_star = p.f_star().unwrap();
    let f0 = super::fstar::objective(&p, &p.theta0());
    let iters = if quick { 600 } else { 2_000 };
    let proto = Protocol::paper_default(1.0 / p.l_global, iters)
        .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-10 });
    println!("\nFig.12 (avg per-communication descent), f(θ⁰)={f0:.4e}");
    for method in [Method::Chb, Method::Lag] {
        let t = runner::run_method(&p, method, &proto, false);
        let rows: Vec<Vec<String>> = t
            .per_comm_descent(f0)
            .iter()
            .map(|(k, loss, d)| {
                vec![
                    k.to_string(),
                    format!("{:.8e}", loss - f_star),
                    format!("{d:.8e}"),
                ]
            })
            .collect();
        csv::write_table(
            &out_dir.join("fig12").join(format!("{}.csv", t.method)),
            &["k", "obj_err", "avg_per_comm_descent"],
            &rows,
        )?;
        let last = rows.last().map(|r| r[2].clone()).unwrap_or_default();
        println!("  {:<4} final avg descent/comm = {last}", t.method);
    }
    Ok(())
}
