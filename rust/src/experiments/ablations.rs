//! Beyond-paper ablation studies (DESIGN.md §5 "extensions").
//!
//! * censor-rule variants: the paper's relative rule vs an absolute
//!   threshold vs periodic transmission — shows why (8) is the right
//!   shape.
//! * β sweep: momentum's effect on both iterations *and* censoring.
//! * worker scaling: comm savings as M grows.
//! * failure injection: CHB under lossy uplinks.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::{
    run_with_rules, AsyncConfig, ComputeModel, EngineKind, Participation,
    RunConfig, SerialPool, Server,
};
use crate::net::{DownlinkSpec, LatencyModel};
use crate::metrics::csv;
use crate::optim::censor::{AbsoluteCensor, PeriodicCensor};
use crate::optim::{
    CensorRule, GradDiffCensor, Method, MethodParams, MethodSpec,
};
use crate::spec::{
    CensorSpec, CodecSpec, DropSpec, EpsilonSpec, ParamSpec, RunSpec,
    Session,
};
use crate::tasks::TaskKind;

use super::figures::synth_linreg_problem;
use super::runner::{self, Protocol};
use super::Problem;

/// Run CHB but with an arbitrary censor rule — the engine's
/// `run_with_rules` injection point (one round loop, no mirror).
fn run_with_censor(
    problem: &Problem,
    params: MethodParams,
    censor: Arc<dyn CensorRule>,
    iters: usize,
) -> crate::metrics::Trace {
    let mut workers = problem.rust_workers();
    let cfg = RunConfig::new(Method::Chb, params, iters);
    let server = Server::new(Method::Chb, &params, problem.theta0());
    let label = censor.name();
    run_with_rules(
        &mut SerialPool::new(&mut workers),
        &cfg,
        server,
        censor,
        label,
    )
}

/// Ablation A: censor-rule shapes at matched comm budgets.
pub fn censor_rules(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB1);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 300 } else { 1_000 };
    let params = MethodParams::new(1.0 / p.l_global)
        .with_beta(0.4)
        .with_epsilon1_scaled(0.1, p.m_workers());

    println!("\n── ablation: censor rules (synthetic linreg), f*={f_star:.4e}");
    let rules: Vec<Arc<dyn CensorRule>> = vec![
        Arc::new(GradDiffCensor { epsilon1: params.epsilon1 }),
        Arc::new(AbsoluteCensor { tau: 1.0 }),
        Arc::new(AbsoluteCensor { tau: 100.0 }),
        Arc::new(PeriodicCensor::new(2)),
    ];
    let labels = ["grad-diff (paper)", "absolute τ=1", "absolute τ=100", "periodic /2"];
    let mut rows = Vec::new();
    for (rule, label) in rules.iter().zip(labels) {
        let t = run_with_censor(&p, params, Arc::clone(rule), iters);
        println!(
            "  {label:<20} comms {:>6}  final err {:.4e}",
            t.total_comms(),
            t.final_loss() - f_star
        );
        rows.push(vec![
            label.to_string(),
            t.total_comms().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_censor").join("summary.csv"),
        &["rule", "comms", "final_obj_err"],
        &rows,
    )
}

/// Ablation B: momentum sweep — β's joint effect on iterations and
/// censoring (the paper fixes β = 0.4 throughout).
pub fn beta_sweep(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB2);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 400 } else { 1_500 };
    println!("\n── ablation: β sweep (CHB, synthetic linreg)");
    let mut rows = Vec::new();
    for beta in [0.0, 0.2, 0.4, 0.6, 0.8] {
        let proto = Protocol {
            alpha: 1.0 / p.l_global,
            beta,
            eps_c: 0.1,
            eps_abs: None,
            max_iters: iters,
            stop: crate::coordinator::StopRule::ObjErrBelow {
                f_star,
                tol: 1e-10,
            },
            participation: Participation::Full,
            engine: EngineKind::Serial,
        };
        let t = runner::run_method(&p, Method::Chb, &proto, false);
        println!(
            "  β={beta:.1}  comms {:>6}  iters {:>6}  final err {:.3e}",
            t.total_comms(),
            t.iterations(),
            t.final_loss() - f_star
        );
        rows.push(vec![
            beta.to_string(),
            t.total_comms().to_string(),
            t.iterations().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_beta").join("summary.csv"),
        &["beta", "comms", "iters", "final_obj_err"],
        &rows,
    )
}

/// Ablation C: worker-count scaling M ∈ {3, 9, 27}.
pub fn worker_scaling(out_dir: &Path, quick: bool) -> Result<()> {
    let iters = if quick { 300 } else { 1_000 };
    println!("\n── ablation: worker scaling (CHB vs HB comms @ equal err)");
    let mut rows = Vec::new();
    for m in [3usize, 9, 27] {
        let l_m: Vec<f64> = (0..m).map(|i| (1.0 + i as f64 * 0.5).powi(2)).collect();
        let per_worker =
            crate::data::synthetic::per_worker_rescaled(0xAB3, m, 50, 30, &l_m);
        let p = Problem::from_worker_datasets(
            TaskKind::LinReg,
            "scale",
            &per_worker,
            0.0,
        );
        let f_star = p.f_star().unwrap();
        let proto = Protocol::paper_default(1.0 / p.l_global, iters).with_stop(
            crate::coordinator::StopRule::ObjErrBelow { f_star, tol: 1e-9 },
        );
        let chb = runner::run_method(&p, Method::Chb, &proto, false);
        let hb = runner::run_method(&p, Method::Hb, &proto, false);
        let saving = 1.0 - chb.total_comms() as f64 / hb.total_comms().max(1) as f64;
        println!(
            "  M={m:<3} CHB {:>6} vs HB {:>6}  (saving {:.1}%)",
            chb.total_comms(),
            hb.total_comms(),
            100.0 * saving
        );
        rows.push(vec![
            m.to_string(),
            chb.total_comms().to_string(),
            hb.total_comms().to_string(),
            format!("{saving:.4}"),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_scaling").join("summary.csv"),
        &["workers", "chb_comms", "hb_comms", "saving"],
        &rows,
    )
}

/// Ablation D: lossy uplinks — CHB's stale-aggregate tolerance.
/// Each run is one `RunSpec` (the drop axis is a spec field).
pub fn failure_injection(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB4);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 400 } else { 1_500 };
    println!("\n── ablation: uplink drop probability (CHB)");
    let mut rows = Vec::new();
    for drop in [0.0, 0.01, 0.05, 0.1] {
        let spec = RunSpec {
            params: ParamSpec {
                alpha: Some(1.0 / p.l_global),
                beta: 0.4,
                epsilon: EpsilonSpec::Scaled { c: 0.1 },
            },
            iters,
            drops: DropSpec { prob: drop, seed: 0xD20 },
            ..RunSpec::new(p.task, &p.dataset)
        };
        let t = Session::from_parts(spec, p.clone())
            .expect("valid ablation spec")
            .run()
            .trace;
        println!(
            "  drop={drop:<5} comms {:>6}  final err {:.4e}",
            t.total_comms(),
            t.final_loss() - f_star
        );
        rows.push(vec![
            drop.to_string(),
            t.total_comms().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_drops").join("summary.csv"),
        &["drop_prob", "delivered_comms", "final_obj_err"],
        &rows,
    )
}

/// Ablation E: CHB ∘ uplink compression — the composition the paper's
/// conclusion proposes.  Censoring cuts the *number* of uplinks;
/// quantization / top-k cut the *bits per uplink*; together they
/// multiply.
pub fn compression(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB5);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 400 } else { 1_500 };
    println!("\n── ablation: CHB ∘ uplink compression (synthetic linreg)");
    let codecs: [(&str, CodecSpec); 4] = [
        ("f64 (none)", CodecSpec::None),
        ("quant-8bit", CodecSpec::Quantizer { bits: 8 }),
        ("quant-4bit", CodecSpec::Quantizer { bits: 4 }),
        ("top-25", CodecSpec::TopK { k: 25 }),
    ];
    let mut rows = Vec::new();
    for (label, codec) in codecs {
        let spec = RunSpec {
            params: ParamSpec {
                alpha: Some(1.0 / p.l_global),
                beta: 0.4,
                epsilon: EpsilonSpec::Scaled { c: 0.1 },
            },
            iters,
            codec,
            stop: crate::spec::StopSpec::ObjErr {
                tol: 1e-9,
                f_star: Some(f_star),
            },
            ..RunSpec::new(p.task, &p.dataset)
        };
        let t = Session::from_parts(spec, p.clone())
            .expect("valid ablation spec")
            .run()
            .trace;
        let bits = t.iters.last().map_or(0, |s| s.bits_cum);
        println!(
            "  {label:<14} comms {:>6}  uplink {:>8.1} KiB  iters {:>5}  \
             final err {:.3e}",
            t.total_comms(),
            bits as f64 / 8.0 / 1024.0,
            t.iterations(),
            t.final_loss() - f_star
        );
        rows.push(vec![
            label.to_string(),
            t.total_comms().to_string(),
            bits.to_string(),
            t.iterations().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_compression").join("summary.csv"),
        &["codec", "comms", "uplink_bits", "iters", "final_obj_err"],
        &rows,
    )
}

/// Ablation E2: the compression ladder — none / top-k / int8+EF /
/// fp16 on all four tasks, each with censoring on (CHB's default
/// rule) and off ([`CensorSpec::Never`]).
///
/// This is the bits-to-accuracy grid for the packed codecs: for every
/// (task, rung, censor) cell the summary records the cumulative
/// uplink bits spent to first reach the accuracy target (90 % of the
/// initial objective error eliminated; half the initial loss for the
/// nonconvex NN).  The headline row pair is `int8-ef` vs `f64`: the
/// packed 8-bit quantizer with error feedback reaches the same target
/// at ≤ ¼ of the uplink bits (8 + ε bits per coordinate instead of
/// 64), while censoring multiplies orthogonally on top by cutting the
/// *number* of uplinks.
pub fn ladder(out_dir: &Path, quick: bool) -> Result<()> {
    let iters = if quick { 500 } else { 2_000 };
    let dir = out_dir.join("ablation_ladder");
    println!("\n── ablation: compression ladder × censoring (all tasks)");
    let rungs: [(&str, CodecSpec); 4] = [
        ("f64", CodecSpec::None),
        ("top-25", CodecSpec::TopK { k: 25 }),
        ("int8-ef", CodecSpec::Int { bits: 8, error_feedback: true }),
        ("fp16", CodecSpec::Fp16 { error_feedback: false }),
    ];
    let mut rows = Vec::new();
    for (ti, task) in [
        TaskKind::LinReg,
        TaskKind::LogReg,
        TaskKind::Lasso,
        TaskKind::Nn,
    ]
    .into_iter()
    .enumerate()
    {
        let m = 4usize;
        let l_m: Vec<f64> =
            (0..m).map(|i| (1.0 + 0.5 * i as f64).powi(2)).collect();
        let per_worker = crate::data::synthetic::per_worker_rescaled(
            0xAB20 + ti as u64,
            m,
            96,
            10,
            &l_m,
        );
        let lam = match task {
            TaskKind::Lasso => 0.05,
            TaskKind::LogReg | TaskKind::Nn => 0.01,
            TaskKind::LinReg => 0.0,
        };
        let p = Problem::from_worker_datasets(task, "ladder", &per_worker, lam);
        let f_star = p.f_star();
        let f0 = super::fstar::objective(&p, &p.theta0());
        let target = match f_star {
            Some(fs) => fs + 0.1 * (f0 - fs),
            None => 0.5 * f0,
        };
        for (rung, codec) in rungs {
            for censor_on in [true, false] {
                let censor = if censor_on {
                    CensorSpec::MethodDefault
                } else {
                    CensorSpec::Never
                };
                let spec = RunSpec {
                    label: Some(format!("{rung}-{}", censor.name())),
                    params: ParamSpec {
                        alpha: Some(0.5 / p.l_global),
                        beta: 0.4,
                        epsilon: EpsilonSpec::Scaled { c: 0.1 },
                    },
                    censor,
                    codec,
                    iters,
                    lambda: p.lambda_global(),
                    ..RunSpec::new(task, &p.dataset)
                };
                let t = Session::from_parts(spec, p.clone())
                    .expect("valid ablation spec")
                    .run()
                    .trace;
                let bits_total = t.iters.last().map_or(0, |s| s.bits_cum);
                let hit = t.iters.iter().find(|s| s.loss <= target);
                let (k_hit, bits_hit) = hit
                    .map(|s| (s.k.to_string(), s.bits_cum.to_string()))
                    .unwrap_or_else(|| ("-".into(), "-".into()));
                println!(
                    "  {:<7} {rung:<8} censor={:<3} comms {:>6}  \
                     bits→target {:>10}  k→target {:>5}  final f {:.4e}",
                    task.name(),
                    if censor_on { "on" } else { "off" },
                    t.total_comms(),
                    bits_hit,
                    k_hit,
                    t.final_loss(),
                );
                rows.push(vec![
                    task.name().to_string(),
                    rung.to_string(),
                    (if censor_on { "on" } else { "off" }).to_string(),
                    t.total_comms().to_string(),
                    bits_total.to_string(),
                    k_hit,
                    bits_hit,
                    format!("{:.8e}", t.final_loss()),
                    format!("{target:.8e}"),
                ]);
            }
        }
    }
    csv::write_table(
        &dir.join("summary.csv"),
        &[
            "task",
            "rung",
            "censor",
            "comms",
            "uplink_bits_total",
            "k_to_target",
            "uplink_bits_to_target",
            "final_loss",
            "target_loss",
        ],
        &rows,
    )
}

/// Run one problem with an arbitrary (server rule, censor) pair —
/// the generalized composition the extensions explore, through the
/// same engine pipeline as every normal run.
fn run_custom(
    problem: &Problem,
    rule: Box<dyn crate::optim::ServerRule>,
    censor: Arc<dyn CensorRule>,
    label: &str,
    iters: usize,
    stop_err: Option<(f64, f64)>,
) -> crate::metrics::Trace {
    let mut workers = problem.rust_workers();
    // method/params in the config are placeholders: the injected
    // (rule, censor) pair carries the actual algorithm
    let mut cfg = RunConfig::new(Method::Chb, MethodParams::new(0.0), iters);
    if let Some((f_star, tol)) = stop_err {
        cfg = cfg.with_stop(crate::coordinator::StopRule::ObjErrBelow {
            f_star,
            tol,
        });
    }
    run_with_rules(
        &mut SerialPool::new(&mut workers),
        &cfg,
        Server::with_rule(rule, problem.theta0()),
        censor,
        label,
    )
}

/// Ablation F: censored Nesterov (CNAG) vs CHB vs censored GD — the
/// censor rule composes with any momentum scheme.  Each variant is a
/// [`MethodSpec`] cell on the declarative grid (the rule-injection
/// side door this ablation used to need is pinned bit-identical by
/// `nesterov_grid_matches_the_rule_injection_side_door`).
pub fn nesterov(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB6);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 800 } else { 3_000 };
    let alpha = 1.0 / p.l_global;
    println!("\n── ablation: censored momentum family (synthetic linreg)");
    let cases: [(&str, MethodSpec); 3] = [
        ("C-GD (LAG)", MethodSpec::Classic(Method::Lag)),
        ("CHB (paper)", MethodSpec::Classic(Method::Chb)),
        ("C-NAG", MethodSpec::Nesterov { censored: true }),
    ];
    let mut rows = Vec::new();
    for (label, method) in cases {
        let spec = RunSpec {
            method,
            params: ParamSpec {
                alpha: Some(alpha),
                beta: 0.4,
                epsilon: EpsilonSpec::Scaled { c: 0.1 },
            },
            iters,
            stop: crate::spec::StopSpec::ObjErr {
                tol: 1e-9,
                f_star: Some(f_star),
            },
            ..RunSpec::new(p.task, &p.dataset)
        };
        let t = Session::from_parts(spec, p.clone())
            .expect("valid ablation spec")
            .run()
            .trace;
        println!(
            "  {label:<12} comms {:>6}  iters {:>5}  final err {:.3e}",
            t.total_comms(),
            t.iterations(),
            t.final_loss() - f_star
        );
        rows.push(vec![
            label.to_string(),
            t.total_comms().to_string(),
            t.iterations().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_nesterov").join("summary.csv"),
        &["rule", "comms", "iters", "final_obj_err"],
        &rows,
    )
}

/// Ablation G: adaptive ε₁ annealing vs the paper's fixed threshold
/// (the conclusion's open problem).
pub fn adaptive_epsilon(out_dir: &Path, quick: bool) -> Result<()> {
    use crate::optim::{AdaptiveCensor, HeavyBallRule};
    let p = synth_linreg_problem(0xAB7);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 800 } else { 3_000 };
    let alpha = 1.0 / p.l_global;
    let m = p.m_workers();
    let eps_ref = crate::optim::censor::epsilon1_scaled(0.1, alpha, m);
    println!("\n── ablation: adaptive ε₁ (anneal hi→lo) vs fixed");
    let mut rows = Vec::new();
    let cases: Vec<(&str, Arc<dyn CensorRule>)> = vec![
        ("fixed 0.1", Arc::new(GradDiffCensor { epsilon1: eps_ref })),
        (
            "anneal 10→0.01",
            Arc::new(AdaptiveCensor {
                eps_hi: crate::optim::censor::epsilon1_scaled(10.0, alpha, m),
                eps_lo: crate::optim::censor::epsilon1_scaled(0.01, alpha, m),
                horizon: iters / 4,
            }),
        ),
        (
            "anneal 1→0.1",
            Arc::new(AdaptiveCensor {
                eps_hi: crate::optim::censor::epsilon1_scaled(1.0, alpha, m),
                eps_lo: eps_ref,
                horizon: iters / 4,
            }),
        ),
    ];
    for (label, censor) in cases {
        let rule = Box::new(HeavyBallRule::new(alpha, 0.4, p.dim()));
        let t = run_custom(&p, rule, censor, label, iters,
                           Some((f_star, 1e-9)));
        println!(
            "  {label:<16} comms {:>6}  iters {:>5}  final err {:.3e}",
            t.total_comms(),
            t.iterations(),
            t.final_loss() - f_star
        );
        rows.push(vec![
            label.to_string(),
            t.total_comms().to_string(),
            t.iterations().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
        ]);
    }
    csv::write_table(
        &out_dir.join("ablation_adaptive_eps").join("summary.csv"),
        &["schedule", "comms", "iters", "final_obj_err"],
        &rows,
    )
}

/// Ablation H: censoring ∘ partial participation — the scheduling
/// axis the paper assumes away.  Sweeps sampling fraction × ε₁ on the
/// synthetic linreg problem and shows the two mechanisms compose:
/// sampling caps who is *asked*, censoring decides who *answers*, and
/// total uplinks multiply down while the run still converges (at a
/// conservative α, since unsampled workers carry stale terms).
pub fn participation_sweep(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB8);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 600 } else { 2_000 };
    // stale aggregates shrink the stability margin — stay well inside
    let alpha = 0.3 / p.l_global;
    println!("\n── ablation: sampling fraction × ε₁ (CHB, synthetic linreg)");
    let mut rows = Vec::new();
    for frac in [0.2, 0.5, 1.0] {
        for eps_c in [0.0, 0.1, 1.0] {
            let participation = if frac >= 1.0 {
                Participation::Full
            } else {
                Participation::UniformSample { frac, seed: 0xCAFE }
            };
            let proto = Protocol {
                alpha,
                beta: 0.4,
                eps_c,
                eps_abs: None,
                max_iters: iters,
                stop: crate::coordinator::StopRule::MaxIters,
                participation,
                engine: EngineKind::Serial,
            };
            let t = runner::run_method(&p, Method::Chb, &proto, false);
            let err = t.final_loss() - f_star;
            println!(
                "  frac={frac:<4} ε₁c={eps_c:<4} comms {:>6}  \
                 mean participants {:>5.1}  final err {:.4e}",
                t.total_comms(),
                t.mean_participants(),
                err
            );
            rows.push(vec![
                frac.to_string(),
                eps_c.to_string(),
                t.total_comms().to_string(),
                format!("{:.2}", t.mean_participants()),
                t.iterations().to_string(),
                format!("{err:.8e}"),
            ]);
        }
    }
    csv::write_table(
        &out_dir.join("ablation_participation").join("summary.csv"),
        &[
            "sample_frac",
            "eps_c",
            "comms",
            "mean_participants",
            "iters",
            "final_obj_err",
        ],
        &rows,
    )
}

/// Ablation I: async vs sync across worker-heterogeneity levels —
/// the execution regime the paper assumes away.  The synchronous
/// engine pays the slowest worker every round (its virtual round time
/// is the max over the cohort), while the event-driven engine folds
/// arrivals as they come: heterogeneity costs staleness instead of
/// wallclock.  Sweeps Pareto tail indices from uniform (sync-like)
/// to heavy-tailed and reports comms, accuracy, virtual time, and
/// staleness; per-regime trace CSVs carry the staleness +
/// virtual-clock columns.
pub fn async_heterogeneity(out_dir: &Path, quick: bool) -> Result<()> {
    let p = synth_linreg_problem(0xAB9);
    let f_star = p.f_star().unwrap();
    let iters = if quick { 600 } else { 2_000 };
    // stale-gradient stability: per-arrival steps leave each worker's
    // contribution ~M steps old, so keep α·L·staleness well below 1
    let alpha = 0.1 / p.l_global;
    let base_spec = RunSpec {
        params: ParamSpec {
            alpha: Some(alpha),
            beta: 0.2,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        },
        iters,
        ..RunSpec::new(p.task, &p.dataset)
    };
    let dir = out_dir.join("ablation_async");
    println!("\n── ablation: async vs sync × heterogeneity (CHB, linreg)");
    let mut rows = Vec::new();

    // synchronous baseline: the round clock pays max-over-cohort
    let sync = Session::from_parts(base_spec.clone(), p.clone())
        .expect("valid ablation spec")
        .run()
        .trace;
    let sync_last = sync.iters.last().unwrap();
    println!(
        "  {:<16} comms {:>6}  final err {:.4e}  vclock {:>9.1} ms",
        "sync (serial)",
        sync.total_comms(),
        sync.final_loss() - f_star,
        sync_last.vclock_us / 1e3,
    );
    rows.push(vec![
        "sync".into(),
        "-".into(),
        sync.total_comms().to_string(),
        format!("{:.8e}", sync.final_loss() - f_star),
        format!("{:.3}", sync_last.vclock_us / 1e3),
        "0".into(),
    ]);
    csv::write_trace(&dir.join("sync.csv"), &sync, f_star)?;

    // async at increasing heterogeneity (smaller shape = heavier tail)
    let regimes: [(&str, ComputeModel); 4] = [
        ("uniform", ComputeModel::Uniform { us: 1_000.0 }),
        (
            "pareto-4.0",
            ComputeModel::Pareto { scale_us: 1_000.0, shape: 4.0, seed: 0xA59 },
        ),
        (
            "pareto-2.0",
            ComputeModel::Pareto { scale_us: 1_000.0, shape: 2.0, seed: 0xA59 },
        ),
        (
            "pareto-1.3",
            ComputeModel::Pareto { scale_us: 1_000.0, shape: 1.3, seed: 0xA59 },
        ),
    ];
    for (label, compute) in regimes {
        let spec = RunSpec {
            engine: EngineKind::Async(AsyncConfig {
                compute,
                latency: LatencyModel::default(),
                max_staleness: Some(20),
            }),
            ..base_spec.clone()
        };
        let report = Session::from_parts(spec, p.clone())
            .expect("valid ablation spec")
            .run();
        let vclock_us =
            report.async_summary.as_ref().expect("async run").vclock_us;
        let t = &report.trace;
        println!(
            "  async {:<10} comms {:>6}  final err {:.4e}  vclock \
             {:>9.1} ms  stale≤{}",
            label,
            t.total_comms(),
            t.final_loss() - f_star,
            vclock_us / 1e3,
            t.max_staleness(),
        );
        rows.push(vec![
            "async".into(),
            label.into(),
            t.total_comms().to_string(),
            format!("{:.8e}", t.final_loss() - f_star),
            format!("{:.3}", vclock_us / 1e3),
            t.max_staleness().to_string(),
        ]);
        csv::write_trace(&dir.join(format!("async_{label}.csv")), t, f_star)?;
        csv::write_staleness(
            &dir.join(format!("async_{label}_staleness.csv")),
            t,
        )?;
    }
    csv::write_table(
        &dir.join("summary.csv"),
        &[
            "regime",
            "compute_model",
            "comms",
            "final_obj_err",
            "vclock_ms",
            "stale_max",
        ],
        &rows,
    )
}

/// Mean per-worker ‖∇f_m(θ⁰)‖² — the scale the decreasing-threshold
/// schedule τ_k = τ₀·ρᵏ is anchored to (CSGD's recipe: τ₀ a fixed
/// fraction of the initial gradient energy, so "aggressive early" is
/// problem-independent).
fn initial_grad_sq_mean(p: &Problem, theta0: &[f64]) -> f64 {
    let mut ws = crate::tasks::TaskWorkspace::default();
    let mut g = vec![0.0; p.dim()];
    let mut sum = 0.0;
    for s in &p.shards {
        let obj = crate::tasks::build_objective(p.task, s, p.lam_m);
        obj.grad_loss_into(theta0, &mut ws, &mut g);
        sum += crate::linalg::norm2_sq(&g);
    }
    sum / p.m_workers().max(1) as f64
}

/// Ablation J: the stochastic (minibatch) regime — censored-SGD
/// communication-per-accuracy on all four tasks.
///
/// Five regimes per task, each one a [`RunSpec`] (method × censor ×
/// batch axes) through the one [`Session`] pipeline (serial engine,
/// fixed minibatch schedule where stochastic):
///
/// * `full-chb`     — the paper's deterministic CHB baseline
/// * `sgd-mini`     — uncensored minibatch SGD (every worker uploads
///   every round): the communication ceiling
/// * `csgd-mini`    — CSGD: GD server rule + the decreasing threshold
///   τ_k = τ₀·ρᵏ (`DecayingCensor`)
/// * `chb-mini`     — minibatch CHB with the same decreasing
///   threshold: momentum + censoring under gradient noise
/// * `chb-mini-var` — minibatch CHB with the variance-compensated
///   relative rule (`VarianceScaledCensor`)
///
/// The summary CSV reports, per (task, regime), the uplink bits spent
/// to first reach the accuracy target (90 % of the initial objective
/// error eliminated for the convex tasks; half the initial loss for
/// the nonconvex NN) — the headline comparison is `chb-mini` vs
/// `sgd-mini` at equal batch size and step size.
pub fn stochastic(out_dir: &Path, quick: bool) -> Result<()> {
    use crate::data::batch::BatchSchedule;

    let iters = if quick { 500 } else { 2_000 };
    // τ decays six orders of magnitude over the run, so late-phase
    // censoring vanishes regardless of the iteration budget
    let rho = 1e-6f64.powf(1.0 / iters as f64);
    let dir = out_dir.join("ablation_stochastic");
    println!("\n── ablation: stochastic regime — CHB vs CSGD vs full batch");
    let mut rows = Vec::new();
    for (ti, task) in [
        TaskKind::LinReg,
        TaskKind::LogReg,
        TaskKind::Lasso,
        TaskKind::Nn,
    ]
    .into_iter()
    .enumerate()
    {
        let m = 4usize;
        let l_m: Vec<f64> =
            (0..m).map(|i| (1.0 + 0.5 * i as f64).powi(2)).collect();
        let per_worker = crate::data::synthetic::per_worker_rescaled(
            0xAB10 + ti as u64,
            m,
            96,
            10,
            &l_m,
        );
        let lam = match task {
            TaskKind::Lasso => 0.05,
            TaskKind::LogReg | TaskKind::Nn => 0.01,
            TaskKind::LinReg => 0.0,
        };
        let p = Problem::from_worker_datasets(task, "stoch", &per_worker, lam);
        let theta0 = p.theta0();
        let f_star = p.f_star();
        // conservative step: minibatch noise + (for CHB) momentum both
        // shrink the stability margin
        let alpha = 0.5 / p.l_global;
        let tau0 = 0.1 * initial_grad_sq_mean(&p, &theta0);
        let schedule =
            BatchSchedule::Minibatch { size: 16, seed: 0xB47C, replace: false };
        let f0 = super::fstar::objective(&p, &theta0);
        let target = match f_star {
            Some(fs) => fs + 0.1 * (f0 - fs),
            None => 0.5 * f0,
        };

        // each regime is one RunSpec: the method picks the server rule
        // (Gd ⇒ plain descent, Chb ⇒ heavy ball), the censor field
        // picks the rule, the batch field picks the sampling schedule
        let regimes: [(&str, Method, CensorSpec, BatchSchedule); 5] = [
            (
                "full-chb",
                Method::Chb,
                CensorSpec::MethodDefault,
                BatchSchedule::Full,
            ),
            ("sgd-mini", Method::Gd, CensorSpec::MethodDefault, schedule),
            (
                "csgd-mini",
                Method::Gd,
                CensorSpec::Decaying { tau0, rho },
                schedule,
            ),
            (
                "chb-mini",
                Method::Chb,
                CensorSpec::Decaying { tau0, rho },
                schedule,
            ),
            (
                "chb-mini-var",
                Method::Chb,
                CensorSpec::VarianceScaled,
                schedule,
            ),
        ];
        for (label, method, censor, batch) in regimes {
            let spec = RunSpec {
                label: Some(label.to_string()),
                method: method.into(),
                params: ParamSpec {
                    alpha: Some(alpha),
                    beta: 0.4,
                    epsilon: EpsilonSpec::Scaled { c: 0.1 },
                },
                censor,
                batch,
                iters,
                lambda: p.lambda_global(),
                ..RunSpec::new(task, &p.dataset)
            };
            let t = Session::from_parts(spec, p.clone())
                .expect("valid ablation spec")
                .run()
                .trace;
            let bits_total = t.iters.last().map_or(0, |s| s.bits_cum);
            let hit = t.iters.iter().find(|s| s.loss <= target);
            let (k_hit, bits_hit) = hit
                .map(|s| (s.k.to_string(), s.bits_cum.to_string()))
                .unwrap_or_else(|| ("-".into(), "-".into()));
            let final_epoch =
                t.iters.last().map_or(0.0, |s| s.epoch);
            println!(
                "  {:<7} {label:<13} comms {:>6}  bits→target {:>10}  \
                 k→target {:>5}  final f {:.4e}  epochs {:.1}",
                task.name(),
                t.total_comms(),
                bits_hit,
                k_hit,
                t.final_loss(),
                final_epoch,
            );
            csv::write_trace(
                &dir.join(format!("{}_{label}.csv", task.name())),
                &t,
                f_star.unwrap_or(0.0),
            )?;
            rows.push(vec![
                task.name().to_string(),
                label.to_string(),
                t.total_comms().to_string(),
                bits_total.to_string(),
                k_hit,
                bits_hit,
                format!("{:.8e}", t.final_loss()),
                format!("{target:.8e}"),
                format!("{final_epoch:.3}"),
            ]);
        }
    }
    csv::write_table(
        &dir.join("summary.csv"),
        &[
            "task",
            "regime",
            "comms",
            "uplink_bits_total",
            "k_to_target",
            "uplink_bits_to_target",
            "final_loss",
            "target_loss",
            "epochs",
        ],
        &rows,
    )
}

/// Ablation K: the method family × downlink grid — bits-to-accuracy
/// counting BOTH directions.
///
/// Every cell is one [`RunSpec`]: the method axis picks the grid
/// variant (classic CHB, K = 4 censored local steps, censored Adam),
/// the censor axis turns rule (8) on/off, and the downlink axis makes
/// the broadcast direction paid (8-bit packed quantizer with error
/// feedback) or free-in-f64 (`none`).  The summary CSV reports, per
/// (task, method, censor, downlink) cell, the cumulative uplink,
/// downlink, and total bits spent to first reach the accuracy target
/// (90 % of the initial objective error eliminated for the convex
/// tasks; half the initial loss for the nonconvex NN).
///
/// The headline comparison: once the downlink is metered, K-step
/// local descent amortizes each broadcast over K heavy-ball updates,
/// so a censored local-steps (or censored-Adam) cell reaches the
/// target at lower *total* bits than censored HB.
pub fn methods(out_dir: &Path, quick: bool) -> Result<()> {
    let iters = if quick { 500 } else { 2_000 };
    let dir = out_dir.join("ablation_methods");
    println!("\n── ablation: method family × downlink codec (all tasks)");
    // (label, grid cell, fixed α override — Adam's step is scale-free,
    // the descent methods use 0.5/L per problem)
    let methods: [(&str, MethodSpec, Option<f64>); 3] = [
        ("chb", MethodSpec::Classic(Method::Chb), None),
        ("local4", MethodSpec::local_steps(4), None),
        ("cadam", MethodSpec::censored_adam(), Some(0.1)),
    ];
    let downlinks: [(&str, DownlinkSpec); 2] = [
        ("none", DownlinkSpec::None),
        ("int8-ef", DownlinkSpec::Int { bits: 8, error_feedback: true }),
    ];
    let mut rows = Vec::new();
    for (ti, task) in [
        TaskKind::LinReg,
        TaskKind::LogReg,
        TaskKind::Lasso,
        TaskKind::Nn,
    ]
    .into_iter()
    .enumerate()
    {
        let m = 4usize;
        let l_m: Vec<f64> =
            (0..m).map(|i| (1.0 + 0.5 * i as f64).powi(2)).collect();
        let per_worker = crate::data::synthetic::per_worker_rescaled(
            0xAB20 + ti as u64,
            m,
            96,
            10,
            &l_m,
        );
        let lam = match task {
            TaskKind::Lasso => 0.05,
            TaskKind::LogReg | TaskKind::Nn => 0.01,
            TaskKind::LinReg => 0.0,
        };
        let p = Problem::from_worker_datasets(task, "methods", &per_worker, lam);
        let f_star = p.f_star();
        let f0 = super::fstar::objective(&p, &p.theta0());
        let target = match f_star {
            Some(fs) => fs + 0.1 * (f0 - fs),
            None => 0.5 * f0,
        };
        for (mname, method, alpha_fixed) in methods {
            for censor_on in [true, false] {
                let censor = if censor_on {
                    CensorSpec::MethodDefault
                } else {
                    CensorSpec::Never
                };
                for (dname, downlink) in downlinks {
                    let spec = RunSpec {
                        label: Some(format!("{mname}-{dname}")),
                        method,
                        params: ParamSpec {
                            alpha: Some(
                                alpha_fixed.unwrap_or(0.5 / p.l_global),
                            ),
                            beta: 0.4,
                            epsilon: EpsilonSpec::Scaled { c: 0.1 },
                        },
                        censor,
                        downlink,
                        iters,
                        lambda: p.lambda_global(),
                        ..RunSpec::new(task, &p.dataset)
                    };
                    let t = Session::from_parts(spec, p.clone())
                        .expect("valid ablation spec")
                        .run()
                        .trace;
                    let last = t.iters.last();
                    let up_total = last.map_or(0, |s| s.bits_cum);
                    let down_total = last.map_or(0, |s| s.down_bits_cum);
                    let epochs = last.map_or(0.0, |s| s.epoch);
                    let hit = t.iters.iter().find(|s| s.loss <= target);
                    let (k_hit, up_hit, down_hit, total_hit) = hit
                        .map(|s| {
                            (
                                s.k.to_string(),
                                s.bits_cum.to_string(),
                                s.down_bits_cum.to_string(),
                                (s.bits_cum + s.down_bits_cum).to_string(),
                            )
                        })
                        .unwrap_or_else(|| {
                            ("-".into(), "-".into(), "-".into(), "-".into())
                        });
                    println!(
                        "  {:<7} {mname:<7} censor={:<3} down={dname:<8} \
                         comms {:>6}  total bits→target {:>11}  final f \
                         {:.4e}",
                        task.name(),
                        if censor_on { "on" } else { "off" },
                        t.total_comms(),
                        total_hit,
                        t.final_loss(),
                    );
                    rows.push(vec![
                        task.name().to_string(),
                        mname.to_string(),
                        (if censor_on { "on" } else { "off" }).to_string(),
                        dname.to_string(),
                        t.total_comms().to_string(),
                        format!("{epochs:.3}"),
                        up_total.to_string(),
                        down_total.to_string(),
                        k_hit,
                        up_hit,
                        down_hit,
                        total_hit,
                        format!("{:.8e}", t.final_loss()),
                        format!("{target:.8e}"),
                    ]);
                }
            }
        }
    }
    csv::write_table(
        &dir.join("summary.csv"),
        &[
            "task",
            "method",
            "censor",
            "downlink",
            "comms",
            "epochs",
            "uplink_bits_total",
            "downlink_bits_total",
            "k_to_target",
            "uplink_bits_to_target",
            "downlink_bits_to_target",
            "total_bits_to_target",
            "final_loss",
            "target_loss",
        ],
        &rows,
    )
}

/// Ablation L: censoring × cohort size at fixed population M — the
/// million-client regime's headline question.  Per-device uplinks are
/// the scarce resource at population scale, so the number that
/// matters is how much of the cohort's per-round uplink budget
/// eq. (8) saves, and whether the saving survives smaller cohorts
/// (each client is sampled more rarely, so its censor reference θ̂ is
/// staler and ‖δ∇‖² larger).  One population run per
/// (cohort, censor) cell, never-censor as the budget baseline.
pub fn cohort_sweep(out_dir: &Path, quick: bool) -> Result<()> {
    use crate::coordinator::PopulationSpec;
    use crate::data::synthetic;

    let clients: u64 = if quick { 10_000 } else { 100_000 };
    let rounds = if quick { 40 } else { 150 };
    let dir = out_dir.join("ablation_cohort");
    println!(
        "\n── ablation: censoring × cohort size (population M={clients})"
    );
    let base_m = 8usize;
    let l_m = synthetic::increasing_l(base_m);
    let per_worker = synthetic::per_worker_rescaled(0xC0C0, base_m, 32, 64, &l_m);
    let p = Problem::from_worker_datasets(
        TaskKind::LinReg,
        "cohort",
        &per_worker,
        0.0,
    );
    // the aggregate sums one gradient per client, so the effective
    // smoothness is ~(M / M_base) · L_base — scale α down to match
    let mult = clients.div_ceil(base_m as u64);
    let alpha = 1.0 / (mult as f64 * p.l_global);
    let mut rows = Vec::new();
    for &cohort in &[32u64, 128, 512] {
        for (censor, label) in [
            (CensorSpec::MethodDefault, "chb"),
            (CensorSpec::Never, "never"),
        ] {
            let spec = RunSpec {
                params: ParamSpec {
                    alpha: Some(alpha),
                    beta: 0.4,
                    epsilon: EpsilonSpec::Scaled { c: 0.1 },
                },
                censor,
                engine: EngineKind::Async(AsyncConfig::default()),
                population: Some(PopulationSpec {
                    clients,
                    cohort,
                    seed: 0xC0C0,
                }),
                iters: rounds,
                lambda: 0.0,
                ..RunSpec::new(TaskKind::LinReg, "cohort")
            };
            let report =
                Session::from_parts(spec, p.clone())?.run_checked()?;
            let s = report
                .population_summary
                .expect("population run emits a summary");
            println!(
                "  cohort={cohort:<4} {label:<6} uplinks {:>7}  censored {:>7} \
                 ({:>5.1}%)  final loss {:.4e}",
                s.uplinks,
                s.censored,
                100.0 * s.censor_rate(),
                report.trace.final_loss(),
            );
            rows.push(vec![
                cohort.to_string(),
                label.to_string(),
                s.uplinks.to_string(),
                s.censored.to_string(),
                format!("{:.6}", s.censor_rate()),
                s.resyncs.to_string(),
                format!("{:.8e}", report.trace.final_loss()),
            ]);
        }
    }
    csv::write_table(
        &dir.join("summary.csv"),
        &[
            "cohort",
            "censor",
            "uplinks",
            "censored",
            "censor_rate",
            "resyncs",
            "final_loss",
        ],
        &rows,
    )
}

/// Run every ablation.
pub fn all(out_dir: &Path, quick: bool) -> Result<()> {
    censor_rules(out_dir, quick)?;
    beta_sweep(out_dir, quick)?;
    worker_scaling(out_dir, quick)?;
    failure_injection(out_dir, quick)?;
    compression(out_dir, quick)?;
    ladder(out_dir, quick)?;
    methods(out_dir, quick)?;
    nesterov(out_dir, quick)?;
    adaptive_epsilon(out_dir, quick)?;
    participation_sweep(out_dir, quick)?;
    stochastic(out_dir, quick)?;
    async_heterogeneity(out_dir, quick)?;
    cohort_sweep(out_dir, quick)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::NesterovRule;

    /// The grid's C-NAG cell replays the rule-injection side door it
    /// replaced, bit for bit: `MethodSpec::Nesterov { censored }`
    /// through `Session` ≡ `run_custom(NesterovRule, GradDiffCensor)`.
    #[test]
    fn nesterov_grid_matches_the_rule_injection_side_door() {
        let p = synth_linreg_problem(0xAB6);
        let alpha = 1.0 / p.l_global;
        let iters = 60;
        let eps1 =
            crate::optim::censor::epsilon1_scaled(0.1, alpha, p.m_workers());
        let side_door = run_custom(
            &p,
            Box::new(NesterovRule::new(alpha, 0.4, p.dim())),
            Arc::new(GradDiffCensor { epsilon1: eps1 }),
            "CNAG",
            iters,
            None,
        );
        let spec = RunSpec {
            method: MethodSpec::Nesterov { censored: true },
            params: ParamSpec {
                alpha: Some(alpha),
                beta: 0.4,
                epsilon: EpsilonSpec::Scaled { c: 0.1 },
            },
            iters,
            ..RunSpec::new(p.task, &p.dataset)
        };
        let grid = Session::from_parts(spec, p.clone())
            .expect("valid grid spec")
            .run()
            .trace;
        assert_eq!(side_door.iterations(), grid.iterations());
        for (a, b) in side_door.iters.iter().zip(&grid.iters) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "loss differs at k={}",
                a.k
            );
            assert_eq!(a.comms_round, b.comms_round, "comms at k={}", a.k);
            assert_eq!(a.bits_cum, b.bits_cum, "uplink bits at k={}", a.k);
        }
    }
}
