//! Shared run orchestration: execute the four methods on one problem
//! with the paper's parameter protocol, collect traces.
//!
//! [`Protocol`] is a preset constructor for [`crate::spec::RunSpec`]:
//! [`Protocol::spec`] materializes the §IV parameter protocol as a
//! spec, and [`run_method`] executes it through
//! [`crate::spec::Session`] — so the experiment drivers run on the
//! same unified engine dispatch as the CLI (the engine axis used to
//! be silently ignored here: `run_method` hard-coded the serial
//! engine regardless of configuration).

use std::path::Path;

use anyhow::Result;

use crate::coordinator::{EngineKind, Participation, StopRule};
use crate::metrics::{csv, Trace};
use crate::optim::{Method, MethodParams};
use crate::spec::{EpsilonSpec, ParamSpec, RunSpec, Session, StopSpec};

use super::Problem;

/// Parameter protocol for one experiment (paper §IV defaults).
#[derive(Clone, Copy, Debug)]
pub struct Protocol {
    /// step size α
    pub alpha: f64,
    /// momentum coefficient β
    pub beta: f64,
    /// ε₁ = eps_c / (α²M²); `eps_abs` overrides when Some (NN runs use
    /// a raw ε₁ = 0.01)
    pub eps_c: f64,
    /// raw ε₁ override (wins over `eps_c` when Some)
    pub eps_abs: Option<f64>,
    /// iteration budget
    pub max_iters: usize,
    /// early-exit rule
    pub stop: StopRule,
    /// per-round client scheduling (paper: full participation)
    pub participation: Participation,
    /// execution backend (paper: serial reference engine)
    pub engine: EngineKind,
}

impl Protocol {
    /// The §IV default: β = 0.4, ε₁ = 0.1/(α²M²), full participation,
    /// serial engine.
    pub fn paper_default(alpha: f64, max_iters: usize) -> Protocol {
        Protocol {
            alpha,
            beta: 0.4,
            eps_c: 0.1,
            eps_abs: None,
            max_iters,
            stop: StopRule::MaxIters,
            participation: Participation::Full,
            engine: EngineKind::Serial,
        }
    }

    /// Replace the stop rule (builder form).
    pub fn with_stop(mut self, stop: StopRule) -> Protocol {
        self.stop = stop;
        self
    }

    /// Replace the participation policy (builder form).
    pub fn with_participation(mut self, p: Participation) -> Protocol {
        self.participation = p;
        self
    }

    /// Replace the execution engine (builder form).
    pub fn with_engine(mut self, engine: EngineKind) -> Protocol {
        self.engine = engine;
        self
    }

    /// Use a raw ε₁ instead of the scaled parameterization.
    pub fn with_eps_abs(mut self, eps: f64) -> Protocol {
        self.eps_abs = Some(eps);
        self
    }

    /// Materialize (α, β, ε₁) for a problem with `m_workers` workers.
    pub fn params(&self, m_workers: usize) -> MethodParams {
        let p = MethodParams::new(self.alpha).with_beta(self.beta);
        match self.eps_abs {
            Some(e) => p.with_epsilon1(e),
            None => p.with_epsilon1_scaled(self.eps_c, m_workers),
        }
    }

    /// Materialize the protocol as a [`RunSpec`] preset for `method`
    /// on `problem` — the §IV grid as one serializable value.
    pub fn spec(
        &self,
        method: Method,
        problem: &Problem,
        comm_map: bool,
    ) -> RunSpec {
        RunSpec {
            lambda: problem.lambda_global(),
            method: method.into(),
            params: ParamSpec {
                alpha: Some(self.alpha),
                beta: self.beta,
                epsilon: match self.eps_abs {
                    Some(eps) => EpsilonSpec::Absolute { eps },
                    None => EpsilonSpec::Scaled { c: self.eps_c },
                },
            },
            engine: self.engine,
            participation: self.participation,
            iters: self.max_iters,
            stop: match self.stop {
                StopRule::MaxIters => StopSpec::MaxIters,
                StopRule::ObjErrBelow { f_star, tol } => {
                    StopSpec::ObjErr { tol, f_star: Some(f_star) }
                }
                StopRule::AggGradBelow { tol } => StopSpec::AggGrad { tol },
            },
            record_comm_map: comm_map,
            ..RunSpec::new(problem.task, &problem.dataset)
        }
    }
}

/// Run one method on a problem; fresh workers each time.  Routed
/// through [`Session`], so the protocol's engine axis is honored
/// (previously this hard-coded the serial engine).
pub fn run_method(
    problem: &Problem,
    method: Method,
    proto: &Protocol,
    comm_map: bool,
) -> Trace {
    let spec = proto.spec(method, problem, comm_map);
    Session::from_parts(spec, problem.clone())
        .expect("protocol presets always validate")
        .run()
        .trace
}

/// Run all four methods; returns traces in Method::ALL order
/// (CHB, HB, LAG, GD — the paper's table order).
pub fn run_all_methods(problem: &Problem, proto: &Protocol) -> Vec<Trace> {
    Method::ALL
        .iter()
        .map(|&m| run_method(problem, m, proto, false))
        .collect()
}

/// Write one CSV per trace under `results/<id>/`.
pub fn write_traces(
    out_dir: &Path,
    id: &str,
    traces: &[Trace],
    f_star: f64,
) -> Result<()> {
    for t in traces {
        let path = out_dir.join(id).join(format!("{}.csv", t.method));
        csv::write_trace(&path, t, f_star)?;
    }
    Ok(())
}

/// Console summary block shared by the figure drivers.
pub fn print_summary(id: &str, problem: &Problem, traces: &[Trace], f_star: f64) {
    println!("\n── {id}: {} / {} (M={}, d={}, L={:.4e})",
        problem.task.name(), problem.dataset, problem.m_workers(),
        problem.dim(), problem.l_global);
    println!(
        "{:<6} {:>10} {:>10} {:>14} {:>14}",
        "method", "comms", "iters", "final f−f*", "final ‖∇‖²"
    );
    for t in traces {
        let last = t.iters.last();
        println!(
            "{:<6} {:>10} {:>10} {:>14.4e} {:>14.4e}",
            t.method,
            t.total_comms(),
            t.iterations(),
            last.map_or(f64::NAN, |s| s.loss - f_star),
            last.map_or(f64::NAN, |s| s.agg_grad_sq),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::tasks::TaskKind;

    fn quick_problem() -> Problem {
        let l_m = synthetic::increasing_l(3);
        let per_worker = synthetic::per_worker_rescaled(5, 3, 20, 10, &l_m);
        Problem::from_worker_datasets(TaskKind::LinReg, "quick", &per_worker, 0.0)
    }

    #[test]
    fn run_all_methods_produces_four_ordered_traces() {
        let p = quick_problem();
        let proto = Protocol::paper_default(1.0 / p.l_global, 50);
        let traces = run_all_methods(&p, &proto);
        assert_eq!(traces.len(), 4);
        assert_eq!(traces[0].method, "CHB");
        assert_eq!(traces[1].method, "HB");
        assert_eq!(traces[2].method, "LAG");
        assert_eq!(traces[3].method, "GD");
        // uncensored methods transmit M per iteration
        assert_eq!(traces[3].total_comms(), 50 * 3);
        assert_eq!(traces[1].total_comms(), 50 * 3);
        // censored methods should save something on this problem
        assert!(traces[0].total_comms() < traces[1].total_comms());
    }

    #[test]
    fn protocol_eps_abs_overrides_scaling() {
        let proto = Protocol::paper_default(0.1, 10).with_eps_abs(0.01);
        let p = proto.params(9);
        assert_eq!(p.epsilon1, 0.01);
        let proto2 = Protocol::paper_default(0.1, 10);
        let p2 = proto2.params(9);
        assert!((p2.epsilon1 - 0.1 / (0.01 * 81.0)).abs() < 1e-12);
    }
}
