//! Experiment harness: one driver per paper figure/table.
//!
//! [`Problem`] bundles everything a run needs (task, shards, λ, L,
//! f*); [`runner`] executes the four methods on it; `figures` /
//! `tables` / `ablations` are the per-artifact drivers listed in
//! DESIGN.md §5.  Every driver writes CSVs under `results/<id>/` and
//! prints the paper-matching summary rows.

pub mod ablations;
pub mod figures;
pub mod fstar;
pub mod runner;
pub mod tables;

use std::path::Path;

use anyhow::Result;

use crate::data::{partition, registry, Dataset, Shard};
use crate::tasks::{self, smoothness, TaskKind};

/// A fully-specified learning problem (one dataset × one task).
///
/// Cloning is cheap: shard storage is `Arc`-shared, so a clone bumps
/// refcounts instead of copying the dataset — which is how the
/// experiment drivers hand the same problem to a
/// [`crate::spec::Session`] per run.
#[derive(Clone)]
pub struct Problem {
    /// the learning task
    pub task: TaskKind,
    /// dataset name (registry key or a driver-local label)
    pub dataset: String,
    /// one padded shard per worker
    pub shards: Vec<Shard>,
    /// per-worker regularization λ_m = λ_global / M, so that
    /// Σ_m ½λ_m‖θ‖² = ½λ_global‖θ‖² (the paper's single global λ)
    pub lam_m: f64,
    /// global smoothness L = Σ_m L_m (α = 1/L protocol)
    pub l_global: f64,
    /// per-worker smoothness constants L_m
    pub l_m: Vec<f64>,
}

impl Problem {
    /// Build from a registry dataset with the paper's worker count.
    pub fn from_registry(
        task: TaskKind,
        dataset: &str,
        data_dir: &Path,
        lam_global: f64,
    ) -> Result<Problem> {
        let spec = registry::spec(dataset)?;
        let ds = registry::load(dataset, data_dir)?;
        // NN protocol: standardized features + mean loss (NnTask); the
        // sigmoid net needs O(1) activations for the paper's α range
        let ds = if task == TaskKind::Nn { ds.standardized() } else { ds };
        let shards = partition::split_even(&ds, spec.workers);
        Ok(Self::from_shards(task, dataset, shards, lam_global))
    }

    /// Build from pre-partitioned per-worker datasets (the synthetic
    /// Fig. 1/2/3 protocols).
    pub fn from_worker_datasets(
        task: TaskKind,
        dataset: &str,
        per_worker: &[Dataset],
        lam_global: f64,
    ) -> Problem {
        let shards = partition::shards_from_datasets(per_worker);
        Self::from_shards(task, dataset, shards, lam_global)
    }

    /// Build directly from shards (used by the subsampling drivers).
    pub fn from_shards(
        task: TaskKind,
        dataset: &str,
        shards: Vec<Shard>,
        lam_global: f64,
    ) -> Problem {
        let m = shards.len();
        let lam_m = lam_global / m as f64;
        let l_m: Vec<f64> = shards
            .iter()
            .map(|s| {
                // NN uses the mean-loss regime (tasks::NnTask::new)
                let wscale = if task == TaskKind::Nn {
                    1.0 / s.n_real.max(1) as f64
                } else {
                    1.0
                };
                smoothness::worker_smoothness_scaled(task, &s.x, lam_m, wscale)
            })
            .collect();
        let l_global = l_m.iter().sum();
        Problem {
            task,
            dataset: dataset.to_string(),
            shards,
            lam_m,
            l_global,
            l_m,
        }
    }

    /// Worker count M.
    pub fn m_workers(&self) -> usize {
        self.shards.len()
    }

    /// The global regularization λ this problem was built with
    /// (λ_m · M — the spec-level parameterization).
    pub fn lambda_global(&self) -> f64 {
        self.lam_m * self.m_workers() as f64
    }

    /// Flat parameter dimension for this (task, dataset).
    pub fn dim(&self) -> usize {
        self.task.theta_dim(self.shards[0].x.cols)
    }

    /// Initial iterate (paper: unspecified; zeros everywhere except
    /// the NN, which needs symmetry breaking).
    pub fn theta0(&self) -> Vec<f64> {
        let p = self.dim();
        if self.task == TaskKind::Nn {
            // small deterministic init, same for every method
            let mut rng = crate::rng::Xoshiro256::new(0x1217);
            (0..p).map(|_| 0.2 * rng.next_gaussian()).collect()
        } else {
            vec![0.0; p]
        }
    }

    /// Pure-rust workers (the default experiment backend).
    pub fn rust_workers(&self) -> Vec<crate::coordinator::Worker> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                crate::coordinator::Worker::new(
                    i,
                    Box::new(crate::coordinator::RustBackend::new(
                        tasks::build_objective(self.task, s, self.lam_m),
                    )),
                )
            })
            .collect()
    }

    /// Materialize the throw-away worker for one simulated client of
    /// a population run.  Clients map onto the problem's base shards
    /// round-robin (`client % M`), so the global population objective
    /// is Σ_s mult_s·f_s(θ) with M resident evaluators — the data
    /// itself is `Arc`-shared inside each [`Shard`], so this costs a
    /// backend + workspace allocation, not a dataset copy.
    pub fn worker_for(&self, client: u64) -> crate::coordinator::Worker {
        let s = &self.shards[(client % self.m_workers() as u64) as usize];
        crate::coordinator::Worker::new(
            client as usize,
            Box::new(crate::coordinator::RustBackend::new(
                tasks::build_objective(self.task, s, self.lam_m),
            )),
        )
    }

    /// Pure-rust workers with a gradient-sampling schedule attached
    /// ([`crate::data::batch::BatchSchedule::Full`] reproduces
    /// [`Problem::rust_workers`] bit for bit).
    pub fn rust_workers_batched(
        &self,
        schedule: crate::data::batch::BatchSchedule,
    ) -> Vec<crate::coordinator::Worker> {
        self.rust_workers()
            .into_iter()
            .map(|w| w.with_batching(schedule))
            .collect()
    }

    /// PJRT workers executing the AOT artifact for this problem.
    pub fn pjrt_workers(
        &self,
        rt: &mut crate::runtime::PjrtRuntime,
    ) -> Result<Vec<crate::coordinator::Worker>> {
        let meta = rt.manifest().find(self.task, &self.dataset)?.clone();
        self.shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let backend = rt.worker_backend(&meta, s, self.lam_m)?;
                Ok(crate::coordinator::Worker::new(i, Box::new(backend)))
            })
            .collect()
    }

    /// Minimum objective value f(θ*) (None for the nonconvex NN,
    /// where the paper reports ‖∇‖² instead).
    pub fn f_star(&self) -> Option<f64> {
        fstar::f_star(self)
    }
}
