//! Table drivers (Tables I–III) and the shared per-dataset "suites"
//! the figure drivers reuse (Fig. 4/5 plot the Table-I runs, etc.).
//!
//! Protocols follow §IV exactly; where the synthetic stand-in's scale
//! differs from the real dataset's, the step size is re-expressed
//! relative to the measured L (EXPERIMENTS.md documents each case).

use std::path::Path;

use anyhow::Result;

use crate::coordinator::StopRule;
use crate::data::Dataset;
use crate::metrics::{csv, Trace};
use crate::rng::Xoshiro256;
use crate::tasks::TaskKind;

use super::runner::{self, Protocol};
use super::Problem;

/// One task's results within a suite.
pub struct SuiteEntry {
    /// the learning task
    pub task: TaskKind,
    /// dataset name
    pub dataset: String,
    /// CHB, HB, LAG, GD (paper order)
    pub traces: Vec<Trace>,
    /// f(θ*) (NaN for the NN task)
    pub f_star: f64,
    /// f(θ⁰) (for per-communication descent)
    pub f_theta0: f64,
}

/// Subsample a dataset to at most `n` rows (deterministic).
pub fn subsample(ds: &Dataset, n: usize, seed: u64) -> Dataset {
    if ds.n() <= n {
        return ds.clone();
    }
    let mut idx: Vec<usize> = (0..ds.n()).collect();
    Xoshiro256::new(seed).shuffle(&mut idx);
    idx.truncate(n);
    let mut x = crate::linalg::Matrix::zeros(n, ds.d());
    let mut y = vec![0.0; n];
    for (i, &src) in idx.iter().enumerate() {
        x.row_mut(i).copy_from_slice(ds.x.row(src));
        y[i] = ds.y[src];
    }
    Dataset { x, y, source: format!("{} (subsampled to {n})", ds.source) }
}

fn run_entry(problem: &Problem, proto: &Protocol) -> SuiteEntry {
    let f_star = problem.f_star().unwrap_or(f64::NAN);
    let traces = runner::run_all_methods(problem, proto);
    let f_theta0 = super::fstar::objective(problem, &problem.theta0());
    SuiteEntry {
        task: problem.task,
        dataset: problem.dataset.clone(),
        traces,
        f_star,
        f_theta0,
    }
}

/// Build a registry problem, optionally subsampled (MNIST on 1 core).
pub fn registry_problem(
    task: TaskKind,
    dataset: &str,
    data_dir: &Path,
    lam: f64,
    max_n: Option<usize>,
) -> Result<Problem> {
    let spec = crate::data::registry::spec(dataset)?;
    let ds = crate::data::registry::load(dataset, data_dir)?;
    let ds = match max_n {
        Some(n) => subsample(&ds, n, 0xD5),
        None => ds,
    };
    // NN protocol: standardized features + mean loss (see NnTask);
    // the sigmoid net needs O(1) activations for the paper's α.
    let ds = if task == TaskKind::Nn { ds.standardized() } else { ds };
    let shards = crate::data::partition::split_even(&ds, spec.workers);
    Ok(Problem::from_shards(task, dataset, shards, lam))
}

// ---------------------------------------------------------------------------
// Table I suite: ijcnn1, 4 tasks (also feeds Fig. 4 and Fig. 5)
// ---------------------------------------------------------------------------

/// Paper protocol: α = 1e-4 for the three regressions (re-expressed
/// against the measured L for the stand-in), ε₁ = 0.1/(α²M²); stops at
/// obj-err 1e-7 (lin/lasso) and 1e-5 (logistic); NN: α = 0.02,
/// ε₁ = 0.01, λ = 1/49990, 500 iterations.
pub fn table1_suite(data_dir: &Path, quick: bool) -> Result<Vec<SuiteEntry>> {
    let mut out = Vec::new();
    let cap = if quick { Some(9_000) } else { None };
    let iters_cap = if quick { 4_000 } else { 20_000 };
    let nn_iters = if quick { 200 } else { 500 };

    // linear regression, target 1e-7
    {
        let p = registry_problem(TaskKind::LinReg, "ijcnn1", data_dir, 0.0, cap)?;
        let f_star = p.f_star().unwrap();
        let alpha = pick_alpha(&p, 1e-4, data_dir);
        let proto = Protocol::paper_default(alpha, iters_cap)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-7 });
        out.push(run_entry(&p, &proto));
    }
    // lasso, λ = 0.5, target 1e-7
    {
        let p = registry_problem(TaskKind::Lasso, "ijcnn1", data_dir, 0.5, cap)?;
        let f_star = p.f_star().unwrap();
        let alpha = pick_alpha(&p, 1e-4, data_dir);
        let proto = Protocol::paper_default(alpha, iters_cap)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-7 });
        out.push(run_entry(&p, &proto));
    }
    // logistic, λ = 0.001, target 1e-5
    {
        let p = registry_problem(TaskKind::LogReg, "ijcnn1", data_dir, 0.001, cap)?;
        let f_star = p.f_star().unwrap();
        let alpha = pick_alpha(&p, 1e-4, data_dir);
        let proto = Protocol::paper_default(alpha, iters_cap)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-5 });
        out.push(run_entry(&p, &proto));
    }
    // NN: fixed iterations, α = 0.02, ε₁ = 0.01, λ = 1/49990
    {
        let p = registry_problem(
            TaskKind::Nn,
            "ijcnn1",
            data_dir,
            1.0 / 49_990.0,
            cap,
        )?;
        let alpha = nn_alpha(&p, 0.02);
        let proto = Protocol::paper_default(alpha, nn_iters).with_eps_abs(0.01);
        out.push(run_entry(&p, &proto));
    }
    Ok(out)
}

/// The paper's absolute α was tuned to the real dataset's scale.  With
/// the real file present we use it verbatim; for the synthetic
/// stand-in we preserve the paper's *regime* — a stable step slightly
/// below 1/L — so the convergence/censoring behavior matches
/// (DESIGN.md §3, EXPERIMENTS.md "step-size re-expression").
fn pick_alpha(p: &Problem, paper_alpha: f64, data_dir: &Path) -> f64 {
    let real = data_dir.join(&p.dataset).exists()
        || data_dir.join(format!("{}.txt", p.dataset)).exists()
        || (p.dataset == "mnist"
            && data_dir.join("train-images-idx3-ubyte").exists());
    if real {
        paper_alpha
    } else {
        0.9 / p.l_global
    }
}

/// NN step size: the paper's α works at σ-activation scale; guard
/// against stand-in curvature blowups (the NN's effective smoothness
/// tracks the data Gram but with weight-dependent slack, so stay well
/// inside 1/L).
fn nn_alpha(p: &Problem, paper_alpha: f64) -> f64 {
    paper_alpha.min(0.5 / p.l_global)
}

// ---------------------------------------------------------------------------
// Table II suite: small UCI datasets, 3 workers (feeds Fig. 6/7)
// ---------------------------------------------------------------------------

/// §IV-B protocol: α = 1/L, ε₁ = 0.1/(α²M²), β = 0.4; stop at 1e-7;
/// λ_logistic = 0.001, λ_lasso = 0.1; NN on adult: α = 0.01,
/// ε₁ = 0.01, λ = 1/1605, 500 iterations.
pub fn table2_suite(data_dir: &Path, quick: bool) -> Result<Vec<SuiteEntry>> {
    let iters_cap = if quick { 4_000 } else { 40_000 };
    let nn_iters = if quick { 300 } else { 500 };
    let mut out = Vec::new();
    for ds in ["housing", "bodyfat", "abalone"] {
        let p = Problem::from_registry(TaskKind::LinReg, ds, data_dir, 0.0)?;
        let f_star = p.f_star().unwrap();
        let proto = Protocol::paper_default(1.0 / p.l_global, iters_cap)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-7 });
        out.push(run_entry(&p, &proto));
    }
    for ds in ["ionosphere", "adult", "derm"] {
        let p = Problem::from_registry(TaskKind::LogReg, ds, data_dir, 0.001)?;
        let f_star = p.f_star().unwrap();
        let proto = Protocol::paper_default(1.0 / p.l_global, iters_cap)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-7 });
        out.push(run_entry(&p, &proto));
    }
    for ds in ["ionosphere", "adult", "derm"] {
        let p = Problem::from_registry(TaskKind::Lasso, ds, data_dir, 0.1)?;
        let f_star = p.f_star().unwrap();
        let proto = Protocol::paper_default(1.0 / p.l_global, iters_cap)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-7 });
        out.push(run_entry(&p, &proto));
    }
    {
        let p =
            registry_problem(TaskKind::Nn, "adult", data_dir, 1.0 / 1_605.0, None)?;
        let alpha = nn_alpha(&p, 0.01);
        let proto = Protocol::paper_default(alpha, nn_iters).with_eps_abs(0.01);
        out.push(run_entry(&p, &proto));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Table III suite: MNIST, fixed iteration budget (feeds Fig. 8/9)
// ---------------------------------------------------------------------------

/// §IV-B MNIST protocol: fixed 2000 iterations (regressions) / 500
/// (NN); α = 1e-8 (lin/lasso), 1e-6 (logistic), 0.02 (NN);
/// λ = 0.5 (lasso), 0.001 (logistic), 1/60000 (NN); ε₁ as usual.
/// `quick` subsamples the stand-in (this is a 1-core image) — the
/// comparison's shape is scale-free.
pub fn table3_suite(data_dir: &Path, quick: bool) -> Result<Vec<SuiteEntry>> {
    let cap = if quick { Some(4_500) } else { None };
    let iters = if quick { 800 } else { 2_000 };
    let nn_iters = if quick { 60 } else { 500 };
    let mut out = Vec::new();

    for (task, lam, paper_alpha) in [
        (TaskKind::LinReg, 0.0, 1e-8),
        (TaskKind::Lasso, 0.5, 1e-8),
        (TaskKind::LogReg, 0.001, 1e-6),
    ] {
        let p = registry_problem(task, "mnist", data_dir, lam, cap)?;
        let alpha = pick_alpha(&p, paper_alpha, data_dir);
        let proto = Protocol::paper_default(alpha, iters);
        out.push(run_entry(&p, &proto));
    }
    {
        let p = registry_problem(
            TaskKind::Nn,
            "mnist",
            data_dir,
            1.0 / 60_000.0,
            cap.map(|c| c / 2),
        )?;
        let alpha = nn_alpha(&p, 0.02);
        let proto = Protocol::paper_default(alpha, nn_iters).with_eps_abs(0.01);
        out.push(run_entry(&p, &proto));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// printing / writing
// ---------------------------------------------------------------------------

/// Print a paper-style table: one row per method.
pub fn print_table(title: &str, entries: &[SuiteEntry], fixed_iters: bool) {
    println!("\n=== {title} ===");
    let header: Vec<String> = entries
        .iter()
        .map(|e| format!("{:^28}", format!("{}/{}", e.task.name(), e.dataset)))
        .collect();
    println!("{:<6} {}", "method", header.join(" | "));
    for (mi, method) in ["CHB", "HB", "LAG", "GD"].iter().enumerate() {
        let cells: Vec<String> = entries
            .iter()
            .map(|e| {
                let t = &e.traces[mi];
                if e.task == TaskKind::Nn {
                    format!(
                        "comm {:>6} ‖∇‖² {:>10.4e}",
                        t.total_comms(),
                        t.iters.last().map_or(f64::NAN, |s| s.agg_grad_sq)
                    )
                } else if fixed_iters {
                    format!(
                        "comm {:>6} err {:>11.4e}",
                        t.total_comms(),
                        t.final_loss() - e.f_star
                    )
                } else {
                    format!(
                        "comm {:>6} iter {:>11}",
                        t.total_comms(),
                        t.iterations()
                    )
                }
            })
            .collect();
        println!("{:<6} {}", method, cells.join(" | "));
    }
}

/// Write each entry's traces + a summary CSV under results/<id>/.
pub fn write_suite(out_dir: &Path, id: &str, entries: &[SuiteEntry]) -> Result<()> {
    let mut rows = Vec::new();
    for e in entries {
        for t in &e.traces {
            let sub = format!("{}_{}", e.task.name(), e.dataset);
            csv::write_trace(
                &out_dir.join(id).join(&sub).join(format!("{}.csv", t.method)),
                t,
                if e.f_star.is_nan() { 0.0 } else { e.f_star },
            )?;
            rows.push(vec![
                e.task.name().to_string(),
                e.dataset.clone(),
                t.method.clone(),
                t.total_comms().to_string(),
                t.iterations().to_string(),
                format!("{:.6e}", t.final_loss() - e.f_star),
                format!(
                    "{:.6e}",
                    t.iters.last().map_or(f64::NAN, |s| s.agg_grad_sq)
                ),
            ]);
        }
    }
    csv::write_table(
        &out_dir.join(id).join("summary.csv"),
        &["task", "dataset", "method", "comms", "iters", "final_obj_err",
          "final_agg_grad_sq"],
        &rows,
    )
}

/// Table I driver.
pub fn table1(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries = table1_suite(data_dir, quick)?;
    print_table("Table I (ijcnn1)", &entries, false);
    write_suite(out_dir, "table1", &entries)
}

/// Table II driver.
pub fn table2(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries = table2_suite(data_dir, quick)?;
    print_table("Table II (small UCI)", &entries, false);
    write_suite(out_dir, "table2", &entries)
}

/// Table III driver.
pub fn table3(out_dir: &Path, data_dir: &Path, quick: bool) -> Result<()> {
    let entries = table3_suite(data_dir, quick)?;
    print_table("Table III (MNIST, fixed iters)", &entries, true);
    write_suite(out_dir, "table3", &entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    #[test]
    fn subsample_preserves_rows_and_is_deterministic() {
        let mut rng = Xoshiro256::new(50);
        let ds = synthetic::gaussian_pm1(&mut rng, 100, 4);
        let a = subsample(&ds, 30, 1);
        let b = subsample(&ds, 30, 1);
        assert_eq!(a.n(), 30);
        assert_eq!(a.x.data, b.x.data);
        // every sampled row exists in the original
        for i in 0..a.n() {
            let found = (0..ds.n()).any(|j| ds.x.row(j) == a.x.row(i));
            assert!(found, "row {i} not from source");
        }
        // no-op when already small enough
        assert_eq!(subsample(&ds, 200, 1).n(), 100);
    }

    #[test]
    fn registry_problem_subsamples_and_rebuilds_smoothness() {
        let p = registry_problem(
            TaskKind::LinReg,
            "ijcnn1",
            Path::new("/nonexistent"),
            0.0,
            Some(900),
        )
        .unwrap();
        assert_eq!(p.m_workers(), 9);
        assert!(p.shards[0].n_real <= 100);
        assert!(p.l_global > 0.0);
        assert_eq!(p.l_m.len(), 9);
    }
}
