//! High-accuracy minimizers for the baseline f(θ*) that "objective
//! error" is measured against (the paper stops runs at
//! f(θᵏ) − f(θ*) < 1e-7, so f* must be resolved well beyond that).
//!
//! * linreg — exact: normal equations via Cholesky.
//! * logreg — Newton's method (quadratic convergence, ~20 iters).
//! * lasso  — FISTA (accelerated proximal gradient; the true prox
//!   method, unlike the subgradient descent being benchmarked).
//! * nn     — None (nonconvex; the paper uses ‖∇ᵏ‖² instead).

use crate::linalg::{self, cholesky, Matrix};
use crate::tasks::{sigmoid, TaskKind};

use super::Problem;

/// Dispatch on task kind.
pub fn f_star(p: &Problem) -> Option<f64> {
    match p.task {
        TaskKind::LinReg => Some(linreg_f_star(p)),
        TaskKind::LogReg => Some(logreg_f_star(p)),
        TaskKind::Lasso => Some(lasso_f_star(p)),
        TaskKind::Nn => None,
    }
}

fn masked_xs(p: &Problem) -> Vec<&Matrix> {
    p.shards.iter().map(|s| s.x.as_ref()).collect()
}

/// Σ_m ½‖X_mθ − y_m‖² minimized exactly: (ΣXᵀX)θ = ΣXᵀy.
/// (Padded rows are all-zero, so they drop out of both sides.)
pub fn linreg_f_star(p: &Problem) -> f64 {
    let d = p.shards[0].x.cols;
    let xs = masked_xs(p);
    let gram = cholesky::gram(&xs);
    let mut rhs = vec![0.0; d];
    let mut tmp = vec![0.0; d];
    for s in &p.shards {
        s.x.gemv_t_into(&s.y, &mut tmp);
        linalg::axpy(1.0, &tmp, &mut rhs);
    }
    // tiny ridge for rank-deficient X (objective value is insensitive)
    let ch = cholesky::Cholesky::factor(&gram, 1e-10)
        .expect("gram + ridge should be PD");
    let theta = ch.solve(&rhs);
    objective(p, &theta)
}

/// Newton on the ℓ2-regularized logistic loss.
pub fn logreg_f_star(p: &Problem) -> f64 {
    let d = p.shards[0].x.cols;
    let lam_total = p.lam_m * p.m_workers() as f64;
    let mut theta = vec![0.0; d];
    let mut grad = vec![0.0; d];
    let mut tmp = vec![0.0; d];
    for _ in 0..60 {
        // gradient and Hessian assembled over all shards
        grad.iter_mut().for_each(|g| *g = 0.0);
        let mut hess = Matrix::zeros(d, d);
        for s in &p.shards {
            let mut z = vec![0.0; s.x.rows];
            s.x.gemv(&theta, &mut z);
            let mut coeff = vec![0.0; s.x.rows];
            for i in 0..s.x.rows {
                if s.mask[i] == 0.0 {
                    continue;
                }
                let margin = s.y[i] * z[i];
                coeff[i] = -s.y[i] * sigmoid(-margin);
                let w = sigmoid(z[i] * s.y[i]) * sigmoid(-z[i] * s.y[i]);
                let row = s.x.row(i);
                for a in 0..d {
                    let ra = w * row[a];
                    if ra == 0.0 {
                        continue;
                    }
                    for b in 0..d {
                        hess.data[a * d + b] += ra * row[b];
                    }
                }
            }
            s.x.gemv_t_into(&coeff, &mut tmp);
            linalg::axpy(1.0, &tmp, &mut grad);
        }
        linalg::axpy(lam_total, &theta, &mut grad);
        let ch = cholesky::Cholesky::factor(&hess, lam_total.max(1e-12))
            .expect("logistic Hessian + λI should be PD");
        let step = ch.solve(&grad);
        let step_sq = linalg::norm2_sq(&step);
        linalg::axpy(-1.0, &step, &mut theta);
        if step_sq < 1e-24 {
            break;
        }
    }
    objective(p, &theta)
}

/// FISTA on ½‖Xθ−y‖² + λ‖θ‖₁ with step 1/L.
pub fn lasso_f_star(p: &Problem) -> f64 {
    let d = p.shards[0].x.cols;
    let lam_total = p.lam_m * p.m_workers() as f64;
    let l = p
        .shards
        .iter()
        .map(|s| crate::tasks::smoothness::lambda_max_xtx(&s.x))
        .sum::<f64>()
        .max(1e-12);
    let step = 1.0 / l;
    let mut theta = vec![0.0; d];
    let mut yk = theta.clone();
    let mut grad = vec![0.0; d];
    let mut tmp = vec![0.0; d];
    let mut t = 1.0f64;
    let mut best = f64::INFINITY;
    let mut stall = 0usize;
    for _ in 0..200_000 {
        // ∇ smooth part at yk
        grad.iter_mut().for_each(|g| *g = 0.0);
        for s in &p.shards {
            let mut r = vec![0.0; s.x.rows];
            s.x.gemv(&yk, &mut r);
            for i in 0..r.len() {
                r[i] -= s.y[i];
            }
            s.x.gemv_t_into(&r, &mut tmp);
            linalg::axpy(1.0, &tmp, &mut grad);
        }
        // prox step: soft-threshold(yk − step·∇, step·λ)
        let thr = step * lam_total;
        let mut theta_next = vec![0.0; d];
        for i in 0..d {
            let v = yk[i] - step * grad[i];
            theta_next[i] = if v > thr {
                v - thr
            } else if v < -thr {
                v + thr
            } else {
                0.0
            };
        }
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let momentum = (t - 1.0) / t_next;
        for i in 0..d {
            yk[i] = theta_next[i] + momentum * (theta_next[i] - theta[i]);
        }
        theta = theta_next;
        t = t_next;
        let f = objective(p, &theta);
        if f < best {
            // "significant" progress resets the stall counter; tiny
            // (sub-1e-14-relative) wobble does not
            let significant = best.is_infinite()
                || best - f > 1e-14 * best.abs().max(1.0);
            best = f;
            stall = if significant { 0 } else { stall + 1 };
        } else {
            stall += 1;
        }
        if stall > 500 {
            break;
        }
    }
    best
}

/// f(θ) = Σ_m f_m(θ) evaluated with the rust objectives.
pub fn objective(p: &Problem, theta: &[f64]) -> f64 {
    let mut ws = crate::tasks::TaskWorkspace::default();
    p.shards
        .iter()
        .map(|s| {
            let obj = crate::tasks::build_objective(p.task, s, p.lam_m);
            obj.loss(theta, &mut ws)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::optim::Method;
    use crate::rng::Xoshiro256;
    use crate::spec::{ParamSpec, RunSpec, Session};

    fn toy_problem(task: TaskKind, lam: f64) -> Problem {
        let mut rng = Xoshiro256::new(40);
        let per_worker: Vec<_> = (0..3)
            .map(|_| synthetic::gaussian_pm1(&mut rng.split(), 30, 6))
            .collect();
        Problem::from_worker_datasets(task, "toy", &per_worker, lam)
    }

    /// Run `method` at α = 1/L for `iters` through the spec layer.
    fn reference_run(p: &Problem, method: Method, iters: usize) -> f64 {
        let spec = RunSpec {
            method: method.into(),
            params: ParamSpec {
                alpha: Some(1.0 / p.l_global),
                ..ParamSpec::default()
            },
            iters,
            lambda: p.lambda_global(),
            ..RunSpec::new(p.task, &p.dataset)
        };
        Session::from_parts(spec, p.clone())
            .expect("valid reference spec")
            .run()
            .trace
            .final_loss()
    }

    #[test]
    fn linreg_fstar_is_a_lower_bound_near_gd_limit() {
        let p = toy_problem(TaskKind::LinReg, 0.0);
        let fs = linreg_f_star(&p);
        // run plain GD for a long time; must approach but not beat f*
        let gd_final = reference_run(&p, Method::Gd, 4000);
        assert!(gd_final >= fs - 1e-9, "GD {gd_final} below f* {fs}");
        assert!(gd_final - fs < 1e-6, "GD didn't approach f*: {gd_final} vs {fs}");
    }

    #[test]
    fn logreg_fstar_has_zero_gradient() {
        let p = toy_problem(TaskKind::LogReg, 0.01);
        let fs = logreg_f_star(&p);
        // perturbing θ* in any direction should not decrease f below f*
        // (weak test: HB from zero can't beat it either)
        let final_loss = reference_run(&p, Method::Hb, 6000);
        assert!(final_loss >= fs - 1e-9);
        assert!(final_loss - fs < 1e-5);
    }

    #[test]
    fn lasso_fstar_beats_subgradient_runs() {
        let p = toy_problem(TaskKind::Lasso, 0.1);
        let fs = lasso_f_star(&p);
        let final_loss = reference_run(&p, Method::Hb, 4000);
        assert!(
            final_loss >= fs - 1e-9,
            "subgradient {final_loss} below FISTA f* {fs}"
        );
    }

    #[test]
    fn nn_has_no_fstar() {
        let p = toy_problem(TaskKind::Nn, 0.01);
        assert!(f_star(&p).is_none());
    }
}
