//! Minimal property-testing driver with shrinking (proptest is not
//! available on this image).
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with
//! value generators).  [`check`] runs N random cases; on failure it
//! re-runs with the failing seed while halving integer sizes through
//! [`Gen::shrunk`] to report a smaller counterexample seed.
//!
//! Usage:
//! ```ignore
//! prop::check("aggregate telescopes", 200, |g| {
//!     let dim = g.usize_in(1..=32);
//!     …
//!     prop::assert_prop!(cond, "message {}", detail);
//!     Ok(())
//! });
//! ```

use crate::rng::Xoshiro256;

/// Outcome of a single case: Err carries the failure message.
pub type CaseResult = Result<(), String>;

/// Seeded value generator handed to each property case.
pub struct Gen {
    rng: Xoshiro256,
    /// size multiplier in (0, 1]; shrinking lowers it
    size: f64,
    /// the case's seed (reported on failure for reproduction)
    pub seed: u64,
}

impl Gen {
    /// Full-size generator for one case.
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::new(seed), size: 1.0, seed }
    }

    fn shrunk(seed: u64, size: f64) -> Self {
        Self { rng: Xoshiro256::new(seed), size, seed }
    }

    /// Uniform integer in `range` (upper end shrinks toward the lower).
    pub fn usize_in(&mut self, range: std::ops::RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        // shrinking pulls the upper end toward lo
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.next_below(span as u64 + 1) as usize
    }

    /// Uniform f64 in [lo, hi) (span shrinks toward lo).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64() * self.size
            + if self.size < 1.0 { 0.0 } else { 0.0 }
    }

    /// Uniform f64 in (−mag, mag), magnitude shrinking with size.
    pub fn f64_signed(&mut self, mag: f64) -> f64 {
        (2.0 * self.rng.next_f64() - 1.0) * mag * self.size
    }

    /// Fair coin.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 0
    }

    /// Standard normal draw.
    pub fn gaussian(&mut self) -> f64 {
        self.rng.next_gaussian()
    }

    /// Vector of `len` draws from [`Gen::f64_signed`].
    pub fn vec_f64(&mut self, len: usize, mag: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_signed(mag)).collect()
    }

    /// Uniformly pick one element of `xs`.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len() as u64) as usize]
    }
}

/// Run `cases` random cases of `prop`.  Panics with the smallest
/// found counterexample's seed + message on failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> CaseResult,
{
    // fixed base seed → reproducible CI; derive per-case seeds
    let mut seeder = crate::rng::SplitMix64::new(0xC4B_5EED ^ name.len() as u64);
    for case in 0..cases {
        let seed = seeder.next_u64();
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            // shrink: same seed, smaller size
            let mut best = (seed, 1.0f64, msg);
            let mut size = 0.5;
            while size > 0.01 {
                let mut g = Gen::shrunk(seed, size);
                if let Err(msg) = prop(&mut g) {
                    best = (seed, size, msg);
                    size *= 0.5;
                } else {
                    break;
                }
            }
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (seed {:#x}, size {:.3}): {}",
                best.0, best.1, best.2
            );
        }
    }
}

/// assert! that returns Err instead of panicking (so shrinking works).
#[macro_export]
macro_rules! assert_prop {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", 100, |g| {
            let a = g.f64_signed(1e6);
            let b = g.f64_signed(1e6);
            crate::assert_prop!(a + b == b + a, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics_with_seed() {
        check("always fails above", 50, |g| {
            let v = g.usize_in(0..=100);
            crate::assert_prop!(v < 5, "v={v}");
            Ok(())
        });
    }

    #[test]
    fn generators_respect_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let u = g.usize_in(3..=7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 2.0);
            assert!((-1.0..=2.0).contains(&f));
        }
    }

    #[test]
    fn shrunk_gen_produces_smaller_values() {
        let mut big = Gen::new(9);
        let mut small = Gen::shrunk(9, 0.05);
        let b: usize = (0..100).map(|_| big.usize_in(0..=1000)).sum();
        let s: usize = (0..100).map(|_| small.usize_in(0..=1000)).sum();
        assert!(s < b / 4, "shrunk {s} vs full {b}");
    }
}
