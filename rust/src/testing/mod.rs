//! Test substrates: a property-testing driver (proptest is not on
//! this image) and shared fixtures.

pub mod prop;
