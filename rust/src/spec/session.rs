//! [`Session`]: resolve a [`RunSpec`] against a problem and execute
//! it through the one [`EngineKind`] dispatch.
//!
//! `Session::from_spec(&spec, &registry)?.run()` is the whole
//! lifecycle: validate → load the dataset → build workers (backend,
//! batch schedule, codec) → materialize (server, censor) → dispatch.
//! Every legacy entry point (`run_serial`/`run_threaded`/`run_rayon`/
//! `run_async_detailed`, `experiments::Protocol`, `main.rs::cmd_run`)
//! routes through here or is a thin wrapper beside it, so a spec run
//! is bit-identical to the hand-assembled path it replaced
//! (`tests/spec_session.rs` pins this on all four tasks × all four
//! engines).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::checkpoint::{
    fnv1a64, Checkpoint, CheckpointError, CheckpointPolicy,
};
use crate::compress::{
    Compressor, ErrorFeedback, PackedFp16, PackedFp32, PackedInt, TopK,
    TopKInt, UniformQuantizer,
};
use crate::coordinator::{
    run_engine_with_rules_ctx, run_population, AsyncSummary, EngineKind,
    LocalStepCfg, RunConfig, RunContext, Server, StopRule, Worker,
};
use crate::experiments::Problem;
use crate::metrics::{csv, PopulationSummary, Trace};
use crate::optim::censor::{
    AbsoluteCensor, DecayingCensor, NeverCensor, PeriodicCensor,
    VarianceScaledCensor,
};
use crate::optim::{self, CensorRule, MethodParams, MethodSpec};
use crate::wire::{
    run_client, ClientConfig, ClientStats, Listener, TransportSpec, WirePool,
    WireStats,
};

use super::{
    BackendKind, CensorSpec, CodecSpec, EpsilonSpec, RunSpec, SpecError,
    StopSpec,
};

/// Where a session finds external inputs: the dataset directory (real
/// files, with deterministic synthetic stand-ins otherwise) and the
/// AOT artifact directory for the PJRT backend.  Everything
/// *environmental* lives here so a [`RunSpec`] stays portable across
/// machines.
#[derive(Clone, Debug)]
pub struct Registry {
    /// dataset directory (default `data`)
    pub data_dir: PathBuf,
    /// PJRT artifact directory (default `artifacts`)
    pub artifacts_dir: PathBuf,
}

impl Default for Registry {
    fn default() -> Self {
        Self {
            data_dir: PathBuf::from("data"),
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl Registry {
    /// Registry over explicit directories.
    pub fn new(data_dir: &Path, artifacts_dir: &Path) -> Self {
        Self {
            data_dir: data_dir.to_path_buf(),
            artifacts_dir: artifacts_dir.to_path_buf(),
        }
    }
}

/// What a finished run hands back: the trace, the async engine's
/// extra bookkeeping when that engine ran, and the spec it came from
/// (so result directories are self-describing).
pub struct RunReport {
    /// the spec this run executed (written out as `manifest.json`)
    pub spec: RunSpec,
    /// the standard per-iteration trace
    pub trace: Trace,
    /// async-only telemetry (`None` under synchronous engines)
    pub async_summary: Option<AsyncSummary>,
    /// bounded-memory per-client telemetry (`None` unless the spec
    /// set a [`crate::coordinator::PopulationSpec`])
    pub population_summary: Option<PopulationSummary>,
}

impl RunReport {
    /// Total uplink payload bits spent over the run (the
    /// communication currency of Figs. 4–12).
    pub fn uplink_bits(&self) -> u64 {
        self.trace.iters.last().map_or(0, |s| s.bits_cum)
    }

    /// The trace CSV filename this report writes
    /// (`<task>_<dataset>_<label>.csv`).
    pub fn trace_filename(&self) -> String {
        format!(
            "{}_{}_{}.csv",
            self.spec.task.name(),
            self.spec.dataset,
            self.trace.method
        )
    }

    /// Write the run's artifacts into `dir`: the trace CSV, the
    /// per-worker staleness CSV (async runs), and `manifest.json` —
    /// the exact spec, so the directory is rerunnable with
    /// `chb-fed run --spec <dir>/manifest.json`.
    pub fn write_artifacts(&self, dir: &Path, f_star: f64) -> Result<()> {
        let trace_path = dir.join(self.trace_filename());
        csv::write_trace(&trace_path, &self.trace, f_star)?;
        if !self.trace.worker_staleness.is_empty() {
            let name = format!(
                "{}_{}_{}_staleness.csv",
                self.spec.task.name(),
                self.spec.dataset,
                self.trace.method
            );
            csv::write_staleness(&dir.join(name), &self.trace)?;
        }
        let manifest = dir.join("manifest.json");
        crate::checkpoint::atomic_write(
            &manifest,
            &(self.spec.to_json_string() + "\n"),
        )
        .with_context(|| format!("write {}", manifest.display()))?;
        if let Some(summary) = &self.population_summary {
            let name = format!(
                "{}_{}_{}_population.csv",
                self.spec.task.name(),
                self.spec.dataset,
                self.trace.method
            );
            let mut text = String::from("stat,value\n");
            for (stat, value) in summary.rows() {
                text.push_str(&format!("{stat},{value}\n"));
            }
            let path = dir.join(name);
            crate::checkpoint::atomic_write(&path, &text)
                .with_context(|| format!("write {}", path.display()))?;
        }
        Ok(())
    }
}

/// A validated, fully-resolved run, ready to execute.
pub struct Session {
    spec: RunSpec,
    problem: Problem,
    workers: Vec<Worker>,
    cfg: RunConfig,
    engine: EngineKind,
    censor: Arc<dyn CensorRule>,
    label: String,
    ctx: RunContext,
}

impl Session {
    /// Resolve `spec` against `registry`: validate, load the dataset
    /// by its registry name, and build the workers (including PJRT
    /// artifact loading when `backend` is `"pjrt"`).
    pub fn from_spec(spec: &RunSpec, registry: &Registry) -> Result<Session> {
        spec.validate()?;
        let problem = Problem::from_registry(
            spec.task,
            &spec.dataset,
            &registry.data_dir,
            spec.lambda,
        )?;
        let workers = match spec.backend {
            BackendKind::Rust => problem.rust_workers_batched(spec.batch),
            BackendKind::Pjrt => {
                let mut rt =
                    crate::runtime::PjrtRuntime::new(&registry.artifacts_dir)?;
                problem.pjrt_workers(&mut rt)?
            }
        };
        Ok(Session::assemble(spec.clone(), problem, workers)?)
    }

    /// Resolve `spec` against an already-built [`Problem`] — the path
    /// the experiment drivers use (their problems are synthetic, not
    /// registry datasets; `spec.dataset` is then just a label).
    /// Restricted to the rust backend: PJRT needs a [`Registry`].
    pub fn from_parts(
        spec: RunSpec,
        problem: Problem,
    ) -> Result<Session, SpecError> {
        spec.validate()?;
        if spec.backend == BackendKind::Pjrt {
            return Err(SpecError::PjrtNeedsRegistry);
        }
        let workers = problem.rust_workers_batched(spec.batch);
        Session::assemble(spec, problem, workers)
    }

    /// Shared tail of the two constructors: resolve parameters, stop
    /// rule, censor, codec, and label against the problem.
    fn assemble(
        spec: RunSpec,
        problem: Problem,
        mut workers: Vec<Worker>,
    ) -> Result<Session, SpecError> {
        let m = problem.m_workers();
        let alpha =
            spec.params.alpha.unwrap_or(1.0 / problem.l_global);
        let mut params = MethodParams::new(alpha).with_beta(spec.params.beta);
        params = match spec.params.epsilon {
            EpsilonSpec::Scaled { c } => params.with_epsilon1_scaled(c, m),
            EpsilonSpec::Absolute { eps } => params.with_epsilon1(eps),
        };
        let stop = match spec.stop {
            StopSpec::MaxIters => StopRule::MaxIters,
            StopSpec::ObjErr { tol, f_star } => {
                let f_star = match f_star {
                    Some(v) => v,
                    // validate() already rejected NN here
                    None => problem.f_star().ok_or(SpecError::NoFStar)?,
                };
                StopRule::ObjErrBelow { f_star, tol }
            }
            StopSpec::AggGrad { tol } => StopRule::AggGradBelow { tol },
        };
        // the RunConfig carries the *base* method label; the injected
        // rule pair below carries the real algorithm
        let mut cfg =
            RunConfig::new(spec.method.base_method(), params, spec.iters)
                .with_stop(stop)
                .with_participation(spec.participation)
                .with_drops(spec.drops.prob, spec.drops.seed)
                .with_faults(spec.faults.clone())
                .with_downlink(spec.downlink);
        if spec.record_comm_map {
            cfg = cfg.with_comm_map();
        }
        let censor: Arc<dyn CensorRule> = match spec.censor {
            CensorSpec::MethodDefault => Arc::from(
                optim::method::build_censor_rule_spec(&spec.method, &params),
            ),
            CensorSpec::Never => Arc::new(NeverCensor),
            CensorSpec::Absolute { tau } => Arc::new(AbsoluteCensor { tau }),
            CensorSpec::Periodic { period } => {
                Arc::new(PeriodicCensor::new(period))
            }
            CensorSpec::Decaying { tau0, rho } => {
                Arc::new(DecayingCensor { tau0, rho })
            }
            CensorSpec::VarianceScaled => Arc::new(VarianceScaledCensor {
                epsilon1: params.epsilon1,
                schedule: spec.batch,
                n_rows: problem.shards[0].n_real,
            }),
        };
        // error-feedback wrapping: the wrapper object is still one
        // shared Arc — its residual state lives in each worker's
        // CodecScratch, so sharing stays sound
        fn ef<C: Compressor + 'static>(
            inner: C,
            on: bool,
        ) -> Arc<dyn Compressor> {
            if on {
                Arc::new(ErrorFeedback(inner))
            } else {
                Arc::new(inner)
            }
        }
        let compressor: Option<Arc<dyn Compressor>> = match spec.codec {
            CodecSpec::None => None,
            CodecSpec::Quantizer { bits } => {
                Some(Arc::new(UniformQuantizer { bits }))
            }
            CodecSpec::TopK { k } => Some(Arc::new(TopK { k })),
            CodecSpec::Fp32 { error_feedback } => {
                Some(ef(PackedFp32, error_feedback))
            }
            CodecSpec::Fp16 { error_feedback } => {
                Some(ef(PackedFp16, error_feedback))
            }
            CodecSpec::Int { bits, error_feedback } => {
                Some(ef(PackedInt { bits }, error_feedback))
            }
            CodecSpec::TopKInt { k, bits } => {
                Some(Arc::new(TopKInt { k, bits }))
            }
        };
        if let Some(c) = compressor {
            workers = workers
                .into_iter()
                .map(|w| w.with_compressor(Arc::clone(&c)))
                .collect();
        }
        if spec.method.k_local() > 1 {
            // K-step local regime: workers walk the trajectory with the
            // resolved α and the base method's β (0 when momentum-free)
            let lcfg = LocalStepCfg {
                k_local: spec.method.k_local(),
                alpha: params.alpha,
                beta: if spec.method.uses_momentum() {
                    params.beta
                } else {
                    0.0
                },
            };
            workers = workers
                .into_iter()
                .map(|w| w.with_local_steps(lcfg))
                .collect();
        }
        let label = spec.label.clone().unwrap_or_else(|| {
            if spec.population.is_some() {
                format!("{}-pop", spec.method.name())
            } else {
                match spec.engine {
                    EngineKind::Async(_) => {
                        format!("{}-async", spec.method.name())
                    }
                    _ => spec.method.name().to_string(),
                }
            }
        });
        // every session carries its manifest hash so checkpoints it
        // writes are pinned to this exact spec, and a resume against a
        // different manifest is a typed error instead of divergence
        let ctx = RunContext {
            spec_hash: Some(fnv1a64(&spec.to_json_string())),
            ..RunContext::default()
        };
        Ok(Session {
            engine: spec.engine,
            spec,
            problem,
            workers,
            cfg,
            censor,
            label,
            ctx,
        })
    }

    /// The resolved problem (dataset shards, L constants, θ⁰, f*).
    pub fn problem(&self) -> &Problem {
        &self.problem
    }

    /// The resolved (α, β, ε₁) — spec defaults filled in against the
    /// problem.
    pub fn params(&self) -> MethodParams {
        self.cfg.params
    }

    /// The engine this session will dispatch to.
    pub fn engine(&self) -> &EngineKind {
        &self.engine
    }

    /// Write a checkpoint every `policy.every` server steps (atomic
    /// tmp-file + rename into `policy.dir`).  Checkpointing draws from
    /// no run RNG, so a checkpointed run is bit-identical to an
    /// un-checkpointed one.
    pub fn with_checkpoints(mut self, policy: CheckpointPolicy) -> Session {
        self.ctx.checkpoint = Some(policy);
        self
    }

    /// Start this session from `checkpoint` instead of θ⁰ — the
    /// restored run continues bit-identically to the uninterrupted
    /// one.  The checkpoint must have been written by a session with
    /// the same manifest (enforced via the manifest hash), the same
    /// engine, and matching dimensions; violations surface as typed
    /// [`CheckpointError`]s from [`Session::run_checked`].
    pub fn resuming_from(mut self, checkpoint: Checkpoint) -> Session {
        self.ctx.resume = Some(checkpoint);
        self
    }

    /// Resolve `spec` against `registry` and restore `checkpoint` into
    /// it — the `chb-fed run --resume` path: re-read `manifest.json`,
    /// rebuild the session, continue from round k.
    pub fn resume(
        spec: &RunSpec,
        registry: &Registry,
        checkpoint: Checkpoint,
    ) -> Result<Session> {
        Ok(Session::from_spec(spec, registry)?.resuming_from(checkpoint))
    }

    /// Execute the run.  Consumes the session (workers are spent) and
    /// cannot fail: everything fallible happened at construction.
    /// Sessions carrying a resume image or a checkpoint policy should
    /// use [`Session::run_checked`] — this wrapper panics on their
    /// I/O/compatibility errors.
    pub fn run(self) -> RunReport {
        self.run_checked()
            .expect("checkpoint-free session runs cannot fail")
    }

    /// [`Session::run`] with checkpoint/resume errors surfaced as
    /// typed [`CheckpointError`]s (bad resume image, checkpoint write
    /// failure) instead of panics.
    pub fn run_checked(self) -> Result<RunReport, CheckpointError> {
        if self.ctx.checkpoint.is_some() || self.ctx.resume.is_some() {
            // checkpoints capture (θ, θ⁻, net, workers, trace) — not
            // the Nesterov/Adam server-rule moments and not the
            // downlink codec's view, so resuming those would silently
            // diverge instead of replaying bit-identically
            if matches!(
                self.spec.method,
                MethodSpec::Nesterov { .. } | MethodSpec::CensoredAdam { .. }
            ) {
                return Err(CheckpointError::Unsupported(format!(
                    "method {} carries server-rule state the checkpoint \
                     image does not capture",
                    self.spec.method.name()
                )));
            }
            if !self.spec.downlink.is_none() {
                return Err(CheckpointError::Unsupported(
                    "downlink compression carries codec view state the \
                     checkpoint image does not capture"
                        .into(),
                ));
            }
        }
        let theta0 = self.problem.theta0();
        let dim = theta0.len();
        let server = Server::with_rule(
            optim::method::build_server_rule_spec(
                &self.spec.method,
                &self.cfg.params,
                dim,
            ),
            theta0,
        );
        if let Some(pop) = self.spec.population {
            return Ok(self.run_population_mode(pop, server));
        }
        let out = run_engine_with_rules_ctx(
            &self.engine,
            self.workers,
            &self.cfg,
            server,
            self.censor,
            &self.label,
            &self.ctx,
        )?;
        Ok(RunReport {
            spec: self.spec,
            trace: out.trace,
            async_summary: out.async_summary,
            population_summary: None,
        })
    }

    /// The population-mode tail of [`Session::run_checked`]: drive
    /// `pop.clients` lazily-materialized clients through the cohort
    /// engine, with the session's resident per-shard workers serving
    /// as the exact global-loss evaluators (client c shares shard
    /// `c % M`, so f_pop(θ) = Σ_s mult_s·f_s(θ)).
    fn run_population_mode(
        self,
        pop: crate::coordinator::PopulationSpec,
        server: Server,
    ) -> RunReport {
        assert!(
            self.ctx.checkpoint.is_none() && self.ctx.resume.is_none(),
            "population runs do not support checkpoint/resume yet"
        );
        // validate() pins population runs to the async engine
        let EngineKind::Async(acfg) = &self.engine else {
            unreachable!("validate() rejected population on {:?}", self.engine)
        };
        let problem = self.problem;
        let base_m = problem.m_workers() as u64;
        let mut evals = self.workers;
        let mut global_loss = |theta: &[f64]| -> f64 {
            evals
                .iter_mut()
                .enumerate()
                .map(|(s, w)| {
                    let s = s as u64;
                    if s < pop.clients {
                        // clients on shard s: s, s+M, s+2M, …
                        let mult = (pop.clients - 1 - s) / base_m + 1;
                        mult as f64 * w.observe(theta).loss
                    } else {
                        0.0
                    }
                })
                .sum()
        };
        let out = run_population(
            &pop,
            &self.cfg,
            acfg,
            server,
            self.censor,
            &self.label,
            &mut |c| problem.worker_for(c),
            &mut global_loss,
        );
        RunReport {
            spec: self.spec,
            trace: out.trace,
            async_summary: None,
            population_summary: Some(out.summary),
        }
    }

    /// Run this session as a standalone coordinator daemon: bind
    /// `transport`, wait for all M workers to dial in, then drive the
    /// round engine with the cohort on the other side of the wire.
    /// The spec's engine must be `wire`.  Locally-built workers are
    /// discarded — only the cohort size and dimension matter here; the
    /// gradients live in the `chb-fed worker` processes.
    ///
    /// Returns the usual [`RunReport`] plus the server-side
    /// [`WireStats`] counters (the CLI writes them as
    /// `wire_stats.csv`).
    pub fn serve(
        self,
        transport: &TransportSpec,
    ) -> Result<(RunReport, WireStats)> {
        let wcfg = match self.engine {
            EngineKind::Wire(w) => w,
            ref other => anyhow::bail!(
                "`serve` needs engine.kind = \"wire\" (spec says {:?})",
                other.name()
            ),
        };
        let m = self.workers.len();
        let theta0 = self.problem.theta0();
        let server = Server::with_rule(
            optim::method::build_server_rule_spec(
                &self.spec.method,
                &self.cfg.params,
                theta0.len(),
            ),
            theta0,
        );
        let dim = server.dim();
        let listener = Listener::bind(transport)
            .with_context(|| format!("bind {transport}"))?;
        let mut pool = WirePool::new(listener, m, dim, wcfg, self.ctx.spec_hash)
            .context("wire handshake")?;
        let trace = crate::coordinator::engine::run_with_rules_ctx(
            &mut pool,
            &self.cfg,
            server,
            self.censor,
            &self.label,
            "wire",
            &self.ctx,
        )?;
        let stats = pool.stats();
        pool.shutdown();
        Ok((
            RunReport {
                spec: self.spec,
                trace,
                async_summary: None,
                population_summary: None,
            },
            stats,
        ))
    }

    /// Run this session as worker `id`: build the same deterministic
    /// shard every cohort member derives from the spec, keep only
    /// worker `id`, and serve its gradients to the coordinator at
    /// `transport` until the server says `Bye`.  The spec's engine must
    /// be `wire` (retry/heartbeat pacing comes from it).
    pub fn worker(
        self,
        id: usize,
        transport: &TransportSpec,
    ) -> Result<ClientStats> {
        let wcfg = match self.engine {
            EngineKind::Wire(w) => w,
            ref other => anyhow::bail!(
                "`worker` needs engine.kind = \"wire\" (spec says {:?})",
                other.name()
            ),
        };
        let m = self.workers.len();
        anyhow::ensure!(id < m, "worker id {id} out of range (M = {m})");
        let mut w = self
            .workers
            .into_iter()
            .nth(id)
            .expect("id < m was just checked");
        let ccfg = ClientConfig {
            transport: transport.clone(),
            m,
            spec_hash: self.ctx.spec_hash,
            retry: wcfg.retry,
            heartbeat_ms: wcfg.heartbeat_ms,
            max_reconnects: 100,
        };
        run_client(&mut w, self.censor, &ccfg)
            .with_context(|| format!("worker {id} against {transport}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::run_serial;
    use crate::data::synthetic;
    use crate::optim::Method;
    use crate::spec::ParamSpec;
    use crate::tasks::TaskKind;

    fn problem() -> Problem {
        let l_m = synthetic::increasing_l(3);
        let per_worker = synthetic::per_worker_rescaled(7, 3, 20, 10, &l_m);
        Problem::from_worker_datasets(
            TaskKind::LinReg,
            "sess",
            &per_worker,
            0.0,
        )
    }

    #[test]
    fn session_reproduces_the_legacy_serial_path() {
        let p = problem();
        let spec = RunSpec {
            params: ParamSpec {
                alpha: Some(1.0 / p.l_global),
                ..ParamSpec::default()
            },
            iters: 40,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let report = Session::from_parts(spec, p.clone()).unwrap().run();
        let cfg = RunConfig::new(
            Method::Chb,
            MethodParams::new(1.0 / p.l_global)
                .with_beta(0.4)
                .with_epsilon1_scaled(0.1, p.m_workers()),
            40,
        );
        let mut ws = p.rust_workers();
        let legacy = run_serial(&mut ws, &cfg, p.theta0());
        assert_eq!(report.trace.iterations(), legacy.iterations());
        for (a, b) in report.trace.iters.iter().zip(&legacy.iters) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "k={}", a.k);
        }
        assert_eq!(report.trace.method, "CHB");
        assert!(report.async_summary.is_none());
        assert_eq!(report.uplink_bits(), legacy.iters.last().unwrap().bits_cum);
    }

    #[test]
    fn default_alpha_resolves_to_one_over_l() {
        let p = problem();
        let spec =
            RunSpec { iters: 5, ..RunSpec::new(TaskKind::LinReg, "sess") };
        let session = Session::from_parts(spec, p.clone()).unwrap();
        assert_eq!(session.params().alpha, 1.0 / p.l_global);
    }

    #[test]
    fn pjrt_backend_needs_a_registry() {
        let spec = RunSpec {
            backend: BackendKind::Pjrt,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        assert_eq!(
            Session::from_parts(spec, problem()).err(),
            Some(SpecError::PjrtNeedsRegistry)
        );
    }

    #[test]
    fn population_session_runs_and_reports_summary() {
        use crate::coordinator::{AsyncConfig, PopulationSpec};
        let p = problem();
        let spec = RunSpec {
            engine: EngineKind::Async(AsyncConfig::default()),
            population: Some(PopulationSpec {
                clients: 1_000,
                cohort: 30,
                seed: 11,
            }),
            iters: 12,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let report = Session::from_parts(spec, p).unwrap().run();
        assert_eq!(report.trace.method, "CHB-pop");
        assert_eq!(report.trace.iterations(), 12);
        let summary = report.population_summary.as_ref().unwrap();
        assert_eq!(summary.clients, 1_000);
        assert_eq!(summary.cohort, 30);
        assert_eq!(summary.uplinks + summary.censored, 12 * 30);
        // population loss is a positive multiple of the shard losses
        assert!(report.trace.iters[0].loss.is_finite());
        assert!(report.trace.final_loss() < report.trace.iters[0].loss);
        // determinism: the same spec replays bit-identically
        let spec2 = RunSpec {
            engine: EngineKind::Async(AsyncConfig::default()),
            population: Some(PopulationSpec {
                clients: 1_000,
                cohort: 30,
                seed: 11,
            }),
            iters: 12,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let report2 = Session::from_parts(spec2, problem()).unwrap().run();
        for (a, b) in report.trace.iters.iter().zip(&report2.trace.iters) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "k={}", a.k);
            assert_eq!(a.vclock_us.to_bits(), b.vclock_us.to_bits());
        }
    }

    #[test]
    fn one_local_step_session_matches_the_classic_method_bitwise() {
        let p = problem();
        let base = RunSpec {
            iters: 30,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let mut grid = base.clone();
        grid.method =
            MethodSpec::LocalSteps { base: Method::Chb, k_local: 1 };
        let a = Session::from_parts(base, p.clone()).unwrap().run();
        let b = Session::from_parts(grid, p).unwrap().run();
        assert_eq!(a.trace.iterations(), b.trace.iterations());
        for (x, y) in a.trace.iters.iter().zip(&b.trace.iters) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "k={}", x.k);
            assert_eq!(x.bits_cum, y.bits_cum);
            assert_eq!(x.down_bits_cum, y.down_bits_cum);
        }
    }

    #[test]
    fn local_steps_session_charges_k_sweeps_per_round() {
        let p = problem();
        let spec = RunSpec {
            method: MethodSpec::local_steps(4),
            iters: 20,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let report = Session::from_parts(spec, p).unwrap().run();
        assert_eq!(report.trace.method, "LOCAL");
        // full participation, full batch: every round consumes K = 4
        // data passes, so the epoch column advances by 4 per round
        assert!((report.trace.iters[0].epoch - 4.0).abs() < 1e-9);
        assert!((report.trace.iters[19].epoch - 80.0).abs() < 1e-9);
        assert!(report.trace.final_loss() < report.trace.iters[0].loss);
    }

    #[test]
    fn censored_adam_session_descends() {
        let p = problem();
        let spec = RunSpec {
            method: MethodSpec::censored_adam(),
            params: ParamSpec {
                alpha: Some(0.02),
                ..ParamSpec::default()
            },
            iters: 200,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let report = Session::from_parts(spec, p).unwrap().run();
        assert_eq!(report.trace.method, "CADAM");
        assert!(report.trace.final_loss() < report.trace.iters[0].loss);
        // round 1 always transmits in full (θ̂⁰ = 0 convention)
        assert_eq!(report.trace.iters[0].comms_round, 3);
    }

    #[test]
    fn stateful_methods_and_compressed_downlink_reject_checkpointing() {
        use crate::checkpoint::CheckpointPolicy;
        use crate::net::DownlinkSpec;
        let mk = |spec: RunSpec| {
            Session::from_parts(spec, problem())
                .unwrap()
                .with_checkpoints(CheckpointPolicy::new(2, "ck-never-written"))
                .run_checked()
        };
        let adam = RunSpec {
            method: MethodSpec::censored_adam(),
            iters: 5,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        assert!(matches!(
            mk(adam),
            Err(CheckpointError::Unsupported(_))
        ));
        let down = RunSpec {
            downlink: DownlinkSpec::Fp16 { error_feedback: false },
            iters: 5,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        assert!(matches!(
            mk(down),
            Err(CheckpointError::Unsupported(_))
        ));
    }

    #[test]
    fn custom_label_overrides_the_method_name() {
        let p = problem();
        let spec = RunSpec {
            label: Some("my-regime".into()),
            iters: 3,
            ..RunSpec::new(TaskKind::LinReg, "sess")
        };
        let report = Session::from_parts(spec, p).unwrap().run();
        assert_eq!(report.trace.method, "my-regime");
        assert_eq!(report.trace_filename(), "linreg_sess_my-regime.csv");
    }
}
