//! [`RunSpec`] ⇄ JSON through the in-tree [`crate::util::json`].
//!
//! The encoding is the `manifest.json` schema: one object per axis,
//! each tagged with a `"kind"` field; unknown keys are rejected
//! (mirroring the strict CLI), required fields produce a
//! [`SpecError::Json`] naming the field.  `to_json_string` →
//! `from_json_str` is exact (a property test pins it); integer seeds
//! survive up to 2^53 (JSON numbers are f64).

use std::collections::BTreeMap;

use crate::coordinator::{
    AsyncConfig, ComputeModel, EngineKind, FaultPlan, Participation,
    PopulationSpec,
};
use crate::data::batch::BatchSchedule;
use crate::net::{DownlinkSpec, LatencyModel};
use crate::optim::method::{ADAM_BETA1, ADAM_BETA2, ADAM_EPS};
use crate::optim::{Method, MethodSpec};
use crate::tasks::TaskKind;
use crate::util::json::Json;
use crate::wire::{ChaosSpec, RetryPolicy, WireConfig};

use super::{
    BackendKind, CensorSpec, CodecSpec, DropSpec, EpsilonSpec, ParamSpec,
    RunSpec, SpecError, StopSpec, SPEC_VERSION,
};

type Obj = BTreeMap<String, Json>;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn num(n: f64) -> Json {
    Json::Num(n)
}

fn unum(n: u64) -> Json {
    Json::Num(n as f64)
}

fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

impl RunSpec {
    /// Encode as a [`Json`] value (the `manifest.json` schema).
    ///
    /// A default (no-fault) [`FaultPlan`] is omitted entirely, so
    /// manifests written before the fault axis existed — and all
    /// fault-free runs — stay byte-identical.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("version", unum(SPEC_VERSION)),
            ("task", s(self.task.name())),
            ("dataset", s(&self.dataset)),
            (
                "label",
                match &self.label {
                    Some(l) => s(l),
                    None => Json::Null,
                },
            ),
            ("lambda", num(self.lambda)),
            ("method", method_to_json(&self.method)),
            ("params", params_to_json(&self.params)),
            ("censor", censor_to_json(&self.censor)),
            ("engine", engine_to_json(&self.engine)),
            ("participation", participation_to_json(&self.participation)),
            ("batch", batch_to_json(&self.batch)),
            ("codec", codec_to_json(&self.codec)),
            ("backend", s(self.backend.name())),
            ("iters", unum(self.iters as u64)),
            ("stop", stop_to_json(&self.stop)),
            (
                "drops",
                obj(vec![
                    ("prob", num(self.drops.prob)),
                    ("seed", unum(self.drops.seed)),
                ]),
            ),
            ("record_comm_map", Json::Bool(self.record_comm_map)),
        ];
        if self.faults != FaultPlan::default() {
            pairs.push(("faults", faults_to_json(&self.faults)));
        }
        // like faults: an uncompressed downlink (every pre-existing
        // manifest) omits the key and stays byte-identical
        if !self.downlink.is_none() {
            pairs.push(("downlink", downlink_to_json(&self.downlink)));
        }
        // like faults: resident-regime manifests (the overwhelming
        // majority) omit the key and stay byte-identical
        if let Some(p) = &self.population {
            pairs.push((
                "population",
                obj(vec![
                    ("clients", unum(p.clients)),
                    ("cohort", unum(p.cohort)),
                    ("seed", unum(p.seed)),
                ]),
            ));
        }
        obj(pairs)
    }

    /// The pretty-printed manifest text (what `manifest.json` holds).
    pub fn to_json_string(&self) -> String {
        self.to_json().dump_pretty()
    }

    /// Decode from a [`Json`] value; strict about unknown keys and
    /// field types (a typo'd key in a hand-written spec errors
    /// instead of silently falling back to a default).
    pub fn from_json(j: &Json) -> Result<RunSpec, SpecError> {
        let map = as_obj(j, "spec")?;
        check_keys(
            map,
            "spec",
            &[
                "version",
                "task",
                "dataset",
                "label",
                "lambda",
                "method",
                "params",
                "censor",
                "engine",
                "participation",
                "batch",
                "codec",
                "backend",
                "iters",
                "stop",
                "drops",
                "faults",
                "downlink",
                "record_comm_map",
                "population",
            ],
        )?;
        let version = req_u64(map, "version")?;
        if version != SPEC_VERSION {
            return Err(SpecError::Json {
                detail: format!(
                    "unsupported version {version} (this build reads \
                     {SPEC_VERSION})"
                ),
            });
        }
        let task_name = req_str(map, "task")?;
        let task = TaskKind::parse(task_name).ok_or_else(|| {
            SpecError::UnknownName {
                field: "task",
                name: task_name.to_string(),
            }
        })?;
        let method = method_from_json(req(map, "method")?)?;
        Ok(RunSpec {
            task,
            dataset: req_str(map, "dataset")?.to_string(),
            label: match map.get("label") {
                None | Some(Json::Null) => None,
                Some(Json::Str(l)) => Some(l.clone()),
                Some(other) => {
                    return Err(bad("label", "string or null", other))
                }
            },
            lambda: opt_f64(map, "lambda")?.unwrap_or(0.001),
            method,
            params: match map.get("params") {
                None => ParamSpec::default(),
                Some(v) => params_from_json(v)?,
            },
            censor: match map.get("censor") {
                None => CensorSpec::MethodDefault,
                Some(v) => censor_from_json(v)?,
            },
            engine: match map.get("engine") {
                None => EngineKind::Serial,
                Some(v) => engine_from_json(v)?,
            },
            participation: match map.get("participation") {
                None => Participation::Full,
                Some(v) => participation_from_json(v)?,
            },
            batch: match map.get("batch") {
                None => BatchSchedule::Full,
                Some(v) => batch_from_json(v)?,
            },
            codec: match map.get("codec") {
                None => CodecSpec::None,
                Some(v) => codec_from_json(v)?,
            },
            backend: match map.get("backend") {
                None => BackendKind::Rust,
                Some(v) => match as_str(v, "backend")? {
                    "rust" => BackendKind::Rust,
                    "pjrt" => BackendKind::Pjrt,
                    other => {
                        return Err(SpecError::UnknownName {
                            field: "backend",
                            name: other.to_string(),
                        })
                    }
                },
            },
            iters: req_u64(map, "iters")? as usize,
            stop: match map.get("stop") {
                None => StopSpec::MaxIters,
                Some(v) => stop_from_json(v)?,
            },
            drops: match map.get("drops") {
                None => DropSpec::default(),
                Some(v) => {
                    let m = as_obj(v, "drops")?;
                    check_keys(m, "drops", &["prob", "seed"])?;
                    DropSpec {
                        prob: req_f64(m, "prob")?,
                        seed: req_u64(m, "seed")?,
                    }
                }
            },
            faults: match map.get("faults") {
                None => FaultPlan::default(),
                Some(v) => faults_from_json(v)?,
            },
            downlink: match map.get("downlink") {
                None => DownlinkSpec::None,
                Some(v) => downlink_from_json(v)?,
            },
            record_comm_map: match map.get("record_comm_map") {
                None => false,
                Some(Json::Bool(b)) => *b,
                Some(other) => {
                    return Err(bad("record_comm_map", "bool", other))
                }
            },
            population: match map.get("population") {
                None => None,
                Some(v) => {
                    let m = as_obj(v, "population")?;
                    check_keys(
                        m,
                        "population",
                        &["clients", "cohort", "seed"],
                    )?;
                    Some(PopulationSpec {
                        clients: req_u64(m, "clients")?,
                        cohort: req_u64(m, "cohort")?,
                        seed: match m.get("seed") {
                            None => 0,
                            Some(v) => as_u64(v, "population.seed")?,
                        },
                    })
                }
            },
        })
    }

    /// Decode from manifest text (see [`RunSpec::from_json`]).
    pub fn from_json_str(text: &str) -> Result<RunSpec, SpecError> {
        let j = Json::parse(text).map_err(|e| SpecError::Json {
            detail: format!("parse: {e}"),
        })?;
        RunSpec::from_json(&j)
    }
}

/// Classic methods (and the two Nesterov flavors) encode as the same
/// plain lowercase string as before this axis grew, so pre-existing
/// manifests stay byte-identical; the parameterized grid variants are
/// kind-tagged objects like every other axis.
fn method_to_json(m: &MethodSpec) -> Json {
    match *m {
        MethodSpec::Classic(_) | MethodSpec::Nesterov { .. } => {
            s(&m.name().to_ascii_lowercase())
        }
        MethodSpec::LocalSteps { base, k_local } => obj(vec![
            ("kind", s("local-steps")),
            ("base", s(&base.name().to_ascii_lowercase())),
            ("k_local", unum(k_local as u64)),
        ]),
        MethodSpec::CensoredAdam { beta1, beta2, eps, amsgrad } => obj(vec![
            ("kind", s("censored-adam")),
            ("beta1", num(beta1)),
            ("beta2", num(beta2)),
            ("eps", num(eps)),
            ("amsgrad", Json::Bool(amsgrad)),
        ]),
    }
}

fn method_from_json(j: &Json) -> Result<MethodSpec, SpecError> {
    let m = match j {
        Json::Str(name) => {
            return MethodSpec::parse(name).ok_or_else(|| {
                SpecError::UnknownName {
                    field: "method",
                    name: name.clone(),
                }
            })
        }
        Json::Obj(m) => m,
        other => return Err(bad("method", "string or object", other)),
    };
    match kind(m, "method")? {
        "local-steps" => {
            check_keys(m, "method", &["kind", "base", "k_local"])?;
            let base = match m.get("base") {
                None => Method::Chb,
                Some(v) => {
                    let name = as_str(v, "method.base")?;
                    Method::parse(name).ok_or_else(|| {
                        SpecError::UnknownName {
                            field: "method.base",
                            name: name.to_string(),
                        }
                    })?
                }
            };
            Ok(MethodSpec::LocalSteps {
                base,
                k_local: req_u64(m, "k_local")? as usize,
            })
        }
        "censored-adam" => {
            check_keys(
                m,
                "method",
                &["kind", "beta1", "beta2", "eps", "amsgrad"],
            )?;
            Ok(MethodSpec::CensoredAdam {
                beta1: opt_f64(m, "beta1")?.unwrap_or(ADAM_BETA1),
                beta2: opt_f64(m, "beta2")?.unwrap_or(ADAM_BETA2),
                eps: opt_f64(m, "eps")?.unwrap_or(ADAM_EPS),
                amsgrad: match m.get("amsgrad") {
                    None => false,
                    Some(Json::Bool(b)) => *b,
                    Some(other) => {
                        return Err(bad("method.amsgrad", "bool", other))
                    }
                },
            })
        }
        other => Err(SpecError::UnknownName {
            field: "method.kind",
            name: other.to_string(),
        }),
    }
}

fn downlink_to_json(d: &DownlinkSpec) -> Json {
    match *d {
        DownlinkSpec::None => obj(vec![("kind", s("none"))]),
        DownlinkSpec::Fp32 { error_feedback } => obj(vec![
            ("kind", s("fp32")),
            ("error_feedback", Json::Bool(error_feedback)),
        ]),
        DownlinkSpec::Fp16 { error_feedback } => obj(vec![
            ("kind", s("fp16")),
            ("error_feedback", Json::Bool(error_feedback)),
        ]),
        DownlinkSpec::Int { bits, error_feedback } => obj(vec![
            ("kind", s("int")),
            ("bits", unum(bits as u64)),
            ("error_feedback", Json::Bool(error_feedback)),
        ]),
    }
}

fn downlink_from_json(j: &Json) -> Result<DownlinkSpec, SpecError> {
    let m = as_obj(j, "downlink")?;
    let ef = |m: &Obj| -> Result<bool, SpecError> {
        match m.get("error_feedback") {
            None => Ok(false),
            Some(Json::Bool(b)) => Ok(*b),
            Some(other) => {
                Err(bad("downlink.error_feedback", "bool", other))
            }
        }
    };
    match kind(m, "downlink")? {
        "none" => {
            check_keys(m, "downlink", &["kind"])?;
            Ok(DownlinkSpec::None)
        }
        "fp32" => {
            check_keys(m, "downlink", &["kind", "error_feedback"])?;
            Ok(DownlinkSpec::Fp32 { error_feedback: ef(m)? })
        }
        "fp16" => {
            check_keys(m, "downlink", &["kind", "error_feedback"])?;
            Ok(DownlinkSpec::Fp16 { error_feedback: ef(m)? })
        }
        "int" => {
            check_keys(m, "downlink", &["kind", "bits", "error_feedback"])?;
            Ok(DownlinkSpec::Int {
                bits: req_u64(m, "bits")? as u32,
                error_feedback: ef(m)?,
            })
        }
        other => Err(SpecError::UnknownName {
            field: "downlink.kind",
            name: other.to_string(),
        }),
    }
}

fn params_to_json(p: &ParamSpec) -> Json {
    obj(vec![
        (
            "alpha",
            match p.alpha {
                Some(a) => num(a),
                None => Json::Null,
            },
        ),
        ("beta", num(p.beta)),
        (
            "epsilon",
            match p.epsilon {
                EpsilonSpec::Scaled { c } => {
                    obj(vec![("kind", s("scaled")), ("c", num(c))])
                }
                EpsilonSpec::Absolute { eps } => {
                    obj(vec![("kind", s("absolute")), ("eps", num(eps))])
                }
            },
        ),
    ])
}

fn params_from_json(j: &Json) -> Result<ParamSpec, SpecError> {
    let m = as_obj(j, "params")?;
    check_keys(m, "params", &["alpha", "beta", "epsilon"])?;
    let alpha = match m.get("alpha") {
        None | Some(Json::Null) => None,
        Some(Json::Num(a)) => Some(*a),
        Some(other) => return Err(bad("params.alpha", "number or null", other)),
    };
    let epsilon = match m.get("epsilon") {
        None => EpsilonSpec::Scaled { c: 0.1 },
        Some(v) => {
            let em = as_obj(v, "params.epsilon")?;
            match kind(em, "params.epsilon")? {
                "scaled" => {
                    check_keys(em, "params.epsilon", &["kind", "c"])?;
                    EpsilonSpec::Scaled { c: req_f64(em, "c")? }
                }
                "absolute" => {
                    check_keys(em, "params.epsilon", &["kind", "eps"])?;
                    EpsilonSpec::Absolute { eps: req_f64(em, "eps")? }
                }
                other => {
                    return Err(SpecError::UnknownName {
                        field: "params.epsilon.kind",
                        name: other.to_string(),
                    })
                }
            }
        }
    };
    Ok(ParamSpec {
        alpha,
        beta: opt_f64(m, "beta")?.unwrap_or(0.4),
        epsilon,
    })
}

fn censor_to_json(c: &CensorSpec) -> Json {
    let mut pairs = vec![("kind", s(c.name()))];
    match *c {
        CensorSpec::Absolute { tau } => pairs.push(("tau", num(tau))),
        CensorSpec::Periodic { period } => {
            pairs.push(("period", unum(period as u64)))
        }
        CensorSpec::Decaying { tau0, rho } => {
            pairs.push(("tau0", num(tau0)));
            pairs.push(("rho", num(rho)));
        }
        CensorSpec::MethodDefault
        | CensorSpec::Never
        | CensorSpec::VarianceScaled => {}
    }
    obj(pairs)
}

fn censor_from_json(j: &Json) -> Result<CensorSpec, SpecError> {
    let m = as_obj(j, "censor")?;
    match kind(m, "censor")? {
        "method-default" => {
            check_keys(m, "censor", &["kind"])?;
            Ok(CensorSpec::MethodDefault)
        }
        "never" => {
            check_keys(m, "censor", &["kind"])?;
            Ok(CensorSpec::Never)
        }
        "absolute" => {
            check_keys(m, "censor", &["kind", "tau"])?;
            Ok(CensorSpec::Absolute { tau: req_f64(m, "tau")? })
        }
        "periodic" => {
            check_keys(m, "censor", &["kind", "period"])?;
            Ok(CensorSpec::Periodic { period: req_u64(m, "period")? as usize })
        }
        "decaying" => {
            check_keys(m, "censor", &["kind", "tau0", "rho"])?;
            Ok(CensorSpec::Decaying {
                tau0: req_f64(m, "tau0")?,
                rho: req_f64(m, "rho")?,
            })
        }
        "variance-scaled" => {
            check_keys(m, "censor", &["kind"])?;
            Ok(CensorSpec::VarianceScaled)
        }
        other => Err(SpecError::UnknownName {
            field: "censor.kind",
            name: other.to_string(),
        }),
    }
}

fn engine_to_json(e: &EngineKind) -> Json {
    match e {
        EngineKind::Serial | EngineKind::Threaded => {
            obj(vec![("kind", s(e.name()))])
        }
        EngineKind::Rayon { threads } => obj(vec![
            ("kind", s("rayon")),
            ("threads", unum(*threads as u64)),
        ]),
        EngineKind::Async(acfg) => obj(vec![
            ("kind", s("async")),
            (
                "compute",
                match acfg.compute {
                    ComputeModel::Uniform { us } => obj(vec![
                        ("kind", s("uniform")),
                        ("us", num(us)),
                    ]),
                    ComputeModel::Pareto { scale_us, shape, seed } => {
                        obj(vec![
                            ("kind", s("pareto")),
                            ("scale_us", num(scale_us)),
                            ("shape", num(shape)),
                            ("seed", unum(seed)),
                        ])
                    }
                },
            ),
            (
                "latency",
                obj(vec![
                    ("fixed_us", num(acfg.latency.fixed_us)),
                    ("per_kib_us", num(acfg.latency.per_kib_us)),
                ]),
            ),
            (
                "max_staleness",
                match acfg.max_staleness {
                    Some(v) => unum(v as u64),
                    None => Json::Null,
                },
            ),
        ]),
        EngineKind::Wire(wcfg) => obj(vec![
            ("kind", s("wire")),
            ("quorum", unum(wcfg.quorum as u64)),
            ("round_deadline_ms", unum(wcfg.round_deadline_ms as u64)),
            ("heartbeat_ms", unum(wcfg.heartbeat_ms as u64)),
            (
                "retry",
                obj(vec![
                    ("max_attempts", unum(wcfg.retry.max_attempts as u64)),
                    ("base_ms", unum(wcfg.retry.base_ms as u64)),
                    ("jitter_seed", unum(wcfg.retry.jitter_seed)),
                ]),
            ),
            (
                "chaos",
                obj(vec![
                    ("drop", num(wcfg.chaos.drop)),
                    ("delay_prob", num(wcfg.chaos.delay_prob)),
                    ("delay_ms", unum(wcfg.chaos.delay_ms as u64)),
                    ("duplicate", num(wcfg.chaos.duplicate)),
                    ("corrupt", num(wcfg.chaos.corrupt)),
                    ("partition", num(wcfg.chaos.partition)),
                    ("seed", unum(wcfg.chaos.seed)),
                ]),
            ),
        ]),
    }
}

fn engine_from_json(j: &Json) -> Result<EngineKind, SpecError> {
    let m = as_obj(j, "engine")?;
    match kind(m, "engine")? {
        "serial" => {
            check_keys(m, "engine", &["kind"])?;
            Ok(EngineKind::Serial)
        }
        "threaded" => {
            check_keys(m, "engine", &["kind"])?;
            Ok(EngineKind::Threaded)
        }
        "rayon" => {
            check_keys(m, "engine", &["kind", "threads"])?;
            Ok(EngineKind::Rayon {
                threads: match m.get("threads") {
                    None => 0,
                    Some(v) => as_u64(v, "engine.threads")? as usize,
                },
            })
        }
        "async" => {
            check_keys(
                m,
                "engine",
                &["kind", "compute", "latency", "max_staleness"],
            )?;
            let compute = match m.get("compute") {
                None => ComputeModel::Uniform { us: 1_000.0 },
                Some(v) => {
                    let cm = as_obj(v, "engine.compute")?;
                    match kind(cm, "engine.compute")? {
                        "uniform" => {
                            check_keys(cm, "engine.compute", &["kind", "us"])?;
                            ComputeModel::Uniform { us: req_f64(cm, "us")? }
                        }
                        "pareto" => {
                            check_keys(
                                cm,
                                "engine.compute",
                                &["kind", "scale_us", "shape", "seed"],
                            )?;
                            ComputeModel::Pareto {
                                scale_us: req_f64(cm, "scale_us")?,
                                shape: req_f64(cm, "shape")?,
                                seed: req_u64(cm, "seed")?,
                            }
                        }
                        other => {
                            return Err(SpecError::UnknownName {
                                field: "engine.compute.kind",
                                name: other.to_string(),
                            })
                        }
                    }
                }
            };
            let latency = match m.get("latency") {
                None => LatencyModel::default(),
                Some(v) => {
                    let lm = as_obj(v, "engine.latency")?;
                    check_keys(
                        lm,
                        "engine.latency",
                        &["fixed_us", "per_kib_us"],
                    )?;
                    LatencyModel {
                        fixed_us: req_f64(lm, "fixed_us")?,
                        per_kib_us: req_f64(lm, "per_kib_us")?,
                    }
                }
            };
            let max_staleness = match m.get("max_staleness") {
                None | Some(Json::Null) => None,
                Some(v) => Some(as_u64(v, "engine.max_staleness")? as usize),
            };
            Ok(EngineKind::Async(AsyncConfig {
                compute,
                latency,
                max_staleness,
            }))
        }
        "wire" => {
            check_keys(
                m,
                "engine",
                &[
                    "kind",
                    "quorum",
                    "round_deadline_ms",
                    "heartbeat_ms",
                    "retry",
                    "chaos",
                ],
            )?;
            let mut wcfg = WireConfig::default();
            if let Some(v) = m.get("quorum") {
                wcfg.quorum = as_u64(v, "engine.quorum")? as usize;
            }
            if let Some(v) = m.get("round_deadline_ms") {
                wcfg.round_deadline_ms =
                    as_u64(v, "engine.round_deadline_ms")? as u32;
            }
            if let Some(v) = m.get("heartbeat_ms") {
                wcfg.heartbeat_ms = as_u64(v, "engine.heartbeat_ms")? as u32;
            }
            if let Some(v) = m.get("retry") {
                let rm = as_obj(v, "engine.retry")?;
                check_keys(
                    rm,
                    "engine.retry",
                    &["max_attempts", "base_ms", "jitter_seed"],
                )?;
                wcfg.retry = RetryPolicy {
                    max_attempts: req_u64(rm, "max_attempts")? as u32,
                    base_ms: req_u64(rm, "base_ms")? as u32,
                    jitter_seed: req_u64(rm, "jitter_seed")?,
                };
            }
            if let Some(v) = m.get("chaos") {
                let cm = as_obj(v, "engine.chaos")?;
                check_keys(
                    cm,
                    "engine.chaos",
                    &[
                        "drop",
                        "delay_prob",
                        "delay_ms",
                        "duplicate",
                        "corrupt",
                        "partition",
                        "seed",
                    ],
                )?;
                wcfg.chaos = ChaosSpec {
                    drop: req_f64(cm, "drop")?,
                    delay_prob: req_f64(cm, "delay_prob")?,
                    delay_ms: req_u64(cm, "delay_ms")? as u32,
                    duplicate: req_f64(cm, "duplicate")?,
                    corrupt: req_f64(cm, "corrupt")?,
                    partition: req_f64(cm, "partition")?,
                    seed: req_u64(cm, "seed")?,
                };
            }
            Ok(EngineKind::Wire(wcfg))
        }
        other => Err(SpecError::UnknownName {
            field: "engine.kind",
            name: other.to_string(),
        }),
    }
}

fn participation_to_json(p: &Participation) -> Json {
    match *p {
        Participation::Full => obj(vec![("kind", s("full"))]),
        Participation::UniformSample { frac, seed } => obj(vec![
            ("kind", s("sample")),
            ("frac", num(frac)),
            ("seed", unum(seed)),
        ]),
        Participation::Straggler { timeout, seed } => obj(vec![
            ("kind", s("straggler")),
            ("timeout", num(timeout)),
            ("seed", unum(seed)),
        ]),
    }
}

fn participation_from_json(j: &Json) -> Result<Participation, SpecError> {
    let m = as_obj(j, "participation")?;
    match kind(m, "participation")? {
        "full" => {
            check_keys(m, "participation", &["kind"])?;
            Ok(Participation::Full)
        }
        "sample" => {
            check_keys(m, "participation", &["kind", "frac", "seed"])?;
            Ok(Participation::UniformSample {
                frac: req_f64(m, "frac")?,
                seed: req_u64(m, "seed")?,
            })
        }
        "straggler" => {
            check_keys(m, "participation", &["kind", "timeout", "seed"])?;
            Ok(Participation::Straggler {
                timeout: req_f64(m, "timeout")?,
                seed: req_u64(m, "seed")?,
            })
        }
        other => Err(SpecError::UnknownName {
            field: "participation.kind",
            name: other.to_string(),
        }),
    }
}

fn batch_to_json(b: &BatchSchedule) -> Json {
    match *b {
        BatchSchedule::Full => obj(vec![("kind", s("full"))]),
        BatchSchedule::Minibatch { size, seed, replace } => obj(vec![
            ("kind", s("minibatch")),
            ("size", unum(size as u64)),
            ("seed", unum(seed)),
            ("replace", Json::Bool(replace)),
        ]),
        BatchSchedule::GrowingBatch { size0, growth, seed } => obj(vec![
            ("kind", s("growing")),
            ("size0", unum(size0 as u64)),
            ("growth", num(growth)),
            ("seed", unum(seed)),
        ]),
    }
}

fn batch_from_json(j: &Json) -> Result<BatchSchedule, SpecError> {
    let m = as_obj(j, "batch")?;
    match kind(m, "batch")? {
        "full" => {
            check_keys(m, "batch", &["kind"])?;
            Ok(BatchSchedule::Full)
        }
        "minibatch" => {
            check_keys(m, "batch", &["kind", "size", "seed", "replace"])?;
            Ok(BatchSchedule::Minibatch {
                size: req_u64(m, "size")? as usize,
                seed: req_u64(m, "seed")?,
                replace: match m.get("replace") {
                    None => false,
                    Some(Json::Bool(b)) => *b,
                    Some(other) => {
                        return Err(bad("batch.replace", "bool", other))
                    }
                },
            })
        }
        "growing" => {
            check_keys(m, "batch", &["kind", "size0", "growth", "seed"])?;
            Ok(BatchSchedule::GrowingBatch {
                size0: req_u64(m, "size0")? as usize,
                growth: req_f64(m, "growth")?,
                seed: req_u64(m, "seed")?,
            })
        }
        other => Err(SpecError::UnknownName {
            field: "batch.kind",
            name: other.to_string(),
        }),
    }
}

fn codec_to_json(c: &CodecSpec) -> Json {
    match *c {
        CodecSpec::None => obj(vec![("kind", s("none"))]),
        CodecSpec::Quantizer { bits } => obj(vec![
            ("kind", s("quantizer")),
            ("bits", unum(bits as u64)),
        ]),
        CodecSpec::TopK { k } => {
            obj(vec![("kind", s("top-k")), ("k", unum(k as u64))])
        }
        CodecSpec::Fp32 { error_feedback } => obj(vec![
            ("kind", s("fp32")),
            ("error_feedback", Json::Bool(error_feedback)),
        ]),
        CodecSpec::Fp16 { error_feedback } => obj(vec![
            ("kind", s("fp16")),
            ("error_feedback", Json::Bool(error_feedback)),
        ]),
        CodecSpec::Int { bits, error_feedback } => obj(vec![
            ("kind", s("int")),
            ("bits", unum(bits as u64)),
            ("error_feedback", Json::Bool(error_feedback)),
        ]),
        CodecSpec::TopKInt { k, bits } => obj(vec![
            ("kind", s("top-k-int")),
            ("k", unum(k as u64)),
            ("bits", unum(bits as u64)),
        ]),
    }
}

fn codec_ef(m: &Obj) -> Result<bool, SpecError> {
    match m.get("error_feedback") {
        None => Ok(false),
        Some(Json::Bool(b)) => Ok(*b),
        Some(other) => Err(bad("codec.error_feedback", "bool", other)),
    }
}

fn codec_from_json(j: &Json) -> Result<CodecSpec, SpecError> {
    let m = as_obj(j, "codec")?;
    match kind(m, "codec")? {
        "none" => {
            check_keys(m, "codec", &["kind"])?;
            Ok(CodecSpec::None)
        }
        "quantizer" => {
            check_keys(m, "codec", &["kind", "bits"])?;
            Ok(CodecSpec::Quantizer { bits: req_u64(m, "bits")? as u32 })
        }
        "top-k" => {
            check_keys(m, "codec", &["kind", "k"])?;
            Ok(CodecSpec::TopK { k: req_u64(m, "k")? as usize })
        }
        "fp32" => {
            check_keys(m, "codec", &["kind", "error_feedback"])?;
            Ok(CodecSpec::Fp32 { error_feedback: codec_ef(m)? })
        }
        "fp16" => {
            check_keys(m, "codec", &["kind", "error_feedback"])?;
            Ok(CodecSpec::Fp16 { error_feedback: codec_ef(m)? })
        }
        "int" => {
            check_keys(m, "codec", &["kind", "bits", "error_feedback"])?;
            Ok(CodecSpec::Int {
                bits: req_u64(m, "bits")? as u32,
                error_feedback: codec_ef(m)?,
            })
        }
        "top-k-int" => {
            check_keys(m, "codec", &["kind", "k", "bits"])?;
            Ok(CodecSpec::TopKInt {
                k: req_u64(m, "k")? as usize,
                bits: req_u64(m, "bits")? as u32,
            })
        }
        other => Err(SpecError::UnknownName {
            field: "codec.kind",
            name: other.to_string(),
        }),
    }
}

fn faults_to_json(fp: &FaultPlan) -> Json {
    obj(vec![
        ("crash_prob", num(fp.crash_prob)),
        ("down_rounds", unum(fp.down_rounds as u64)),
        ("seed", unum(fp.seed)),
        (
            "server_kills",
            Json::Arr(
                fp.server_kills.iter().map(|&k| unum(k as u64)).collect(),
            ),
        ),
    ])
}

fn faults_from_json(j: &Json) -> Result<FaultPlan, SpecError> {
    let m = as_obj(j, "faults")?;
    check_keys(
        m,
        "faults",
        &["crash_prob", "down_rounds", "seed", "server_kills"],
    )?;
    let server_kills = match m.get("server_kills") {
        None => Vec::new(),
        Some(Json::Arr(a)) => a
            .iter()
            .map(|v| as_u64(v, "faults.server_kills").map(|k| k as usize))
            .collect::<Result<Vec<_>, _>>()?,
        Some(other) => return Err(bad("faults.server_kills", "array", other)),
    };
    Ok(FaultPlan {
        crash_prob: opt_f64(m, "crash_prob")?.unwrap_or(0.0),
        down_rounds: match m.get("down_rounds") {
            None => 1,
            Some(v) => as_u64(v, "faults.down_rounds")? as usize,
        },
        seed: match m.get("seed") {
            None => 0,
            Some(v) => as_u64(v, "faults.seed")?,
        },
        server_kills,
    })
}

fn stop_to_json(st: &StopSpec) -> Json {
    match *st {
        StopSpec::MaxIters => obj(vec![("kind", s("max-iters"))]),
        StopSpec::ObjErr { tol, f_star } => obj(vec![
            ("kind", s("obj-err")),
            ("tol", num(tol)),
            (
                "f_star",
                match f_star {
                    Some(v) => num(v),
                    None => Json::Null,
                },
            ),
        ]),
        StopSpec::AggGrad { tol } => {
            obj(vec![("kind", s("agg-grad")), ("tol", num(tol))])
        }
    }
}

fn stop_from_json(j: &Json) -> Result<StopSpec, SpecError> {
    let m = as_obj(j, "stop")?;
    match kind(m, "stop")? {
        "max-iters" => {
            check_keys(m, "stop", &["kind"])?;
            Ok(StopSpec::MaxIters)
        }
        "obj-err" => {
            check_keys(m, "stop", &["kind", "tol", "f_star"])?;
            Ok(StopSpec::ObjErr {
                tol: req_f64(m, "tol")?,
                f_star: match m.get("f_star") {
                    None | Some(Json::Null) => None,
                    Some(Json::Num(v)) => Some(*v),
                    Some(other) => {
                        return Err(bad("stop.f_star", "number or null", other))
                    }
                },
            })
        }
        "agg-grad" => {
            check_keys(m, "stop", &["kind", "tol"])?;
            Ok(StopSpec::AggGrad { tol: req_f64(m, "tol")? })
        }
        other => Err(SpecError::UnknownName {
            field: "stop.kind",
            name: other.to_string(),
        }),
    }
}

// ── decoding helpers ────────────────────────────────────────────────

fn bad(field: &str, want: &str, got: &Json) -> SpecError {
    SpecError::Json {
        detail: format!("{field}: expected {want}, got {got:?}"),
    }
}

fn as_obj<'a>(j: &'a Json, field: &str) -> Result<&'a Obj, SpecError> {
    match j {
        Json::Obj(m) => Ok(m),
        other => Err(bad(field, "object", other)),
    }
}

fn as_str<'a>(j: &'a Json, field: &str) -> Result<&'a str, SpecError> {
    j.as_str().ok_or_else(|| bad(field, "string", j))
}

fn as_f64(j: &Json, field: &str) -> Result<f64, SpecError> {
    j.as_f64().ok_or_else(|| bad(field, "number", j))
}

fn as_u64(j: &Json, field: &str) -> Result<u64, SpecError> {
    let v = as_f64(j, field)?;
    if v < 0.0 || v.fract() != 0.0 {
        return Err(SpecError::Json {
            detail: format!(
                "{field}: expected a non-negative integer, got {v}"
            ),
        });
    }
    Ok(v as u64)
}

fn req<'a>(m: &'a Obj, key: &str) -> Result<&'a Json, SpecError> {
    m.get(key).ok_or_else(|| SpecError::Json {
        detail: format!("missing required field {key:?}"),
    })
}

fn req_str<'a>(m: &'a Obj, key: &str) -> Result<&'a str, SpecError> {
    as_str(req(m, key)?, key)
}

fn req_f64(m: &Obj, key: &str) -> Result<f64, SpecError> {
    as_f64(req(m, key)?, key)
}

fn req_u64(m: &Obj, key: &str) -> Result<u64, SpecError> {
    as_u64(req(m, key)?, key)
}

fn opt_f64(m: &Obj, key: &str) -> Result<Option<f64>, SpecError> {
    match m.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(as_f64(v, key)?)),
    }
}

fn kind<'a>(m: &'a Obj, field: &str) -> Result<&'a str, SpecError> {
    match m.get("kind") {
        Some(v) => as_str(v, field),
        None => Err(SpecError::Json {
            detail: format!("{field}: missing \"kind\" tag"),
        }),
    }
}

fn check_keys(
    m: &Obj,
    context: &str,
    allowed: &[&str],
) -> Result<(), SpecError> {
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(SpecError::Json {
                detail: format!(
                    "{context}: unknown key {k:?} (allowed: {allowed:?})"
                ),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_round_trips() {
        let spec = RunSpec::new(TaskKind::LinReg, "synth");
        let text = spec.to_json_string();
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);
    }

    #[test]
    fn every_axis_round_trips() {
        let spec = RunSpec {
            label: Some("ablate".into()),
            method: Method::Gd.into(),
            params: ParamSpec {
                alpha: Some(0.015625),
                beta: 0.25,
                epsilon: EpsilonSpec::Absolute { eps: 0.01 },
            },
            censor: CensorSpec::Decaying { tau0: 2.5, rho: 0.5 },
            engine: EngineKind::Async(AsyncConfig {
                compute: ComputeModel::Pareto {
                    scale_us: 1_000.0,
                    shape: 1.5,
                    seed: 0xA57,
                },
                latency: LatencyModel { fixed_us: 250.0, per_kib_us: 4.0 },
                max_staleness: Some(12),
            }),
            batch: BatchSchedule::Minibatch {
                size: 16,
                seed: 0xB47C,
                replace: true,
            },
            codec: CodecSpec::TopK { k: 25 },
            stop: StopSpec::ObjErr { tol: 1e-9, f_star: Some(1.25) },
            drops: DropSpec { prob: 0.25, seed: 99 },
            record_comm_map: true,
            ..RunSpec::new(TaskKind::Lasso, "housing")
        };
        let text = spec.to_json_string();
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);
    }

    #[test]
    fn wire_engine_round_trips_and_defaults() {
        let spec = RunSpec {
            engine: EngineKind::Wire(WireConfig {
                quorum: 3,
                round_deadline_ms: 750,
                heartbeat_ms: 250,
                retry: RetryPolicy {
                    max_attempts: 7,
                    base_ms: 20,
                    jitter_seed: 0xBEE5,
                },
                chaos: ChaosSpec {
                    drop: 0.1,
                    delay_prob: 0.05,
                    delay_ms: 2,
                    duplicate: 0.02,
                    corrupt: 0.01,
                    partition: 0.005,
                    seed: 0xC405,
                },
            }),
            ..RunSpec::new(TaskKind::LinReg, "synth")
        };
        let text = spec.to_json_string();
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);
        // omitted retry/chaos sub-objects fall back to defaults
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "engine": {"kind": "wire", "quorum": 2}
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(
            spec.engine,
            EngineKind::Wire(WireConfig { quorum: 2, ..WireConfig::default() })
        );
        // unknown wire keys are rejected like every other axis
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "engine": {"kind": "wire", "quroum": 2}
        }"#;
        let err = RunSpec::from_json_str(text).unwrap_err();
        assert!(err.to_string().contains("quroum"), "{err}");
    }

    #[test]
    fn packed_codecs_round_trip() {
        for codec in [
            CodecSpec::Fp32 { error_feedback: false },
            CodecSpec::Fp16 { error_feedback: true },
            CodecSpec::Int { bits: 8, error_feedback: true },
            CodecSpec::Int { bits: 4, error_feedback: false },
        ] {
            let spec = RunSpec {
                codec,
                ..RunSpec::new(TaskKind::LinReg, "synth")
            };
            let text = spec.to_json_string();
            assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec, "{text}");
        }
        // error_feedback defaults to false when the key is omitted
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "codec": {"kind": "fp16"}
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(spec.codec, CodecSpec::Fp16 { error_feedback: false });
    }

    #[test]
    fn fault_plan_round_trips_and_defaults_are_omitted() {
        let base = RunSpec::new(TaskKind::LinReg, "synth");
        // default plan: the "faults" key does not appear at all, so
        // pre-existing manifests stay byte-identical
        assert!(!base.to_json_string().contains("faults"));
        let spec = RunSpec {
            faults: FaultPlan {
                crash_prob: 0.15,
                down_rounds: 3,
                seed: 0xFA17,
                server_kills: vec![5, 40],
            },
            ..base
        };
        let text = spec.to_json_string();
        assert!(text.contains("faults"));
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);
        // a hand-written plan gets per-field defaults
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "faults": {"server_kills": [7]}
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(
            spec.faults,
            FaultPlan { server_kills: vec![7], ..FaultPlan::default() }
        );
    }

    #[test]
    fn population_round_trips_and_default_is_omitted() {
        let base = RunSpec::new(TaskKind::LinReg, "synth");
        assert!(!base.to_json_string().contains("population"));
        let spec = RunSpec {
            engine: EngineKind::Async(AsyncConfig::default()),
            population: Some(PopulationSpec {
                clients: 1_000_000,
                cohort: 1_000,
                seed: 0x5ca1e,
            }),
            ..base
        };
        let text = spec.to_json_string();
        assert!(text.contains("population"));
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);
        // hand-written: seed defaults to 0, unknown keys rejected
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "population": {"clients": 10000, "cohort": 100}
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(
            spec.population,
            Some(PopulationSpec { clients: 10_000, cohort: 100, seed: 0 })
        );
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "population": {"clients": 10000, "cohrot": 100}
        }"#;
        let err = RunSpec::from_json_str(text).unwrap_err();
        assert!(err.to_string().contains("cohrot"), "{err}");
    }

    #[test]
    fn method_grid_round_trips() {
        for method in [
            MethodSpec::Nesterov { censored: false },
            MethodSpec::Nesterov { censored: true },
            MethodSpec::LocalSteps { base: Method::Hb, k_local: 6 },
            MethodSpec::CensoredAdam {
                beta1: 0.875,
                beta2: 0.984375,
                eps: 0.0009765625,
                amsgrad: true,
            },
        ] {
            let spec = RunSpec {
                method,
                ..RunSpec::new(TaskKind::LinReg, "synth")
            };
            let text = spec.to_json_string();
            assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec, "{text}");
        }
        // classic methods still encode as the bare lowercase string
        let text = RunSpec::new(TaskKind::LinReg, "synth").to_json_string();
        assert!(text.contains("\"method\": \"chb\""), "{text}");
        // hand-written censored-adam gets the Kingma–Ba defaults
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": {"kind": "censored-adam"}, "iters": 10
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(spec.method, MethodSpec::censored_adam());
        // unknown method kinds are rejected like every other axis
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": {"kind": "sgd"}, "iters": 10
        }"#;
        assert!(matches!(
            RunSpec::from_json_str(text),
            Err(SpecError::UnknownName { field: "method.kind", .. })
        ));
    }

    #[test]
    fn downlink_round_trips_and_default_is_omitted() {
        use crate::net::DownlinkSpec;
        let base = RunSpec::new(TaskKind::LinReg, "synth");
        assert!(!base.to_json_string().contains("downlink"));
        for downlink in [
            DownlinkSpec::Fp32 { error_feedback: false },
            DownlinkSpec::Fp16 { error_feedback: true },
            DownlinkSpec::Int { bits: 8, error_feedback: true },
        ] {
            let spec = RunSpec { downlink, ..base.clone() };
            let text = spec.to_json_string();
            assert!(text.contains("downlink"));
            assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec, "{text}");
        }
        // error_feedback defaults to false when the key is omitted
        let text = r#"{
            "version": 1, "task": "linreg", "dataset": "synth",
            "method": "chb", "iters": 10,
            "downlink": {"kind": "int", "bits": 8}
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(
            spec.downlink,
            DownlinkSpec::Int { bits: 8, error_feedback: false }
        );
    }

    #[test]
    fn top_k_int_codec_round_trips() {
        let spec = RunSpec {
            codec: CodecSpec::TopKInt { k: 12, bits: 6 },
            ..RunSpec::new(TaskKind::LinReg, "synth")
        };
        let text = spec.to_json_string();
        assert!(text.contains("top-k-int"), "{text}");
        assert_eq!(RunSpec::from_json_str(&text).unwrap(), spec);
    }

    #[test]
    fn minimal_hand_written_spec_gets_defaults() {
        let text = r#"{
            "version": 1,
            "task": "logreg",
            "dataset": "ijcnn1",
            "method": "chb",
            "iters": 100
        }"#;
        let spec = RunSpec::from_json_str(text).unwrap();
        assert_eq!(spec.params, ParamSpec::default());
        assert_eq!(spec.engine, EngineKind::Serial);
        assert_eq!(spec.codec, CodecSpec::None);
        assert_eq!(spec.stop, StopSpec::MaxIters);
        assert!(!spec.record_comm_map);
        spec.validate().unwrap();
    }

    #[test]
    fn unknown_keys_and_kinds_are_rejected() {
        let text = r#"{"version": 1, "task": "linreg", "dataset": "synth",
                       "method": "chb", "iters": 10, "itres": 20}"#;
        let err = RunSpec::from_json_str(text).unwrap_err();
        assert!(err.to_string().contains("itres"), "{err}");
        let text = r#"{"version": 1, "task": "linreg", "dataset": "synth",
                       "method": "chb", "iters": 10,
                       "engine": {"kind": "gpu"}}"#;
        assert!(matches!(
            RunSpec::from_json_str(text),
            Err(SpecError::UnknownName { field: "engine.kind", .. })
        ));
    }

    #[test]
    fn version_and_required_fields_are_enforced() {
        assert!(RunSpec::from_json_str("{}").is_err());
        let text = r#"{"version": 99, "task": "linreg", "dataset": "synth",
                       "method": "chb", "iters": 10}"#;
        let err = RunSpec::from_json_str(text).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn negative_or_fractional_integers_are_rejected() {
        let text = r#"{"version": 1, "task": "linreg", "dataset": "synth",
                       "method": "chb", "iters": 10.5}"#;
        assert!(RunSpec::from_json_str(text).is_err());
        let text = r#"{"version": 1, "task": "linreg", "dataset": "synth",
                       "method": "chb", "iters": -3}"#;
        assert!(RunSpec::from_json_str(text).is_err());
    }
}
