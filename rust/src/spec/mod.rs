//! The declarative run layer: one serializable [`RunSpec`] describes
//! a complete run, one [`Session`] executes it.
//!
//! The paper's experiment grid is methods × censor rules × engines ×
//! participation × batching × compression × failure models.  Before
//! this layer the grid was assembled by hand at every call site —
//! four parallel `run_*` entry points, three overlapping config types
//! (`RunConfig`, `AsyncConfig`, `experiments::Protocol`), and a CLI
//! that wired ~30 flags straight into them; invalid combinations
//! (PJRT × minibatch, async knobs on a sync engine) failed late or
//! not at all.  A [`RunSpec`] is the whole description in one typed
//! value:
//!
//! * cross-field validation up front — [`RunSpec::validate`] returns
//!   a typed [`SpecError`] before anything is built;
//! * JSON round-trip through the in-tree [`crate::util::json`] —
//!   [`RunSpec::to_json_string`] / [`RunSpec::from_json_str`] are
//!   exact inverses (property-tested), so every run can be written as
//!   a `manifest.json` next to its trace CSVs and replayed
//!   bit-for-bit with `chb-fed run --spec manifest.json`;
//! * one execution path — [`Session::from_spec`] resolves the spec
//!   against a [`Registry`] (data + artifact directories), and
//!   [`Session::run`] dispatches through
//!   [`crate::coordinator::EngineKind`] to the single round loop.
//!
//! Integer seeds survive the JSON round trip exactly up to 2^53
//! (numbers are carried as f64, like every JSON implementation
//! without bignum support); [`RunSpec::validate`] rejects larger
//! seeds ([`SpecError::SeedTooLarge`]) so a manifest can never be a
//! silently rounded record of the run it describes.
//!
//! ```
//! use chb_fed::spec::RunSpec;
//! use chb_fed::tasks::TaskKind;
//!
//! let spec = RunSpec::new(TaskKind::LinReg, "synth");
//! spec.validate().unwrap();
//! let replayed = RunSpec::from_json_str(&spec.to_json_string()).unwrap();
//! assert_eq!(spec, replayed);
//! ```

mod json;
mod session;

pub use session::{Registry, RunReport, Session};

use crate::coordinator::{EngineKind, FaultPlan, Participation, PopulationSpec};
use crate::data::batch::BatchSchedule;
use crate::optim::{Method, MethodSpec};
use crate::tasks::TaskKind;

pub use crate::net::downlink::DownlinkSpec;

/// Manifest schema version written by [`RunSpec::to_json_string`].
pub const SPEC_VERSION: u64 = 1;

/// Largest seed value that survives the JSON round trip exactly
/// (2^53 — manifests carry numbers as f64).  [`RunSpec::validate`]
/// rejects larger seeds so a written manifest is never a silently
/// unfaithful record of the run.
pub const MAX_EXACT_SEED: u64 = 1 << 53;

/// Typed validation / decoding error for a [`RunSpec`].
///
/// Every variant names the offending field, so CLI users and spec
/// files get actionable messages instead of a late panic (the old
/// failure mode for e.g. async knobs combined with a sync engine).
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// a numeric field is NaN/∞
    NonFinite {
        /// offending field (dotted path)
        field: &'static str,
        /// the value given
        value: f64,
    },
    /// a field that must be strictly positive is not
    NonPositive {
        /// offending field (dotted path)
        field: &'static str,
        /// the value given
        value: f64,
    },
    /// a numeric field is outside its closed range
    OutOfRange {
        /// offending field (dotted path)
        field: &'static str,
        /// the value given
        value: f64,
        /// inclusive lower bound
        lo: f64,
        /// inclusive upper bound
        hi: f64,
    },
    /// `iters` is 0 — the run would record nothing
    ZeroIters,
    /// a count field (batch size, top-k k, …) is 0
    ZeroSize {
        /// offending field (dotted path)
        field: &'static str,
    },
    /// quantizer bit width outside 2..=32
    QuantBits {
        /// the width given
        bits: u32,
    },
    /// PJRT evaluates the full AOT shard per round — minibatch /
    /// growing batch schedules need the rust backend
    PjrtBatching,
    /// the async engine is full-participation by construction; a
    /// sampling/straggler policy would run unsampled and mislabel its
    /// results
    AsyncParticipation {
        /// the rejected policy's name
        participation: &'static str,
    },
    /// an `obj-err` stop rule without an explicit `f_star` on a task
    /// with no computable minimum (the nonconvex NN)
    NoFStar,
    /// a seed above [`MAX_EXACT_SEED`] — it would be rounded when the
    /// manifest is written, so the replay would not be bit-identical
    SeedTooLarge {
        /// offending field (dotted path)
        field: &'static str,
        /// the seed given
        seed: u64,
    },
    /// the PJRT backend needs artifact files — build the session with
    /// [`Session::from_spec`] and a [`Registry`], not from a bare
    /// problem
    PjrtNeedsRegistry,
    /// an enum-coded field carries an unknown name
    UnknownName {
        /// offending field (dotted path)
        field: &'static str,
        /// the name given
        name: String,
    },
    /// malformed manifest JSON (missing/ill-typed field, unknown key,
    /// bad version, parse failure)
    Json {
        /// human-readable description with field context
        detail: String,
    },
    /// an invalid population/cohort combination, or a population run
    /// combined with an axis the cohort engine cannot honor exactly
    /// (lazy censor-reference resync needs deterministic full-batch,
    /// codec-free gradients)
    Population {
        /// what is wrong
        detail: &'static str,
    },
    /// an invalid method-grid combination (local steps off the
    /// full-batch schedule, a stateful server rule under server
    /// kills, …)
    Method {
        /// what is wrong
        detail: &'static str,
    },
    /// an invalid downlink-channel combination (compression outside
    /// the sync engines, server-side codec state under server kills)
    Downlink {
        /// what is wrong
        detail: &'static str,
    },
}

impl std::fmt::Display for SpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SpecError::NonFinite { field, value } => {
                write!(f, "spec.{field}: must be finite, got {value}")
            }
            SpecError::NonPositive { field, value } => {
                write!(f, "spec.{field}: must be > 0, got {value}")
            }
            SpecError::OutOfRange { field, value, lo, hi } => {
                write!(f, "spec.{field}: {value} outside [{lo}, {hi}]")
            }
            SpecError::ZeroIters => write!(
                f,
                "spec.iters: must be ≥ 1 (a 0-iteration run records nothing)"
            ),
            SpecError::ZeroSize { field } => {
                write!(f, "spec.{field}: must be ≥ 1")
            }
            SpecError::QuantBits { bits } => write!(
                f,
                "spec.codec.bits: quantizer needs 2..=32 bits, got {bits}"
            ),
            SpecError::PjrtBatching => write!(
                f,
                "spec: backend \"pjrt\" evaluates the full AOT shard per \
                 round; minibatch/growing batch schedules need backend \
                 \"rust\""
            ),
            SpecError::AsyncParticipation { participation } => write!(
                f,
                "spec: the async engine runs full participation by \
                 construction; drop participation {participation:?}"
            ),
            SpecError::NoFStar => write!(
                f,
                "spec.stop: obj-err without an explicit f_star is not \
                 computable for the nonconvex nn task"
            ),
            SpecError::SeedTooLarge { field, seed } => write!(
                f,
                "spec.{field}: seed {seed} exceeds 2^53 and would be \
                 rounded in manifest.json (replay would diverge); use a \
                 seed ≤ {MAX_EXACT_SEED}"
            ),
            SpecError::PjrtNeedsRegistry => write!(
                f,
                "spec: backend \"pjrt\" needs artifact files — build the \
                 session via Session::from_spec with a Registry"
            ),
            SpecError::UnknownName { field, name } => {
                write!(f, "spec.{field}: unknown name {name:?}")
            }
            SpecError::Json { detail } => write!(f, "spec json: {detail}"),
            SpecError::Population { detail } => {
                write!(f, "spec.population: {detail}")
            }
            SpecError::Method { detail } => {
                write!(f, "spec.method: {detail}")
            }
            SpecError::Downlink { detail } => {
                write!(f, "spec.downlink: {detail}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// ε₁ parameterization: the paper's scaled form or a raw value.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EpsilonSpec {
    /// ε₁ = c/(α²M²) — the §IV protocol (resolved against the
    /// problem's worker count at session build)
    Scaled {
        /// the paper's c (0.1 throughout §IV)
        c: f64,
    },
    /// a raw ε₁ (the NN runs use ε₁ = 0.01)
    Absolute {
        /// the threshold itself
        eps: f64,
    },
}

/// Hyperparameters as written in a spec; `alpha: None` means "1/L of
/// the resolved problem" (the paper's default step size).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParamSpec {
    /// step size α (None = 1/L, resolved at session build)
    pub alpha: Option<f64>,
    /// momentum coefficient β (ignored by GD/LAG)
    pub beta: f64,
    /// censor-threshold parameterization (ignored by GD/HB)
    pub epsilon: EpsilonSpec,
}

impl Default for ParamSpec {
    /// Paper defaults: α = 1/L, β = 0.4, ε₁ = 0.1/(α²M²).
    fn default() -> Self {
        Self {
            alpha: None,
            beta: 0.4,
            epsilon: EpsilonSpec::Scaled { c: 0.1 },
        }
    }
}

/// Which censor rule workers apply — `MethodDefault` reproduces the
/// method's own rule (the paper's composition table); the others are
/// the ablation/related-work rules, now first-class run axes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CensorSpec {
    /// the method's own rule: grad-diff (8) for LAG/CHB, never for
    /// GD/HB
    MethodDefault,
    /// transmit every round regardless of method
    Never,
    /// fixed energy threshold: transmit iff ‖δ∇‖² > τ
    Absolute {
        /// the threshold τ
        tau: f64,
    },
    /// transmit every `period`-th round (period 0 is normalized to 1,
    /// i.e. never skip)
    Periodic {
        /// the period
        period: usize,
    },
    /// CSGD's decreasing threshold τ_k = τ₀·ρᵏ
    Decaying {
        /// threshold at k = 0
        tau0: f64,
        /// per-round decay ρ ∈ (0, 1]
        rho: f64,
    },
    /// eq. (8) with ε₁/ϕ_k batch-fraction compensation (equal to the
    /// method rule at ϕ = 1); ε₁ and the shard size resolve at session
    /// build from `params.epsilon` and the problem
    VarianceScaled,
}

impl CensorSpec {
    /// Spec-file name of the variant.
    pub fn name(&self) -> &'static str {
        match self {
            CensorSpec::MethodDefault => "method-default",
            CensorSpec::Never => "never",
            CensorSpec::Absolute { .. } => "absolute",
            CensorSpec::Periodic { .. } => "periodic",
            CensorSpec::Decaying { .. } => "decaying",
            CensorSpec::VarianceScaled => "variance-scaled",
        }
    }
}

/// Uplink codec — the compression axis the paper's conclusion
/// proposes composing with censoring.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecSpec {
    /// full-precision f64 payloads
    None,
    /// uniform symmetric quantizer at `bits` per coordinate
    Quantizer {
        /// bits per coordinate (2..=32)
        bits: u32,
    },
    /// top-k magnitude sparsification (sparse wire format)
    TopK {
        /// coordinates kept per uplink
        k: usize,
    },
    /// bit-packed f32 fields (32 bits/coordinate on the wire)
    Fp32 {
        /// carry the narrowing error into the next round
        error_feedback: bool,
    },
    /// bit-packed IEEE half-precision fields (16 bits/coordinate)
    Fp16 {
        /// carry the rounding error into the next round
        error_feedback: bool,
    },
    /// bit-packed `bits`-wide uniform integer levels + f32 scale
    /// header (`bits: 8` is the ladder's int8 rung)
    Int {
        /// bits per coordinate (2..=32)
        bits: u32,
        /// carry the quantization error into the next round
        error_feedback: bool,
    },
    /// sparse + packed hybrid: top-k magnitude selection, survivors
    /// quantized to `bits`-wide levels (32 + (32+bits)·nnz on the wire)
    TopKInt {
        /// coordinates kept per uplink
        k: usize,
        /// bits per surviving coordinate (2..=32)
        bits: u32,
    },
}

impl CodecSpec {
    /// Spec-file name of the variant.
    pub fn name(&self) -> &'static str {
        match self {
            CodecSpec::None => "none",
            CodecSpec::Quantizer { .. } => "quantizer",
            CodecSpec::TopK { .. } => "top-k",
            CodecSpec::Fp32 { .. } => "fp32",
            CodecSpec::Fp16 { .. } => "fp16",
            CodecSpec::Int { .. } => "int",
            CodecSpec::TopKInt { .. } => "top-k-int",
        }
    }
}

/// Where gradients come from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// the in-process f64 objectives (default)
    Rust,
    /// AOT-compiled Pallas artifacts through PJRT
    Pjrt,
}

impl BackendKind {
    /// Spec-file name ("rust" / "pjrt").
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Rust => "rust",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// When to stop, in spec form.  Unlike
/// [`crate::coordinator::StopRule`], `obj-err` may leave `f_star`
/// unset — the session resolves it from the problem's high-accuracy
/// minimizer (an error for the nonconvex NN).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopSpec {
    /// run exactly `iters`
    MaxIters,
    /// stop once f(θᵏ) − f* < tol
    ObjErr {
        /// the tolerance
        tol: f64,
        /// explicit f* (None = resolve from the problem)
        f_star: Option<f64>,
    },
    /// stop once ‖∇ᵏ‖² < tol (nonconvex runs)
    AggGrad {
        /// the tolerance
        tol: f64,
    },
}

/// Uplink failure injection (default: no drops).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DropSpec {
    /// per-message drop probability ∈ [0, 1]
    pub prob: f64,
    /// seed for the drop stream
    pub seed: u64,
}

/// One complete, serializable description of a run — every axis the
/// codebase exposes, in one value.  See the module docs for the
/// JSON manifest workflow.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// learning task
    pub task: TaskKind,
    /// dataset name ([`crate::data::registry`] key for
    /// [`Session::from_spec`]; a free label when the problem is
    /// supplied directly via [`Session::from_parts`])
    pub dataset: String,
    /// optional trace label override (None = the method's name, with
    /// an `-async` suffix under the async engine)
    pub label: Option<String>,
    /// global regularization λ (split λ/M per worker)
    pub lambda: f64,
    /// which point of the method grid drives the run: one of the four
    /// paper algorithms ([`MethodSpec::Classic`], unchanged bitwise),
    /// censored Nesterov, K local steps between uplinks, or the
    /// censored-Adam server rule
    pub method: MethodSpec,
    /// (α, β, ε₁) in spec form
    pub params: ParamSpec,
    /// worker-side censor rule
    pub censor: CensorSpec,
    /// execution backend, including the async engine's compute /
    /// latency / staleness knobs
    pub engine: EngineKind,
    /// per-round client scheduling
    pub participation: Participation,
    /// gradient-sampling schedule
    pub batch: BatchSchedule,
    /// uplink compression codec
    pub codec: CodecSpec,
    /// downlink (server→worker) channel: bit accounting always;
    /// optional broadcast compression through the same codec stack
    /// with server-side error feedback (serialized to `manifest.json`
    /// only when not `None`, so existing manifests stay byte-stable)
    pub downlink: DownlinkSpec,
    /// gradient backend
    pub backend: BackendKind,
    /// iteration budget (server steps in every engine)
    pub iters: usize,
    /// early-exit rule
    pub stop: StopSpec,
    /// uplink failure injection
    pub drops: DropSpec,
    /// seeded worker crash/rejoin + server-kill schedule (default: no
    /// faults — the paper setting; serialized to `manifest.json` only
    /// when non-default, so existing manifests stay byte-stable)
    pub faults: FaultPlan,
    /// record the O(K·M) per-worker transmit map
    pub record_comm_map: bool,
    /// population-scale cohort mode: simulate `clients` devices with
    /// `cohort` materialized per round over the dataset's base shards
    /// (None = the resident regime, one worker per shard; serialized
    /// to `manifest.json` only when set, so existing manifests stay
    /// byte-stable)
    pub population: Option<PopulationSpec>,
}

impl RunSpec {
    /// The paper-default run of `task` on `dataset`: CHB, α = 1/L,
    /// β = 0.4, ε₁ = 0.1/(α²M²), serial engine, full participation,
    /// full batches, no compression, no drops, 500 iterations.
    pub fn new(task: TaskKind, dataset: &str) -> RunSpec {
        RunSpec {
            task,
            dataset: dataset.to_string(),
            label: None,
            lambda: 0.001,
            method: MethodSpec::Classic(Method::Chb),
            params: ParamSpec::default(),
            censor: CensorSpec::MethodDefault,
            engine: EngineKind::Serial,
            participation: Participation::Full,
            batch: BatchSchedule::Full,
            codec: CodecSpec::None,
            downlink: DownlinkSpec::None,
            backend: BackendKind::Rust,
            iters: 500,
            stop: StopSpec::MaxIters,
            drops: DropSpec::default(),
            faults: FaultPlan::default(),
            record_comm_map: false,
            population: None,
        }
    }

    /// Check every field and cross-field constraint; the first
    /// violation is returned as a typed [`SpecError`].
    pub fn validate(&self) -> Result<(), SpecError> {
        finite("lambda", self.lambda)?;
        if self.lambda < 0.0 {
            return Err(SpecError::OutOfRange {
                field: "lambda",
                value: self.lambda,
                lo: 0.0,
                hi: f64::INFINITY,
            });
        }
        if self.iters == 0 {
            return Err(SpecError::ZeroIters);
        }
        self.validate_method()?;
        self.validate_params()?;
        self.validate_censor()?;
        self.validate_engine()?;
        self.validate_participation()?;
        self.validate_batch()?;
        self.validate_codec()?;
        self.validate_downlink()?;
        self.validate_stop()?;
        self.validate_faults()?;
        self.validate_population()?;
        self.validate_seeds()?;
        finite("drops.prob", self.drops.prob)?;
        if !(0.0..=1.0).contains(&self.drops.prob) {
            return Err(SpecError::OutOfRange {
                field: "drops.prob",
                value: self.drops.prob,
                lo: 0.0,
                hi: 1.0,
            });
        }
        // cross-field: PJRT evaluates the full AOT shard per round
        if self.backend == BackendKind::Pjrt
            && self.batch != BatchSchedule::Full
        {
            return Err(SpecError::PjrtBatching);
        }
        // cross-field: the async engine is full-participation by
        // construction (this used to be a runtime assert, hit only
        // after datasets were loaded and workers built)
        if matches!(self.engine, EngineKind::Async(_))
            && self.participation != Participation::Full
        {
            return Err(SpecError::AsyncParticipation {
                participation: self.participation.name(),
            });
        }
        Ok(())
    }

    /// Method-grid cross-field rules.  Local steps need the
    /// deterministic full-batch schedule (the K-step trajectory and
    /// its censor delta are defined on exact shard gradients), and the
    /// stateful server rules (Nesterov's previous aggregate, Adam's
    /// moment vectors) are runtime-only state — checkpoints cannot
    /// capture them, so server-kill schedules are rejected up front.
    fn validate_method(&self) -> Result<(), SpecError> {
        match self.method {
            MethodSpec::Classic(_) => {}
            MethodSpec::Nesterov { .. } => {
                if !self.faults.server_kills.is_empty() {
                    return Err(SpecError::Method {
                        detail: "the nesterov rule's previous-aggregate \
                                 state is not checkpoint-serialized; drop \
                                 faults.server_kills",
                    });
                }
            }
            MethodSpec::LocalSteps { k_local, .. } => {
                if k_local == 0 {
                    return Err(SpecError::ZeroSize {
                        field: "method.k_local",
                    });
                }
                if self.batch != BatchSchedule::Full {
                    return Err(SpecError::Method {
                        detail: "local steps need the full-batch schedule \
                                 (the K-step trajectory and its censor \
                                 delta are defined on exact shard \
                                 gradients)",
                    });
                }
            }
            MethodSpec::CensoredAdam { beta1, beta2, eps, .. } => {
                for (field, v) in
                    [("method.beta1", beta1), ("method.beta2", beta2)]
                {
                    finite(field, v)?;
                    if !(0.0..1.0).contains(&v) {
                        return Err(SpecError::OutOfRange {
                            field,
                            value: v,
                            lo: 0.0,
                            hi: 1.0,
                        });
                    }
                }
                positive("method.eps", eps)?;
                if !self.faults.server_kills.is_empty() {
                    return Err(SpecError::Method {
                        detail: "adam moment vectors are not checkpoint-\
                                 serialized; drop faults.server_kills",
                    });
                }
            }
        }
        Ok(())
    }

    /// Downlink-channel cross-field rules.  Bit *accounting* composes
    /// with every engine; broadcast *compression* runs only on the
    /// sync engines — the async/cohort loops re-broadcast on their
    /// virtual clock and the wire protocol frames dense hex θ — and
    /// its server-side view/error-feedback state is runtime-only, so
    /// server-kill schedules are rejected.
    fn validate_downlink(&self) -> Result<(), SpecError> {
        match self.downlink {
            DownlinkSpec::None => return Ok(()),
            DownlinkSpec::Fp32 { .. } | DownlinkSpec::Fp16 { .. } => {}
            DownlinkSpec::Int { bits, .. } => {
                if !(2..=32).contains(&bits) {
                    return Err(SpecError::QuantBits { bits });
                }
            }
        }
        if !matches!(
            self.engine,
            EngineKind::Serial | EngineKind::Threaded | EngineKind::Rayon { .. }
        ) {
            return Err(SpecError::Downlink {
                detail: "downlink compression runs on the sync engines \
                         (serial/threaded/rayon); async and wire account \
                         bits but broadcast uncompressed",
            });
        }
        if !self.faults.server_kills.is_empty() {
            return Err(SpecError::Downlink {
                detail: "the downlink codec's view/error-feedback state is \
                         not checkpoint-serialized; drop \
                         faults.server_kills",
            });
        }
        Ok(())
    }

    fn validate_params(&self) -> Result<(), SpecError> {
        if let Some(a) = self.params.alpha {
            positive("params.alpha", a)?;
        }
        finite("params.beta", self.params.beta)?;
        if self.params.beta < 0.0 {
            return Err(SpecError::OutOfRange {
                field: "params.beta",
                value: self.params.beta,
                lo: 0.0,
                hi: f64::INFINITY,
            });
        }
        let (field, v) = match self.params.epsilon {
            EpsilonSpec::Scaled { c } => ("params.epsilon.c", c),
            EpsilonSpec::Absolute { eps } => ("params.epsilon.eps", eps),
        };
        finite(field, v)?;
        if v < 0.0 {
            return Err(SpecError::OutOfRange {
                field,
                value: v,
                lo: 0.0,
                hi: f64::INFINITY,
            });
        }
        Ok(())
    }

    fn validate_censor(&self) -> Result<(), SpecError> {
        match self.censor {
            CensorSpec::Absolute { tau } => {
                finite("censor.tau", tau)?;
                if tau < 0.0 {
                    return Err(SpecError::OutOfRange {
                        field: "censor.tau",
                        value: tau,
                        lo: 0.0,
                        hi: f64::INFINITY,
                    });
                }
            }
            CensorSpec::Decaying { tau0, rho } => {
                finite("censor.tau0", tau0)?;
                if tau0 < 0.0 {
                    return Err(SpecError::OutOfRange {
                        field: "censor.tau0",
                        value: tau0,
                        lo: 0.0,
                        hi: f64::INFINITY,
                    });
                }
                finite("censor.rho", rho)?;
                if !(rho > 0.0 && rho <= 1.0) {
                    return Err(SpecError::OutOfRange {
                        field: "censor.rho",
                        value: rho,
                        lo: 0.0,
                        hi: 1.0,
                    });
                }
            }
            CensorSpec::MethodDefault
            | CensorSpec::Never
            | CensorSpec::Periodic { .. }
            | CensorSpec::VarianceScaled => {}
        }
        Ok(())
    }

    fn validate_engine(&self) -> Result<(), SpecError> {
        use crate::coordinator::ComputeModel;
        match &self.engine {
            EngineKind::Async(acfg) => {
                match acfg.compute {
                    ComputeModel::Uniform { us } => {
                        positive("engine.compute.us", us)?;
                    }
                    ComputeModel::Pareto { scale_us, shape, .. } => {
                        positive("engine.compute.scale_us", scale_us)?;
                        positive("engine.compute.shape", shape)?;
                    }
                }
                for (field, v) in [
                    ("engine.latency.fixed_us", acfg.latency.fixed_us),
                    ("engine.latency.per_kib_us", acfg.latency.per_kib_us),
                ] {
                    finite(field, v)?;
                    if v < 0.0 {
                        return Err(SpecError::OutOfRange {
                            field,
                            value: v,
                            lo: 0.0,
                            hi: f64::INFINITY,
                        });
                    }
                }
                Ok(())
            }
            EngineKind::Wire(wcfg) => {
                if wcfg.retry.max_attempts == 0 {
                    return Err(SpecError::ZeroSize {
                        field: "engine.retry.max_attempts",
                    });
                }
                if wcfg.round_deadline_ms == 0 {
                    return Err(SpecError::ZeroSize {
                        field: "engine.round_deadline_ms",
                    });
                }
                if wcfg.heartbeat_ms == 0 {
                    return Err(SpecError::ZeroSize {
                        field: "engine.heartbeat_ms",
                    });
                }
                let c = &wcfg.chaos;
                let mut sum = 0.0;
                for (field, v) in [
                    ("engine.chaos.drop", c.drop),
                    ("engine.chaos.delay_prob", c.delay_prob),
                    ("engine.chaos.duplicate", c.duplicate),
                    ("engine.chaos.corrupt", c.corrupt),
                    ("engine.chaos.partition", c.partition),
                ] {
                    finite(field, v)?;
                    if !(0.0..=1.0).contains(&v) {
                        return Err(SpecError::OutOfRange {
                            field,
                            value: v,
                            lo: 0.0,
                            hi: 1.0,
                        });
                    }
                    if field != "engine.chaos.partition" {
                        sum += v;
                    }
                }
                // drop/delay/duplicate/corrupt share one draw: their
                // thresholds must partition [0, 1]
                if sum > 1.0 {
                    return Err(SpecError::OutOfRange {
                        field: "engine.chaos (drop+delay+duplicate+corrupt)",
                        value: sum,
                        lo: 0.0,
                        hi: 1.0,
                    });
                }
                Ok(())
            }
            EngineKind::Serial
            | EngineKind::Threaded
            | EngineKind::Rayon { .. } => Ok(()),
        }
    }

    fn validate_participation(&self) -> Result<(), SpecError> {
        match self.participation {
            Participation::Full => Ok(()),
            Participation::UniformSample { frac, .. } => {
                finite("participation.frac", frac)?;
                if !(frac > 0.0 && frac <= 1.0) {
                    return Err(SpecError::OutOfRange {
                        field: "participation.frac",
                        value: frac,
                        lo: 0.0,
                        hi: 1.0,
                    });
                }
                Ok(())
            }
            Participation::Straggler { timeout, .. } => {
                finite("participation.timeout", timeout)?;
                if timeout < 0.0 {
                    return Err(SpecError::OutOfRange {
                        field: "participation.timeout",
                        value: timeout,
                        lo: 0.0,
                        hi: f64::INFINITY,
                    });
                }
                Ok(())
            }
        }
    }

    fn validate_batch(&self) -> Result<(), SpecError> {
        match self.batch {
            BatchSchedule::Full => Ok(()),
            BatchSchedule::Minibatch { size, .. } => {
                if size == 0 {
                    return Err(SpecError::ZeroSize { field: "batch.size" });
                }
                Ok(())
            }
            BatchSchedule::GrowingBatch { size0, growth, .. } => {
                if size0 == 0 {
                    return Err(SpecError::ZeroSize { field: "batch.size0" });
                }
                finite("batch.growth", growth)?;
                if growth < 1.0 {
                    return Err(SpecError::OutOfRange {
                        field: "batch.growth",
                        value: growth,
                        lo: 1.0,
                        hi: f64::INFINITY,
                    });
                }
                Ok(())
            }
        }
    }

    fn validate_codec(&self) -> Result<(), SpecError> {
        match self.codec {
            CodecSpec::None => Ok(()),
            CodecSpec::Quantizer { bits } => {
                if !(2..=32).contains(&bits) {
                    return Err(SpecError::QuantBits { bits });
                }
                Ok(())
            }
            CodecSpec::TopK { k } => {
                if k == 0 {
                    return Err(SpecError::ZeroSize { field: "codec.k" });
                }
                Ok(())
            }
            CodecSpec::Fp32 { .. } | CodecSpec::Fp16 { .. } => Ok(()),
            // the spec layer owns the quantizer range check (the codec
            // hot path only debug-asserts it)
            CodecSpec::Int { bits, .. } => {
                if !(2..=32).contains(&bits) {
                    return Err(SpecError::QuantBits { bits });
                }
                Ok(())
            }
            CodecSpec::TopKInt { k, bits } => {
                if k == 0 {
                    return Err(SpecError::ZeroSize { field: "codec.k" });
                }
                if !(2..=32).contains(&bits) {
                    return Err(SpecError::QuantBits { bits });
                }
                Ok(())
            }
        }
    }

    fn validate_faults(&self) -> Result<(), SpecError> {
        finite("faults.crash_prob", self.faults.crash_prob)?;
        if !(0.0..=1.0).contains(&self.faults.crash_prob) {
            return Err(SpecError::OutOfRange {
                field: "faults.crash_prob",
                value: self.faults.crash_prob,
                lo: 0.0,
                hi: 1.0,
            });
        }
        if self.faults.crash_prob > 0.0 && self.faults.down_rounds == 0 {
            return Err(SpecError::ZeroSize { field: "faults.down_rounds" });
        }
        let kills = &self.faults.server_kills;
        for (i, &k) in kills.iter().enumerate() {
            if k == 0 {
                return Err(SpecError::ZeroSize {
                    field: "faults.server_kills",
                });
            }
            if i > 0 && kills[i - 1] >= k {
                return Err(SpecError::Json {
                    detail: format!(
                        "faults.server_kills: must be strictly increasing \
                         (got {} then {k})",
                        kills[i - 1]
                    ),
                });
            }
        }
        Ok(())
    }

    /// The population axis composes with few others: the lazy
    /// censor-reference resync (re-deriving ∇f_c(θ̂) from an archived
    /// iterate) is exact only for deterministic full-batch, codec-free
    /// gradients, and the cohort engine runs on the async engine's
    /// compute/latency clock with its own cohort scheduling.
    fn validate_population(&self) -> Result<(), SpecError> {
        let Some(pop) = &self.population else { return Ok(()) };
        if pop.clients == 0 {
            return Err(SpecError::ZeroSize { field: "population.clients" });
        }
        if pop.cohort == 0 {
            return Err(SpecError::ZeroSize { field: "population.cohort" });
        }
        if pop.cohort > pop.clients {
            return Err(SpecError::Population {
                detail: "cohort exceeds clients",
            });
        }
        if !matches!(self.engine, EngineKind::Async(_)) {
            return Err(SpecError::Population {
                detail: "population runs need engine \"async\" (the cohort \
                         loop schedules uplinks on its virtual clock)",
            });
        }
        if !matches!(self.method, MethodSpec::Classic(_)) {
            return Err(SpecError::Population {
                detail: "population runs cover the four classic methods \
                         only (the cohort loop has no local-step or \
                         stateful-rule path)",
            });
        }
        if self.codec != CodecSpec::None {
            return Err(SpecError::Population {
                detail: "population runs need codec \"none\" (lazy censor-\
                         reference resync must reproduce the transmitted \
                         gradient exactly)",
            });
        }
        if self.batch != BatchSchedule::Full {
            return Err(SpecError::Population {
                detail: "population runs need full batches (lazy censor-\
                         reference resync must reproduce the transmitted \
                         gradient exactly)",
            });
        }
        if self.backend != BackendKind::Rust {
            return Err(SpecError::Population {
                detail: "population runs need backend \"rust\" (clients \
                         materialize lazily against in-process shards)",
            });
        }
        if self.participation != Participation::Full {
            return Err(SpecError::Population {
                detail: "population runs own their scheduling (the cohort \
                         sampler); drop the participation policy",
            });
        }
        if self.drops.prob != 0.0 {
            return Err(SpecError::Population {
                detail: "population runs do not compose with uplink drops \
                         yet",
            });
        }
        if self.faults != FaultPlan::default() {
            return Err(SpecError::Population {
                detail: "population runs do not compose with fault plans \
                         yet",
            });
        }
        if self.record_comm_map {
            return Err(SpecError::Population {
                detail: "the per-client comm map is O(K·M) — the memory \
                         population mode exists to avoid",
            });
        }
        Ok(())
    }

    /// Every seed in the spec must survive the f64-carried JSON round
    /// trip exactly, or the written manifest would replay a different
    /// stream than the run it records.
    fn validate_seeds(&self) -> Result<(), SpecError> {
        use crate::coordinator::ComputeModel;
        seed_ok("drops.seed", self.drops.seed)?;
        if let Some(pop) = &self.population {
            seed_ok("population.seed", pop.seed)?;
        }
        seed_ok("faults.seed", self.faults.seed)?;
        match self.participation {
            Participation::UniformSample { seed, .. }
            | Participation::Straggler { seed, .. } => {
                seed_ok("participation.seed", seed)?
            }
            Participation::Full => {}
        }
        match self.batch {
            BatchSchedule::Minibatch { seed, .. }
            | BatchSchedule::GrowingBatch { seed, .. } => {
                seed_ok("batch.seed", seed)?
            }
            BatchSchedule::Full => {}
        }
        if let EngineKind::Async(acfg) = &self.engine {
            if let ComputeModel::Pareto { seed, .. } = acfg.compute {
                seed_ok("engine.compute.seed", seed)?;
            }
        }
        if let EngineKind::Wire(wcfg) = &self.engine {
            seed_ok("engine.chaos.seed", wcfg.chaos.seed)?;
            seed_ok("engine.retry.jitter_seed", wcfg.retry.jitter_seed)?;
        }
        Ok(())
    }

    fn validate_stop(&self) -> Result<(), SpecError> {
        match self.stop {
            StopSpec::MaxIters => Ok(()),
            StopSpec::ObjErr { tol, f_star } => {
                finite("stop.tol", tol)?;
                if let Some(fs) = f_star {
                    finite("stop.f_star", fs)?;
                } else if self.task == TaskKind::Nn {
                    return Err(SpecError::NoFStar);
                }
                Ok(())
            }
            StopSpec::AggGrad { tol } => finite("stop.tol", tol),
        }
    }
}

fn finite(field: &'static str, value: f64) -> Result<(), SpecError> {
    if value.is_finite() {
        Ok(())
    } else {
        Err(SpecError::NonFinite { field, value })
    }
}

fn positive(field: &'static str, value: f64) -> Result<(), SpecError> {
    finite(field, value)?;
    if value > 0.0 {
        Ok(())
    } else {
        Err(SpecError::NonPositive { field, value })
    }
}

fn seed_ok(field: &'static str, seed: u64) -> Result<(), SpecError> {
    if seed <= MAX_EXACT_SEED {
        Ok(())
    } else {
        Err(SpecError::SeedTooLarge { field, seed })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AsyncConfig, ComputeModel};

    fn base() -> RunSpec {
        RunSpec::new(TaskKind::LinReg, "synth")
    }

    #[test]
    fn default_spec_validates() {
        base().validate().unwrap();
    }

    #[test]
    fn pjrt_rejects_minibatch_schedules() {
        let spec = RunSpec {
            backend: BackendKind::Pjrt,
            batch: BatchSchedule::Minibatch {
                size: 16,
                seed: 1,
                replace: false,
            },
            ..base()
        };
        assert_eq!(spec.validate(), Err(SpecError::PjrtBatching));
        // full batches on pjrt are fine
        let spec = RunSpec { backend: BackendKind::Pjrt, ..base() };
        spec.validate().unwrap();
    }

    #[test]
    fn async_rejects_partial_participation() {
        let spec = RunSpec {
            engine: EngineKind::Async(AsyncConfig::default()),
            participation: Participation::UniformSample { frac: 0.5, seed: 1 },
            ..base()
        };
        assert_eq!(
            spec.validate(),
            Err(SpecError::AsyncParticipation { participation: "sample" })
        );
    }

    #[test]
    fn async_compute_knobs_are_checked() {
        let spec = RunSpec {
            engine: EngineKind::Async(AsyncConfig {
                compute: ComputeModel::Uniform { us: 0.0 },
                ..AsyncConfig::default()
            }),
            ..base()
        };
        assert_eq!(
            spec.validate(),
            Err(SpecError::NonPositive {
                field: "engine.compute.us",
                value: 0.0
            })
        );
        let spec = RunSpec {
            engine: EngineKind::Async(AsyncConfig {
                compute: ComputeModel::Pareto {
                    scale_us: 100.0,
                    shape: -1.0,
                    seed: 0,
                },
                ..AsyncConfig::default()
            }),
            ..base()
        };
        assert!(matches!(
            spec.validate(),
            Err(SpecError::NonPositive { field: "engine.compute.shape", .. })
        ));
    }

    #[test]
    fn numeric_bounds_are_enforced() {
        let mut s = base();
        s.iters = 0;
        assert_eq!(s.validate(), Err(SpecError::ZeroIters));
        let mut s = base();
        s.params.alpha = Some(-0.1);
        assert!(matches!(s.validate(), Err(SpecError::NonPositive { .. })));
        let mut s = base();
        s.params.beta = f64::NAN;
        assert!(matches!(s.validate(), Err(SpecError::NonFinite { .. })));
        let mut s = base();
        s.drops.prob = 1.5;
        assert!(matches!(s.validate(), Err(SpecError::OutOfRange { .. })));
        let mut s = base();
        s.codec = CodecSpec::Quantizer { bits: 1 };
        assert_eq!(s.validate(), Err(SpecError::QuantBits { bits: 1 }));
        let mut s = base();
        s.codec = CodecSpec::TopK { k: 0 };
        assert_eq!(s.validate(), Err(SpecError::ZeroSize { field: "codec.k" }));
        // the packed-int range check lives here, not in the codec hot
        // path (which only debug-asserts)
        let mut s = base();
        s.codec = CodecSpec::Int { bits: 1, error_feedback: true };
        assert_eq!(s.validate(), Err(SpecError::QuantBits { bits: 1 }));
        let mut s = base();
        s.codec = CodecSpec::Int { bits: 33, error_feedback: false };
        assert_eq!(s.validate(), Err(SpecError::QuantBits { bits: 33 }));
        let mut s = base();
        s.codec = CodecSpec::TopKInt { k: 0, bits: 8 };
        assert_eq!(s.validate(), Err(SpecError::ZeroSize { field: "codec.k" }));
        let mut s = base();
        s.codec = CodecSpec::TopKInt { k: 4, bits: 1 };
        assert_eq!(s.validate(), Err(SpecError::QuantBits { bits: 1 }));
        let mut s = base();
        s.codec = CodecSpec::TopKInt { k: 4, bits: 8 };
        assert!(s.validate().is_ok());
        let mut s = base();
        s.codec = CodecSpec::Fp16 { error_feedback: true };
        assert!(s.validate().is_ok());
        let mut s = base();
        s.batch =
            BatchSchedule::GrowingBatch { size0: 8, growth: 0.9, seed: 1 };
        assert!(matches!(s.validate(), Err(SpecError::OutOfRange { .. })));
        let mut s = base();
        s.censor = CensorSpec::Decaying { tau0: 1.0, rho: 0.0 };
        assert!(matches!(s.validate(), Err(SpecError::OutOfRange { .. })));
    }

    #[test]
    fn seeds_beyond_exact_f64_range_are_rejected() {
        let big = MAX_EXACT_SEED + 1;
        let mut s = base();
        s.drops.seed = big;
        assert_eq!(
            s.validate(),
            Err(SpecError::SeedTooLarge { field: "drops.seed", seed: big })
        );
        let mut s = base();
        s.participation = Participation::UniformSample { frac: 0.5, seed: big };
        assert!(matches!(
            s.validate(),
            Err(SpecError::SeedTooLarge { field: "participation.seed", .. })
        ));
        let mut s = base();
        s.batch =
            BatchSchedule::Minibatch { size: 8, seed: big, replace: false };
        assert!(matches!(
            s.validate(),
            Err(SpecError::SeedTooLarge { field: "batch.seed", .. })
        ));
        // the boundary itself is exact and accepted
        let mut s = base();
        s.drops.seed = MAX_EXACT_SEED;
        s.validate().unwrap();
    }

    #[test]
    fn fault_plan_bounds_are_enforced() {
        use crate::coordinator::FaultPlan;
        let mut s = base();
        s.faults = FaultPlan { crash_prob: 1.5, ..FaultPlan::default() };
        assert!(matches!(
            s.validate(),
            Err(SpecError::OutOfRange { field: "faults.crash_prob", .. })
        ));
        let mut s = base();
        s.faults = FaultPlan {
            crash_prob: 0.1,
            down_rounds: 0,
            ..FaultPlan::default()
        };
        assert_eq!(
            s.validate(),
            Err(SpecError::ZeroSize { field: "faults.down_rounds" })
        );
        let mut s = base();
        s.faults =
            FaultPlan { server_kills: vec![10, 10], ..FaultPlan::default() };
        assert!(s.validate().is_err());
        let mut s = base();
        s.faults =
            FaultPlan { server_kills: vec![0], ..FaultPlan::default() };
        assert_eq!(
            s.validate(),
            Err(SpecError::ZeroSize { field: "faults.server_kills" })
        );
        let mut s = base();
        s.faults = FaultPlan {
            crash_prob: 0.1,
            down_rounds: 2,
            seed: 7,
            server_kills: vec![5, 20],
        };
        s.validate().unwrap();
        let mut s = base();
        s.faults =
            FaultPlan { seed: MAX_EXACT_SEED + 1, ..FaultPlan::default() };
        assert!(matches!(
            s.validate(),
            Err(SpecError::SeedTooLarge { field: "faults.seed", .. })
        ));
    }

    #[test]
    fn nn_obj_err_needs_explicit_f_star() {
        let mut s = RunSpec::new(TaskKind::Nn, "synth");
        s.stop = StopSpec::ObjErr { tol: 1e-6, f_star: None };
        assert_eq!(s.validate(), Err(SpecError::NoFStar));
        s.stop = StopSpec::ObjErr { tol: 1e-6, f_star: Some(0.5) };
        s.validate().unwrap();
    }

    #[test]
    fn method_grid_bounds_are_enforced() {
        let mut s = base();
        s.method = MethodSpec::LocalSteps { base: Method::Chb, k_local: 0 };
        assert_eq!(
            s.validate(),
            Err(SpecError::ZeroSize { field: "method.k_local" })
        );
        let mut s = base();
        s.method = MethodSpec::local_steps(4);
        s.batch =
            BatchSchedule::Minibatch { size: 8, seed: 1, replace: false };
        assert!(matches!(s.validate(), Err(SpecError::Method { .. })));
        let mut s = base();
        s.method = MethodSpec::local_steps(4);
        s.validate().unwrap();
        let mut s = base();
        s.method = MethodSpec::CensoredAdam {
            beta1: 1.0,
            beta2: 0.999,
            eps: 1e-8,
            amsgrad: false,
        };
        assert!(matches!(
            s.validate(),
            Err(SpecError::OutOfRange { field: "method.beta1", .. })
        ));
        let mut s = base();
        s.method = MethodSpec::CensoredAdam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 0.0,
            amsgrad: false,
        };
        assert!(matches!(
            s.validate(),
            Err(SpecError::NonPositive { field: "method.eps", .. })
        ));
        // stateful server rules reject server-kill schedules
        for m in [
            MethodSpec::censored_adam(),
            MethodSpec::Nesterov { censored: true },
        ] {
            let mut s = base();
            s.method = m;
            s.faults = FaultPlan {
                server_kills: vec![5],
                ..FaultPlan::default()
            };
            assert!(matches!(s.validate(), Err(SpecError::Method { .. })));
            let mut s = base();
            s.method = m;
            s.validate().unwrap();
        }
        // local steps compose with server kills (no persistent worker
        // state beyond what checkpoints already carry)
        let mut s = base();
        s.method = MethodSpec::local_steps(4);
        s.faults =
            FaultPlan { server_kills: vec![5], ..FaultPlan::default() };
        s.validate().unwrap();
    }

    #[test]
    fn downlink_bounds_are_enforced() {
        let mut s = base();
        s.downlink = DownlinkSpec::Int { bits: 1, error_feedback: true };
        assert_eq!(s.validate(), Err(SpecError::QuantBits { bits: 1 }));
        let mut s = base();
        s.downlink = DownlinkSpec::Int { bits: 8, error_feedback: true };
        s.validate().unwrap();
        // compression needs a sync engine; accounting-only (None) is
        // fine everywhere
        let mut s = base();
        s.downlink = DownlinkSpec::Fp16 { error_feedback: false };
        s.engine = EngineKind::Async(AsyncConfig::default());
        assert!(matches!(s.validate(), Err(SpecError::Downlink { .. })));
        let mut s = base();
        s.engine = EngineKind::Async(AsyncConfig::default());
        s.validate().unwrap();
        let mut s = base();
        s.downlink = DownlinkSpec::Fp32 { error_feedback: true };
        s.faults =
            FaultPlan { server_kills: vec![5], ..FaultPlan::default() };
        assert!(matches!(s.validate(), Err(SpecError::Downlink { .. })));
    }

    #[test]
    fn population_rejects_grid_methods() {
        let s = RunSpec { method: MethodSpec::local_steps(4), ..pop_base() };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
        let s = RunSpec { method: MethodSpec::censored_adam(), ..pop_base() };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
    }

    #[test]
    fn spec_errors_display_their_field() {
        let msg = SpecError::NonPositive { field: "params.alpha", value: -1.0 }
            .to_string();
        assert!(msg.contains("params.alpha"), "{msg}");
        let msg = SpecError::PjrtBatching.to_string();
        assert!(msg.contains("pjrt"), "{msg}");
    }

    fn pop_base() -> RunSpec {
        RunSpec {
            engine: EngineKind::Async(AsyncConfig::default()),
            population: Some(PopulationSpec {
                clients: 10_000,
                cohort: 100,
                seed: 7,
            }),
            ..base()
        }
    }

    #[test]
    fn population_spec_validates_on_the_async_engine() {
        pop_base().validate().unwrap();
    }

    #[test]
    fn population_bounds_are_enforced() {
        let mut s = pop_base();
        s.population = Some(PopulationSpec { clients: 0, cohort: 1, seed: 0 });
        assert_eq!(
            s.validate(),
            Err(SpecError::ZeroSize { field: "population.clients" })
        );
        s.population =
            Some(PopulationSpec { clients: 10, cohort: 0, seed: 0 });
        assert_eq!(
            s.validate(),
            Err(SpecError::ZeroSize { field: "population.cohort" })
        );
        s.population =
            Some(PopulationSpec { clients: 10, cohort: 11, seed: 0 });
        assert!(matches!(
            s.validate(),
            Err(SpecError::Population { .. })
        ));
        s.population = Some(PopulationSpec {
            clients: 10,
            cohort: 5,
            seed: MAX_EXACT_SEED + 1,
        });
        assert_eq!(
            s.validate(),
            Err(SpecError::SeedTooLarge {
                field: "population.seed",
                seed: MAX_EXACT_SEED + 1,
            })
        );
    }

    #[test]
    fn population_rejects_uncomposable_axes() {
        // sync engine
        let s = RunSpec { engine: EngineKind::Serial, ..pop_base() };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
        // codec
        let s = RunSpec { codec: CodecSpec::TopK { k: 4 }, ..pop_base() };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
        // minibatch
        let s = RunSpec {
            batch: BatchSchedule::Minibatch {
                size: 8,
                seed: 1,
                replace: false,
            },
            ..pop_base()
        };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
        // pjrt
        let s = RunSpec { backend: BackendKind::Pjrt, ..pop_base() };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
        // comm map
        let s = RunSpec { record_comm_map: true, ..pop_base() };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
        // drops
        let s = RunSpec {
            drops: DropSpec { prob: 0.1, seed: 1 },
            ..pop_base()
        };
        assert!(matches!(s.validate(), Err(SpecError::Population { .. })));
    }
}
