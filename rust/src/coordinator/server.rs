//! Server-side state (Algorithm 1, lines 2 and 10).
//!
//! The server never sees per-worker gradients — only deltas from the
//! uncensored workers.  Its aggregate ∇ᵏ follows eq. (5):
//!
//! ```text
//! ∇ᵏ = ∇^{k−1} + Σ_{m ∈ Mᵏ} δ∇_m^k
//! ```
//!
//! which telescopes to Σ_m ∇f_m(θ̂_mᵏ) — the invariant the property
//! tests pin against the workers' `last_transmitted()` state.

use crate::linalg;
use crate::optim::{self, Method, MethodParams, ServerRule};

use super::worker::WorkerRound;
use crate::optim::CensorDecision;

/// Aggregated outcome of one server round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// server iteration index after this round
    pub k: usize,
    /// number of uplink transmissions |Mᵏ| this round
    pub transmitted: usize,
    /// Σ_m f_m(θᵏ) (instrumentation)
    pub loss: f64,
    /// ‖∇ᵏ‖² after folding this round's deltas (the paper's NN metric)
    pub agg_grad_sq: f64,
    /// ‖θ^{k+1} − θᵏ‖²
    pub step_sq: f64,
}

/// The parameter server.
pub struct Server {
    /// current iterate θᵏ
    pub theta: Vec<f64>,
    /// previous iterate θ^{k−1} (the momentum term's anchor)
    pub theta_prev: Vec<f64>,
    /// ∇ᵏ — running aggregate of eq. (5)
    pub agg_grad: Vec<f64>,
    rule: Box<dyn ServerRule>,
    k: usize,
}

impl Server {
    /// Server for (method, params) starting at θ⁰ = `theta0`.
    pub fn new(method: Method, params: &MethodParams, theta0: Vec<f64>) -> Self {
        let rule =
            optim::method::build_server_rule(method, params, theta0.len());
        Self::with_rule(rule, theta0)
    }

    /// Server with an injected update rule — the ablations compose
    /// arbitrary (rule, censor) pairs outside the Method table.
    pub fn with_rule(rule: Box<dyn ServerRule>, theta0: Vec<f64>) -> Self {
        let dim = theta0.len();
        Self {
            theta_prev: theta0.clone(),
            theta: theta0,
            agg_grad: vec![0.0; dim],
            rule,
            k: 0,
        }
    }

    /// Parameter dimension d.
    pub fn dim(&self) -> usize {
        self.theta.len()
    }

    /// Server steps taken so far.
    pub fn iteration(&self) -> usize {
        self.k
    }

    /// ‖θᵏ − θ^{k−1}‖² — broadcast alongside θᵏ so workers can
    /// evaluate the censor rule's RHS.
    pub fn theta_step_sq(&self) -> f64 {
        linalg::dist2_sq(&self.theta, &self.theta_prev)
    }

    /// Overwrite (θ, θ_prev, ∇, k) from a checkpoint.  The update rule
    /// is *not* serialized: HB/CHB momentum is recomputed from
    /// `theta − theta_prev` each step, so rebuilding the rule from the
    /// manifest's (method, params) plus this state resumes
    /// bit-identically.
    pub fn restore_state(
        &mut self,
        theta: Vec<f64>,
        theta_prev: Vec<f64>,
        agg_grad: Vec<f64>,
        k: usize,
    ) {
        assert_eq!(theta.len(), self.theta.len(), "dimension mismatch");
        assert_eq!(theta_prev.len(), self.theta.len(), "dimension mismatch");
        assert_eq!(agg_grad.len(), self.theta.len(), "dimension mismatch");
        self.theta = theta;
        self.theta_prev = theta_prev;
        self.agg_grad = agg_grad;
        self.k = k;
    }

    /// Fold one worker's uplink into the running aggregate ∇ (the
    /// eq. 5 sum) without closing the round — the streaming half of
    /// [`Server::apply_round`].  The population engine folds uplinks
    /// one at a time as they arrive off the event queue, so server
    /// memory stays O(model) instead of buffering a cohort of
    /// reports.  Returns whether a delta was folded.
    pub fn fold_uplink(&mut self, r: &WorkerRound) -> bool {
        if r.decision != CensorDecision::Transmit {
            return false;
        }
        debug_assert!(
            r.delta.fits(self.agg_grad.len()),
            "payload shape mismatch from worker {}",
            r.worker
        );
        // O(d) dense, O(nnz) sparse — each stored coordinate folds
        // exactly once, so Σ folded payloads stays equal to Σ
        // worker-side decoded deltas (the eq. 5 telescope)
        r.delta.fold_into(&mut self.agg_grad);
        true
    }

    /// Close a round whose uplinks were already folded via
    /// [`Server::fold_uplink`]: advance k, measure ∇, and step θ
    /// (eq. 4).  `transmitted` and `loss` are the caller's fold-side
    /// counters, echoed into the outcome.
    pub fn finish_round(
        &mut self,
        transmitted: usize,
        loss: f64,
    ) -> RoundOutcome {
        self.k += 1;
        let agg_grad_sq = linalg::norm2_sq(&self.agg_grad);
        self.rule
            .step(&mut self.theta, &mut self.theta_prev, &self.agg_grad);
        RoundOutcome {
            k: self.k,
            transmitted,
            loss,
            agg_grad_sq,
            step_sq: self.theta_step_sq(),
        }
    }

    /// Fold one round of worker reports and advance θ (eq. 4 + 5).
    /// Exactly [`Server::fold_uplink`] over the batch followed by
    /// [`Server::finish_round`] — the folds never read k, so the
    /// split is bit-identical to the historical single-pass body.
    pub fn apply_round(&mut self, rounds: &[WorkerRound]) -> RoundOutcome {
        let mut transmitted = 0;
        let mut loss = 0.0;
        for r in rounds {
            loss += r.loss;
            transmitted += usize::from(self.fold_uplink(r));
        }
        self.finish_round(transmitted, loss)
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::compress::Payload;

    fn tx(worker: usize, delta: Vec<f64>, loss: f64) -> WorkerRound {
        let delta_sq = delta.iter().map(|d| d * d).sum();
        let bits = 64 * delta.len() as u64;
        WorkerRound {
            worker,
            decision: CensorDecision::Transmit,
            delta: Arc::new(Payload::Dense(delta)),
            loss,
            delta_sq,
            bits,
            batch_frac: 1.0,
        }
    }

    fn skip(worker: usize, loss: f64) -> WorkerRound {
        WorkerRound {
            worker,
            decision: CensorDecision::Skip,
            delta: Arc::new(Payload::default()),
            loss,
            delta_sq: 0.0,
            bits: 0,
            batch_frac: 1.0,
        }
    }

    #[test]
    fn aggregate_accumulates_only_transmitted_deltas() {
        let p = MethodParams::new(0.0); // α = 0: θ must not move
        let mut s = Server::new(Method::Gd, &p, vec![0.0, 0.0]);
        let out = s.apply_round(&[
            tx(0, vec![1.0, 0.0], 0.5),
            skip(1, 0.25),
            tx(2, vec![0.0, 2.0], 0.25),
        ]);
        assert_eq!(out.transmitted, 2);
        assert_eq!(s.agg_grad, vec![1.0, 2.0]);
        assert!((out.loss - 1.0).abs() < 1e-15);
        assert_eq!(s.theta, vec![0.0, 0.0]);
    }

    #[test]
    fn sparse_payloads_fold_identically_to_their_dense_decode() {
        let p = MethodParams::new(0.0);
        let sparse = WorkerRound {
            worker: 0,
            decision: CensorDecision::Transmit,
            delta: Arc::new(Payload::Sparse {
                idx: vec![1, 3],
                val: vec![-2.5, 4.0],
            }),
            loss: 0.0,
            delta_sq: 0.0,
            bits: 128,
            batch_frac: 1.0,
        };
        let dense = tx(0, vec![0.0, -2.5, 0.0, 4.0], 0.0);
        let mut a = Server::new(Method::Gd, &p, vec![1.0; 4]);
        let mut b = Server::new(Method::Gd, &p, vec![1.0; 4]);
        a.apply_round(&[sparse]);
        b.apply_round(&[dense]);
        for (x, y) in a.agg_grad.iter().zip(&b.agg_grad) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn aggregate_persists_across_rounds() {
        let p = MethodParams::new(0.0);
        let mut s = Server::new(Method::Gd, &p, vec![0.0]);
        s.apply_round(&[tx(0, vec![3.0], 0.0)]);
        s.apply_round(&[skip(0, 0.0)]);
        s.apply_round(&[tx(0, vec![-1.0], 0.0)]);
        // eq. (5): ∇ = 3 + 0 + (−1) = 2
        assert_eq!(s.agg_grad, vec![2.0]);
    }

    #[test]
    fn gd_update_uses_aggregate() {
        let p = MethodParams::new(0.5);
        let mut s = Server::new(Method::Gd, &p, vec![1.0]);
        let out = s.apply_round(&[tx(0, vec![2.0], 0.0)]);
        assert_eq!(s.theta, vec![0.0]); // 1 − 0.5·2
        assert!((out.step_sq - 1.0).abs() < 1e-15);
        assert!((out.agg_grad_sq - 4.0).abs() < 1e-15);
    }

    #[test]
    fn chb_momentum_applies_across_rounds() {
        let p = MethodParams::new(1.0).with_beta(0.5);
        let mut s = Server::new(Method::Chb, &p, vec![0.0]);
        s.apply_round(&[tx(0, vec![-1.0], 0.0)]); // θ: 0 → 1 (no momentum yet)
        assert_eq!(s.theta, vec![1.0]);
        s.apply_round(&[skip(0, 0.0)]); // θ: 1 + 1·1 (−∇=1) + 0.5·(1−0) = 2.5
        assert!((s.theta[0] - 2.5).abs() < 1e-15);
    }

    #[test]
    fn streaming_fold_matches_apply_round_bitwise() {
        let p = MethodParams::new(0.3).with_beta(0.2);
        let rounds = [
            tx(0, vec![1.5, -0.5], 0.1),
            skip(1, 0.2),
            tx(2, vec![0.25, 2.0], 0.3),
        ];
        let mut batch = Server::new(Method::Chb, &p, vec![1.0, -1.0]);
        let mut stream = Server::new(Method::Chb, &p, vec![1.0, -1.0]);
        for _ in 0..3 {
            let a = batch.apply_round(&rounds);
            let mut t = 0;
            let mut l = 0.0;
            for r in &rounds {
                l += r.loss;
                t += usize::from(stream.fold_uplink(r));
            }
            let b = stream.finish_round(t, l);
            assert_eq!(a.k, b.k);
            assert_eq!(a.transmitted, b.transmitted);
            assert_eq!(a.agg_grad_sq.to_bits(), b.agg_grad_sq.to_bits());
            assert_eq!(a.step_sq.to_bits(), b.step_sq.to_bits());
        }
        for (x, y) in batch.theta.iter().zip(&stream.theta) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn iteration_counter_advances() {
        let p = MethodParams::new(0.1);
        let mut s = Server::new(Method::Hb, &p, vec![0.0]);
        assert_eq!(s.iteration(), 0);
        s.apply_round(&[]);
        s.apply_round(&[]);
        assert_eq!(s.iteration(), 2);
    }
}
