//! Asynchronous discrete-event CHB engine.
//!
//! The synchronous engines advance in lockstep rounds; this engine
//! advances a **virtual clock**: every worker loops independently
//! (receive θ → compute for a model-drawn time → censor → maybe
//! upload), messages travel through the [`LatencyModel`] on an
//! [`EventQueue`], and the server folds deltas **as they arrive**.
//! Eq. (5) makes this sound by construction: the server aggregate
//! telescopes over *transmitted* deltas, so a delta that arrives `s`
//! server-steps late simply folds late — the aggregate still equals
//! Σ_m ∇f_m(θ̂_m) over each worker's last-transmitted state (the
//! repo's load-bearing invariant, see ARCHITECTURE.md), and the
//! lateness is surfaced as per-worker staleness telemetry instead of
//! being a correctness hazard.
//!
//! Server semantics: uplink reports that arrive at the **same virtual
//! instant** fold as one batch followed by a single θ step (ties are
//! processed in worker-id order, so f64 sums are deterministic).
//! Under zero network latency and a uniform compute model every
//! instant contains all M reports — the event order collapses to
//! synchronous rounds and the engine reproduces [`run_serial`]
//! bit-for-bit (`tests/async_engine.rs` pins this on all four paper
//! tasks).  Under heterogeneous compute (the [`ComputeModel::Pareto`]
//! regime) batches shrink toward single arrivals and the server steps
//! per arrival, which is where censoring pays most: slow workers stop
//! costing wallclock, they only add staleness.
//!
//! The optional staleness bound wraps each worker's censor rule in a
//! [`StalenessBoundedCensor`] — the LAG-style "transmit at least every
//! S rounds" guard that keeps every worker's contribution to the
//! aggregate boundedly stale.
//!
//! [`run_serial`]: super::engine::run_serial

use std::sync::Arc;

use crate::checkpoint::{
    AsyncState, Checkpoint, CheckpointError, EvSnap, QueuedEv, ServerState,
    StationState, WorkerState, CHECKPOINT_VERSION,
};
use crate::metrics::{IterStat, StalenessStats, Trace};
use crate::net::{
    Direction, EventKey, EventQueue, LatencyModel, SimNetwork,
};
use crate::optim::{
    self, CensorDecision, CensorRule, StalenessBoundedCensor,
};
use crate::rng::{SplitMix64, Xoshiro256};

use super::engine::{net_state, restore_net, AsyncSummary, RunConfig, RunContext};
use super::participation::Participation;
use super::protocol::broadcast_bytes;
use super::server::Server;
use super::worker::{Worker, WorkerRound, WorkerSnapshot};

/// Per-worker compute-time model (virtual µs per gradient round).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ComputeModel {
    /// Every worker takes exactly `us` per round — with a zero-latency
    /// network this degenerates to synchronous rounds.
    Uniform {
        /// virtual µs per gradient evaluation (must be > 0)
        us: f64,
    },
    /// Heavy-tailed heterogeneity: each (worker, round) draws
    /// t = `scale_us`·(1−U)^(−1/`shape`) — a Pareto(shape) tail, the
    /// classic straggler model.  Smaller `shape` ⇒ heavier tail
    /// (shape ≤ 1 has infinite mean); draws come from per-worker
    /// seeded streams so the schedule is reproducible.
    Pareto {
        /// Pareto scale x_m (minimum compute time, virtual µs)
        scale_us: f64,
        /// Pareto tail index a (smaller = more heterogeneous)
        shape: f64,
        /// master seed for the per-worker draw streams
        seed: u64,
    },
}

impl ComputeModel {
    pub(crate) fn master_seed(&self) -> u64 {
        match self {
            ComputeModel::Uniform { .. } => 0,
            ComputeModel::Pareto { seed, .. } => *seed,
        }
    }

    pub(crate) fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        match *self {
            ComputeModel::Uniform { us } => {
                assert!(us > 0.0, "uniform compute time must be > 0");
                us
            }
            ComputeModel::Pareto { scale_us, shape, .. } => {
                assert!(
                    scale_us > 0.0 && shape > 0.0,
                    "pareto scale and shape must be > 0"
                );
                // inverse CDF; 1−U ∈ (0, 1] keeps the draw finite
                scale_us * (1.0 - rng.next_f64()).powf(-1.0 / shape)
            }
        }
    }
}

/// Asynchronous-engine knobs (everything else comes from [`RunConfig`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsyncConfig {
    /// per-worker compute-time model
    pub compute: ComputeModel,
    /// transfer-time model ordering uplinks/downlinks on the event
    /// queue ([`LatencyModel::zero`] degenerates to synchronous rounds)
    pub latency: LatencyModel,
    /// when Some(S): wrap every worker's censor rule in a
    /// [`StalenessBoundedCensor`] allowing at most S consecutive
    /// censored rounds (S = 0 disables censoring outright)
    pub max_staleness: Option<usize>,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self {
            compute: ComputeModel::Uniform { us: 1_000.0 },
            latency: LatencyModel::default(),
            max_staleness: None,
        }
    }
}

/// Everything the async engine can report beyond the [`Trace`] —
/// the bookkeeping sums the telescoping property test audits.
pub struct AsyncOutcome {
    /// the standard per-step trace (staleness + vclock columns filled)
    pub trace: Trace,
    /// final server aggregate ∇ᵏ
    pub agg_grad: Vec<f64>,
    /// Σ of folded deltas, accumulated independently in fold order
    /// (bit-identical to `agg_grad` by construction)
    pub applied_sum: Vec<f64>,
    /// Σ of transmitted deltas lost to uplink drops (the worker's θ̂
    /// advanced but the server never folded)
    pub dropped_sum: Vec<f64>,
    /// Σ of transmitted deltas still in flight when the run stopped
    pub inflight_sum: Vec<f64>,
    /// final virtual-clock reading (µs)
    pub vclock_us: f64,
}

impl AsyncOutcome {
    /// Split into the trace and the engine-level [`AsyncSummary`] —
    /// the one conversion point the [`super::engine::run_engine`]
    /// dispatch (and therefore `spec::Session`) uses, so new telemetry
    /// fields are threaded here and nowhere else.
    pub fn split(self) -> (Trace, AsyncSummary) {
        (
            self.trace,
            AsyncSummary {
                vclock_us: self.vclock_us,
                agg_grad: self.agg_grad,
                applied_sum: self.applied_sum,
                dropped_sum: self.dropped_sum,
                inflight_sum: self.inflight_sum,
            },
        )
    }
}

/// Event payloads; ordering at one instant is Down → Compute → Up.
enum Ev {
    /// θ broadcast reaches a worker; it starts computing
    Down,
    /// a worker's gradient round finishes; it censors and maybe uploads
    Compute,
    /// a worker report reaches the server (version = server step count
    /// when its θ was issued; skips arrive as zero-byte pings)
    Up(WorkerRound, usize),
}

const RANK_DOWN: u8 = 0;
const RANK_COMPUTE: u8 = 1;
const RANK_UP: u8 = 2;

/// What each worker is currently working against (snapshot taken when
/// the server issued the broadcast — the payload is frozen at send).
struct Station {
    theta: Arc<Vec<f64>>,
    step_sq: f64,
    version: usize,
}

/// Run the asynchronous engine and return the full outcome.
///
/// `cfg.method` / `cfg.params` / `cfg.max_iters` (server steps) /
/// `cfg.stop` / drop injection apply exactly as in the synchronous
/// engines.  `cfg.participation` must be [`Participation::Full`]
/// (asserted): every worker loops continuously, which is full
/// participation by construction — a sampling/straggler config would
/// otherwise run unsampled and mislabel its results.
///
/// ```
/// use chb_fed::coordinator::{run_async_detailed, AsyncConfig, RunConfig};
/// use chb_fed::experiments::figures::synth_linreg_problem;
/// use chb_fed::net::LatencyModel;
/// use chb_fed::optim::{Method, MethodParams};
///
/// let p = synth_linreg_problem(7);
/// let params = MethodParams::new(1.0 / p.l_global)
///     .with_beta(0.4)
///     .with_epsilon1_scaled(0.1, p.m_workers());
/// let cfg = RunConfig::new(Method::Chb, params, 50);
/// // uniform compute + zero latency = synchronous rounds, by theorem
/// let acfg = AsyncConfig {
///     latency: LatencyModel::zero(),
///     ..AsyncConfig::default()
/// };
/// let mut ws = p.rust_workers();
/// let out = run_async_detailed(&mut ws, &cfg, &acfg, p.theta0());
/// assert_eq!(out.trace.iterations(), 50);
/// assert_eq!(out.trace.max_staleness(), 0);
/// ```
pub fn run_async_detailed(
    workers: &mut [Worker],
    cfg: &RunConfig,
    acfg: &AsyncConfig,
    theta0: Vec<f64>,
) -> AsyncOutcome {
    let censor: Arc<dyn CensorRule> = Arc::from(
        optim::method::build_censor_rule(cfg.method, &cfg.params),
    );
    let server = Server::new(cfg.method, &cfg.params, theta0);
    let label = format!("{}-async", cfg.method.name());
    run_async_with_rules(workers, cfg, acfg, server, censor, &label)
}

/// [`run_async_detailed`] with an injected (server, censor) pair —
/// the same ablation entry point as [`run_with_rules`] in the
/// synchronous engine.
///
/// [`run_with_rules`]: super::engine::run_with_rules
pub fn run_async_with_rules(
    workers: &mut [Worker],
    cfg: &RunConfig,
    acfg: &AsyncConfig,
    server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
) -> AsyncOutcome {
    run_async_with_rules_ctx(
        workers,
        cfg,
        acfg,
        server,
        censor,
        label,
        &RunContext::default(),
    )
    .expect("checkpoint-free run cannot fail")
}

/// [`run_async_with_rules`] with a checkpoint/resume environment —
/// the asynchronous counterpart of
/// [`run_with_rules_ctx`](super::engine::run_with_rules_ctx).
///
/// Checkpoints are taken at server-step boundaries (right after a fold
/// and its re-broadcasts), capturing the entire virtual world: the
/// pending event queue with exact keys, per-worker stations,
/// compute-time RNG streams, staleness-censor counters, and the
/// telescoping bookkeeping sums.  Fault-plan worker crashes are keyed
/// on each worker's *local* round count (there are no global rounds
/// here); server kills are keyed on server steps, exactly as in the
/// synchronous engines.
#[allow(clippy::too_many_arguments)]
pub fn run_async_with_rules_ctx(
    workers: &mut [Worker],
    cfg: &RunConfig,
    acfg: &AsyncConfig,
    mut server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
    ctx: &RunContext,
) -> Result<AsyncOutcome, CheckpointError> {
    assert!(
        cfg.participation == Participation::Full,
        "the async engine runs full participation by construction; \
         got {:?}",
        cfg.participation
    );
    let m = workers.len();
    let dim = server.dim();
    let mut net = SimNetwork::new(m)
        .with_drops(cfg.drop_prob, cfg.drop_seed)
        .with_latency(acfg.latency);
    let mut q: EventQueue<Ev> = EventQueue::new();
    let mut trace = Trace::new(label);
    trace.worker_staleness = vec![StalenessStats::default(); m];
    let faults = &cfg.faults;

    // per-worker censor rules: the staleness bound carries a
    // consecutive-skip counter, so it must not be shared across
    // workers (and the checkpoint layer captures each counter through
    // the `wrappers` handles)
    let mut wrappers: Vec<Arc<StalenessBoundedCensor>> = Vec::new();
    let censors: Vec<Arc<dyn CensorRule>> = (0..m)
        .map(|_| match acfg.max_staleness {
            None => Arc::clone(&censor),
            Some(s) => {
                let w =
                    Arc::new(StalenessBoundedCensor::new(Arc::clone(&censor), s));
                wrappers.push(Arc::clone(&w));
                w as Arc<dyn CensorRule>
            }
        })
        .collect();

    // per-worker compute-time streams (independent of event order)
    let mut seeder = SplitMix64::new(acfg.compute.master_seed() ^ 0xA51C);
    let mut comp_rng: Vec<Xoshiro256> =
        (0..m).map(|_| Xoshiro256::new(seeder.next_u64())).collect();

    // latest known per-worker loss, so the trace keeps reporting a
    // global-loss estimate even when only a subset reports per step
    let theta0_arc = Arc::new(server.theta.clone());
    let mut loss_cache: Vec<f64> =
        workers.iter_mut().map(|w| w.observe(&theta0_arc).loss).collect();

    let mut stations: Vec<Station> = (0..m)
        .map(|_| Station {
            theta: Arc::clone(&theta0_arc),
            step_sq: 0.0,
            version: 0,
        })
        .collect();

    // per-worker completed gradient rounds — the fault plan's round
    // key in this engine
    let mut local_rounds = vec![0usize; m];

    let mut applied_sum = vec![0.0; dim];
    let mut dropped_sum = vec![0.0; dim];
    let mut vclock_us = 0.0;

    let down_bytes = broadcast_bytes(dim);
    if let Some(cp) = &ctx.resume {
        cp.check_compat(ctx.spec_hash, "async", dim, m)?;
        let astate = cp.async_state.as_ref().ok_or_else(|| {
            CheckpointError::Corrupt(
                "async checkpoint is missing its \"async\" section".into(),
            )
        })?;
        if astate.censor_skips.len() != wrappers.len() {
            return Err(CheckpointError::Corrupt(format!(
                "checkpoint carries {} staleness-censor counters, \
                 this run has {}",
                astate.censor_skips.len(),
                wrappers.len()
            )));
        }
        apply_async(
            cp,
            astate,
            &mut server,
            workers,
            &mut net,
            &mut q,
            &mut stations,
            &mut loss_cache,
            &mut comp_rng,
            &wrappers,
            &mut local_rounds,
            &mut applied_sum,
            &mut dropped_sum,
            &mut vclock_us,
            &mut trace,
        );
    } else if cfg.max_iters > 0 {
        // initial broadcast at t = 0
        for w in 0..m {
            net.send(Direction::Down, w, down_bytes);
            q.push(
                net.latency.transfer_us(down_bytes),
                RANK_DOWN,
                w,
                Ev::Down,
            );
        }
    }

    // the server-kill recovery image: the most recent checkpoint, or
    // the starting state when none has been taken yet
    let mut recovery = if faults.server_kills.is_empty() {
        None
    } else {
        Some(capture_async(
            ctx.spec_hash,
            &server,
            workers,
            &net,
            &q,
            &stations,
            &loss_cache,
            &comp_rng,
            &wrappers,
            &local_rounds,
            &applied_sum,
            &dropped_sum,
            vclock_us,
            &trace,
        ))
    };
    // next kill point to fire (sorted; replay must not re-kill)
    let mut kill_idx = faults
        .server_kills
        .partition_point(|&kk| kk <= server.iteration());

    // reports that arrived at the current instant, in worker-id order
    // (two parallel vecs so apply_round gets &[WorkerRound] directly,
    // without cloning dim-d deltas on the hot path)
    let mut batch: Vec<WorkerRound> = Vec::with_capacity(m);
    let mut batch_versions: Vec<usize> = Vec::with_capacity(m);

    'event_loop: while let Some((key, ev)) = q.pop() {
        let t = key.time_us;
        let w = key.worker;
        vclock_us = t;
        match ev {
            Ev::Down => {
                let dt = acfg.compute.sample(&mut comp_rng[w]);
                q.push(t + dt, RANK_COMPUTE, w, Ev::Compute);
            }
            Ev::Compute => {
                let st = &stations[w];
                local_rounds[w] += 1;
                let lr = local_rounds[w];
                let mut round = if faults.enabled() && faults.down(w, lr) {
                    // crashed mid-loop: no gradient, no censor-state
                    // change — eq. (5) carries the stale term, and the
                    // zero-byte completion ping keeps the worker's
                    // event loop alive for its eventual rejoin
                    trace.fault_downs += 1;
                    workers[w].observe(&st.theta)
                } else if faults.enabled() && faults.rejoin(w, lr) {
                    // first completed round back: transmit uncensored
                    // to re-sync θ̂ before censored reporting restarts
                    trace.fault_rejoins += 1;
                    workers[w].round_forced(
                        &st.theta,
                        st.step_sq,
                        censors[w].as_ref(),
                        st.version + 1,
                    )
                } else {
                    workers[w].round(
                        &st.theta,
                        st.step_sq,
                        censors[w].as_ref(),
                        st.version + 1,
                    )
                };
                let up_delay;
                if round.decision == CensorDecision::Transmit {
                    let nbytes = round.bits.div_ceil(8) + 8;
                    up_delay = net.latency.transfer_us(nbytes);
                    if !net.send(Direction::Up, w, nbytes) {
                        // dropped uplink: θ̂_m advanced worker-side but
                        // the server never folds — eq. (5) carries the
                        // stale term, exactly as in the sync engine
                        // (the Skip decision guards every later fold)
                        round.delta.fold_into(&mut dropped_sum);
                        round.decision = CensorDecision::Skip;
                    }
                } else {
                    // censored: a zero-byte completion ping still takes
                    // the fixed link latency, but costs no counted
                    // uplink message (the paper's comm metric)
                    up_delay = net.latency.transfer_us(0);
                }
                q.push(t + up_delay, RANK_UP, w, Ev::Up(round, st.version));
            }
            Ev::Up(round, version) => {
                batch.push(round);
                batch_versions.push(version);
                // same-instant reports fold as one batch: lower-rank
                // events at t are already drained (heap order), so the
                // only things left at t are sibling Ups
                let more = q
                    .peek()
                    .is_some_and(|k| k.time_us == t && k.rank == RANK_UP);
                if more {
                    continue;
                }
                // downlink ledger: every broadcast so far carried the
                // dense model (this engine never compresses the
                // downlink); message counts live in the net state, so
                // resume/replay reconstructs the ledger exactly
                let down_cum = net.total_down_messages()
                    * crate::net::dense_delta_bits(dim);
                let stop = fold_batch(
                    &mut server,
                    cfg,
                    &mut trace,
                    &batch,
                    &batch_versions,
                    &mut loss_cache,
                    &mut applied_sum,
                    t,
                    down_cum,
                );
                if stop || server.iteration() >= cfg.max_iters {
                    break 'event_loop;
                }
                // reply to every worker that just reported: fresh θ
                let snapshot = Arc::new(server.theta.clone());
                let step_sq = server.theta_step_sq();
                let version = server.iteration();
                batch_versions.clear();
                for r in batch.drain(..) {
                    let id = r.worker;
                    stations[id] = Station {
                        theta: Arc::clone(&snapshot),
                        step_sq,
                        version,
                    };
                    net.send(Direction::Down, id, down_bytes);
                    q.push(
                        t + net.latency.transfer_us(down_bytes),
                        RANK_DOWN,
                        id,
                        Ev::Down,
                    );
                }
                // a server-step boundary: the state now says "after
                // step k, replies issued" — the checkpointable instant
                let k_now = server.iteration();
                if let Some(policy) = &ctx.checkpoint {
                    if policy.due(k_now) {
                        let cp = capture_async(
                            ctx.spec_hash,
                            &server,
                            workers,
                            &net,
                            &q,
                            &stations,
                            &loss_cache,
                            &comp_rng,
                            &wrappers,
                            &local_rounds,
                            &applied_sum,
                            &dropped_sum,
                            vclock_us,
                            &trace,
                        );
                        cp.save(&policy.path())?;
                        if recovery.is_some() {
                            recovery = Some(cp);
                        }
                    }
                }
                if kill_idx < faults.server_kills.len()
                    && faults.server_kills[kill_idx] == k_now
                {
                    kill_idx += 1;
                    // the server dies after step k_now and comes back
                    // from its last checkpoint; the deterministic
                    // replay reproduces the kill-free run bit for bit
                    let cp = recovery.clone().expect("recovery image exists");
                    let astate =
                        cp.async_state.as_ref().expect("captured async state");
                    apply_async(
                        &cp,
                        astate,
                        &mut server,
                        workers,
                        &mut net,
                        &mut q,
                        &mut stations,
                        &mut loss_cache,
                        &mut comp_rng,
                        &wrappers,
                        &mut local_rounds,
                        &mut applied_sum,
                        &mut dropped_sum,
                        &mut vclock_us,
                        &mut trace,
                    );
                    batch.clear();
                    batch_versions.clear();
                }
            }
        }
    }

    // account for transmitted deltas still on the wire at exit
    let mut inflight_sum = vec![0.0; dim];
    for (_, ev) in q.drain_ordered() {
        if let Ev::Up(r, _) = ev {
            if r.decision == CensorDecision::Transmit {
                r.delta.fold_into(&mut inflight_sum);
            }
        }
    }

    trace.per_worker_comms = workers.iter().map(|w| w.transmissions).collect();
    Ok(AsyncOutcome {
        trace,
        agg_grad: server.agg_grad.clone(),
        applied_sum,
        dropped_sum,
        inflight_sum,
        vclock_us,
    })
}

/// Snapshot the complete asynchronous world at a server-step boundary.
#[allow(clippy::too_many_arguments)]
fn capture_async(
    spec_hash: Option<u64>,
    server: &Server,
    workers: &[Worker],
    net: &SimNetwork,
    q: &EventQueue<Ev>,
    stations: &[Station],
    loss_cache: &[f64],
    comp_rng: &[Xoshiro256],
    wrappers: &[Arc<StalenessBoundedCensor>],
    local_rounds: &[usize],
    applied_sum: &[f64],
    dropped_sum: &[f64],
    vclock_us: f64,
    trace: &Trace,
) -> Checkpoint {
    let (seq, last_popped_us) = q.counters();
    let queue = q
        .entries_ordered()
        .into_iter()
        .map(|(key, ev)| QueuedEv {
            time_us: key.time_us,
            rank: key.rank,
            worker: key.worker,
            seq: key.seq(),
            ev: match ev {
                Ev::Down => EvSnap::Down,
                Ev::Compute => EvSnap::Compute,
                Ev::Up(round, version) => EvSnap::Up {
                    round: round.clone(),
                    version: *version,
                },
            },
        })
        .collect();
    Checkpoint {
        version: CHECKPOINT_VERSION,
        spec_hash,
        engine: "async".into(),
        k: server.iteration(),
        dim: server.dim(),
        server: ServerState {
            theta: server.theta.clone(),
            theta_prev: server.theta_prev.clone(),
            agg_grad: server.agg_grad.clone(),
            k: server.iteration(),
        },
        workers: workers
            .iter()
            .map(|w| {
                let s = w.snapshot();
                WorkerState {
                    id: s.id,
                    last_tx: s.last_tx,
                    transmissions: s.transmissions,
                    residual: s.residual,
                }
            })
            .collect(),
        schedule_rng: None,
        net: net_state(net),
        trace: trace.clone(),
        async_state: Some(AsyncState {
            queue,
            seq,
            last_popped_us,
            stations: stations
                .iter()
                .map(|s| StationState {
                    theta: s.theta.as_ref().clone(),
                    step_sq: s.step_sq,
                    version: s.version,
                })
                .collect(),
            loss_cache: loss_cache.to_vec(),
            comp_rng: comp_rng.iter().map(|r| r.state()).collect(),
            censor_skips: wrappers.iter().map(|w| w.pending_skips()).collect(),
            local_rounds: local_rounds.to_vec(),
            applied_sum: applied_sum.to_vec(),
            dropped_sum: dropped_sum.to_vec(),
            vclock_us,
        }),
    }
}

/// Overwrite every piece of asynchronous run state from a checkpoint.
/// Callers validate compatibility (and the presence of `astate`) first,
/// so this function cannot fail part-way through a mutation.
#[allow(clippy::too_many_arguments)]
fn apply_async(
    cp: &Checkpoint,
    astate: &AsyncState,
    server: &mut Server,
    workers: &mut [Worker],
    net: &mut SimNetwork,
    q: &mut EventQueue<Ev>,
    stations: &mut Vec<Station>,
    loss_cache: &mut [f64],
    comp_rng: &mut [Xoshiro256],
    wrappers: &[Arc<StalenessBoundedCensor>],
    local_rounds: &mut [usize],
    applied_sum: &mut [f64],
    dropped_sum: &mut [f64],
    vclock_us: &mut f64,
    trace: &mut Trace,
) {
    server.restore_state(
        cp.server.theta.clone(),
        cp.server.theta_prev.clone(),
        cp.server.agg_grad.clone(),
        cp.server.k,
    );
    for (w, ws) in workers.iter_mut().zip(&cp.workers) {
        w.restore(&WorkerSnapshot {
            id: ws.id,
            last_tx: ws.last_tx.clone(),
            transmissions: ws.transmissions,
            residual: ws.residual.clone(),
        });
    }
    restore_net(net, &cp.net);
    let entries = astate
        .queue
        .iter()
        .map(|e| {
            let key = EventKey {
                time_us: e.time_us,
                rank: e.rank,
                worker: e.worker,
                seq: e.seq,
            };
            let ev = match &e.ev {
                EvSnap::Down => Ev::Down,
                EvSnap::Compute => Ev::Compute,
                EvSnap::Up { round, version } => {
                    Ev::Up(round.clone(), *version)
                }
            };
            (key, ev)
        })
        .collect();
    *q = EventQueue::restore(entries, astate.seq, astate.last_popped_us);
    *stations = astate
        .stations
        .iter()
        .map(|s| Station {
            theta: Arc::new(s.theta.clone()),
            step_sq: s.step_sq,
            version: s.version,
        })
        .collect();
    loss_cache.copy_from_slice(&astate.loss_cache);
    for (r, s) in comp_rng.iter_mut().zip(&astate.comp_rng) {
        *r = Xoshiro256::from_state(*s);
    }
    for (w, &n) in wrappers.iter().zip(&astate.censor_skips) {
        w.set_pending_skips(n);
    }
    local_rounds.copy_from_slice(&astate.local_rounds);
    applied_sum.copy_from_slice(&astate.applied_sum);
    dropped_sum.copy_from_slice(&astate.dropped_sum);
    *vclock_us = astate.vclock_us;
    *trace = cp.trace.clone();
}

/// Fold one same-instant batch of reports and take one server step;
/// returns whether the stop rule fired.  The batch arrives in
/// worker-id order (heap tie-breaking), so all f64 sums here are
/// deterministic and — in the degenerate all-M case — identical to the
/// synchronous fold.
#[allow(clippy::too_many_arguments)]
fn fold_batch(
    server: &mut Server,
    cfg: &RunConfig,
    trace: &mut Trace,
    batch: &[WorkerRound],
    versions: &[usize],
    loss_cache: &mut [f64],
    applied_sum: &mut [f64],
    t: f64,
    down_bits_cum: u64,
) -> bool {
    debug_assert_eq!(batch.len(), versions.len());
    let mut stale_max = 0usize;
    let mut bits_round = 0u64;
    let now = server.iteration();
    for (r, version) in batch.iter().zip(versions) {
        loss_cache[r.worker] = r.loss;
        if r.decision == CensorDecision::Transmit {
            let s = now - version;
            stale_max = stale_max.max(s);
            trace.worker_staleness[r.worker].record(s);
            bits_round += r.bits;
            r.delta.fold_into(applied_sum);
        }
    }
    if cfg.record_comm_map {
        let mut row = vec![false; loss_cache.len()];
        for r in batch.iter() {
            row[r.worker] = r.decision == CensorDecision::Transmit;
        }
        trace.comm_map.push(row);
    }
    // mean shard fraction over this batch's gradients (see the
    // synchronous fold) — the epoch column accumulates it scaled by
    // the batch's share of the cohort, so one epoch still means "one
    // full pass over the global dataset" under per-arrival folds
    let batch_frac = batch.iter().map(|r| r.batch_frac).sum::<f64>()
        / batch.len().max(1) as f64;
    let epoch_inc = batch.iter().map(|r| r.batch_frac).sum::<f64>()
        / loss_cache.len().max(1) as f64;
    let out = server.apply_round(batch);
    // global loss: every worker's latest report, summed in id order
    // (identical to the synchronous sum when all M are in the batch)
    let mut global_loss = 0.0;
    for &l in loss_cache.iter() {
        global_loss += l;
    }
    let prev = trace.iters.last();
    let stat = IterStat {
        k: out.k,
        loss: global_loss,
        comms_round: out.transmitted,
        comms_cum: prev.map_or(0, |s| s.comms_cum) + out.transmitted,
        agg_grad_sq: out.agg_grad_sq,
        step_sq: out.step_sq,
        bits_cum: prev.map_or(0, |s| s.bits_cum) + bits_round,
        down_bits_cum,
        vclock_us: t,
        stale_max,
        batch_frac,
        epoch: prev.map_or(0.0, |s| s.epoch) + epoch_inc,
    };
    trace.participants.push(batch.len());
    let stop = cfg.should_stop(&stat);
    trace.iters.push(stat);
    stop
}

/// Deprecated trace-only shim kept for source compatibility — it was
/// a near-duplicate of [`run_async_detailed`] that silently discarded
/// the telescoping bookkeeping.  Describe the run as a
/// [`crate::spec::RunSpec`] and go through [`crate::spec::Session`]
/// (or [`super::engine::run_engine`] with
/// [`super::engine::EngineKind::Async`]); for the raw trace,
/// `run_async_detailed(..).trace` is the same one-liner this wraps.
#[deprecated(
    since = "0.2.0",
    note = "route through spec::Session / coordinator::run_engine \
            (or use run_async_detailed(..).trace)"
)]
pub fn run_async(
    workers: &mut [Worker],
    cfg: &RunConfig,
    acfg: &AsyncConfig,
    theta0: Vec<f64>,
) -> Trace {
    run_async_detailed(workers, cfg, acfg, theta0).trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::run_serial;
    use crate::coordinator::worker::GradientBackend;
    use crate::linalg;
    use crate::optim::{Method, MethodParams};

    /// f_m(θ) = ½ c_m ‖θ − t_m‖² toy backend (same as engine tests).
    struct Quad {
        c: f64,
        t: Vec<f64>,
    }

    impl GradientBackend for Quad {
        fn dim(&self) -> usize {
            self.t.len()
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let mut l = 0.0;
            for i in 0..theta.len() {
                let d = theta[i] - self.t[i];
                grad[i] = self.c * d;
                l += d * d;
            }
            0.5 * self.c * l
        }
    }

    fn quad_workers(dim: usize, m: usize) -> Vec<Worker> {
        (0..m)
            .map(|i| {
                let t: Vec<f64> =
                    (0..dim).map(|j| ((i + j) % 5) as f64 - 2.0).collect();
                Worker::new(i, Box::new(Quad { c: 1.0 + i as f64 * 0.3, t }))
            })
            .collect()
    }

    fn total_c(m: usize) -> f64 {
        (0..m).map(|i| 1.0 + i as f64 * 0.3).sum()
    }

    fn degenerate() -> AsyncConfig {
        AsyncConfig {
            compute: ComputeModel::Uniform { us: 1_000.0 },
            latency: LatencyModel::zero(),
            max_staleness: None,
        }
    }

    #[test]
    fn degenerate_async_matches_serial_bitwise_on_quadratic() {
        let (dim, m) = (5, 4);
        let p = MethodParams::new(0.8 / total_c(m))
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 120).with_comm_map();
        let mut ws = quad_workers(dim, m);
        let serial = run_serial(&mut ws, &cfg, vec![0.5; dim]);
        let mut ws = quad_workers(dim, m);
        let a = run_async_detailed(&mut ws, &cfg, &degenerate(), vec![0.5; dim])
            .trace;
        assert_eq!(serial.iterations(), a.iterations());
        for (x, y) in serial.iters.iter().zip(&a.iters) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "loss k={}", x.k);
            assert_eq!(x.comms_cum, y.comms_cum, "comms k={}", x.k);
            assert_eq!(x.bits_cum, y.bits_cum, "bits k={}", x.k);
            assert_eq!(
                x.down_bits_cum, y.down_bits_cum,
                "down bits k={}",
                x.k
            );
            assert_eq!(y.stale_max, 0, "staleness k={}", x.k);
        }
        assert_eq!(serial.comm_map, a.comm_map);
        assert_eq!(serial.per_worker_comms, a.per_worker_comms);
        assert_eq!(serial.participants, a.participants);
        assert_eq!(a.max_staleness(), 0);
    }

    #[test]
    fn heterogeneous_compute_produces_partial_batches_and_staleness() {
        let (dim, m) = (4, 5);
        // conservative α: per-arrival steps mean each worker's gradient
        // is ~M steps stale, and stability needs roughly α·L·τ ≲ 1
        let p = MethodParams::new(0.1 / total_c(m))
            .with_beta(0.2)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 600);
        let acfg = AsyncConfig {
            compute: ComputeModel::Pareto {
                scale_us: 1_000.0,
                shape: 2.0,
                seed: 0xA57,
            },
            latency: LatencyModel::default(),
            max_staleness: None,
        };
        let mut ws = quad_workers(dim, m);
        let trace =
            run_async_detailed(&mut ws, &cfg, &acfg, vec![2.0; dim]).trace;
        assert_eq!(trace.iterations(), 600);
        // heavy-tailed compute must desynchronize the cohort
        assert!(
            trace.participants.iter().any(|&n| n < m),
            "every batch was full — no asynchrony"
        );
        assert!(trace.max_staleness() > 0, "no staleness recorded");
        // the virtual clock is strictly increasing
        for w in trace.iters.windows(2) {
            assert!(w[1].vclock_us >= w[0].vclock_us);
        }
        // still converges on the strongly convex problem (to within
        // the bias any long-absent worker's stale term can leave)
        let first = trace.iters.first().unwrap().loss;
        let last = trace.final_loss();
        assert!(last.is_finite() && last < first * 1e-1, "{first} → {last}");
    }

    #[test]
    fn max_staleness_zero_disables_censoring() {
        let (dim, m) = (3, 4);
        let p = MethodParams::new(0.3 / total_c(m))
            .with_beta(0.3)
            .with_epsilon1_scaled(10.0, m); // aggressive censoring…
        let cfg = RunConfig::new(Method::Chb, p, 60);
        let acfg = AsyncConfig {
            max_staleness: Some(0), // …overridden: transmit every round
            ..degenerate()
        };
        let mut ws = quad_workers(dim, m);
        let trace =
            run_async_detailed(&mut ws, &cfg, &acfg, vec![1.0; dim]).trace;
        // every completion transmitted: comms == Σ folds == participants
        let folds: usize =
            trace.worker_staleness.iter().map(|s| s.folds).sum();
        assert_eq!(folds, trace.total_comms());
        assert_eq!(
            trace.participants.iter().sum::<usize>(),
            trace.total_comms()
        );
    }

    #[test]
    fn detailed_outcome_bookkeeping_balances_under_drops() {
        let (dim, m) = (4, 6);
        // small α: the identity below is exact regardless of progress,
        // but a divergent run would overflow the comparison to NaN
        let p = MethodParams::new(0.05 / total_c(m))
            .with_beta(0.2)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 150).with_drops(0.25, 99);
        let acfg = AsyncConfig {
            compute: ComputeModel::Pareto {
                scale_us: 500.0,
                shape: 2.0,
                seed: 7,
            },
            latency: LatencyModel::default(),
            max_staleness: Some(10),
        };
        let mut ws = quad_workers(dim, m);
        let out = run_async_detailed(&mut ws, &cfg, &acfg, vec![3.0; dim]);
        // the server aggregate is exactly the independently-accumulated
        // fold sum (same deltas, same order)
        for i in 0..dim {
            assert_eq!(
                out.agg_grad[i].to_bits(),
                out.applied_sum[i].to_bits()
            );
        }
        // decoded-delta bookkeeping: Σ_m θ̂_m == folded + dropped +
        // in-flight, under arbitrary arrival orderings and drops
        let mut last_tx = vec![0.0; dim];
        for w in ws.iter() {
            linalg::axpy(1.0, w.last_transmitted(), &mut last_tx);
        }
        let mut rhs = out.agg_grad.clone();
        linalg::axpy(1.0, &out.dropped_sum, &mut rhs);
        linalg::axpy(1.0, &out.inflight_sum, &mut rhs);
        let scale = crate::linalg::norm2(&last_tx).max(1.0);
        for i in 0..dim {
            assert!(
                (last_tx[i] - rhs[i]).abs() <= 1e-9 * scale,
                "telescope broke at coord {i}: {} vs {}",
                last_tx[i],
                rhs[i]
            );
        }
    }
}
