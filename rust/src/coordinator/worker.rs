//! Worker-side state machine (Algorithm 1, lines 3–9).

use std::sync::Arc;

use crate::compress::{CodecScratch, Compressor, Payload};
use crate::data::batch::{BatchSampler, BatchSchedule};
use crate::linalg;
use crate::net::dense_delta_bits;
use crate::optim::{CensorDecision, CensorRule};
use crate::tasks::{TaskWorkspace, WorkerObjective};

/// Where a worker's gradient comes from.  The pure-rust backend wraps
/// a [`WorkerObjective`]; the PJRT backend (runtime/pjrt.rs) executes
/// the AOT artifact.  Both must compute the *same* function.
pub trait GradientBackend: Send {
    /// Parameter dimension d this backend computes over.
    fn dim(&self) -> usize;

    /// Real (unpadded) shard rows — the universe minibatch schedules
    /// draw from.  0 (the default) means "not row-indexed": such a
    /// backend supports [`BatchSchedule::Full`] only.
    fn num_rows(&self) -> usize {
        0
    }

    /// Write ∇f_m(θ) into `grad`, return f_m(θ).
    fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64;

    /// Write the scaled minibatch gradient estimate over `rows` into
    /// `grad` (see [`WorkerObjective::grad_loss_batch_into`]).  The
    /// default panics: backends that never report rows are never
    /// handed a batch schedule (enforced at sampler construction).
    fn grad_loss_batch_into(
        &mut self,
        theta: &[f64],
        rows: &[u32],
        grad: &mut [f64],
    ) -> f64 {
        let _ = (theta, rows, grad);
        unimplemented!("this gradient backend is not row-indexed")
    }

    /// Full-shard objective value only — the measurement-side pass a
    /// batched round uses so traces keep reporting the global loss.
    /// Default allocates; hot-path backends override.
    fn loss(&mut self, theta: &[f64]) -> f64 {
        let mut g = vec![0.0; self.dim()];
        self.grad_loss_into(theta, &mut g)
    }
}

/// f64 in-process backend: one immutable objective + this worker's
/// private evaluation workspace (the scratch that used to hide inside
/// the objectives behind `RefCell` + `unsafe impl Sync`).
pub struct RustBackend {
    obj: Box<dyn WorkerObjective>,
    ws: TaskWorkspace,
}

impl RustBackend {
    /// Wrap a task objective as a gradient backend.
    pub fn new(obj: Box<dyn WorkerObjective>) -> Self {
        Self { obj, ws: TaskWorkspace::default() }
    }
}

impl GradientBackend for RustBackend {
    fn dim(&self) -> usize {
        self.obj.dim()
    }

    fn num_rows(&self) -> usize {
        self.obj.num_rows()
    }

    fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
        self.obj.grad_loss_into(theta, &mut self.ws, grad)
    }

    fn grad_loss_batch_into(
        &mut self,
        theta: &[f64],
        rows: &[u32],
        grad: &mut [f64],
    ) -> f64 {
        self.obj.grad_loss_batch_into(theta, rows, &mut self.ws, grad)
    }

    fn loss(&mut self, theta: &[f64]) -> f64 {
        self.obj.loss(theta, &mut self.ws)
    }
}

/// What a worker reports for one round (the uplink message, or the
/// record that it stayed silent).
#[derive(Clone, Debug)]
pub struct WorkerRound {
    /// reporting worker's id
    pub worker: usize,
    /// did the censor rule allow a transmission?
    pub decision: CensorDecision,
    /// δ∇_m^k as an uplink [`Payload`] (codec-decoded when compression
    /// is on; sparse when the codec emits sparse) — only meaningful
    /// when `decision == Transmit`.  Shared via `Arc` with the
    /// worker's reusable transmit slot, so the steady-state round
    /// allocates nothing: the worker reclaims the buffer as soon as
    /// every engine-side clone of the report has been dropped.
    pub delta: Arc<Payload>,
    /// f_m(θᵏ) — measurement-side only, costs no communication
    pub loss: f64,
    /// ‖δ∇_m^k‖² (recorded for Lemma-2 style diagnostics)
    pub delta_sq: f64,
    /// simulated wire size of the uplink payload (0 when skipping)
    pub bits: u64,
    /// fraction of this worker's shard the gradient visited: 1.0 in
    /// the full-batch regime, `|B|/n` under minibatch schedules (> 1
    /// when a with-replacement draw oversamples the shard), and 0.0
    /// for loss-only observations (no gradient was computed at all)
    pub batch_frac: f64,
}

/// K-step local-update configuration ([`crate::optim::MethodSpec::LocalSteps`]):
/// between uplinks the worker runs `k_local` heavy-ball steps on its
/// own shard objective and reports the *sum* of the visited gradients
/// as one pseudo-gradient — censoring, uplink codecs, and the server
/// aggregate all operate on that sum unchanged, so eq. (5) still
/// telescopes (over pseudo-gradients instead of gradients).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LocalStepCfg {
    /// local steps per round (≥ 2; 1 installs no local path at all)
    pub k_local: usize,
    /// local step size α (the session resolves the server's α here)
    pub alpha: f64,
    /// local momentum β (0.0 when the base method carries none)
    pub beta: f64,
}

/// The persistent (checkpoint-worthy) slice of a [`Worker`]: the
/// censor reference state θ̂ (as the last-transmitted gradient), the
/// lifetime transmit counter, and the error-feedback residual.  The
/// gradient/delta/payload buffers are per-round scratch and are
/// deliberately absent — restoring a snapshot and replaying the next
/// round reproduces them bit-identically.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSnapshot {
    /// worker id m ∈ 0..M
    pub id: usize,
    /// ∇f_m(θ̂_m) — the censor reference (decoded-payload bookkeeping
    /// under compression)
    pub last_tx: Vec<f64>,
    /// lifetime transmit counter S_m
    pub transmissions: usize,
    /// error-feedback residual (empty when no EF codec has run)
    pub residual: Vec<f64>,
}

/// One federated worker: shard + censor state.
pub struct Worker {
    /// worker id m ∈ 0..M
    pub id: usize,
    backend: Box<dyn GradientBackend>,
    /// ∇f_m(θ̂_m^{k−1}) — the last gradient this worker *transmitted*
    last_tx_grad: Vec<f64>,
    /// scratch: current gradient (steady-state allocation-free)
    grad: Vec<f64>,
    /// scratch: δ∇ buffer reused across rounds
    delta: Vec<f64>,
    /// the payload arena: the transmit slot handed (by `Arc` clone) to
    /// the engine each round, reclaimed for in-place reuse once the
    /// engine drops its clone — a still-in-flight payload (async
    /// engine) simply forces one fresh buffer
    tx_slot: Arc<Payload>,
    /// shared zero-size payload carried by skip/observe reports
    /// (cloning the `Arc` is a refcount bump, not an allocation)
    empty: Arc<Payload>,
    /// reusable codec workspace (top-k argsort etc.)
    codec_scratch: CodecScratch,
    /// optional uplink codec (paper conclusion: CHB ∘ quantization)
    compressor: Option<Arc<dyn Compressor>>,
    /// optional gradient-sampling stream; `None` = the legacy
    /// full-batch path, bit-for-bit
    sampler: Option<BatchSampler>,
    /// optional K-step local-update regime; `None` = one gradient per
    /// round, bit-for-bit
    local: Option<LocalStepCfg>,
    /// scratch: local trajectory iterate θ_j (sized on first use)
    local_theta: Vec<f64>,
    /// scratch: local trajectory iterate θ_{j−1}
    local_prev: Vec<f64>,
    /// scratch: per-step local gradient ∇f_m(θ_j)
    local_grad: Vec<f64>,
    /// lifetime transmit counter S_m (Lemma 2)
    pub transmissions: usize,
}

impl Worker {
    /// Fresh worker over a gradient backend, with the θ̂⁰ = 0
    /// convention (first round always transmits the full gradient).
    pub fn new(id: usize, backend: Box<dyn GradientBackend>) -> Self {
        let dim = backend.dim();
        Self {
            id,
            backend,
            // θ̂⁰ convention: "no gradient transmitted yet" ⇒ zero
            // vector, so the first δ∇ is the full gradient and every
            // worker transmits at k = 1 (RHS of (8) is 0 at k = 1).
            last_tx_grad: vec![0.0; dim],
            grad: vec![0.0; dim],
            delta: vec![0.0; dim],
            tx_slot: Arc::new(Payload::default()),
            empty: Arc::new(Payload::default()),
            codec_scratch: CodecScratch::default(),
            compressor: None,
            sampler: None,
            local: None,
            local_theta: Vec::new(),
            local_prev: Vec::new(),
            local_grad: Vec::new(),
            transmissions: 0,
        }
    }

    /// Attach an uplink codec.  The worker advances its θ̂ bookkeeping
    /// with the *decoded* payload, so server and worker stay in exact
    /// agreement (eq. (5) still telescopes) and the codec error
    /// appears only as bounded gradient staleness.
    pub fn with_compressor(mut self, c: Arc<dyn Compressor>) -> Self {
        self.compressor = Some(c);
        self
    }

    /// Attach a gradient-sampling schedule.  [`BatchSchedule::Full`]
    /// installs no sampler at all — the worker stays on the legacy
    /// full-batch path, bit-for-bit.  Any other schedule requires a
    /// row-indexed backend ([`GradientBackend::num_rows`] > 0).
    pub fn with_batching(mut self, schedule: BatchSchedule) -> Self {
        self.sampler = match schedule {
            BatchSchedule::Full => None,
            s => Some(BatchSampler::new(s, self.id, self.backend.num_rows())),
        };
        self
    }

    /// Attach a K-step local-update regime.  `k_local = 1` installs
    /// nothing — the worker stays on the legacy one-gradient-per-round
    /// path, bit-for-bit.  Local steps are full-batch (the spec layer
    /// rejects the combination with minibatch schedules).
    pub fn with_local_steps(mut self, cfg: LocalStepCfg) -> Self {
        self.local = if cfg.k_local > 1 { Some(cfg) } else { None };
        self
    }

    /// Parameter dimension d.
    pub fn dim(&self) -> usize {
        self.backend.dim()
    }

    /// Execute one round at iterate θᵏ.  `theta_step_sq` is
    /// ‖θᵏ − θ^{k−1}‖², precomputed by the server and included in the
    /// broadcast (it is a scalar; the paper's workers know both
    /// iterates anyway).
    pub fn round(
        &mut self,
        theta: &[f64],
        theta_step_sq: f64,
        censor: &dyn CensorRule,
        k: usize,
    ) -> WorkerRound {
        self.round_inner(theta, theta_step_sq, censor, k, false)
    }

    /// Forced-transmission round (fault-plan rejoin): identical to
    /// [`Worker::round`] except the censor is bypassed — the worker
    /// transmits unconditionally, re-syncing its reference state θ̂ to
    /// the current gradient before censored reporting resumes.
    pub fn round_forced(
        &mut self,
        theta: &[f64],
        theta_step_sq: f64,
        censor: &dyn CensorRule,
        k: usize,
    ) -> WorkerRound {
        self.round_inner(theta, theta_step_sq, censor, k, true)
    }

    fn round_inner(
        &mut self,
        theta: &[f64],
        theta_step_sq: f64,
        censor: &dyn CensorRule,
        k: usize,
        force: bool,
    ) -> WorkerRound {
        // gradient flavor: full sweep (legacy, bit-pinned) unless the
        // sampler draws a proper row subset for round k.  Batched
        // rounds still report the FULL-shard loss (measurement side,
        // zero communication) so traces stay comparable across
        // schedules.  Local-step rounds walk a K-step trajectory and
        // charge K full sweeps to the epoch column.
        let (loss, batch_frac) = if let Some(cfg) = self.local {
            (self.local_sweep(theta, cfg), cfg.k_local as f64)
        } else {
            match &mut self.sampler {
                None => {
                    (self.backend.grad_loss_into(theta, &mut self.grad), 1.0)
                }
                Some(s) => {
                    let n = s.n_rows() as f64;
                    match s.draw(k) {
                        None => (
                            self.backend.grad_loss_into(theta, &mut self.grad),
                            1.0,
                        ),
                        Some(rows) => {
                            let frac = rows.len() as f64 / n;
                            self.backend.grad_loss_batch_into(
                                theta,
                                rows,
                                &mut self.grad,
                            );
                            (self.backend.loss(theta), frac)
                        }
                    }
                }
            }
        };
        linalg::sub_into(&self.grad, &self.last_tx_grad, &mut self.delta);
        let delta_sq = linalg::norm2_sq(&self.delta);
        let decision = if force {
            CensorDecision::Transmit
        } else {
            censor.decide(delta_sq, theta_step_sq, k)
        };
        let (delta, bits) = if decision == CensorDecision::Transmit {
            self.transmissions += 1;
            // reclaim the arena slot for in-place reuse; if an engine
            // still holds the previous payload (async in-flight), that
            // buffer is genuinely on the wire — start a fresh one
            if Arc::get_mut(&mut self.tx_slot).is_none() {
                self.tx_slot = Arc::new(Payload::default());
            }
            let slot =
                Arc::get_mut(&mut self.tx_slot).expect("slot just freed");
            let bits = match &self.compressor {
                None => {
                    // Algorithm 1 line 5: transmit δ∇, update θ̂_m ← θᵏ
                    slot.set_dense_from(&self.delta);
                    self.last_tx_grad.copy_from_slice(&self.grad);
                    dense_delta_bits(self.delta.len())
                }
                Some(c) => {
                    let bits = c.compress_into(
                        &self.delta,
                        &mut self.codec_scratch,
                        slot,
                    );
                    // bookkeeping uses the decoded payload — server
                    // and worker agree exactly on Σ transmitted deltas
                    slot.fold_into(&mut self.last_tx_grad);
                    bits
                }
            };
            (Arc::clone(&self.tx_slot), bits)
        } else {
            (Arc::clone(&self.empty), 0)
        };
        WorkerRound {
            worker: self.id,
            decision,
            delta,
            loss,
            delta_sq,
            bits,
            batch_frac,
        }
    }

    /// Walk the K-step local heavy-ball trajectory from the broadcast
    /// iterate θᵏ and leave the pseudo-gradient Σ_j ∇f_m(θ_j) in
    /// `self.grad`.  Local momentum restarts at zero every round (the
    /// trajectory is a pure function of θᵏ, so censor rematerialization
    /// and checkpoint replay stay exact).  Returns f_m(θᵏ) — the loss
    /// at the *broadcast* iterate, so traces stay comparable with every
    /// other method.
    fn local_sweep(&mut self, theta: &[f64], cfg: LocalStepCfg) -> f64 {
        let dim = theta.len();
        if self.local_theta.len() != dim {
            self.local_theta.resize(dim, 0.0);
            self.local_prev.resize(dim, 0.0);
            self.local_grad.resize(dim, 0.0);
        }
        self.local_theta.copy_from_slice(theta);
        self.local_prev.copy_from_slice(theta);
        let mut loss = 0.0;
        for j in 0..cfg.k_local {
            let l = self
                .backend
                .grad_loss_into(&self.local_theta, &mut self.local_grad);
            if j == 0 {
                loss = l;
                // copy, not add-into-zeros: keeps −0.0 coords bitwise
                self.grad.copy_from_slice(&self.local_grad);
            } else {
                for i in 0..dim {
                    self.grad[i] += self.local_grad[i];
                }
            }
            if j + 1 < cfg.k_local {
                // θ_{j+1} = θ_j − α∇f_m(θ_j) + β(θ_j − θ_{j−1})
                for i in 0..dim {
                    let t = self.local_theta[i];
                    self.local_theta[i] = t - cfg.alpha * self.local_grad[i]
                        + cfg.beta * (t - self.local_prev[i]);
                    self.local_prev[i] = t;
                }
            }
        }
        loss
    }

    /// Measurement-only round for a worker outside the scheduled set
    /// (partial participation): evaluates f_m(θᵏ) so the trace keeps
    /// reporting the *global* loss, but never touches the censor state
    /// — no δ∇ bookkeeping, no transmission, no bits on the wire.
    /// From the server's perspective this is indistinguishable from a
    /// censored worker, which eq. (5) tolerates by design.  Uses the
    /// forward-only loss pass (bit-identical value to the gradient
    /// pass — pinned by `tasks::tests`) so observers skip the
    /// backward work entirely.
    pub fn observe(&mut self, theta: &[f64]) -> WorkerRound {
        let loss = self.backend.loss(theta);
        WorkerRound {
            worker: self.id,
            decision: CensorDecision::Skip,
            delta: Arc::clone(&self.empty),
            loss,
            delta_sq: 0.0,
            bits: 0,
            // no gradient computed: must not dilute the round's mean
            // batch fraction or advance the epoch column
            batch_frac: 0.0,
        }
    }

    /// Rebuild the censor reference ∇f_m(θ̂) by direct evaluation at a
    /// historical iterate — the population engine's lazy
    /// rematerialization path.  A client outside the current cohort
    /// keeps no d-vector at all, only the round index of its last
    /// transmission; when it is sampled again, this recomputes the
    /// reference from the archived broadcast iterate.  The recompute
    /// is exact — bit-identical to the gradient the client transmitted
    /// back then — because the backend is deterministic and population
    /// runs are full-batch and codec-free (spec-validated); under a
    /// lossy codec the reference would instead need the decoded-payload
    /// bookkeeping this method skips.
    pub fn resync_reference(&mut self, theta_hat: &[f64]) {
        assert_eq!(
            theta_hat.len(),
            self.last_tx_grad.len(),
            "θ̂ dimension mismatch"
        );
        self.backend.grad_loss_into(theta_hat, &mut self.last_tx_grad);
    }

    /// Current gradient (for diagnostics; engine-side only).
    pub fn current_grad(&self) -> &[f64] {
        &self.grad
    }

    /// Last transmitted gradient (for invariant checks).
    pub fn last_transmitted(&self) -> &[f64] {
        &self.last_tx_grad
    }

    /// Capture the persistent state (checkpointing).
    pub fn snapshot(&self) -> WorkerSnapshot {
        WorkerSnapshot {
            id: self.id,
            last_tx: self.last_tx_grad.clone(),
            transmissions: self.transmissions,
            residual: self.codec_scratch.residual().to_vec(),
        }
    }

    /// Restore the persistent state from a snapshot.  The next round
    /// this worker runs is bit-identical to the round the snapshotted
    /// worker would have run.
    pub fn restore(&mut self, s: &WorkerSnapshot) {
        assert_eq!(self.id, s.id, "snapshot/worker id mismatch");
        assert_eq!(
            self.last_tx_grad.len(),
            s.last_tx.len(),
            "snapshot dimension mismatch"
        );
        self.last_tx_grad.copy_from_slice(&s.last_tx);
        self.transmissions = s.transmissions;
        self.codec_scratch.set_residual(&s.residual);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{GradDiffCensor, NeverCensor};

    /// Quadratic toy backend: f(θ) = ½‖θ − c‖², ∇ = θ − c.
    struct Toy {
        c: Vec<f64>,
    }

    impl GradientBackend for Toy {
        fn dim(&self) -> usize {
            self.c.len()
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let mut l = 0.0;
            for i in 0..theta.len() {
                grad[i] = theta[i] - self.c[i];
                l += grad[i] * grad[i];
            }
            0.5 * l
        }
    }

    #[test]
    fn first_round_always_transmits_full_gradient() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![1.0, 2.0] }));
        let r = w.round(&[0.0, 0.0], 0.0, &GradDiffCensor { epsilon1: 9e9 }, 1);
        assert_eq!(r.decision, CensorDecision::Transmit);
        assert_eq!(r.delta.to_dense(2), vec![-1.0, -2.0]);
        assert_eq!(r.bits, 128);
        assert_eq!(w.transmissions, 1);
    }

    #[test]
    fn unchanged_theta_skips_after_first_transmit() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![1.0] }));
        let censor = GradDiffCensor { epsilon1: 0.5 };
        let r1 = w.round(&[0.0], 0.0, &censor, 1);
        assert_eq!(r1.decision, CensorDecision::Transmit);
        // same θ again: δ∇ = 0 ≤ anything → skip, no state change
        let r2 = w.round(&[0.0], 0.0, &censor, 2);
        assert_eq!(r2.decision, CensorDecision::Skip);
        assert_eq!(w.transmissions, 1);
        assert!(r2.delta.is_empty());
    }

    #[test]
    fn delta_is_relative_to_last_transmitted_not_last_computed() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![0.0] }));
        // huge ε₁ ⇒ worker skips everything after the first transmit
        let censor = GradDiffCensor { epsilon1: 1e12 };
        let r1 = w.round(&[1.0], 0.0, &censor, 1);
        assert_eq!(r1.decision, CensorDecision::Transmit); // rhs = 0, lhs > 0
        let _ = w.round(&[2.0], 1.0, &censor, 2); // skip
        let r3 = w.round(&[3.0], 1.0, &censor, 3); // skip
        assert_eq!(r3.decision, CensorDecision::Skip);
        // δ at k=3 must be grad(3) − grad(1) = 3 − 1 = 2 (not 3 − 2)
        assert!((r3.delta_sq - 4.0).abs() < 1e-12);
        assert_eq!(w.last_transmitted(), &[1.0]);
    }

    #[test]
    fn never_censor_transmits_every_round_and_deltas_telescope() {
        let mut w = Worker::new(3, Box::new(Toy { c: vec![5.0] }));
        let mut sum = 0.0;
        let thetas = [[1.0], [2.0], [-1.0]];
        for (k, th) in thetas.iter().enumerate() {
            let r = w.round(th, 1.0, &NeverCensor, k + 1);
            assert_eq!(r.decision, CensorDecision::Transmit);
            sum += r.delta.to_dense(1)[0];
        }
        // Σδ telescopes to the latest gradient: (−1) − 5 = −6
        assert!((sum - (-6.0)).abs() < 1e-12);
        assert_eq!(w.transmissions, 3);
    }

    #[test]
    fn compressed_transmissions_keep_worker_and_server_in_sync() {
        use crate::compress::UniformQuantizer;
        let mut w = Worker::new(0, Box::new(Toy { c: vec![0.0, 0.0] }))
            .with_compressor(Arc::new(UniformQuantizer { bits: 4 }));
        let censor = NeverCensor;
        // server-side replica of the aggregate
        let mut agg = vec![0.0; 2];
        for (k, th) in [[1.0, -2.0], [0.5, 3.0], [-4.0, 0.25]].iter().enumerate() {
            let r = w.round(th, 1.0, &censor, k + 1);
            assert_eq!(r.decision, CensorDecision::Transmit);
            // 4-bit payload: 32-bit scale + 4 bits × 2 coords
            assert_eq!(r.bits, 32 + 8);
            r.delta.fold_into(&mut agg);
            // invariant: server aggregate == worker's θ̂ bookkeeping
            assert_eq!(agg, w.last_transmitted());
        }
        // lossy: last_transmitted differs from the exact gradient, but
        // boundedly (4-bit relative error ≤ 1/7 of max|grad|)
        let exact = [-4.0, 0.25];
        for i in 0..2 {
            assert!((w.last_transmitted()[i] - exact[i]).abs() < 4.0 / 7.0 * 3.0);
        }
    }

    #[test]
    fn transmit_slot_is_reused_once_the_engine_drops_the_report() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![1.0, 2.0] }));
        let r1 = w.round(&[0.0, 0.0], 0.0, &NeverCensor, 1);
        let p1 = Arc::as_ptr(&r1.delta);
        drop(r1); // engine folded and discarded the report
        let r2 = w.round(&[1.0, 1.0], 1.0, &NeverCensor, 2);
        // same allocation, reused in place — the zero-alloc steady state
        assert_eq!(p1, Arc::as_ptr(&r2.delta));
        assert_eq!(r2.delta.to_dense(2), vec![1.0, 1.0]);
    }

    #[test]
    fn in_flight_payload_forces_a_fresh_buffer_not_a_corruption() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![1.0] }));
        let r1 = w.round(&[0.0], 0.0, &NeverCensor, 1);
        // r1 still alive (async: on the wire) while round 2 runs
        let r2 = w.round(&[3.0], 1.0, &NeverCensor, 2);
        assert_ne!(Arc::as_ptr(&r1.delta), Arc::as_ptr(&r2.delta));
        // the in-flight payload is untouched by the newer round
        assert_eq!(r1.delta.to_dense(1), vec![-1.0]);
        assert_eq!(r2.delta.to_dense(1), vec![3.0]);
    }

    #[test]
    fn skip_reports_share_one_empty_payload() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![1.0] }));
        let censor = GradDiffCensor { epsilon1: 1e12 };
        let _ = w.round(&[0.5], 0.0, &censor, 1);
        let s1 = w.round(&[0.5], 0.0, &censor, 2);
        let s2 = w.observe(&[0.5]);
        assert_eq!(s1.decision, CensorDecision::Skip);
        assert!(s1.delta.is_empty() && s2.delta.is_empty());
        // both are refcount bumps on the same zero-size payload
        assert_eq!(Arc::as_ptr(&s1.delta), Arc::as_ptr(&s2.delta));
    }

    /// Row-indexed toy: f(θ) = Σ_i ½(θ − c_i)² over n scalar "rows".
    struct RowToy {
        c: Vec<f64>,
    }

    impl GradientBackend for RowToy {
        fn dim(&self) -> usize {
            1
        }

        fn num_rows(&self) -> usize {
            self.c.len()
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let mut l = 0.0;
            grad[0] = 0.0;
            for &c in &self.c {
                let d = theta[0] - c;
                grad[0] += d;
                l += d * d;
            }
            0.5 * l
        }

        fn grad_loss_batch_into(
            &mut self,
            theta: &[f64],
            rows: &[u32],
            grad: &mut [f64],
        ) -> f64 {
            let s = self.c.len() as f64 / rows.len() as f64;
            let mut l = 0.0;
            grad[0] = 0.0;
            for &i in rows {
                let d = theta[0] - self.c[i as usize];
                grad[0] += d;
                l += d * d;
            }
            grad[0] *= s;
            0.5 * l * s
        }
    }

    #[test]
    fn full_schedule_is_bitwise_the_unbatched_worker() {
        use crate::data::batch::BatchSchedule;
        let c = vec![1.0, 2.0, -3.0, 0.5];
        let mut plain = Worker::new(0, Box::new(RowToy { c: c.clone() }));
        let mut batched = Worker::new(0, Box::new(RowToy { c }))
            .with_batching(BatchSchedule::Full);
        for (k, th) in [[0.0], [0.7], [-0.2]].iter().enumerate() {
            let a = plain.round(th, 1.0, &NeverCensor, k + 1);
            let b = batched.round(th, 1.0, &NeverCensor, k + 1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.delta, b.delta);
            assert_eq!(b.batch_frac, 1.0);
        }
    }

    #[test]
    fn minibatch_round_reports_fraction_and_full_shard_loss() {
        use crate::data::batch::BatchSchedule;
        let c = vec![1.0, 2.0, -3.0, 0.5];
        let mut full = Worker::new(0, Box::new(RowToy { c: c.clone() }));
        let mut mini =
            Worker::new(0, Box::new(RowToy { c })).with_batching(
                BatchSchedule::Minibatch { size: 2, seed: 7, replace: false },
            );
        let rf = full.round(&[0.3], 1.0, &NeverCensor, 1);
        let rm = mini.round(&[0.3], 1.0, &NeverCensor, 1);
        // the reported loss is the full-shard value either way …
        assert_eq!(rf.loss.to_bits(), rm.loss.to_bits());
        // … while the gradient visited half the rows
        assert_eq!(rm.batch_frac, 0.5);
        assert_eq!(rf.batch_frac, 1.0);
    }

    #[test]
    fn local_steps_report_the_sum_of_trajectory_gradients() {
        // quadratic shard: ∇f(θ) = θ − c.  K = 2, β = 0:
        // θ₁ = θ₀ − α(θ₀ − c); pseudo-gradient = (θ₀−c) + (θ₁−c)
        let (alpha, c) = (0.25, 3.0);
        let mut w = Worker::new(0, Box::new(Toy { c: vec![c] }))
            .with_local_steps(LocalStepCfg { k_local: 2, alpha, beta: 0.0 });
        let th0 = 1.0_f64;
        let r = w.round(&[th0], 0.0, &NeverCensor, 1);
        let g0 = th0 - c;
        let th1 = th0 - alpha * g0;
        let expect = g0 + (th1 - c);
        assert_eq!(r.delta.to_dense(1)[0].to_bits(), expect.to_bits());
        assert_eq!(r.batch_frac, 2.0);
        // loss is reported at the broadcast iterate, not a local one
        assert_eq!(r.loss.to_bits(), (0.5 * g0 * g0).to_bits());
    }

    #[test]
    fn local_momentum_follows_the_heavy_ball_recursion() {
        let (alpha, beta, c) = (0.2, 0.5, 4.0);
        let mut w = Worker::new(0, Box::new(Toy { c: vec![c] }))
            .with_local_steps(LocalStepCfg { k_local: 3, alpha, beta });
        // reference trajectory, same op order as the worker's
        let mut th = 2.0_f64;
        let mut prev = th;
        let mut sum = 0.0_f64;
        for j in 0..3 {
            let g = th - c;
            if j == 0 {
                sum = g;
            } else {
                sum += g;
            }
            let t = th;
            th = t - alpha * g + beta * (t - prev);
            prev = t;
        }
        let r = w.round(&[2.0], 0.0, &NeverCensor, 1);
        assert_eq!(r.delta.to_dense(1)[0].to_bits(), sum.to_bits());
        assert_eq!(r.batch_frac, 3.0);
    }

    #[test]
    fn one_local_step_is_bitwise_the_plain_worker() {
        let mut plain = Worker::new(0, Box::new(Toy { c: vec![1.0, -2.0] }));
        let mut local = Worker::new(0, Box::new(Toy { c: vec![1.0, -2.0] }))
            .with_local_steps(LocalStepCfg {
                k_local: 1,
                alpha: 0.1,
                beta: 0.4,
            });
        let censor = GradDiffCensor { epsilon1: 0.5 };
        for (k, th) in
            [[0.0, 0.0], [0.3, 0.1], [0.3, 0.1]].iter().enumerate()
        {
            let a = plain.round(th, 0.01, &censor, k + 1);
            let b = local.round(th, 0.01, &censor, k + 1);
            assert_eq!(a.decision, b.decision, "k={}", k + 1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.delta.to_dense(2), b.delta.to_dense(2));
            assert_eq!(b.batch_frac, 1.0);
        }
    }

    #[test]
    fn resync_reference_reproduces_the_transmitted_gradient() {
        // client A transmitted at θ̂ and stayed resident; client B is a
        // fresh materialization resynced at the archived θ̂ — the two
        // must agree bitwise on the reference and on the next delta
        let mut a = Worker::new(0, Box::new(Toy { c: vec![1.0, -2.0] }));
        let theta_hat = [0.5, 0.25];
        let _ = a.round(&theta_hat, 0.0, &NeverCensor, 1);
        let mut b = Worker::new(0, Box::new(Toy { c: vec![1.0, -2.0] }));
        b.resync_reference(&theta_hat);
        assert_eq!(a.last_transmitted(), b.last_transmitted());
        let ra = a.round(&[2.0, 2.0], 1.0, &NeverCensor, 2);
        let rb = b.round(&[2.0, 2.0], 1.0, &NeverCensor, 2);
        assert_eq!(ra.delta.to_dense(2), rb.delta.to_dense(2));
        assert_eq!(ra.delta_sq.to_bits(), rb.delta_sq.to_bits());
    }

    #[test]
    fn loss_reported_even_when_skipping() {
        let mut w = Worker::new(0, Box::new(Toy { c: vec![0.0] }));
        let censor = GradDiffCensor { epsilon1: 1e12 };
        let _ = w.round(&[2.0], 0.0, &censor, 1);
        let r = w.round(&[2.0], 0.0, &censor, 2);
        assert_eq!(r.decision, CensorDecision::Skip);
        assert!((r.loss - 2.0).abs() < 1e-12); // ½·4
    }
}
