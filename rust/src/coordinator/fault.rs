//! Fault injection: seeded worker crash/rejoin schedules and server
//! kill/restore points — richer than the per-message delta-drop model
//! in [`crate::net`].
//!
//! A [`FaultPlan`] is part of the run's *semantics* (it changes the
//! trace), so it lives in [`crate::spec::RunSpec`] and serializes into
//! `manifest.json`; the checkpoint policy, which does not change the
//! trace, stays environmental.
//!
//! The crash schedule is a **pure function** of `(seed, worker,
//! round)`: worker `w` is down at round `k` iff some round `j` in the
//! window `(k − down_rounds, k]` drew a crash.  No generator state is
//! carried between rounds, so the same plan reproduces the same
//! schedule on every engine and interleaving — and checkpoints need
//! not serialize any fault state at all.
//!
//! Semantics per event:
//!
//! * **down** — the worker is forced inactive: it still observes the
//!   broadcast (loss is recorded) but computes no delta and touches no
//!   censor state.  Eq. (5) simply carries its stale term, exactly as
//!   for a censored worker, so the telescope invariant is undisturbed.
//! * **rejoin** — the first round after an outage the worker is forced
//!   to transmit, bypassing its censor: this re-syncs its reference
//!   state θ̂ (the server-visible last-transmitted gradient) before it
//!   reports censored rounds again.
//! * **server kill** — at each round in `server_kills` the server is
//!   killed and restored from its most recent checkpoint (the initial
//!   state when none was taken yet), then replays forward.  Because
//!   every engine is deterministic, the replayed trace is bit-identical
//!   to the kill-free run — the recovery property the resume tests pin.

use crate::rng::SplitMix64;

/// Seeded crash/rejoin + server-kill schedule (default: no faults).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// per-(worker, round) probability a crash is triggered
    pub crash_prob: f64,
    /// rounds a triggered crash keeps the worker down (≥ 1)
    pub down_rounds: usize,
    /// seed of the crash-draw hash
    pub seed: u64,
    /// rounds at which the server is killed and restored from its
    /// last checkpoint (sorted, deduplicated, each fires once)
    pub server_kills: Vec<usize>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            crash_prob: 0.0,
            down_rounds: 1,
            seed: 0,
            server_kills: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// Does this plan inject anything at all?  (The engines skip all
    /// fault bookkeeping when not.)
    pub fn enabled(&self) -> bool {
        self.crash_prob > 0.0 || !self.server_kills.is_empty()
    }

    /// Crash draw for `(worker, round)` — the pure hash underneath
    /// [`FaultPlan::down`].
    fn triggered(&self, worker: usize, round: usize) -> bool {
        if self.crash_prob <= 0.0 || round == 0 {
            return false;
        }
        let mut sm = SplitMix64::new(
            self.seed
                ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (round as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        let u = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.crash_prob
    }

    /// Is `worker` down at round `k`?  True iff any round in the
    /// trailing window `(k − down_rounds, k]` triggered a crash.
    pub fn down(&self, worker: usize, k: usize) -> bool {
        if self.crash_prob <= 0.0 {
            return false;
        }
        let lo = k.saturating_sub(self.down_rounds.max(1) - 1).max(1);
        (lo..=k).any(|j| self.triggered(worker, j))
    }

    /// Is round `k` the worker's first round back after an outage?
    /// (Forces an uncensored transmission to re-sync θ̂.)
    pub fn rejoin(&self, worker: usize, k: usize) -> bool {
        k > 1 && !self.down(worker, k) && self.down(worker, k - 1)
    }

    /// Is the server killed at round `k`?
    pub fn server_killed_at(&self, k: usize) -> bool {
        self.server_kills.binary_search(&k).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(prob: f64, down_rounds: usize, seed: u64) -> FaultPlan {
        FaultPlan { crash_prob: prob, down_rounds, seed, ..Default::default() }
    }

    #[test]
    fn default_plan_injects_nothing() {
        let p = FaultPlan::default();
        assert!(!p.enabled());
        for w in 0..4 {
            for k in 1..=50 {
                assert!(!p.down(w, k));
                assert!(!p.rejoin(w, k));
            }
        }
    }

    #[test]
    fn schedule_is_a_pure_function_of_seed_worker_round() {
        let a = plan(0.2, 3, 42);
        let b = plan(0.2, 3, 42);
        let c = plan(0.2, 3, 43);
        let mut diverged = false;
        for w in 0..6 {
            for k in 1..=100 {
                assert_eq!(a.down(w, k), b.down(w, k), "w={w} k={k}");
                diverged |= a.down(w, k) != c.down(w, k);
            }
        }
        assert!(diverged, "different seeds should differ somewhere");
    }

    #[test]
    fn outages_last_down_rounds() {
        let p = plan(0.05, 4, 7);
        // find a triggered round and check the window shape around it
        let mut checked = false;
        for w in 0..8 {
            for k in 1..=200 {
                if p.triggered(w, k) {
                    for j in k..k + 4 {
                        assert!(p.down(w, j), "w={w} trigger {k} round {j}");
                    }
                    checked = true;
                }
            }
        }
        assert!(checked, "probability 0.05 over 1600 draws should trigger");
    }

    #[test]
    fn rejoin_fires_exactly_on_recovery_rounds() {
        let p = plan(0.1, 2, 9);
        for w in 0..4 {
            for k in 2..=150 {
                let expect = !p.down(w, k) && p.down(w, k - 1);
                assert_eq!(p.rejoin(w, k), expect, "w={w} k={k}");
            }
        }
    }

    #[test]
    fn server_kill_lookup_uses_the_sorted_list() {
        let p = FaultPlan {
            server_kills: vec![3, 10, 25],
            ..FaultPlan::default()
        };
        assert!(p.enabled());
        assert!(p.server_killed_at(10));
        assert!(!p.server_killed_at(11));
    }
}
