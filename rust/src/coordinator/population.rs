//! Million-client federation: cohort rounds over a client population
//! at O(model + cohort) server memory.
//!
//! The paper validates CHB at tens of workers; production federated
//! learning is 10⁶ devices with small per-round cohorts — exactly the
//! regime where censoring pays off most, since per-device uplinks are
//! the scarce resource.  The resident engines cannot represent that
//! population: every [`Worker`] holds its objective, gradient scratch,
//! and a d-vector censor reference, so memory is O(M·d).  This engine
//! makes three replacements:
//!
//! 1. **Compact client state.**  A client outside the current cohort
//!    is 8 bytes: the round it last transmitted and its lifetime
//!    transmit counter ([`ClientState`]).  When the
//!    [`CohortSampler`] draws it again, the engine materializes a
//!    throw-away [`Worker`] against the `Arc`-shared base shards and
//!    rebuilds its censor reference ∇f_c(θ̂) *exactly* via
//!    [`Worker::resync_reference`] against the archived broadcast
//!    iterate θ̂ = θ^(k̂−1) — bit-identical to the gradient it
//!    transmitted at round k̂, because gradients are deterministic and
//!    population runs are full-batch and codec-free.  The eq. (5)
//!    telescope therefore holds over the whole population even though
//!    no client keeps a resident d-vector.
//!
//! 2. **Pure cohort sampling.**  Cohorts are a pure function of
//!    (round, seed) — see [`CohortSampler`] — so the trace is
//!    independent of execution backend and replayable per round.
//!
//! 3. **Streaming aggregation.**  Uplinks are scheduled on the
//!    [`EventQueue`] (timer-wheel backend) with per-client compute +
//!    latency times and folded **one at a time** into the server's
//!    O(model) aggregate via [`Server::fold_uplink`]; per-client
//!    telemetry goes into reservoir/histogram summaries
//!    ([`PopulationSummary`]) so the [`Trace`] stays O(rounds), not
//!    O(clients).
//!
//! Memory accounting per run: O(d) server state + O(cohort·d)
//! transient worker materializations + O(rounds·d) archived broadcast
//! iterates + 8 B × M client index — "O(model + cohort)" for any
//! fixed round budget, independent of M.  The global loss column is
//! exact: clients map onto base shards round-robin, so
//! Σ_c f_c(θ) = Σ_s mult_s·f_s(θ) with M_base resident evaluators.

use std::sync::Arc;

use crate::metrics::{IterStat, PopulationSummary, Trace};
use crate::net::EventQueue;
use crate::optim::{CensorDecision, CensorRule};
use crate::rng::{SplitMix64, Xoshiro256};

use super::async_engine::AsyncConfig;
use super::engine::RunConfig;
use super::participation::CohortSampler;
use super::server::Server;
use super::worker::{Worker, WorkerRound};

/// The population axis of a run: how many simulated clients exist and
/// how many are cohorted per round.  Lives beside [`super::FaultPlan`]
/// in the coordinator so `spec/` can embed it without a layer cycle.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PopulationSpec {
    /// population size M (simulated clients)
    pub clients: u64,
    /// per-round cohort size (1 ..= clients)
    pub cohort: u64,
    /// cohort-sampler seed
    pub seed: u64,
}

/// sentinel: this client has never transmitted (the θ̂⁰ = 0 convention)
const NEVER: u32 = u32::MAX;

/// The entire resident footprint of one out-of-cohort client.
#[derive(Clone, Copy)]
struct ClientState {
    /// round of the last delivered transmission (NEVER = none yet)
    last_round: u32,
    /// lifetime transmit counter S_c
    transmissions: u32,
}

/// What a population run produces: the O(rounds) trace plus the
/// fixed-size telemetry bundle.
pub struct PopulationOutcome {
    /// standard per-round trace (per-client columns deliberately
    /// empty — they are O(M); see [`PopulationSummary`])
    pub trace: Trace,
    /// bounded-memory per-client telemetry
    pub summary: PopulationSummary,
}

/// Run a censored-heavy-ball population: `cfg.max_iters` cohort
/// rounds over `pop.clients` simulated clients.
///
/// `make_worker` materializes the throw-away worker for one client id
/// (objective against `Arc`-shared data); `global_loss` evaluates the
/// exact population loss Σ_c f_c(θ) (measurement side only — it costs
/// no simulated communication).  Both are injected so this engine has
/// no dependency on the experiment layer and is testable with toy
/// backends.
#[allow(clippy::too_many_arguments)]
pub fn run_population(
    pop: &PopulationSpec,
    cfg: &RunConfig,
    acfg: &AsyncConfig,
    mut server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
    make_worker: &mut dyn FnMut(u64) -> Worker,
    global_loss: &mut dyn FnMut(&[f64]) -> f64,
) -> PopulationOutcome {
    let m = pop.clients;
    let cohort_n = pop.cohort.min(m).max(1);
    assert!(m >= 1, "population needs at least one client");
    // 8 bytes per client — the only O(M) allocation in the run
    let mut states =
        vec![ClientState { last_round: NEVER, transmissions: 0 }; m as usize];
    // archived broadcast iterates: θ^(k−1) at index k−1, so a client
    // whose last transmission was round k̂ resyncs against index k̂−1
    let mut theta_history: Vec<Arc<Vec<f64>>> =
        Vec::with_capacity(cfg.max_iters);
    let sampler = CohortSampler::new(pop.seed);
    let mut summary = PopulationSummary::new(m, cohort_n);
    let mut trace = Trace::new(label);
    let mut queue: EventQueue<WorkerRound> = EventQueue::new();
    let mut vclock = 0.0f64;
    let compute_seed = acfg.compute.master_seed();

    for k in 1..=cfg.max_iters {
        let theta = Arc::new(server.theta.clone());
        let step_sq = server.theta_step_sq();
        theta_history.push(Arc::clone(&theta));
        // per-round compute-time stream, pure in (compute seed, k)
        let mut crng = Xoshiro256::new(
            SplitMix64::new(
                compute_seed ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            )
            .next_u64(),
        );
        let cohort = sampler.draw(k as u64, cohort_n, m);
        for &c in &cohort {
            let st = states[c as usize];
            let mut w = make_worker(c);
            if st.last_round != NEVER {
                // lazy rematerialization: exact censor reference from
                // the archived iterate it last transmitted against
                w.resync_reference(
                    &theta_history[(st.last_round - 1) as usize],
                );
                w.transmissions = st.transmissions as usize;
                summary.resyncs += 1;
                summary
                    .reference_age
                    .record(k - st.last_round as usize);
            } else {
                summary.reference_age.record(0);
            }
            let r = w.round(&theta, step_sq, censor.as_ref(), k);
            summary.delta_sq.record(r.delta_sq);
            if r.decision == CensorDecision::Transmit {
                let st = &mut states[c as usize];
                st.last_round = k as u32;
                st.transmissions += 1;
                summary.uplinks += 1;
                // uplink lands at compute time + wire time; the event
                // queue (timer wheel) orders the round's arrivals
                let bytes = r.bits.div_ceil(8) + 8;
                let t_arr = vclock
                    + acfg.compute.sample(&mut crng)
                    + acfg.latency.transfer_us(bytes);
                queue.push(t_arr, 0, c as usize, r);
            } else {
                summary.censored += 1;
            }
            // `w` drops here: objective + scratch freed; the client's
            // persistent footprint is back to 8 bytes
        }
        // streaming fold: arrivals pop in simulated-time order and
        // fold immediately into the O(model) aggregate
        let mut transmitted = 0usize;
        let mut bits_round = 0u64;
        while let Some((key, r)) = queue.pop() {
            vclock = key.time_us;
            bits_round += r.bits;
            transmitted += usize::from(server.fold_uplink(&r));
        }
        let loss = global_loss(&theta);
        let out = server.finish_round(transmitted, loss);
        let prev = trace.iters.last();
        let stat = IterStat {
            k: out.k,
            loss: out.loss,
            comms_round: out.transmitted,
            comms_cum: prev.map_or(0, |s| s.comms_cum) + out.transmitted,
            agg_grad_sq: out.agg_grad_sq,
            step_sq: out.step_sq,
            bits_cum: prev.map_or(0, |s| s.bits_cum) + bits_round,
            // every cohorted client received the dense θᵏ broadcast
            down_bits_cum: prev.map_or(0, |s| s.down_bits_cum)
                + cohort.len() as u64
                    * crate::net::dense_delta_bits(theta.len()),
            vclock_us: vclock,
            // cohort rounds fold every delta at the iterate it was
            // computed on — arrival staleness is identically zero (the
            // censor-reference age lives in `summary.reference_age`)
            stale_max: 0,
            batch_frac: 1.0,
            // cohort/M of the global data is visited per round
            epoch: prev.map_or(0.0, |s| s.epoch)
                + cohort.len() as f64 / m as f64,
        };
        trace.participants.push(cohort.len());
        let stop = cfg.should_stop(&stat);
        trace.iters.push(stat);
        summary.rounds = k;
        if stop {
            break;
        }
    }
    // O(M) scan once at exit; the summary keeps O(buckets)
    for st in &states {
        summary.tx_per_client.record(st.transmissions as usize);
    }
    PopulationOutcome { trace, summary }
}

#[cfg(test)]
mod tests {
    use super::super::worker::GradientBackend;
    use super::*;
    use crate::net::LatencyModel;
    use crate::optim::method::{build_censor_rule, build_server_rule};
    use crate::optim::{Method, MethodParams};
    use crate::coordinator::ComputeModel;

    /// Quadratic toy per client: f_c(θ) = ½‖θ − t_c‖², ∇ = θ − t_c,
    /// with target t_c derived from the client's shard id — clients
    /// sharing a shard share an objective, like the real mapping.
    struct Quad {
        target: Vec<f64>,
    }

    impl GradientBackend for Quad {
        fn dim(&self) -> usize {
            self.target.len()
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let mut l = 0.0;
            for i in 0..theta.len() {
                grad[i] = theta[i] - self.target[i];
                l += grad[i] * grad[i];
            }
            0.5 * l
        }
    }

    const BASE_M: u64 = 4;
    const DIM: usize = 3;

    fn target(shard: u64) -> Vec<f64> {
        (0..DIM).map(|i| (shard as f64 + 1.0) * 0.25 + i as f64).collect()
    }

    fn make(c: u64) -> Worker {
        Worker::new(
            c as usize,
            Box::new(Quad { target: target(c % BASE_M) }),
        )
    }

    fn run(clients: u64, cohort: u64, iters: usize) -> PopulationOutcome {
        // the aggregate sums one gradient per *client*, so the stable
        // step size scales as 1/M (α·M < 2 for the unit quadratic)
        let params = MethodParams::new(0.8 / clients as f64)
            .with_beta(0.3)
            .with_epsilon1(1e-4);
        let pop = PopulationSpec { clients, cohort, seed: 9 };
        let cfg = RunConfig::new(Method::Chb, params, iters);
        let acfg = AsyncConfig {
            compute: ComputeModel::Uniform { us: 50.0 },
            latency: LatencyModel { fixed_us: 10.0, per_kib_us: 2.0 },
            max_staleness: None,
        };
        let server = Server::with_rule(
            build_server_rule(Method::Chb, &params, DIM),
            vec![0.0; DIM],
        );
        let censor: Arc<dyn CensorRule> =
            Arc::from(build_censor_rule(Method::Chb, &params));
        let mut gl = |theta: &[f64]| -> f64 {
            (0..BASE_M.min(clients))
                .map(|s| {
                    let mult = (clients - 1 - s) / BASE_M + 1;
                    let mut g = vec![0.0; DIM];
                    mult as f64
                        * Quad { target: target(s) }
                            .grad_loss_into(theta, &mut g)
                })
                .sum()
        };
        run_population(
            &pop,
            &cfg,
            &acfg,
            server,
            censor,
            "CHB-pop",
            &mut make,
            &mut gl,
        )
    }

    #[test]
    fn population_run_descends_and_records_o_rounds_trace() {
        let out = run(1000, 50, 30);
        assert_eq!(out.trace.iterations(), 30);
        assert!(out.trace.final_loss() < out.trace.iters[0].loss);
        // O(rounds): per-client columns stay empty by design
        assert!(out.trace.per_worker_comms.is_empty());
        assert!(out.trace.comm_map.is_empty());
        assert!(out.trace.worker_staleness.is_empty());
        assert_eq!(out.trace.participants, vec![50; 30]);
        // the summary accounts every cohort evaluation
        assert_eq!(out.summary.uplinks + out.summary.censored, 30 * 50);
        assert_eq!(out.summary.tx_per_client.total(), 1000);
        // virtual clock advances monotonically across rounds
        for w in out.trace.iters.windows(2) {
            assert!(w[1].vclock_us >= w[0].vclock_us);
        }
    }

    #[test]
    fn population_trace_is_deterministic() {
        let a = run(500, 20, 15);
        let b = run(500, 20, 15);
        assert_eq!(a.trace.iterations(), b.trace.iterations());
        for (x, y) in a.trace.iters.iter().zip(&b.trace.iters) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "k={}", x.k);
            assert_eq!(x.agg_grad_sq.to_bits(), y.agg_grad_sq.to_bits());
            assert_eq!(x.comms_round, y.comms_round);
            assert_eq!(x.bits_cum, y.bits_cum);
            assert_eq!(x.vclock_us.to_bits(), y.vclock_us.to_bits());
        }
        assert_eq!(a.summary.uplinks, b.summary.uplinks);
        assert_eq!(a.summary.delta_sq.sample(), b.summary.delta_sq.sample());
    }

    #[test]
    fn eq5_telescope_holds_under_lazy_rematerialization() {
        // ∇ᵏ must equal Σ over clients of their last-transmitted
        // gradient — the eq. (5) invariant, here across clients that
        // were materialized, dropped, and resynced many times
        let clients = 64u64;
        let cohort = 16u64;
        let iters = 25usize;
        let params = MethodParams::new(0.8 / clients as f64)
            .with_beta(0.3)
            .with_epsilon1(1e-4);
        let pop = PopulationSpec { clients, cohort, seed: 4 };
        let cfg = RunConfig::new(Method::Chb, params, iters);
        let acfg = AsyncConfig {
            compute: ComputeModel::Uniform { us: 1.0 },
            latency: LatencyModel::zero(),
            max_staleness: None,
        };
        let server = Server::with_rule(
            build_server_rule(Method::Chb, &params, DIM),
            vec![0.0; DIM],
        );
        let censor: Arc<dyn CensorRule> =
            Arc::from(build_censor_rule(Method::Chb, &params));
        // shadow bookkeeping: every client's last transmitted gradient,
        // reconstructed from the trace-independent history of iterates
        let mut gl = |_: &[f64]| 0.0;
        let out = run_population(
            &pop,
            &cfg,
            &acfg,
            server,
            censor,
            "CHB-pop",
            &mut make,
            &mut gl,
        );
        // replay: run the same protocol with fully-resident workers
        // (the O(M·d) reference implementation) and compare aggregates
        let params2 = params;
        let mut server2 = Server::with_rule(
            build_server_rule(Method::Chb, &params2, DIM),
            vec![0.0; DIM],
        );
        let censor2: Arc<dyn CensorRule> =
            Arc::from(build_censor_rule(Method::Chb, &params2));
        let mut resident: Vec<Worker> = (0..clients).map(make).collect();
        let sampler = CohortSampler::new(pop.seed);
        for k in 1..=iters {
            let theta = server2.theta.clone();
            let step_sq = server2.theta_step_sq();
            let mut transmitted = 0usize;
            // uniform compute + zero latency ⇒ every uplink lands at
            // the same instant, so the event queue's total order ties
            // break on client id — fold in that order to match the
            // population engine's floating-point sum bitwise
            let mut reports: Vec<(u64, _)> = sampler
                .draw(k as u64, cohort, clients)
                .into_iter()
                .map(|c| {
                    let r = resident[c as usize].round(
                        &theta,
                        step_sq,
                        censor2.as_ref(),
                        k,
                    );
                    (c, r)
                })
                .collect();
            reports.sort_by_key(|(c, _)| *c);
            for (_, r) in &reports {
                transmitted += usize::from(server2.fold_uplink(r));
            }
            let o = server2.finish_round(transmitted, 0.0);
            // the lazily-materialized population must match the
            // resident reference bitwise, round by round
            let stat = &out.trace.iters[k - 1];
            assert_eq!(stat.comms_round, o.transmitted, "round {k}");
            assert_eq!(
                stat.agg_grad_sq.to_bits(),
                o.agg_grad_sq.to_bits(),
                "round {k}: aggregate diverged"
            );
            assert_eq!(
                stat.step_sq.to_bits(),
                o.step_sq.to_bits(),
                "round {k}: step diverged"
            );
        }
        // … comparing replay outcomes against the population trace
        // happens below; first assert the trace is well-formed
        for (k, stat) in out.trace.iters.iter().enumerate() {
            assert_eq!(stat.k, k + 1);
        }
        // the resident aggregate telescopes to Σ last_tx
        let mut sum = vec![0.0; DIM];
        for w in &resident {
            for (s, g) in sum.iter_mut().zip(w.last_transmitted()) {
                *s += g;
            }
        }
        for (s, a) in sum.iter().zip(&server2.agg_grad) {
            assert!((s - a).abs() < 1e-9, "telescope violated: {s} vs {a}");
        }
        // cross-check the population run's comms against the resident
        // replay's transmit counters
        let resident_tx: usize = resident.iter().map(|w| w.transmissions).sum();
        assert_eq!(out.trace.total_comms(), resident_tx);
    }

    #[test]
    fn summaries_stay_bounded_at_large_populations() {
        // M = 10⁵ with a 10-client cohort: only 10 workers ever
        // materialize per round, and every telemetry structure keeps
        // its fixed capacity — nothing in the output scales with M
        let out = run(100_000, 10, 5);
        assert_eq!(out.trace.iterations(), 5);
        assert!(out.summary.delta_sq.sample().len() <= 1024);
        assert_eq!(out.summary.reference_age.counts().len(), 256);
        assert_eq!(out.summary.tx_per_client.counts().len(), 256);
        assert_eq!(out.summary.tx_per_client.total(), 100_000);
        assert_eq!(out.summary.uplinks + out.summary.censored, 50);
    }

    #[test]
    fn censoring_fires_and_is_recorded_in_the_summary() {
        // ε₁ = 10: a client resampled within ~3 rounds of its last
        // transmit has ‖∇f(θᵏ) − ∇f(θ̂)‖² = ‖θᵏ − θ̂‖² of a few
        // steps — below 10·‖θᵏ − θ^{k−1}‖² — and must stay silent
        let clients = 200u64;
        let cohort = 100u64;
        let params = MethodParams::new(0.8 / clients as f64)
            .with_beta(0.3)
            .with_epsilon1(10.0);
        let pop = PopulationSpec { clients, cohort, seed: 9 };
        let cfg = RunConfig::new(Method::Chb, params, 40);
        let acfg = AsyncConfig {
            compute: ComputeModel::Uniform { us: 50.0 },
            latency: LatencyModel { fixed_us: 10.0, per_kib_us: 2.0 },
            max_staleness: None,
        };
        let server = Server::with_rule(
            build_server_rule(Method::Chb, &params, DIM),
            vec![0.0; DIM],
        );
        let censor: Arc<dyn CensorRule> =
            Arc::from(build_censor_rule(Method::Chb, &params));
        let mut gl = |_: &[f64]| 0.0;
        let out = run_population(
            &pop, &cfg, &acfg, server, censor, "CHB-pop", &mut make, &mut gl,
        );
        assert!(out.summary.censor_rate() > 0.0, "censor never fired");
        assert!(out.summary.resyncs > 0, "no lazy rematerializations");
        // censored evaluations leave no queue traffic behind
        assert_eq!(
            out.trace.total_comms() as u64,
            out.summary.uplinks,
            "every delivered uplink is accounted once"
        );
    }
}
