//! Execution backends for one federated round — the [`WorkerPool`]
//! trait and its three implementations.
//!
//! The [`engine::RoundEngine`](super::engine::RoundEngine) owns the
//! protocol (scheduling, network accounting, server fold); a pool owns
//! only *where the workers run*:
//!
//! * [`SerialPool`] — in-place on the calling thread, in worker-id
//!   order.  The deterministic reference; what the experiment sweeps
//!   use (no thread overhead at d = 50).
//! * [`ThreadedPool`] — one OS thread per worker speaking the
//!   [`protocol`](super::protocol) channel protocol.  The
//!   deployment-shaped path; right for small M with expensive
//!   gradients (e.g. PJRT backends).
//! * [`RayonPool`] — a work-stealing pool: per round, a set of scoped
//!   OS threads claim workers from a shared queue, so hundreds or
//!   thousands of simulated workers share `available_parallelism()`
//!   cores and a slow worker never idles the rest.  Implemented on
//!   std only (the
//!   external `rayon` crate is deliberately not a dependency — this
//!   image builds hermetically), mirroring rayon's dynamic
//!   load-balancing with an atomic claim counter.
//!
//! All three produce bit-identical [`WorkerRound`] sequences for the
//! same [`RoundInput`]: each worker's computation is pure f64 and the
//! results are re-ordered by worker id before the server folds them,
//! so f64 summation order never depends on thread interleaving.
//! `tests/engine_equivalence.rs` pins this across all four tasks.
//!
//! Every pool also preserves the worker's zero-allocation payload
//! arena: a report's delta is an `Arc` clone of the worker's reusable
//! transmit slot, and because the engine folds and drops each round's
//! reports before the next `run_round` begins, the worker reclaims
//! its buffer every round regardless of which pool carried it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::optim::CensorRule;

use super::protocol::{Downlink, Uplink};
use super::worker::{Worker, WorkerRound, WorkerSnapshot};

/// Everything a worker needs to execute round k (the broadcast,
/// engine-side).  Cheap to clone: the iterate and active set are
/// shared via `Arc` exactly as a real broadcast shares one payload.
#[derive(Clone)]
pub struct RoundInput {
    /// iteration index k (1-based)
    pub k: usize,
    /// θᵏ
    pub theta: Arc<Vec<f64>>,
    /// ‖θᵏ − θ^{k−1}‖², the censor rule's RHS scale
    pub step_sq: f64,
    /// `active[id]`: is worker `id` scheduled this round?
    pub active: Arc<Vec<bool>>,
    /// `force[id]`: must worker `id` transmit uncensored this round?
    /// (fault-plan rejoins re-sync θ̂ through this; empty ⇒ nobody)
    pub force: Arc<Vec<bool>>,
    /// the skip-transmission rule every worker applies
    pub censor: Arc<dyn CensorRule>,
}

/// Execute one round for one worker: scheduled workers run the full
/// Algorithm-1 round (gradient, censor rule, maybe transmit);
/// unscheduled workers only report f_m(θᵏ) for the global-loss
/// instrumentation and leave all censor state untouched.
pub(crate) fn run_worker_round(w: &mut Worker, input: &RoundInput) -> WorkerRound {
    if input.active[w.id] {
        if !input.force.is_empty() && input.force[w.id] {
            w.round_forced(&input.theta, input.step_sq, input.censor.as_ref(), input.k)
        } else {
            w.round(&input.theta, input.step_sq, input.censor.as_ref(), input.k)
        }
    } else {
        w.observe(&input.theta)
    }
}

/// Where the M workers execute.  Implementations must return one
/// [`WorkerRound`] per worker, ordered by worker id, so the server
/// fold (and its f64 sums) is deterministic across backends.
pub trait WorkerPool {
    /// Number of workers M this pool executes.
    fn num_workers(&self) -> usize;

    /// Run round `input` on every worker.
    fn run_round(&mut self, input: &RoundInput) -> Vec<WorkerRound>;

    /// Per-worker lifetime transmission counts S_m (Lemma 2).
    /// Engines call this once, after the last round; threaded pools
    /// shut their workers down here.
    fn per_worker_comms(&mut self) -> Vec<usize>;

    /// Capture every worker's censor-relevant state (ordered by
    /// worker id) for a checkpoint.  Non-destructive: the pool keeps
    /// running afterwards.
    fn snapshots(&mut self) -> Vec<WorkerSnapshot>;

    /// Restore every worker from `snaps` (one per worker, ordered by
    /// worker id) — the inverse of [`WorkerPool::snapshots`], used on
    /// resume and server-kill replay.
    fn restore(&mut self, snaps: &[WorkerSnapshot]);

    /// Short label for logs and benches.
    fn name(&self) -> &'static str;
}

/// Deterministic single-threaded reference pool.
pub struct SerialPool<'a> {
    workers: &'a mut [Worker],
}

impl<'a> SerialPool<'a> {
    /// Pool over borrowed workers (caller keeps post-run access).
    pub fn new(workers: &'a mut [Worker]) -> Self {
        Self { workers }
    }
}

impl WorkerPool for SerialPool<'_> {
    fn num_workers(&self) -> usize {
        self.workers.len()
    }

    fn run_round(&mut self, input: &RoundInput) -> Vec<WorkerRound> {
        self.workers
            .iter_mut()
            .map(|w| run_worker_round(w, input))
            .collect()
    }

    fn per_worker_comms(&mut self) -> Vec<usize> {
        self.workers.iter().map(|w| w.transmissions).collect()
    }

    fn snapshots(&mut self) -> Vec<WorkerSnapshot> {
        self.workers.iter().map(|w| w.snapshot()).collect()
    }

    fn restore(&mut self, snaps: &[WorkerSnapshot]) {
        assert_eq!(snaps.len(), self.workers.len(), "snapshot count");
        for (w, s) in self.workers.iter_mut().zip(snaps) {
            w.restore(s);
        }
    }

    fn name(&self) -> &'static str {
        "serial"
    }
}

/// One OS thread per worker, channel protocol with the engine loop.
pub struct ThreadedPool {
    m: usize,
    down_txs: Vec<mpsc::Sender<Downlink>>,
    up_rx: mpsc::Receiver<Uplink>,
    handles: Vec<JoinHandle<Worker>>,
    /// cached after shutdown so `per_worker_comms` is idempotent
    comms: Option<Vec<usize>>,
}

impl ThreadedPool {
    /// Spawn one OS thread per worker, wired up with channels.
    pub fn new(workers: Vec<Worker>) -> Self {
        let m = workers.len();
        let (up_tx, up_rx) = mpsc::channel::<Uplink>();
        let mut down_txs = Vec::with_capacity(m);
        let mut handles = Vec::with_capacity(m);
        for mut w in workers {
            let (down_tx, down_rx) = mpsc::channel::<Downlink>();
            let up = up_tx.clone();
            handles.push(std::thread::spawn(move || {
                while let Ok(msg) = down_rx.recv() {
                    match msg {
                        Downlink::Round(input) => {
                            let round = run_worker_round(&mut w, &input);
                            if up.send(Uplink { round }).is_err() {
                                break;
                            }
                        }
                        Downlink::Snapshot(tx) => {
                            if tx.send(w.snapshot()).is_err() {
                                break;
                            }
                        }
                        Downlink::Restore(snap, ack) => {
                            w.restore(&snap);
                            if ack.send(()).is_err() {
                                break;
                            }
                        }
                        Downlink::Stop => break,
                    }
                }
                w // hand the worker back for per-worker stats
            }));
            down_txs.push(down_tx);
        }
        Self { m, down_txs, up_rx, handles, comms: None }
    }

    fn shutdown(&mut self) -> Vec<usize> {
        if let Some(c) = &self.comms {
            return c.clone();
        }
        for tx in &self.down_txs {
            let _ = tx.send(Downlink::Stop);
        }
        let mut per = vec![0usize; self.m];
        for h in self.handles.drain(..) {
            let w = h.join().expect("worker thread panicked");
            per[w.id] = w.transmissions;
        }
        self.comms = Some(per.clone());
        per
    }
}

impl WorkerPool for ThreadedPool {
    fn num_workers(&self) -> usize {
        self.m
    }

    fn run_round(&mut self, input: &RoundInput) -> Vec<WorkerRound> {
        for tx in &self.down_txs {
            tx.send(Downlink::Round(input.clone()))
                .expect("worker thread died");
        }
        // collect all M reports, then order by worker id so the fold
        // (and its f64 sums) is deterministic
        let mut rounds: Vec<Option<WorkerRound>> =
            (0..self.m).map(|_| None).collect();
        for _ in 0..self.m {
            let up = self.up_rx.recv().expect("worker thread died");
            let id = up.round.worker;
            rounds[id] = Some(up.round);
        }
        rounds
            .into_iter()
            .map(|r| r.expect("missing worker report"))
            .collect()
    }

    fn per_worker_comms(&mut self) -> Vec<usize> {
        self.shutdown()
    }

    fn snapshots(&mut self) -> Vec<WorkerSnapshot> {
        let (tx, rx) = mpsc::channel();
        for down in &self.down_txs {
            down.send(Downlink::Snapshot(tx.clone()))
                .expect("worker thread died");
        }
        let mut out: Vec<Option<WorkerSnapshot>> =
            (0..self.m).map(|_| None).collect();
        for _ in 0..self.m {
            let s = rx.recv().expect("worker thread died");
            let id = s.id;
            out[id] = Some(s);
        }
        out.into_iter().map(|s| s.expect("missing snapshot")).collect()
    }

    fn restore(&mut self, snaps: &[WorkerSnapshot]) {
        assert_eq!(snaps.len(), self.m, "snapshot count");
        let (ack_tx, ack_rx) = mpsc::channel();
        for s in snaps {
            self.down_txs[s.id]
                .send(Downlink::Restore(s.clone(), ack_tx.clone()))
                .expect("worker thread died");
        }
        for _ in 0..self.m {
            ack_rx.recv().expect("worker thread died");
        }
    }

    fn name(&self) -> &'static str {
        "threaded"
    }
}

impl Drop for ThreadedPool {
    fn drop(&mut self) {
        if self.comms.is_none() {
            let _ = self.shutdown();
        }
    }
}

/// Work-stealing pool: each round, `threads` scoped OS threads claim
/// workers from a shared atomic queue, so M ≫ cores scales and uneven
/// per-worker gradient costs balance dynamically.
///
/// Threads are scoped per round (`std::thread::scope`), not
/// persistent: that costs one spawn/join cycle per thread per round
/// (~tens of µs), which is noise once per-round gradient work is
/// large (many workers or big shards — this pool's target regime) but
/// means [`SerialPool`] stays the right choice for small-M sweeps.
/// The simplicity buys something real: no channel shutdown protocol,
/// no way to deadlock, and worker state is directly inspectable
/// between rounds.
pub struct RayonPool {
    workers: Vec<Mutex<Worker>>,
    threads: usize,
}

impl RayonPool {
    /// Pool sized to the machine (`available_parallelism`).
    pub fn new(workers: Vec<Worker>) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_threads(workers, threads)
    }

    /// Pool with an explicit thread count (tests force real
    /// multi-threading on 1-core CI machines through this).
    pub fn with_threads(workers: Vec<Worker>, threads: usize) -> Self {
        Self {
            workers: workers.into_iter().map(Mutex::new).collect(),
            threads: threads.max(1),
        }
    }
}

impl WorkerPool for RayonPool {
    fn num_workers(&self) -> usize {
        self.workers.len()
    }

    fn run_round(&mut self, input: &RoundInput) -> Vec<WorkerRound> {
        let m = self.workers.len();
        let nthreads = self.threads.min(m).max(1);
        if nthreads == 1 {
            // 1-core images: skip the scope machinery entirely
            return self
                .workers
                .iter_mut()
                .map(|w| {
                    run_worker_round(w.get_mut().expect("poisoned"), input)
                })
                .collect();
        }
        let next = AtomicUsize::new(0);
        let workers = &self.workers;
        let claimed = std::thread::scope(|s| {
            let handles: Vec<_> = (0..nthreads)
                .map(|_| {
                    s.spawn(|| {
                        let mut local = Vec::new();
                        loop {
                            // self-scheduling claim: whichever thread
                            // is free takes the next worker
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= m {
                                break;
                            }
                            let mut w =
                                workers[i].lock().expect("poisoned");
                            local.push((i, run_worker_round(&mut w, input)));
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("pool thread panicked"))
                .collect::<Vec<_>>()
        });
        // scatter back into worker-id order
        let mut out: Vec<Option<WorkerRound>> = (0..m).map(|_| None).collect();
        for (i, r) in claimed {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| r.expect("worker never claimed"))
            .collect()
    }

    fn per_worker_comms(&mut self) -> Vec<usize> {
        self.workers
            .iter_mut()
            .map(|w| w.get_mut().expect("poisoned").transmissions)
            .collect()
    }

    fn snapshots(&mut self) -> Vec<WorkerSnapshot> {
        self.workers
            .iter_mut()
            .map(|w| w.get_mut().expect("poisoned").snapshot())
            .collect()
    }

    fn restore(&mut self, snaps: &[WorkerSnapshot]) {
        assert_eq!(snaps.len(), self.workers.len(), "snapshot count");
        for (w, s) in self.workers.iter_mut().zip(snaps) {
            w.get_mut().expect("poisoned").restore(s);
        }
    }

    fn name(&self) -> &'static str {
        "rayon"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::GradientBackend;
    use crate::optim::NeverCensor;

    struct Lin {
        slope: f64,
    }

    impl GradientBackend for Lin {
        fn dim(&self) -> usize {
            2
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            for (g, t) in grad.iter_mut().zip(theta) {
                *g = self.slope * t;
            }
            theta.iter().map(|t| 0.5 * self.slope * t * t).sum()
        }
    }

    fn workers(m: usize) -> Vec<Worker> {
        (0..m)
            .map(|i| {
                Worker::new(i, Box::new(Lin { slope: 1.0 + i as f64 }))
            })
            .collect()
    }

    fn input(m: usize, active: Vec<bool>) -> RoundInput {
        assert_eq!(active.len(), m);
        RoundInput {
            k: 1,
            theta: Arc::new(vec![1.0, -1.0]),
            step_sq: 0.0,
            active: Arc::new(active),
            force: Arc::new(Vec::new()),
            censor: Arc::new(NeverCensor),
        }
    }

    fn rounds_match(a: &[WorkerRound], b: &[WorkerRound]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.worker, y.worker);
            assert_eq!(x.decision, y.decision);
            assert_eq!(x.delta, y.delta);
            assert_eq!(x.loss.to_bits(), y.loss.to_bits());
        }
    }

    #[test]
    fn all_pools_return_id_ordered_identical_rounds() {
        let m = 5;
        let inp = input(m, vec![true; m]);
        let mut ws = workers(m);
        let serial = SerialPool::new(&mut ws).run_round(&inp);
        let mut threaded = ThreadedPool::new(workers(m));
        let tr = threaded.run_round(&inp);
        let mut rayon = RayonPool::with_threads(workers(m), 3);
        let rr = rayon.run_round(&inp);
        for (i, r) in serial.iter().enumerate() {
            assert_eq!(r.worker, i);
        }
        rounds_match(&serial, &tr);
        rounds_match(&serial, &rr);
        assert_eq!(threaded.per_worker_comms(), vec![1; m]);
        assert_eq!(rayon.per_worker_comms(), vec![1; m]);
    }

    #[test]
    fn inactive_workers_observe_without_state_change() {
        let m = 3;
        let inp = input(m, vec![true, false, true]);
        let mut ws = workers(m);
        let rounds = SerialPool::new(&mut ws).run_round(&inp);
        assert_eq!(rounds[1].decision, crate::optim::CensorDecision::Skip);
        assert_eq!(rounds[1].bits, 0);
        assert!(rounds[1].delta.is_empty());
        // loss is still reported for global instrumentation
        assert!(rounds[1].loss > 0.0);
        // censor state untouched: no transmission recorded
        assert_eq!(ws[1].transmissions, 0);
        assert_eq!(ws[0].transmissions, 1);
        assert_eq!(ws[1].last_transmitted(), &[0.0, 0.0]);
    }

    #[test]
    fn threaded_pool_drop_without_shutdown_does_not_hang() {
        let pool = ThreadedPool::new(workers(4));
        drop(pool);
    }

    #[test]
    fn rayon_pool_handles_more_threads_than_workers() {
        let inp = input(2, vec![true, true]);
        let mut pool = RayonPool::with_threads(workers(2), 16);
        let rounds = pool.run_round(&inp);
        assert_eq!(rounds.len(), 2);
        assert_eq!(rounds[0].worker, 0);
        assert_eq!(rounds[1].worker, 1);
    }
}
