//! Wire protocol for the threaded pool (and byte accounting for the
//! network simulator).
//!
//! Rust channels carry these messages in-process; the `*_bytes`
//! helpers model what a real deployment would serialize, so the byte
//! counters in `net/` stay meaningful.

use std::sync::mpsc::Sender;

use super::pool::RoundInput;
use super::worker::{WorkerRound, WorkerSnapshot};

/// server → worker
#[derive(Clone)]
pub enum Downlink {
    /// start a round: θᵏ, the censor scale, and the active set
    Round(RoundInput),
    /// report censor-relevant state for a checkpoint
    Snapshot(Sender<WorkerSnapshot>),
    /// restore censor-relevant state (resume / server-kill replay);
    /// the worker acks so the engine can block until all M are reset
    Restore(WorkerSnapshot, Sender<()>),
    /// shut the worker thread down
    Stop,
}

/// worker → server
#[derive(Debug)]
pub struct Uplink {
    /// the worker's full round report
    pub round: WorkerRound,
}

/// Serialized size of a broadcast: d·8 (θ) + 8 (step_sq) + 8 (k).
pub fn broadcast_bytes(dim: usize) -> u64 {
    (dim * 8 + 16) as u64
}

/// Serialized size of a dense gradient-delta upload: d·8 + 8 (worker
/// id tag).  Compression-aware uploads are charged from the payload
/// itself instead ([`crate::net::dense_delta_bits`] /
/// [`crate::net::sparse_delta_bits`] via `WorkerRound::bits`, +8 B
/// framing in the engine), so this helper models only the
/// uncompressed baseline.
pub fn uplink_bytes(dim: usize) -> u64 {
    crate::net::dense_delta_bits(dim) / 8 + 8
}

/// Size of a "skip" — censored workers send nothing at all.
pub const SKIP_BYTES: u64 = 0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_model_scales_with_dimension() {
        assert_eq!(broadcast_bytes(0), 16);
        assert_eq!(broadcast_bytes(50), 416);
        assert_eq!(uplink_bytes(50), 408);
        assert!(uplink_bytes(784 * 30 + 61) > uplink_bytes(22));
    }
}
