//! Layer-3 federated runtime: the paper's Algorithm 1.
//!
//! One [`Server`] owns the iterate θ and the running gradient
//! aggregate ∇ᵏ (eq. 5); M [`Worker`]s own their shards, their last
//! *transmitted* gradient ∇f_m(θ̂_m), and a gradient backend (pure
//! rust or PJRT).  A round is:
//!
//! 1. the [`Participation`] schedule picks this round's active set,
//! 2. server broadcasts θᵏ to the scheduled workers,
//! 3. each scheduled worker computes ∇f_m(θᵏ), forms δ∇_m^k, applies
//!    the censor rule (8), and either uploads δ∇_m^k or stays silent
//!    (unscheduled workers are treated as censored),
//! 4. server folds received deltas into ∇ᵏ and steps θ via the
//!    method's update rule (eq. 4).
//!
//! One [`engine::RoundEngine`] runs that pipeline over any
//! [`WorkerPool`]: [`SerialPool`] (deterministic reference),
//! [`ThreadedPool`] (one OS thread per worker, channel protocol — the
//! deployment-shaped path), or [`RayonPool`] (work-stealing, scales to
//! thousands of simulated workers).  All pools produce bit-identical
//! traces; `tests/engine_equivalence.rs` and a property test pin that.
//!
//! Beside the synchronous round engines sits a second execution
//! *regime*: the [`async_engine`] replaces lockstep rounds with a
//! discrete-event virtual clock — per-worker compute-time models,
//! latency-ordered message delivery, and a server that folds deltas
//! as they arrive, stale by `s` steps.  With zero latency and uniform
//! compute it reduces bit-identically to the serial engine
//! (`tests/async_engine.rs`).
//!
//! Orthogonal to both axes is the *gradient-sampling* layer
//! (`data::batch`): a [`Worker`] built with
//! [`Worker::with_batching`] evaluates row-subset minibatch gradients
//! per its `BatchSchedule` (full shard / fixed minibatch / growing
//! batch), while still reporting the full-shard loss so traces stay
//! comparable.  `BatchSchedule::Full` is bit-identical to the legacy
//! path on every engine (`tests/batch_equivalence.rs`).
//!
//! Above the resident engines sits the *population* layer
//! ([`population`]): M up to 10⁶ simulated clients at 8 bytes each, a
//! pure-function [`CohortSampler`] that draws each round's cohort in
//! O(cohort), lazy worker materialization with exact censor-reference
//! resync, and streaming O(model) aggregation off the timer-wheel
//! event queue — per-client telemetry collapses into a bounded
//! [`PopulationSummary`](crate::metrics::PopulationSummary).
//!
//! Fault tolerance cuts across every engine: a seeded [`FaultPlan`]
//! forces workers down (observe-only rounds — telescope-safe by
//! eq. 5) and back up (a forced uncensored transmit re-syncs θ̂), and
//! kills/restores the server at chosen steps; the `_ctx` engine
//! variants take an [`engine::RunContext`] that adds periodic atomic
//! checkpoints and bit-identical resume (`tests/checkpoint_resume.rs`).

pub mod async_engine;
pub mod engine;
pub mod fault;
pub mod participation;
pub mod pool;
pub mod population;
pub mod protocol;
pub mod server;
pub mod worker;

#[allow(deprecated)] // the shim stays importable from its old path
pub use async_engine::run_async;
pub use async_engine::{
    run_async_detailed, run_async_with_rules, run_async_with_rules_ctx,
    AsyncConfig, AsyncOutcome, ComputeModel,
};
pub use engine::{
    run_engine, run_engine_with_rules, run_engine_with_rules_ctx, run_rayon,
    run_serial, run_threaded, run_with_rules, run_with_rules_ctx,
    AsyncSummary, EngineKind, EngineRun, RoundEngine, RunConfig, RunContext,
    StopRule,
};
pub use fault::FaultPlan;
pub use participation::{CohortSampler, Participation, Schedule};
pub use population::{run_population, PopulationOutcome, PopulationSpec};
pub use pool::{RayonPool, RoundInput, SerialPool, ThreadedPool, WorkerPool};
pub use server::Server;
pub use worker::{
    GradientBackend, LocalStepCfg, RustBackend, Worker, WorkerRound,
    WorkerSnapshot,
};
