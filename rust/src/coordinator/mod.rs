//! Layer-3 federated runtime: the paper's Algorithm 1.
//!
//! One [`Server`] owns the iterate θ and the running gradient
//! aggregate ∇ᵏ (eq. 5); M [`Worker`]s own their shards, their last
//! *transmitted* gradient ∇f_m(θ̂_m), and a gradient backend (pure
//! rust or PJRT).  A round is:
//!
//! 1. server broadcasts θᵏ (M downlink messages),
//! 2. each worker computes ∇f_m(θᵏ), forms δ∇_m^k, applies the censor
//!    rule (8), and either uploads δ∇_m^k or stays silent,
//! 3. server folds received deltas into ∇ᵏ and steps θ via the
//!    method's update rule (eq. 4).
//!
//! Engines: [`engine::run_serial`] (deterministic, used by the sweeps)
//! and [`engine::run_threaded`] (one OS thread per worker, channel
//! protocol — the deployment-shaped path).  Both produce identical
//! traces; a property test pins that.

pub mod engine;
pub mod protocol;
pub mod server;
pub mod worker;

pub use engine::{run_serial, run_threaded, RunConfig, StopRule};
pub use server::Server;
pub use worker::{GradientBackend, RustBackend, Worker, WorkerRound};
