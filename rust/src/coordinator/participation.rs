//! Participation scheduling — which workers even join a round.
//!
//! The paper's protocol is full participation: every worker evaluates
//! its gradient every round and only the *uplink* is censored.  Real
//! federated deployments additionally select clients per round (the
//! per-round worker selection of LAG-style schemes, or device
//! availability at production scale).  This layer generates, per
//! round, the *active set* of scheduled workers; workers outside the
//! set behave exactly like censored workers from the server's point of
//! view — eq. (5) simply carries their stale term, which the protocol
//! tolerates by design.
//!
//! Scheduling is engine-side: the same seeded [`Schedule`] drives the
//! serial, threaded, and rayon pools, so a `(policy, seed)` pair
//! reproduces the identical participant sets — and therefore the
//! identical trace — on every execution backend.  A property test
//! pins this.

use std::collections::HashMap;

use crate::rng::{SplitMix64, Xoshiro256};

/// Per-round client-participation policy.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum Participation {
    /// Every worker, every round — the paper's setting.
    #[default]
    Full,
    /// Uniform random sampling without replacement: each round,
    /// `round(frac·M)` workers (clamped to [1, M]) are drawn by a
    /// seeded partial Fisher–Yates shuffle.
    UniformSample { frac: f64, seed: u64 },
    /// Deadline-based: each round every worker draws a simulated
    /// compute time from Exp(1) (mean 1.0, i.e. `timeout` is in units
    /// of the mean round time); workers slower than `timeout` miss
    /// the round and are treated as censored.  If the whole cohort
    /// misses, the single fastest worker still reports, so a round is
    /// never empty.
    Straggler { timeout: f64, seed: u64 },
}

impl Participation {
    /// Short label for logs and CSV filenames.
    pub fn name(&self) -> &'static str {
        match self {
            Participation::Full => "full",
            Participation::UniformSample { .. } => "sample",
            Participation::Straggler { .. } => "straggler",
        }
    }
}

/// Stateful per-run schedule: owns the seeded RNG stream so successive
/// rounds draw successive participant sets deterministically.
pub struct Schedule {
    policy: Participation,
    rng: Xoshiro256,
}

impl Schedule {
    /// Schedule for one run of `policy` (seeds its own RNG stream).
    pub fn new(policy: Participation) -> Self {
        let seed = match policy {
            Participation::Full => 0,
            Participation::UniformSample { seed, .. }
            | Participation::Straggler { seed, .. } => seed,
        };
        Self { policy, rng: Xoshiro256::new(seed) }
    }

    /// The policy this schedule draws from.
    pub fn policy(&self) -> Participation {
        self.policy
    }

    /// Raw RNG state (checkpoint capture).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the RNG stream from a captured [`Schedule::rng_state`]
    /// so the next `active_set` draw continues bit-identically.
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Xoshiro256::from_state(s);
    }

    /// The active set for round `k` over `m` workers: `active[id]` is
    /// true iff worker `id` is scheduled.  Always has ≥ 1 worker.
    pub fn active_set(&mut self, _k: usize, m: usize) -> Vec<bool> {
        match self.policy {
            Participation::Full => vec![true; m],
            Participation::UniformSample { frac, .. } => {
                let count = ((frac * m as f64).round() as usize).clamp(1, m);
                if count == m {
                    return vec![true; m];
                }
                // partial Fisher–Yates: after `count` swaps the prefix
                // is a uniform sample without replacement
                let mut ids: Vec<usize> = (0..m).collect();
                for i in 0..count {
                    let j = i + self.rng.next_below((m - i) as u64) as usize;
                    ids.swap(i, j);
                }
                let mut active = vec![false; m];
                for &id in &ids[..count] {
                    active[id] = true;
                }
                active
            }
            Participation::Straggler { timeout, .. } => {
                let mut active = vec![false; m];
                let mut fastest = (0usize, f64::INFINITY);
                let mut any = false;
                for (id, slot) in active.iter_mut().enumerate() {
                    // Exp(1) compute time via inverse CDF
                    let t = -(1.0 - self.rng.next_f64()).ln();
                    if t < fastest.1 {
                        fastest = (id, t);
                    }
                    if t <= timeout {
                        *slot = true;
                        any = true;
                    }
                }
                if !any && m > 0 {
                    active[fastest.0] = true;
                }
                active
            }
        }
    }
}

/// Population → cohort sampler for the million-client engine.
///
/// Unlike [`Schedule`] (a stateful RNG stream over a resident
/// `Vec<bool>` of all M workers — O(M) per round), this sampler is a
/// **pure function of (round, seed)**: each round reseeds its own
/// generator, and the draw runs a *sparse* partial Fisher–Yates that
/// tracks only displaced entries in a hash map — O(cohort) time and
/// memory even at M = 10⁶.  Purity is what keeps population traces
/// engine-independent: any engine (or a resumed run) can re-derive
/// round k's cohort without replaying rounds 1..k−1.
#[derive(Clone, Copy, Debug)]
pub struct CohortSampler {
    seed: u64,
}

impl CohortSampler {
    /// Sampler for one population run.
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    /// The round-`round` cohort: `cohort` distinct client ids drawn
    /// uniformly without replacement from `0..clients`, in draw order.
    pub fn draw(&self, round: u64, cohort: u64, clients: u64) -> Vec<u64> {
        assert!(
            cohort >= 1 && cohort <= clients,
            "cohort {cohort} outside [1, {clients}]"
        );
        // per-round stream: SplitMix64 whitens (seed, round) into the
        // xoshiro seed so consecutive rounds are decorrelated
        let mut sm = SplitMix64::new(
            self.seed
                .wrapping_add(round.wrapping_mul(0xA24B_AED4_963E_E407)),
        );
        let mut rng = Xoshiro256::new(sm.next_u64());
        // sparse partial Fisher–Yates over the virtual array a[x] = x:
        // only displaced slots are materialized, so the prefix of a
        // full M-element shuffle costs O(cohort), not O(M)
        let mut displaced: HashMap<u64, u64> =
            HashMap::with_capacity(2 * cohort as usize);
        let mut out = Vec::with_capacity(cohort as usize);
        for i in 0..cohort {
            let j = i + rng.next_below(clients - i);
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            out.push(vj);
            // a[j] ← old a[i]; slot i is never read again
            displaced.insert(j, vi);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count(active: &[bool]) -> usize {
        active.iter().filter(|&&a| a).count()
    }

    #[test]
    fn full_schedules_everyone_every_round() {
        let mut s = Schedule::new(Participation::Full);
        for k in 1..=5 {
            assert_eq!(s.active_set(k, 7), vec![true; 7]);
        }
    }

    #[test]
    fn uniform_sample_has_exact_count_and_is_seeded() {
        let policy = Participation::UniformSample { frac: 0.5, seed: 42 };
        let mut a = Schedule::new(policy);
        let mut b = Schedule::new(policy);
        let mut saw_different_rounds = false;
        let mut prev: Option<Vec<bool>> = None;
        for k in 1..=20 {
            let sa = a.active_set(k, 8);
            let sb = b.active_set(k, 8);
            assert_eq!(sa, sb, "same seed must reproduce round {k}");
            assert_eq!(count(&sa), 4, "round(0.5·8) workers");
            if prev.as_ref().is_some_and(|p| p != &sa) {
                saw_different_rounds = true;
            }
            prev = Some(sa);
        }
        assert!(saw_different_rounds, "sampling should vary across rounds");
    }

    #[test]
    fn uniform_sample_clamps_to_at_least_one_and_at_most_m() {
        let mut lo = Schedule::new(Participation::UniformSample {
            frac: 0.0,
            seed: 1,
        });
        assert_eq!(count(&lo.active_set(1, 5)), 1);
        let mut hi = Schedule::new(Participation::UniformSample {
            frac: 2.0,
            seed: 1,
        });
        assert_eq!(count(&hi.active_set(1, 5)), 5);
    }

    #[test]
    fn straggler_rounds_are_never_empty() {
        // timeout 0: nobody makes the deadline, the fastest still reports
        let mut s = Schedule::new(Participation::Straggler {
            timeout: 0.0,
            seed: 9,
        });
        for k in 1..=10 {
            assert_eq!(count(&s.active_set(k, 6)), 1, "round {k}");
        }
    }

    #[test]
    fn straggler_timeout_monotone_in_expectation() {
        let m = 16;
        let rounds = 200;
        let total = |timeout: f64| -> usize {
            let mut s =
                Schedule::new(Participation::Straggler { timeout, seed: 3 });
            (1..=rounds).map(|k| count(&s.active_set(k, m))).sum()
        };
        let tight = total(0.2);
        let loose = total(2.0);
        assert!(
            tight < loose,
            "tight deadline {tight} should schedule fewer than loose {loose}"
        );
        // Exp(1): P(t ≤ 2) ≈ 0.86 — loose deadline keeps most workers
        assert!(loose > rounds * m / 2);
    }

    #[test]
    fn straggler_is_seeded_and_deterministic() {
        let policy = Participation::Straggler { timeout: 0.8, seed: 77 };
        let mut a = Schedule::new(policy);
        let mut b = Schedule::new(policy);
        for k in 1..=30 {
            assert_eq!(a.active_set(k, 9), b.active_set(k, 9), "round {k}");
        }
    }

    #[test]
    fn cohort_draw_is_distinct_and_in_range() {
        let s = CohortSampler::new(7);
        for round in 1..=20u64 {
            let c = s.draw(round, 50, 1_000);
            assert_eq!(c.len(), 50);
            assert!(c.iter().all(|&id| id < 1_000));
            let mut sorted = c.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 50, "round {round}: duplicate client");
        }
    }

    #[test]
    fn cohort_draw_is_a_pure_function_of_round_and_seed() {
        let s = CohortSampler::new(123);
        // same (round, seed) out of order ⇒ identical cohorts — no
        // hidden stream state between rounds
        let r5 = s.draw(5, 10, 10_000);
        let _ = s.draw(9, 10, 10_000);
        let _ = s.draw(1, 10, 10_000);
        assert_eq!(s.draw(5, 10, 10_000), r5);
        assert_eq!(CohortSampler::new(123).draw(5, 10, 10_000), r5);
        // different rounds / seeds draw different cohorts
        assert_ne!(s.draw(6, 10, 10_000), r5);
        assert_ne!(CohortSampler::new(124).draw(5, 10, 10_000), r5);
    }

    #[test]
    fn cohort_equal_to_population_is_a_permutation() {
        let s = CohortSampler::new(3);
        let mut c = s.draw(1, 64, 64);
        c.sort_unstable();
        assert_eq!(c, (0..64).collect::<Vec<u64>>());
    }

    #[test]
    fn cohort_draw_is_roughly_uniform_over_clients() {
        // every client of a small population should appear across
        // enough rounds (coverage, not exact balance)
        let s = CohortSampler::new(11);
        let mut seen = vec![false; 100];
        for round in 1..=200u64 {
            for id in s.draw(round, 10, 100) {
                seen[id as usize] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "some client never sampled");
    }
}
