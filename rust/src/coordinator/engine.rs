//! Round engines: serial (deterministic reference) and threaded
//! (one OS thread per worker, the deployment-shaped path).
//!
//! Both engines run the identical protocol and produce identical
//! traces — `tests/engine_equivalence.rs` pins this.  The serial
//! engine is what the experiment sweeps use (no thread overhead at
//! d = 50); the threaded engine is what `chb-fed run --engine
//! threaded` and the e2e example use.

use std::sync::mpsc;
use std::sync::Arc;

use crate::metrics::{IterStat, Trace};
use crate::net::{Direction, SimNetwork};
use crate::optim::{self, CensorDecision, Method, MethodParams};

use super::protocol::{broadcast_bytes, Downlink, Uplink};
use super::server::Server;
use super::worker::Worker;

/// When to stop a run (checked after every iteration).
#[derive(Clone, Copy, Debug)]
pub enum StopRule {
    /// run exactly `max_iters`
    MaxIters,
    /// stop once f(θᵏ) − f* < tol (the Tables I/II protocol)
    ObjErrBelow { f_star: f64, tol: f64 },
    /// stop once ‖∇ᵏ‖² < tol (nonconvex runs)
    AggGradBelow { tol: f64 },
}

/// Full description of one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub method: Method,
    pub params: MethodParams,
    pub max_iters: usize,
    pub stop: StopRule,
    /// record the O(K·M) per-worker transmit map (Fig. 1)
    pub record_comm_map: bool,
    /// uplink drop probability (failure injection; 0 = paper setting)
    pub drop_prob: f64,
    pub drop_seed: u64,
}

impl RunConfig {
    pub fn new(method: Method, params: MethodParams, max_iters: usize) -> Self {
        Self {
            method,
            params,
            max_iters,
            stop: StopRule::MaxIters,
            record_comm_map: false,
            drop_prob: 0.0,
            drop_seed: 0,
        }
    }

    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }

    pub fn with_comm_map(mut self) -> Self {
        self.record_comm_map = true;
        self
    }

    pub fn with_drops(mut self, prob: f64, seed: u64) -> Self {
        self.drop_prob = prob;
        self.drop_seed = seed;
        self
    }

    fn should_stop(&self, stat: &IterStat) -> bool {
        match self.stop {
            StopRule::MaxIters => false,
            StopRule::ObjErrBelow { f_star, tol } => stat.loss - f_star < tol,
            StopRule::AggGradBelow { tol } => stat.agg_grad_sq < tol,
        }
    }
}

/// Shared per-iteration bookkeeping for both engines.
fn fold_round(
    server: &mut Server,
    net: &mut SimNetwork,
    cfg: &RunConfig,
    rounds: &mut Vec<super::worker::WorkerRound>,
    trace: &mut Trace,
) -> IterStat {
    let dim = server.dim();
    // network accounting + failure injection; payload size comes from
    // the worker (compression-aware), +8 B worker-id framing
    let mut up_bytes = Vec::with_capacity(rounds.len());
    for r in rounds.iter_mut() {
        if r.decision == CensorDecision::Transmit {
            let nbytes = r.bits.div_ceil(8) + 8;
            let delivered = net.send(Direction::Up, r.worker, nbytes);
            up_bytes.push(nbytes);
            if !delivered {
                // dropped uplink: the worker believes it transmitted
                // (its θ̂_m advanced) but the server never folds the
                // delta — eq. (5) simply carries the stale term.
                r.decision = CensorDecision::Skip;
                r.delta.clear();
            }
        }
    }
    net.advance_round(broadcast_bytes(dim), &up_bytes);

    if cfg.record_comm_map {
        let mut row = vec![false; rounds.len()];
        for r in rounds.iter() {
            row[r.worker] = r.decision == CensorDecision::Transmit;
        }
        trace.comm_map.push(row);
    }

    let bits_round: u64 = rounds
        .iter()
        .filter(|r| r.decision == CensorDecision::Transmit)
        .map(|r| r.bits)
        .sum();
    let out = server.apply_round(rounds);
    let prev = trace.iters.last();
    IterStat {
        k: out.k,
        loss: out.loss,
        comms_round: out.transmitted,
        comms_cum: prev.map_or(0, |s| s.comms_cum) + out.transmitted,
        agg_grad_sq: out.agg_grad_sq,
        step_sq: out.step_sq,
        bits_cum: prev.map_or(0, |s| s.bits_cum) + bits_round,
    }
}

/// Deterministic single-threaded engine.
pub fn run_serial(
    workers: &mut [Worker],
    cfg: &RunConfig,
    theta0: Vec<f64>,
) -> Trace {
    let censor = optim::method::build_censor_rule(cfg.method, &cfg.params);
    let mut server = Server::new(cfg.method, &cfg.params, theta0);
    let mut net =
        SimNetwork::new(workers.len()).with_drops(cfg.drop_prob, cfg.drop_seed);
    let mut trace = Trace::new(cfg.method.name());
    let dim = server.dim();

    for k in 1..=cfg.max_iters {
        let step_sq = server.theta_step_sq();
        let theta = server.theta.clone();
        let mut rounds = Vec::with_capacity(workers.len());
        for w in workers.iter_mut() {
            net.send(Direction::Down, w.id, broadcast_bytes(dim));
            rounds.push(w.round(&theta, step_sq, censor.as_ref(), k));
        }
        let stat = fold_round(&mut server, &mut net, cfg, &mut rounds, &mut trace);
        let stop = cfg.should_stop(&stat);
        trace.iters.push(stat);
        if stop {
            break;
        }
    }
    trace.per_worker_comms = workers.iter().map(|w| w.transmissions).collect();
    trace
}

/// Threaded engine: each worker runs on its own OS thread, speaking
/// the `protocol::Downlink`/`Uplink` channel protocol with the server
/// loop on the calling thread.
pub fn run_threaded(
    workers: Vec<Worker>,
    cfg: &RunConfig,
    theta0: Vec<f64>,
) -> Trace {
    let m = workers.len();
    let censor: Arc<dyn crate::optim::CensorRule> = Arc::from(
        optim::method::build_censor_rule(cfg.method, &cfg.params),
    );
    let mut server = Server::new(cfg.method, &cfg.params, theta0);
    let mut net =
        SimNetwork::new(m).with_drops(cfg.drop_prob, cfg.drop_seed);
    let mut trace = Trace::new(cfg.method.name());
    let dim = server.dim();

    // spawn workers
    let (up_tx, up_rx) = mpsc::channel::<Uplink>();
    let mut down_txs = Vec::with_capacity(m);
    let mut handles = Vec::with_capacity(m);
    for mut w in workers {
        let (down_tx, down_rx) = mpsc::channel::<Downlink>();
        let up = up_tx.clone();
        let censor = Arc::clone(&censor);
        handles.push(std::thread::spawn(move || {
            while let Ok(msg) = down_rx.recv() {
                match msg {
                    Downlink::Broadcast { k, theta, step_sq } => {
                        let round =
                            w.round(&theta, step_sq, censor.as_ref(), k);
                        if up.send(Uplink { round }).is_err() {
                            break;
                        }
                    }
                    Downlink::Stop => break,
                }
            }
            w // hand the worker back for per-worker stats
        }));
        down_txs.push(down_tx);
    }
    drop(up_tx);

    for k in 1..=cfg.max_iters {
        let step_sq = server.theta_step_sq();
        let theta = Arc::new(server.theta.clone());
        for (id, tx) in down_txs.iter().enumerate() {
            net.send(Direction::Down, id, broadcast_bytes(dim));
            tx.send(Downlink::Broadcast { k, theta: Arc::clone(&theta), step_sq })
                .expect("worker thread died");
        }
        // collect all M reports, then order by worker id so the fold
        // (and its f64 sums) is deterministic
        let mut rounds: Vec<Option<super::worker::WorkerRound>> =
            (0..m).map(|_| None).collect();
        for _ in 0..m {
            let up = up_rx.recv().expect("worker thread died");
            let id = up.round.worker;
            rounds[id] = Some(up.round);
        }
        let mut rounds: Vec<_> =
            rounds.into_iter().map(|r| r.expect("missing worker")).collect();
        let stat = fold_round(&mut server, &mut net, cfg, &mut rounds, &mut trace);
        let stop = cfg.should_stop(&stat);
        trace.iters.push(stat);
        if stop {
            break;
        }
    }
    for tx in &down_txs {
        let _ = tx.send(Downlink::Stop);
    }
    let mut per_worker = vec![0usize; m];
    for h in handles {
        let w = h.join().expect("worker panicked");
        per_worker[w.id] = w.transmissions;
    }
    trace.per_worker_comms = per_worker;
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::{GradientBackend, Worker};
    use crate::optim::Method;

    /// f_m(θ) = ½ c_m ‖θ − t_m‖²  — strongly convex toy problem.
    struct Quad {
        c: f64,
        t: Vec<f64>,
    }

    impl GradientBackend for Quad {
        fn dim(&self) -> usize {
            self.t.len()
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let mut l = 0.0;
            for i in 0..theta.len() {
                let d = theta[i] - self.t[i];
                grad[i] = self.c * d;
                l += d * d;
            }
            0.5 * self.c * l
        }
    }

    fn quad_workers(dim: usize, m: usize) -> Vec<Worker> {
        (0..m)
            .map(|i| {
                let t: Vec<f64> =
                    (0..dim).map(|j| ((i + j) % 5) as f64 - 2.0).collect();
                Worker::new(
                    i,
                    Box::new(Quad { c: 1.0 + i as f64 * 0.3, t }),
                )
            })
            .collect()
    }

    fn total_c(m: usize) -> f64 {
        (0..m).map(|i| 1.0 + i as f64 * 0.3).sum()
    }

    /// Analytic minimum of Σ ½c_m‖θ−t_m‖²: θ* = Σc_m t_m / Σc_m.
    fn quad_f_star(dim: usize, m: usize) -> f64 {
        let cs: Vec<f64> = (0..m).map(|i| 1.0 + i as f64 * 0.3).collect();
        let ts: Vec<Vec<f64>> = (0..m)
            .map(|i| (0..dim).map(|j| ((i + j) % 5) as f64 - 2.0).collect())
            .collect();
        let csum: f64 = cs.iter().sum();
        let theta_star: Vec<f64> = (0..dim)
            .map(|j| {
                (0..m).map(|i| cs[i] * ts[i][j]).sum::<f64>() / csum
            })
            .collect();
        (0..m)
            .map(|i| {
                0.5 * cs[i]
                    * (0..dim)
                        .map(|j| (theta_star[j] - ts[i][j]).powi(2))
                        .sum::<f64>()
            })
            .sum()
    }

    #[test]
    fn gd_converges_on_quadratic() {
        let (dim, m) = (4, 3);
        let mut ws = quad_workers(dim, m);
        let alpha = 1.0 / total_c(m);
        let cfg = RunConfig::new(Method::Gd, MethodParams::new(alpha), 200);
        let trace = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        assert_eq!(trace.iterations(), 200);
        // GD transmits every worker every round
        assert_eq!(trace.total_comms(), 200 * m);
        let f_star = quad_f_star(dim, m);
        let first = trace.iters.first().unwrap().loss - f_star;
        let last = trace.final_loss() - f_star;
        assert!(last < first * 1e-6, "no convergence: {first} → {last}");
    }

    #[test]
    fn chb_converges_with_fewer_comms_than_hb() {
        let (dim, m) = (6, 5);
        let alpha = 1.0 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let mut ws = quad_workers(dim, m);
        let chb =
            run_serial(&mut ws, &RunConfig::new(Method::Chb, p, 300), vec![0.0; dim]);
        let mut ws = quad_workers(dim, m);
        let hb =
            run_serial(&mut ws, &RunConfig::new(Method::Hb, p, 300), vec![0.0; dim]);
        let f_star = quad_f_star(dim, m);
        assert!(
            chb.final_loss() - f_star
                < (hb.iters.first().unwrap().loss - f_star) * 1e-6
        );
        assert!(
            chb.total_comms() < hb.total_comms(),
            "CHB {} vs HB {}",
            chb.total_comms(),
            hb.total_comms()
        );
    }

    #[test]
    fn epsilon_zero_chb_equals_hb_trace() {
        let (dim, m) = (3, 4);
        let alpha = 0.5 / total_c(m);
        let p = MethodParams::new(alpha).with_beta(0.3).with_epsilon1(0.0);
        let mut ws = quad_workers(dim, m);
        let chb =
            run_serial(&mut ws, &RunConfig::new(Method::Chb, p, 50), vec![1.0; dim]);
        let mut ws = quad_workers(dim, m);
        let hb =
            run_serial(&mut ws, &RunConfig::new(Method::Hb, p, 50), vec![1.0; dim]);
        for (a, b) in chb.iters.iter().zip(&hb.iters) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "k={}", a.k);
        }
    }

    #[test]
    fn threaded_matches_serial_bit_for_bit() {
        let (dim, m) = (5, 7);
        let alpha = 0.8 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 120).with_comm_map();
        let mut ws = quad_workers(dim, m);
        let serial = run_serial(&mut ws, &cfg, vec![0.5; dim]);
        let threaded = run_threaded(quad_workers(dim, m), &cfg, vec![0.5; dim]);
        assert_eq!(serial.iterations(), threaded.iterations());
        for (a, b) in serial.iters.iter().zip(&threaded.iters) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss k={}", a.k);
            assert_eq!(a.comms_cum, b.comms_cum, "comms k={}", a.k);
        }
        assert_eq!(serial.per_worker_comms, threaded.per_worker_comms);
        assert_eq!(serial.comm_map, threaded.comm_map);
    }

    #[test]
    fn stop_rule_obj_err_halts_early() {
        let (dim, m) = (4, 3);
        let mut ws = quad_workers(dim, m);
        let alpha = 1.0 / total_c(m);
        let f_star = quad_f_star(dim, m);
        let cfg = RunConfig::new(Method::Hb, MethodParams::new(alpha), 10_000)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-6 });
        let trace = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        assert!(trace.iterations() < 10_000, "stop rule never fired");
        assert!(trace.final_loss() - f_star < 1e-6);
    }

    #[test]
    fn dropped_uplinks_do_not_crash_and_counts_reflect_delivery() {
        let (dim, m) = (4, 6);
        let alpha = 0.5 / total_c(m);
        let p = MethodParams::new(alpha).with_beta(0.2).with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 100).with_drops(0.2, 99);
        let mut ws = quad_workers(dim, m);
        // start far from the optimum so the drop-induced bias (which is
        // O(stale-delta), independent of θ⁰) stays below the initial error
        let trace = run_serial(&mut ws, &cfg, vec![10.0; dim]);
        // per-worker counters count *attempts*; trace counts deliveries
        let attempts: usize = trace.per_worker_comms.iter().sum();
        assert!(trace.total_comms() <= attempts);
        // Dropped deltas leave the aggregate permanently stale, so the
        // run converges to a *biased* point — but it must stay bounded
        // and still improve on the start.
        let f_star = quad_f_star(dim, m);
        let first = trace.iters.first().unwrap().loss - f_star;
        let last = trace.final_loss() - f_star;
        assert!(last.is_finite(), "diverged under drops");
        assert!(last < first, "no progress at all: {first} → {last}");
    }
}
