//! The round engine: one protocol loop, pluggable execution backends.
//!
//! [`RoundEngine`] owns the per-round pipeline (participation
//! scheduling → broadcast accounting → worker dispatch → uplink
//! accounting/failure injection → server fold → stop rule) and is
//! generic over a [`WorkerPool`]: serial (deterministic reference),
//! threaded (one OS thread per worker, the deployment-shaped path),
//! or rayon (work-stealing, scales to thousands of simulated
//! workers).  All pools run the identical protocol and produce
//! identical traces — `tests/engine_equivalence.rs` pins this.
//!
//! The four historical entry points ([`run_serial`], [`run_threaded`],
//! [`run_rayon`], and the async engine's `run_async_detailed`) are
//! thin wrappers over one [`EngineKind`] dispatch ([`run_engine`]);
//! new code should describe a run as a [`crate::spec::RunSpec`] and
//! go through [`crate::spec::Session`], which routes here.

use std::sync::Arc;

use crate::checkpoint::{
    Checkpoint, CheckpointError, CheckpointPolicy, LinkState, NetState,
    ServerState, WorkerState, CHECKPOINT_VERSION,
};
use crate::metrics::{IterStat, Trace};
use crate::net::{
    downlink_frame_bytes, Direction, DownlinkChannel, DownlinkSpec,
    LinkStats, SimNetwork,
};
use crate::optim::{self, CensorDecision, CensorRule, Method, MethodParams};

use super::async_engine::{run_async_with_rules_ctx, AsyncConfig};
use super::fault::FaultPlan;
use super::participation::{Participation, Schedule};
use super::pool::{RayonPool, RoundInput, SerialPool, ThreadedPool, WorkerPool};
use super::server::Server;
use super::worker::{Worker, WorkerSnapshot};

/// When to stop a run (checked after every iteration).
#[derive(Clone, Copy, Debug)]
pub enum StopRule {
    /// run exactly `max_iters`
    MaxIters,
    /// stop once f(θᵏ) − f* < tol (the Tables I/II protocol)
    ObjErrBelow { f_star: f64, tol: f64 },
    /// stop once ‖∇ᵏ‖² < tol (nonconvex runs)
    AggGradBelow { tol: f64 },
}

/// Full description of one run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// which of the four paper algorithms to run
    pub method: Method,
    /// (α, β, ε₁)
    pub params: MethodParams,
    /// iteration budget (server steps, in every engine)
    pub max_iters: usize,
    /// early-exit rule checked after every iteration
    pub stop: StopRule,
    /// which workers join each round (default: the paper's full
    /// participation)
    pub participation: Participation,
    /// record the O(K·M) per-worker transmit map (Fig. 1)
    pub record_comm_map: bool,
    /// uplink drop probability (failure injection; 0 = paper setting)
    pub drop_prob: f64,
    /// seed for the drop stream
    pub drop_seed: u64,
    /// seeded worker crash/rejoin + server-kill schedule (default:
    /// none — the paper setting)
    pub faults: FaultPlan,
    /// broadcast channel: `None` charges the uncompressed 64·d bits
    /// per scheduled worker (bit-identical traces to the pre-downlink
    /// code); the other variants compress the broadcast delta through
    /// the packed codec stack (sync engines only)
    pub downlink: DownlinkSpec,
}

impl RunConfig {
    /// Paper defaults: run to `max_iters`, full participation, no
    /// comm-map recording, no failure injection.
    pub fn new(method: Method, params: MethodParams, max_iters: usize) -> Self {
        Self {
            method,
            params,
            max_iters,
            stop: StopRule::MaxIters,
            participation: Participation::Full,
            record_comm_map: false,
            drop_prob: 0.0,
            drop_seed: 0,
            faults: FaultPlan::default(),
            downlink: DownlinkSpec::None,
        }
    }

    /// Replace the stop rule (builder form).
    pub fn with_stop(mut self, stop: StopRule) -> Self {
        self.stop = stop;
        self
    }

    /// Replace the participation policy (builder form).
    pub fn with_participation(mut self, p: Participation) -> Self {
        self.participation = p;
        self
    }

    /// Record the O(K·M) per-worker transmit map (Fig. 1).
    pub fn with_comm_map(mut self) -> Self {
        self.record_comm_map = true;
        self
    }

    /// Inject seeded uplink drops with probability `prob`.
    pub fn with_drops(mut self, prob: f64, seed: u64) -> Self {
        self.drop_prob = prob;
        self.drop_seed = seed;
        self
    }

    /// Inject a seeded worker crash/rejoin + server-kill schedule.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Route the broadcast through a downlink codec (builder form).
    pub fn with_downlink(mut self, downlink: DownlinkSpec) -> Self {
        self.downlink = downlink;
        self
    }

    pub(crate) fn should_stop(&self, stat: &IterStat) -> bool {
        match self.stop {
            StopRule::MaxIters => false,
            StopRule::ObjErrBelow { f_star, tol } => stat.loss - f_star < tol,
            StopRule::AggGradBelow { tol } => stat.agg_grad_sq < tol,
        }
    }
}

/// Per-iteration bookkeeping shared by every pool: uplink accounting +
/// failure injection, comm-map recording, server fold.
fn fold_round(
    server: &mut Server,
    net: &mut SimNetwork,
    cfg: &RunConfig,
    rounds: &mut [super::worker::WorkerRound],
    trace: &mut Trace,
    down_bytes: u64,
    down_bits_round: u64,
) -> IterStat {
    // network accounting + failure injection; payload size comes from
    // the worker (compression-aware), +8 B worker-id framing
    let mut up_bytes = Vec::with_capacity(rounds.len());
    for r in rounds.iter_mut() {
        if r.decision == CensorDecision::Transmit {
            let nbytes = r.bits.div_ceil(8) + 8;
            let delivered = net.send(Direction::Up, r.worker, nbytes);
            up_bytes.push(nbytes);
            if !delivered {
                // dropped uplink: the worker believes it transmitted
                // (its θ̂_m advanced) but the server never folds the
                // delta — eq. (5) simply carries the stale term.  The
                // Skip decision alone guards every fold; the payload
                // stays attached to the report (it is the worker's
                // shared arena slot, not ours to mutate).
                r.decision = CensorDecision::Skip;
            }
        }
    }
    net.advance_round(down_bytes, &up_bytes);

    if cfg.record_comm_map {
        let mut row = vec![false; rounds.len()];
        for r in rounds.iter() {
            row[r.worker] = r.decision == CensorDecision::Transmit;
        }
        trace.comm_map.push(row);
    }

    let bits_round: u64 = rounds
        .iter()
        .filter(|r| r.decision == CensorDecision::Transmit)
        .map(|r| r.bits)
        .sum();
    // batch_frac column: mean shard fraction over the workers that
    // actually computed a gradient this round (observers report 0.0
    // and are excluded, so partial participation does not dilute the
    // schedule's fraction).  epoch column: Σ fractions / M ≈ global
    // data passes consumed — it advances by < 1 when only part of the
    // cohort computes, and by exactly 1 per round in the legacy
    // full-batch full-participation regime.
    let (frac_sum, computed) = rounds
        .iter()
        .filter(|r| r.batch_frac > 0.0)
        .fold((0.0f64, 0usize), |(s, c), r| (s + r.batch_frac, c + 1));
    let batch_frac =
        if computed > 0 { frac_sum / computed as f64 } else { 1.0 };
    let epoch_inc = frac_sum / rounds.len().max(1) as f64;
    let out = server.apply_round(rounds);
    let prev = trace.iters.last();
    IterStat {
        k: out.k,
        loss: out.loss,
        comms_round: out.transmitted,
        comms_cum: prev.map_or(0, |s| s.comms_cum) + out.transmitted,
        agg_grad_sq: out.agg_grad_sq,
        step_sq: out.step_sq,
        bits_cum: prev.map_or(0, |s| s.bits_cum) + bits_round,
        down_bits_cum: prev.map_or(0, |s| s.down_bits_cum) + down_bits_round,
        vclock_us: net.sim_clock_us,
        // synchronous rounds fold every delta at the iterate it was
        // computed on — arrival staleness is identically zero
        stale_max: 0,
        batch_frac,
        epoch: prev.map_or(0.0, |s| s.epoch) + epoch_inc,
    }
}

/// Execution-environment options for one run: checkpoint cadence,
/// resume source, and the manifest identity stamped into checkpoints.
/// The default (`None` everywhere) reproduces the historical behavior
/// exactly — and because writing a checkpoint never draws from any run
/// RNG, a checkpointed run and an un-checkpointed run of the same
/// config are bit-identical too.
#[derive(Clone, Debug, Default)]
pub struct RunContext {
    /// write a checkpoint every `policy.every` server steps
    pub checkpoint: Option<CheckpointPolicy>,
    /// resume from this snapshot instead of starting at round 1
    pub resume: Option<Checkpoint>,
    /// FNV-1a hash of the owning `manifest.json` (stamped into
    /// checkpoints, verified on resume)
    pub spec_hash: Option<u64>,
}

/// Capture the network simulator into its checkpoint form.
pub(crate) fn net_state(net: &SimNetwork) -> NetState {
    let link = |l: &LinkStats| LinkState { messages: l.messages, bytes: l.bytes };
    NetState {
        rng: net.rng_state(),
        dropped: net.dropped(),
        sim_clock_us: net.sim_clock_us,
        up: net.up.iter().map(link).collect(),
        down: net.down.iter().map(link).collect(),
    }
}

/// Restore the network simulator from its checkpoint form (shape was
/// validated at decode time).
pub(crate) fn restore_net(net: &mut SimNetwork, state: &NetState) {
    net.restore_state(state.rng, state.dropped);
    net.sim_clock_us = state.sim_clock_us;
    for (l, s) in net.up.iter_mut().zip(&state.up) {
        *l = LinkStats { messages: s.messages, bytes: s.bytes };
    }
    for (l, s) in net.down.iter_mut().zip(&state.down) {
        *l = LinkStats { messages: s.messages, bytes: s.bytes };
    }
}

fn capture_sync(
    engine: &str,
    spec_hash: Option<u64>,
    server: &Server,
    pool: &mut dyn WorkerPool,
    schedule: &Schedule,
    net: &SimNetwork,
    trace: &Trace,
) -> Checkpoint {
    Checkpoint {
        version: CHECKPOINT_VERSION,
        spec_hash,
        engine: engine.to_string(),
        k: server.iteration(),
        dim: server.dim(),
        server: ServerState {
            theta: server.theta.clone(),
            theta_prev: server.theta_prev.clone(),
            agg_grad: server.agg_grad.clone(),
            k: server.iteration(),
        },
        workers: pool
            .snapshots()
            .into_iter()
            .map(|s| WorkerState {
                id: s.id,
                last_tx: s.last_tx,
                transmissions: s.transmissions,
                residual: s.residual,
            })
            .collect(),
        schedule_rng: Some(schedule.rng_state()),
        net: net_state(net),
        trace: trace.clone(),
        async_state: None,
    }
}

/// Apply a captured (or loaded) checkpoint to the live sync-engine
/// state.  Only called with a fully decoded, shape-validated
/// [`Checkpoint`], so a corrupt file can never half-mutate a run.
fn restore_sync(
    cp: &Checkpoint,
    server: &mut Server,
    pool: &mut dyn WorkerPool,
    schedule: &mut Schedule,
    net: &mut SimNetwork,
    trace: &mut Trace,
) {
    server.restore_state(
        cp.server.theta.clone(),
        cp.server.theta_prev.clone(),
        cp.server.agg_grad.clone(),
        cp.server.k,
    );
    let snaps: Vec<WorkerSnapshot> = cp
        .workers
        .iter()
        .map(|w| WorkerSnapshot {
            id: w.id,
            last_tx: w.last_tx.clone(),
            transmissions: w.transmissions,
            residual: w.residual.clone(),
        })
        .collect();
    pool.restore(&snaps);
    if let Some(s) = cp.schedule_rng {
        schedule.set_rng_state(s);
    }
    restore_net(net, &cp.net);
    *trace = cp.trace.clone();
}

/// The single round loop behind every engine flavor (dyn-dispatched so
/// it is compiled once, not per pool type).  `server` and `censor`
/// arrive pre-built, which is also the ablation entry point: inject a
/// (server rule, censor) pair outside the Method composition table
/// (censored Nesterov, non-paper censor rules, …) — `cfg.method` and
/// `cfg.params` are then ignored, while scheduling, drop injection,
/// comm accounting, and stop rules apply exactly as in a normal run.
///
/// `engine_name` labels checkpoints ("serial"/"threaded"/"rayon") and
/// is what a resume is validated against; `ctx` carries the
/// checkpoint/resume environment.  Errors are all checkpoint-layer
/// (resume incompatibility, I/O) — a checkpoint-free run cannot fail.
pub fn run_with_rules_ctx(
    pool: &mut dyn WorkerPool,
    cfg: &RunConfig,
    mut server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
    engine_name: &str,
    ctx: &RunContext,
) -> Result<Trace, CheckpointError> {
    let m = pool.num_workers();
    let mut net =
        SimNetwork::new(m).with_drops(cfg.drop_prob, cfg.drop_seed);
    let mut schedule = Schedule::new(cfg.participation);
    let mut trace = Trace::new(label);
    let dim = server.dim();
    let faults = &cfg.faults;
    let mut channel = DownlinkChannel::new(cfg.downlink);
    // a compressing channel carries view state the checkpoint does not
    // capture; the spec layer rejects these combinations up front
    debug_assert!(
        !channel.is_compressing()
            || (ctx.resume.is_none() && faults.server_kills.is_empty()),
        "downlink compression does not compose with resume or \
         server-kill replay"
    );

    let mut start_k = 1;
    if let Some(cp) = &ctx.resume {
        cp.check_compat(ctx.spec_hash, engine_name, dim, m)?;
        restore_sync(cp, &mut server, pool, &mut schedule, &mut net, &mut trace);
        start_k = cp.k + 1;
    }
    // the server-kill recovery image: the most recent checkpoint, or
    // the pre-loop state when none has been taken yet
    let mut recovery = if faults.server_kills.is_empty() {
        None
    } else {
        Some(capture_sync(
            engine_name,
            ctx.spec_hash,
            &server,
            pool,
            &schedule,
            &net,
            &trace,
        ))
    };
    // next kill point to fire (the list is sorted): killing,
    // restoring, and replaying back through the same round must not
    // re-kill, so fired points are left behind the index
    let mut kill_idx =
        faults.server_kills.partition_point(|&kk| kk < start_k);

    let mut k = start_k;
    while k <= cfg.max_iters {
        let mut active_vec = schedule.active_set(k, m);
        let mut force = Vec::new();
        if faults.enabled() {
            force = vec![false; m];
            for (w, f) in force.iter_mut().enumerate() {
                if faults.down(w, k) {
                    // crashed: forced inactive — observes only, exactly
                    // like a censored worker, so eq. (5) carries its
                    // stale term undisturbed
                    active_vec[w] = false;
                    trace.fault_downs += 1;
                } else if active_vec[w] && faults.rejoin(w, k) {
                    // first round back: transmit uncensored to re-sync
                    // θ̂ before censored reporting restarts
                    *f = true;
                    trace.fault_rejoins += 1;
                }
            }
        }
        let active = Arc::new(active_vec);
        let n_active = active.iter().filter(|&&a| a).count();
        // θᵏ (or the channel's codec view of it) only goes down to
        // the scheduled workers; each one is charged the payload
        let (theta_view, view_step_sq, down_bits) =
            channel.encode(&server.theta, server.theta_step_sq());
        let down_bytes = downlink_frame_bytes(down_bits);
        net.broadcast(&active, down_bytes);
        let input = RoundInput {
            k,
            theta: theta_view,
            step_sq: view_step_sq,
            active,
            force: Arc::new(force),
            censor: Arc::clone(&censor),
        };
        let mut rounds = pool.run_round(&input);
        debug_assert!(
            rounds.len() == m
                && rounds.iter().enumerate().all(|(i, r)| r.worker == i),
            "pool must report every worker in id order"
        );
        let stat = fold_round(
            &mut server,
            &mut net,
            cfg,
            &mut rounds,
            &mut trace,
            down_bytes,
            down_bits * n_active as u64,
        );
        trace.participants.push(n_active);
        let stop = cfg.should_stop(&stat);
        trace.iters.push(stat);
        if stop {
            break;
        }
        if let Some(policy) = &ctx.checkpoint {
            if policy.due(k) {
                let cp = capture_sync(
                    engine_name,
                    ctx.spec_hash,
                    &server,
                    pool,
                    &schedule,
                    &net,
                    &trace,
                );
                cp.save(&policy.path())?;
                if recovery.is_some() {
                    recovery = Some(cp);
                }
            }
        }
        if kill_idx < faults.server_kills.len()
            && faults.server_kills[kill_idx] == k
        {
            kill_idx += 1;
            // the server dies after round k and comes back from its
            // last checkpoint; determinism makes the replay, and thus
            // the final trace, bit-identical to the kill-free run
            let cp = recovery.as_ref().expect("recovery image exists");
            restore_sync(
                cp,
                &mut server,
                pool,
                &mut schedule,
                &mut net,
                &mut trace,
            );
            k = cp.k + 1;
            continue;
        }
        k += 1;
    }
    trace.per_worker_comms = pool.per_worker_comms();
    Ok(trace)
}

/// [`run_with_rules_ctx`] without a checkpoint/resume environment —
/// the historical signature, kept for the legacy entry points and
/// direct engine users.
pub fn run_with_rules(
    pool: &mut dyn WorkerPool,
    cfg: &RunConfig,
    server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
) -> Trace {
    let name = pool.name();
    run_with_rules_ctx(
        pool,
        cfg,
        server,
        censor,
        label,
        name,
        &RunContext::default(),
    )
    .expect("checkpoint-free run cannot fail")
}

/// The generic round engine: protocol loop over any [`WorkerPool`].
pub struct RoundEngine<P: WorkerPool> {
    pool: P,
}

impl<P: WorkerPool> RoundEngine<P> {
    /// Engine over an already-built pool.
    pub fn new(pool: P) -> Self {
        Self { pool }
    }

    /// Execute the run.  Consumes the engine: pools are single-run
    /// (worker censor state is spent, and a threaded pool's channels
    /// are shut down when the run finishes).
    pub fn run(mut self, cfg: &RunConfig, theta0: Vec<f64>) -> Trace {
        let censor: Arc<dyn CensorRule> = Arc::from(
            optim::method::build_censor_rule(cfg.method, &cfg.params),
        );
        let server = Server::new(cfg.method, &cfg.params, theta0);
        run_with_rules(&mut self.pool, cfg, server, censor, cfg.method.name())
    }
}

/// Which execution backend runs the protocol loop — the one axis the
/// four historical `run_*` entry points used to hard-code.  All four
/// kinds execute the identical protocol; with zero latency and
/// uniform compute even [`EngineKind::Async`] reduces bit-identically
/// to [`EngineKind::Serial`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EngineKind {
    /// deterministic single-threaded reference
    Serial,
    /// one OS thread per worker (channel protocol)
    Threaded,
    /// in-tree work-stealing pool; `threads = 0` sizes to the machine
    Rayon {
        /// worker-thread count (0 = `available_parallelism`)
        threads: usize,
    },
    /// discrete-event virtual-clock engine with per-worker compute and
    /// latency models
    Async(AsyncConfig),
    /// the round protocol over real sockets: a loopback
    /// [`crate::wire::WirePool`] server plus one client thread per
    /// worker, speaking the versioned CRC-framed codec (zero chaos ⇒
    /// bit-identical to [`EngineKind::Serial`])
    Wire(crate::wire::WireConfig),
}

impl EngineKind {
    /// CLI / log label ("serial", "threaded", "rayon", "async", "wire").
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Serial => "serial",
            EngineKind::Threaded => "threaded",
            EngineKind::Rayon { .. } => "rayon",
            EngineKind::Async(_) => "async",
            EngineKind::Wire(_) => "wire",
        }
    }
}

/// Async-engine bookkeeping beyond the trace (what
/// [`super::async_engine::AsyncOutcome`] reports next to it); `None`
/// for the synchronous kinds.
#[derive(Clone, Debug)]
pub struct AsyncSummary {
    /// final virtual-clock reading (µs)
    pub vclock_us: f64,
    /// final server aggregate ∇ᵏ
    pub agg_grad: Vec<f64>,
    /// Σ folded deltas (bit-identical to `agg_grad` by construction)
    pub applied_sum: Vec<f64>,
    /// Σ transmitted deltas lost to uplink drops
    pub dropped_sum: Vec<f64>,
    /// Σ transmitted deltas still in flight at exit
    pub inflight_sum: Vec<f64>,
}

/// What one engine run produces: the trace, plus the async engine's
/// extra bookkeeping when that backend ran.
#[derive(Clone, Debug)]
pub struct EngineRun {
    /// the standard per-iteration trace
    pub trace: Trace,
    /// async-only telemetry (`None` for synchronous kinds)
    pub async_summary: Option<AsyncSummary>,
}

/// The one dispatch every engine flavor routes through: run `cfg` on
/// `workers` under the chosen [`EngineKind`] with an injected
/// (server, censor) pair and a checkpoint/resume environment — the
/// superset of [`run_with_rules_ctx`] and
/// [`super::async_engine::run_async_with_rules_ctx`].
pub fn run_engine_with_rules_ctx(
    kind: &EngineKind,
    mut workers: Vec<Worker>,
    cfg: &RunConfig,
    server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
    ctx: &RunContext,
) -> Result<EngineRun, CheckpointError> {
    let name = kind.name();
    match kind {
        EngineKind::Serial => Ok(EngineRun {
            trace: run_with_rules_ctx(
                &mut SerialPool::new(&mut workers),
                cfg,
                server,
                censor,
                label,
                name,
                ctx,
            )?,
            async_summary: None,
        }),
        EngineKind::Threaded => Ok(EngineRun {
            trace: run_with_rules_ctx(
                &mut ThreadedPool::new(workers),
                cfg,
                server,
                censor,
                label,
                name,
                ctx,
            )?,
            async_summary: None,
        }),
        EngineKind::Rayon { threads } => {
            let mut pool = if *threads == 0 {
                RayonPool::new(workers)
            } else {
                RayonPool::with_threads(workers, *threads)
            };
            Ok(EngineRun {
                trace: run_with_rules_ctx(
                    &mut pool, cfg, server, censor, label, name, ctx,
                )?,
                async_summary: None,
            })
        }
        EngineKind::Async(acfg) => {
            let out = run_async_with_rules_ctx(
                &mut workers,
                cfg,
                acfg,
                server,
                censor,
                label,
                ctx,
            )?;
            let (trace, summary) = out.split();
            Ok(EngineRun { trace, async_summary: Some(summary) })
        }
        EngineKind::Wire(wcfg) => Ok(EngineRun {
            trace: crate::wire::run_loopback_ctx(
                wcfg, workers, cfg, server, censor, label, ctx,
            )?,
            async_summary: None,
        }),
    }
}

/// [`run_engine_with_rules_ctx`] without a checkpoint/resume
/// environment — the historical signature.
pub fn run_engine_with_rules(
    kind: &EngineKind,
    workers: Vec<Worker>,
    cfg: &RunConfig,
    server: Server,
    censor: Arc<dyn CensorRule>,
    label: &str,
) -> EngineRun {
    run_engine_with_rules_ctx(
        kind,
        workers,
        cfg,
        server,
        censor,
        label,
        &RunContext::default(),
    )
    .expect("checkpoint-free run cannot fail")
}

/// Run `(cfg.method, cfg.params)` on any [`EngineKind`] — the unified
/// form of the four `run_*` entry points.  Labels match the legacy
/// wrappers (`"CHB"` sync, `"CHB-async"` async), so traces are
/// drop-in comparable.
pub fn run_engine(
    kind: &EngineKind,
    workers: Vec<Worker>,
    cfg: &RunConfig,
    theta0: Vec<f64>,
) -> EngineRun {
    let censor: Arc<dyn CensorRule> = Arc::from(
        optim::method::build_censor_rule(cfg.method, &cfg.params),
    );
    let server = Server::new(cfg.method, &cfg.params, theta0);
    let label = match kind {
        EngineKind::Async(_) => format!("{}-async", cfg.method.name()),
        _ => cfg.method.name().to_string(),
    };
    run_engine_with_rules(kind, workers, cfg, server, censor, &label)
}

/// Deterministic single-threaded run (borrowed workers, so callers
/// can inspect worker state afterwards).
pub fn run_serial(
    workers: &mut [Worker],
    cfg: &RunConfig,
    theta0: Vec<f64>,
) -> Trace {
    RoundEngine::new(SerialPool::new(workers)).run(cfg, theta0)
}

/// One OS thread per worker, channel protocol.
pub fn run_threaded(
    workers: Vec<Worker>,
    cfg: &RunConfig,
    theta0: Vec<f64>,
) -> Trace {
    RoundEngine::new(ThreadedPool::new(workers)).run(cfg, theta0)
}

/// Work-stealing pool sized to the machine; scales to M ≫ cores.
pub fn run_rayon(
    workers: Vec<Worker>,
    cfg: &RunConfig,
    theta0: Vec<f64>,
) -> Trace {
    RoundEngine::new(RayonPool::new(workers)).run(cfg, theta0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::{GradientBackend, Worker};
    use crate::optim::Method;

    /// f_m(θ) = ½ c_m ‖θ − t_m‖²  — strongly convex toy problem.
    struct Quad {
        c: f64,
        t: Vec<f64>,
    }

    impl GradientBackend for Quad {
        fn dim(&self) -> usize {
            self.t.len()
        }

        fn grad_loss_into(&mut self, theta: &[f64], grad: &mut [f64]) -> f64 {
            let mut l = 0.0;
            for i in 0..theta.len() {
                let d = theta[i] - self.t[i];
                grad[i] = self.c * d;
                l += d * d;
            }
            0.5 * self.c * l
        }
    }

    fn quad_workers(dim: usize, m: usize) -> Vec<Worker> {
        (0..m)
            .map(|i| {
                let t: Vec<f64> =
                    (0..dim).map(|j| ((i + j) % 5) as f64 - 2.0).collect();
                Worker::new(
                    i,
                    Box::new(Quad { c: 1.0 + i as f64 * 0.3, t }),
                )
            })
            .collect()
    }

    fn total_c(m: usize) -> f64 {
        (0..m).map(|i| 1.0 + i as f64 * 0.3).sum()
    }

    /// Analytic minimum of Σ ½c_m‖θ−t_m‖²: θ* = Σc_m t_m / Σc_m.
    fn quad_f_star(dim: usize, m: usize) -> f64 {
        let cs: Vec<f64> = (0..m).map(|i| 1.0 + i as f64 * 0.3).collect();
        let ts: Vec<Vec<f64>> = (0..m)
            .map(|i| (0..dim).map(|j| ((i + j) % 5) as f64 - 2.0).collect())
            .collect();
        let csum: f64 = cs.iter().sum();
        let theta_star: Vec<f64> = (0..dim)
            .map(|j| {
                (0..m).map(|i| cs[i] * ts[i][j]).sum::<f64>() / csum
            })
            .collect();
        (0..m)
            .map(|i| {
                0.5 * cs[i]
                    * (0..dim)
                        .map(|j| (theta_star[j] - ts[i][j]).powi(2))
                        .sum::<f64>()
            })
            .sum()
    }

    fn assert_traces_bitwise_equal(a: &Trace, b: &Trace, what: &str) {
        assert_eq!(a.iterations(), b.iterations(), "{what}: iterations");
        for (x, y) in a.iters.iter().zip(&b.iters) {
            assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what} loss k={}", x.k);
            assert_eq!(x.comms_cum, y.comms_cum, "{what} comms k={}", x.k);
        }
        assert_eq!(a.per_worker_comms, b.per_worker_comms, "{what}: per-worker");
        assert_eq!(a.comm_map, b.comm_map, "{what}: comm map");
        assert_eq!(a.participants, b.participants, "{what}: participants");
    }

    #[test]
    fn gd_converges_on_quadratic() {
        let (dim, m) = (4, 3);
        let mut ws = quad_workers(dim, m);
        let alpha = 1.0 / total_c(m);
        let cfg = RunConfig::new(Method::Gd, MethodParams::new(alpha), 200);
        let trace = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        assert_eq!(trace.iterations(), 200);
        // GD transmits every worker every round
        assert_eq!(trace.total_comms(), 200 * m);
        let f_star = quad_f_star(dim, m);
        let first = trace.iters.first().unwrap().loss - f_star;
        let last = trace.final_loss() - f_star;
        assert!(last < first * 1e-6, "no convergence: {first} → {last}");
    }

    #[test]
    fn chb_converges_with_fewer_comms_than_hb() {
        let (dim, m) = (6, 5);
        let alpha = 1.0 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let mut ws = quad_workers(dim, m);
        let chb =
            run_serial(&mut ws, &RunConfig::new(Method::Chb, p, 300), vec![0.0; dim]);
        let mut ws = quad_workers(dim, m);
        let hb =
            run_serial(&mut ws, &RunConfig::new(Method::Hb, p, 300), vec![0.0; dim]);
        let f_star = quad_f_star(dim, m);
        assert!(
            chb.final_loss() - f_star
                < (hb.iters.first().unwrap().loss - f_star) * 1e-6
        );
        assert!(
            chb.total_comms() < hb.total_comms(),
            "CHB {} vs HB {}",
            chb.total_comms(),
            hb.total_comms()
        );
    }

    #[test]
    fn epsilon_zero_chb_equals_hb_trace() {
        let (dim, m) = (3, 4);
        let alpha = 0.5 / total_c(m);
        let p = MethodParams::new(alpha).with_beta(0.3).with_epsilon1(0.0);
        let mut ws = quad_workers(dim, m);
        let chb =
            run_serial(&mut ws, &RunConfig::new(Method::Chb, p, 50), vec![1.0; dim]);
        let mut ws = quad_workers(dim, m);
        let hb =
            run_serial(&mut ws, &RunConfig::new(Method::Hb, p, 50), vec![1.0; dim]);
        for (a, b) in chb.iters.iter().zip(&hb.iters) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "k={}", a.k);
        }
    }

    #[test]
    fn threaded_matches_serial_bit_for_bit() {
        let (dim, m) = (5, 7);
        let alpha = 0.8 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 120).with_comm_map();
        let mut ws = quad_workers(dim, m);
        let serial = run_serial(&mut ws, &cfg, vec![0.5; dim]);
        let threaded = run_threaded(quad_workers(dim, m), &cfg, vec![0.5; dim]);
        assert_traces_bitwise_equal(&serial, &threaded, "serial vs threaded");
    }

    #[test]
    fn rayon_matches_serial_bit_for_bit() {
        let (dim, m) = (5, 7);
        let alpha = 0.8 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 120).with_comm_map();
        let mut ws = quad_workers(dim, m);
        let serial = run_serial(&mut ws, &cfg, vec![0.5; dim]);
        let rayon = run_rayon(quad_workers(dim, m), &cfg, vec![0.5; dim]);
        assert_traces_bitwise_equal(&serial, &rayon, "serial vs rayon(auto)");
        // force real multi-threading regardless of the host's core count
        let rayon3 = RoundEngine::new(super::RayonPool::with_threads(
            quad_workers(dim, m),
            3,
        ))
        .run(&cfg, vec![0.5; dim]);
        assert_traces_bitwise_equal(&serial, &rayon3, "serial vs rayon(3)");
    }

    #[test]
    fn full_participation_records_all_workers_every_round() {
        let (dim, m) = (3, 4);
        let mut ws = quad_workers(dim, m);
        let cfg =
            RunConfig::new(Method::Gd, MethodParams::new(0.1 / total_c(m)), 25);
        let trace = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        assert_eq!(trace.participants, vec![m; 25]);
    }

    #[test]
    fn seeded_sampling_is_reproducible_and_partial() {
        let (dim, m) = (4, 6);
        let alpha = 0.5 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.3)
            .with_epsilon1_scaled(0.1, m);
        let part = Participation::UniformSample { frac: 0.5, seed: 7 };
        let cfg = RunConfig::new(Method::Chb, p, 80)
            .with_comm_map()
            .with_participation(part);
        let mut ws = quad_workers(dim, m);
        let a = run_serial(&mut ws, &cfg, vec![1.0; dim]);
        let mut ws = quad_workers(dim, m);
        let b = run_serial(&mut ws, &cfg, vec![1.0; dim]);
        assert_traces_bitwise_equal(&a, &b, "same seed rerun");
        // exactly round(0.5·6) = 3 participants per round, and only
        // participants can transmit
        assert!(a.participants.iter().all(|&n| n == 3));
        for (s, &n) in a.iters.iter().zip(&a.participants) {
            assert!(s.comms_round <= n, "k={}: {} > {n}", s.k, s.comms_round);
        }
        // the same schedule drives every pool
        let threaded = run_threaded(quad_workers(dim, m), &cfg, vec![1.0; dim]);
        let rayon = run_rayon(quad_workers(dim, m), &cfg, vec![1.0; dim]);
        assert_traces_bitwise_equal(&a, &threaded, "sampled serial vs threaded");
        assert_traces_bitwise_equal(&a, &rayon, "sampled serial vs rayon");
    }

    #[test]
    fn straggler_rounds_stay_consistent_and_converge() {
        let (dim, m) = (4, 5);
        // conservative α: stale aggregates (missed rounds) shrink the
        // stability margin, IAG-style
        let alpha = 0.3 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.2)
            .with_epsilon1_scaled(0.1, m);
        let part = Participation::Straggler { timeout: 1.2, seed: 11 };
        let cfg = RunConfig::new(Method::Chb, p, 800).with_participation(part);
        let mut ws = quad_workers(dim, m);
        let trace = run_serial(&mut ws, &cfg, vec![2.0; dim]);
        // rounds are never empty and never exceed M
        assert!(trace.participants.iter().all(|&n| (1..=m).contains(&n)));
        // Exp(1) with timeout 1.2 keeps ~70% — some rounds must be partial
        assert!(trace.participants.iter().any(|&n| n < m));
        // straggler-as-skip leaves the aggregate usable: the run still
        // converges on the strongly convex problem
        let f_star = quad_f_star(dim, m);
        let first = trace.iters.first().unwrap().loss - f_star;
        let last = trace.final_loss() - f_star;
        assert!(last.is_finite() && last < first * 1e-2, "{first} → {last}");
    }

    #[test]
    fn run_engine_dispatch_matches_the_legacy_wrappers() {
        let (dim, m) = (5, 6);
        let p = MethodParams::new(0.8 / total_c(m))
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 80).with_comm_map();
        let mut ws = quad_workers(dim, m);
        let serial = run_serial(&mut ws, &cfg, vec![0.5; dim]);
        for kind in [
            EngineKind::Serial,
            EngineKind::Threaded,
            EngineKind::Rayon { threads: 0 },
            EngineKind::Rayon { threads: 3 },
        ] {
            let run =
                run_engine(&kind, quad_workers(dim, m), &cfg, vec![0.5; dim]);
            assert!(run.async_summary.is_none());
            assert_traces_bitwise_equal(
                &serial,
                &run.trace,
                &format!("run_engine {}", kind.name()),
            );
            assert_eq!(run.trace.method, "CHB");
        }
        // degenerate async through the same dispatch: identical trace,
        // plus the async bookkeeping
        let acfg = AsyncConfig {
            latency: crate::net::LatencyModel::zero(),
            ..AsyncConfig::default()
        };
        let run = run_engine(
            &EngineKind::Async(acfg),
            quad_workers(dim, m),
            &cfg,
            vec![0.5; dim],
        );
        assert_eq!(run.trace.method, "CHB-async");
        let summary = run.async_summary.expect("async summary");
        assert_eq!(summary.agg_grad.len(), dim);
        assert_traces_bitwise_equal(&serial, &run.trace, "run_engine async");
    }

    #[test]
    fn downlink_accounting_charges_every_scheduled_worker() {
        let (dim, m) = (4, 3);
        let mut ws = quad_workers(dim, m);
        let alpha = 1.0 / total_c(m);
        let cfg = RunConfig::new(Method::Gd, MethodParams::new(alpha), 10);
        let trace = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        // uncompressed broadcast: 64·d bits × M workers per round
        for (i, s) in trace.iters.iter().enumerate() {
            assert_eq!(s.down_bits_cum, ((i + 1) * m * 64 * dim) as u64);
        }
        assert_eq!(trace.total_downlink_bits(), (10 * m * 64 * dim) as u64);
    }

    #[test]
    fn compressed_downlink_converges_and_charges_fewer_bits() {
        let (dim, m) = (6, 5);
        let alpha = 1.0 / total_c(m);
        let p = MethodParams::new(alpha)
            .with_beta(0.4)
            .with_epsilon1_scaled(0.1, m);
        let base = RunConfig::new(Method::Chb, p, 300);
        let mut ws = quad_workers(dim, m);
        let dense = run_serial(&mut ws, &base, vec![0.0; dim]);
        let cfg = base
            .clone()
            .with_downlink(DownlinkSpec::Int { bits: 8, error_feedback: true });
        let mut ws = quad_workers(dim, m);
        let packed = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        let f_star = quad_f_star(dim, m);
        let first = packed.iters.first().unwrap().loss - f_star;
        let last = packed.final_loss() - f_star;
        assert!(
            last < first * 1e-3,
            "no convergence under int8 downlink: {first} → {last}"
        );
        // round 1 is the dense model sync; every later round carries
        // the 32-bit scale header + 8 bits/coordinate
        let per_round = (32 + 8 * dim as u64) * m as u64;
        let round1 = (64 * dim * m) as u64;
        assert_eq!(
            packed.total_downlink_bits(),
            round1 + 299 * per_round
        );
        assert!(
            packed.total_downlink_bits() < dense.total_downlink_bits(),
            "int8 downlink did not reduce broadcast bits"
        );
    }

    #[test]
    fn stop_rule_obj_err_halts_early() {
        let (dim, m) = (4, 3);
        let mut ws = quad_workers(dim, m);
        let alpha = 1.0 / total_c(m);
        let f_star = quad_f_star(dim, m);
        let cfg = RunConfig::new(Method::Hb, MethodParams::new(alpha), 10_000)
            .with_stop(StopRule::ObjErrBelow { f_star, tol: 1e-6 });
        let trace = run_serial(&mut ws, &cfg, vec![0.0; dim]);
        assert!(trace.iterations() < 10_000, "stop rule never fired");
        assert!(trace.final_loss() - f_star < 1e-6);
    }

    #[test]
    fn dropped_uplinks_do_not_crash_and_counts_reflect_delivery() {
        let (dim, m) = (4, 6);
        let alpha = 0.5 / total_c(m);
        let p = MethodParams::new(alpha).with_beta(0.2).with_epsilon1_scaled(0.1, m);
        let cfg = RunConfig::new(Method::Chb, p, 100).with_drops(0.2, 99);
        let mut ws = quad_workers(dim, m);
        // start far from the optimum so the drop-induced bias (which is
        // O(stale-delta), independent of θ⁰) stays below the initial error
        let trace = run_serial(&mut ws, &cfg, vec![10.0; dim]);
        // per-worker counters count *attempts*; trace counts deliveries
        let attempts: usize = trace.per_worker_comms.iter().sum();
        assert!(trace.total_comms() <= attempts);
        // Dropped deltas leave the aggregate permanently stale, so the
        // run converges to a *biased* point — but it must stay bounded
        // and still improve on the start.
        let f_star = quad_f_star(dim, m);
        let first = trace.iters.first().unwrap().loss - f_star;
        let last = trace.final_loss() - f_star;
        assert!(last.is_finite(), "diverged under drops");
        assert!(last < first, "no progress at all: {first} → {last}");
    }
}
