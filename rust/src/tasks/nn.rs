//! The paper's nonconvex task: a 1-hidden-layer sigmoid network.
//!
//! pred = σ(X W1 + b1)·w2 + b2,  f_m = ½‖pred − y‖² + ½λ_m‖θ‖²
//!
//! θ packs (W1[d,h] row-major, b1[h], w2[h], b2) — identical layout to
//! python/compile/kernels/ref.nn_pack, so PJRT artifacts and this
//! backend are interchangeable.  Backprop is manual, matching the
//! fused Pallas kernel step for step.
//!
//! Both gradient flavors (full shard and row-subset minibatch) run
//! through one generic pass monomorphized over the row iterator, so
//! the full-batch instantiation compiles to exactly the legacy loop —
//! no per-row branching on the batch mode, and bit-identical results.

use std::sync::Arc;

use crate::data::Shard;
use crate::linalg::{self, Matrix};

use super::{batch_scale, scratch, sigmoid, TaskWorkspace, WorkerObjective};

/// Paper: "one hidden layer with 30 nodes".
pub const HIDDEN: usize = 30;

/// Flat parameter count: d·h + h + h + 1.
pub fn param_dim(d: usize, h: usize) -> usize {
    d * h + 2 * h + 1
}

/// View into the flat parameter vector.
pub struct Packed<'a> {
    /// hidden-layer weights, (d × h) row-major
    pub w1: &'a [f64],
    /// hidden-layer biases (h)
    pub b1: &'a [f64],
    /// output weights (h)
    pub w2: &'a [f64],
    /// output bias
    pub b2: f64,
}

/// Split a flat θ into the (W1, b1, w2, b2) views.
pub fn unpack(theta: &[f64], d: usize, h: usize) -> Packed<'_> {
    assert_eq!(theta.len(), param_dim(d, h));
    let (w1, rest) = theta.split_at(d * h);
    let (b1, rest) = rest.split_at(h);
    let (w2, rest) = rest.split_at(h);
    Packed { w1, b1, w2, b2: rest[0] }
}

/// Worker objective for the NN task.
///
/// Shard storage is `Arc`-shared with the owning [`Shard`] (see
/// [`super::LinRegTask`]); activation scratch lives in the
/// caller-owned [`TaskWorkspace`], so the objective itself is
/// immutable shared state.
pub struct NnTask {
    x: Arc<Matrix>,
    y: Arc<Vec<f64>>,
    mask: Arc<Vec<f64>>,
    lam: f64,
    /// data-term multiplier; 1/N_m gives the paper's mean-loss NN
    /// regime (gradients O(1) so α = 0.01…0.02 is stable)
    wscale: f64,
    h: usize,
    n_real: usize,
}

impl NnTask {
    /// Mean-loss NN objective (the paper's regime) over one shard.
    pub fn new(shard: &Shard, lam: f64, h: usize) -> Self {
        Self::with_scale(shard, lam, h, 1.0 / shard.n_real.max(1) as f64)
    }

    /// Explicit data-term scale (1.0 = plain sum loss).
    pub fn with_scale(shard: &Shard, lam: f64, h: usize, wscale: f64) -> Self {
        Self {
            x: Arc::clone(&shard.x),
            y: Arc::clone(&shard.y),
            mask: Arc::clone(&shard.mask),
            lam,
            wscale,
            h,
            n_real: shard.n_real,
        }
    }

    /// Hidden-layer width h.
    pub fn hidden(&self) -> usize {
        self.h
    }

    /// Data-term multiplier (1/N_m in the mean-loss regime).
    pub fn wscale(&self) -> f64 {
        self.wscale
    }

    /// One forward+backward pass over the rows yielded by `rows`, with
    /// the data-term gradient and loss scaled by `data_scale`.  The
    /// full-batch caller passes `0..n` and `wscale`; the minibatch
    /// caller passes the drawn index set and `wscale · n_real/|B|`.
    /// Monomorphization keeps each instantiation's inner loops free of
    /// any batch-mode branching, and the `0..n` instantiation performs
    /// exactly the legacy op sequence (bit-identical traces).
    fn pass<I>(
        &self,
        theta: &[f64],
        rows: I,
        data_scale: f64,
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64
    where
        I: Iterator<Item = usize> + Clone,
    {
        let (n, d, h) = (self.x.rows, self.x.cols, self.h);
        let p = unpack(theta, d, h);
        let z = scratch(&mut ws.z, n * h);
        let r = scratch(&mut ws.resid, n);
        let dz = scratch(&mut ws.dz, n * h);

        // forward: z = σ(XW1 + b1), pred = z·w2 + b2, r = (pred − y)·mask
        // k-outer / j-inner so every W1 access is stride-1 (W1 is
        // row-major d×h); this is the cache layout the Pallas kernel's
        // (bn,d)×(d,h) tile matmul uses, and it is ~2× over the naive
        // j-outer loop at MNIST shapes (EXPERIMENTS.md §Perf).
        for i in rows.clone() {
            if self.mask[i] == 0.0 {
                r[i] = 0.0;
                continue;
            }
            let xrow = self.x.row(i);
            let zrow = &mut z[i * h..(i + 1) * h];
            zrow.copy_from_slice(p.b1);
            for k in 0..d {
                let xk = xrow[k];
                if xk == 0.0 {
                    continue;
                }
                // stride-1 rank-1 update through the shared kernel
                // (identical op order to the hand-rolled loop)
                linalg::axpy(xk, &p.w1[k * h..(k + 1) * h], zrow);
            }
            for v in zrow.iter_mut() {
                *v = sigmoid(*v);
            }
            let pred = linalg::dot(zrow, p.w2) + p.b2;
            r[i] = pred - self.y[i];
        }

        // backward into the packed gradient layout
        grad.fill(0.0);
        let (gw1, rest) = grad.split_at_mut(d * h);
        let (gb1, rest) = rest.split_at_mut(h);
        let (gw2, gb2) = rest.split_at_mut(h);
        let mut loss = 0.0;
        for i in rows {
            let ri = r[i];
            if self.mask[i] == 0.0 {
                continue;
            }
            loss += ri * ri;
            let zrow = &z[i * h..(i + 1) * h];
            let dzrow = &mut dz[i * h..(i + 1) * h];
            for j in 0..h {
                gw2[j] += ri * zrow[j];
                dzrow[j] = ri * p.w2[j] * zrow[j] * (1.0 - zrow[j]);
                gb1[j] += dzrow[j];
            }
            gb2[0] += ri;
            let xrow = self.x.row(i);
            for k in 0..d {
                let xk = xrow[k];
                if xk == 0.0 {
                    continue;
                }
                // gw1[k,·] += x_k · dz — same shared rank-1 kernel
                linalg::axpy(xk, dzrow, &mut gw1[k * h..(k + 1) * h]);
            }
        }
        // scale the data terms (mean-loss regime), then regularize
        if data_scale != 1.0 {
            linalg::scale(data_scale, grad);
        }
        linalg::axpy(self.lam, theta, grad);
        0.5 * loss * data_scale + 0.5 * self.lam * linalg::norm2_sq(theta)
    }
}

impl WorkerObjective for NnTask {
    fn dim(&self) -> usize {
        param_dim(self.x.cols, self.h)
    }

    fn num_rows(&self) -> usize {
        self.n_real
    }

    fn grad_loss_into(
        &self,
        theta: &[f64],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        self.pass(theta, 0..self.x.rows, self.wscale, ws, grad)
    }

    fn grad_loss_batch_into(
        &self,
        theta: &[f64],
        rows: &[u32],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        let scale = self.wscale * batch_scale(self.n_real, rows.len());
        self.pass(
            theta,
            rows.iter().map(|&i| i as usize),
            scale,
            ws,
            grad,
        )
    }

    fn loss(&self, theta: &[f64], ws: &mut TaskWorkspace) -> f64 {
        // forward-only pass: same per-row op order as the full pass,
        // without touching the gradient buffers.  Reuses a prefix of
        // the activation scratch (grow-only, so it never fights the
        // n·h sizing of the gradient passes).
        let (d, h) = (self.x.cols, self.h);
        let p = unpack(theta, d, h);
        if ws.z.len() < h {
            ws.z.resize(h, 0.0);
        }
        let zrow = &mut ws.z[..h];
        let mut loss = 0.0;
        for i in 0..self.x.rows {
            if self.mask[i] == 0.0 {
                continue;
            }
            let xrow = self.x.row(i);
            zrow.copy_from_slice(p.b1);
            for k in 0..d {
                let xk = xrow[k];
                if xk == 0.0 {
                    continue;
                }
                linalg::axpy(xk, &p.w1[k * h..(k + 1) * h], zrow);
            }
            for v in zrow.iter_mut() {
                *v = sigmoid(*v);
            }
            let pred = linalg::dot(zrow, p.w2) + p.b2;
            let ri = pred - self.y[i];
            loss += ri * ri;
        }
        0.5 * loss * self.wscale + 0.5 * self.lam * linalg::norm2_sq(theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::shard_whole;
    use crate::data::synthetic;
    use crate::rng::Xoshiro256;

    #[test]
    fn param_dim_matches_paper_nn() {
        // d=22 (ijcnn1), h=30 → 22·30 + 61 = 721
        assert_eq!(param_dim(22, 30), 721);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let mut rng = Xoshiro256::new(10);
        let ds = synthetic::gaussian_pm1(&mut rng, 20, 4);
        let shard = shard_whole(&ds);
        let h = 5;
        let obj = NnTask::new(&shard, 0.01, h);
        let theta: Vec<f64> = Xoshiro256::new(11)
            .gaussian_vec(param_dim(4, h))
            .iter()
            .map(|v| 0.5 * v)
            .collect();
        let mut ws = TaskWorkspace::default();
        let mut grad = vec![0.0; theta.len()];
        obj.grad_loss_into(&theta, &mut ws, &mut grad);
        let hstep = 1e-5;
        let mut tp = theta.clone();
        for i in 0..theta.len() {
            tp[i] = theta[i] + hstep;
            let fp = obj.loss(&tp, &mut ws);
            tp[i] = theta[i] - hstep;
            let fm = obj.loss(&tp, &mut ws);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * hstep);
            assert!(
                (grad[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coord {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn batch_gradient_matches_batch_finite_differences() {
        // FD of the *batch* loss estimate against the batch gradient:
        // pins the n/|B| scaling through the whole backprop
        let mut rng = Xoshiro256::new(14);
        let ds = synthetic::gaussian_pm1(&mut rng, 12, 3);
        let shard = shard_whole(&ds);
        let h = 4;
        let obj = NnTask::new(&shard, 0.02, h);
        let rows = [1u32, 4, 7, 10];
        let theta: Vec<f64> = Xoshiro256::new(15)
            .gaussian_vec(param_dim(3, h))
            .iter()
            .map(|v| 0.5 * v)
            .collect();
        let mut ws = TaskWorkspace::default();
        let mut grad = vec![0.0; theta.len()];
        obj.grad_loss_batch_into(&theta, &rows, &mut ws, &mut grad);
        let hstep = 1e-5;
        let mut tp = theta.clone();
        let mut g_scratch = vec![0.0; theta.len()];
        for i in 0..theta.len() {
            tp[i] = theta[i] + hstep;
            let fp =
                obj.grad_loss_batch_into(&tp, &rows, &mut ws, &mut g_scratch);
            tp[i] = theta[i] - hstep;
            let fm =
                obj.grad_loss_batch_into(&tp, &rows, &mut ws, &mut g_scratch);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * hstep);
            assert!(
                (grad[i] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "coord {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn masked_rows_are_inert() {
        let mut rng = Xoshiro256::new(12);
        let ds = synthetic::gaussian_pm1(&mut rng, 8, 3);
        let base = shard_whole(&ds);
        let mut padded = base.clone();
        let mut x = Matrix::zeros(12, 3);
        for i in 0..8 {
            x.row_mut(i).copy_from_slice(base.x.row(i));
        }
        padded.x = Arc::new(x);
        Arc::make_mut(&mut padded.y).extend([0.0; 4]);
        Arc::make_mut(&mut padded.mask).extend([0.0; 4]);
        let h = 4;
        let theta = Xoshiro256::new(13).gaussian_vec(param_dim(3, h));
        let (o1, o2) = (NnTask::new(&base, 0.1, h), NnTask::new(&padded, 0.1, h));
        let mut ws = TaskWorkspace::default();
        let mut g1 = vec![0.0; theta.len()];
        let mut g2 = vec![0.0; theta.len()];
        let l1 = o1.grad_loss_into(&theta, &mut ws, &mut g1);
        let l2 = o2.grad_loss_into(&theta, &mut ws, &mut g2);
        assert!((l1 - l2).abs() < 1e-12);
        for i in 0..theta.len() {
            assert!((g1[i] - g2[i]).abs() < 1e-12);
        }
    }
}
