//! Objective definitions — the pure-rust gradient backend.
//!
//! Mirrors python/compile/kernels/ref.py exactly (same loss
//! conventions, §IV of the paper), in f64 so objective errors down to
//! 1e-7 are resolvable.  The PJRT backend (runtime/) computes the same
//! functions from the AOT artifacts in f32; integration tests compare
//! the two.
//!
//! Every implementation is allocation-free on the hot path: gradients
//! are written into caller buffers through [`WorkerObjective::grad_loss_into`].

pub mod nn;
pub mod smoothness;

use std::sync::Arc;

use crate::data::Shard;
use crate::linalg::{self, Matrix};

pub use nn::NnTask;

/// Which of the paper's four learning tasks is being solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// linear regression ½‖Xθ − y‖²
    LinReg,
    /// ℓ2-regularized logistic regression
    LogReg,
    /// lasso (ℓ1-regularized least squares, subgradient)
    Lasso,
    /// 1×30-sigmoid neural network (nonconvex)
    Nn,
}

impl TaskKind {
    /// CLI name ("linreg", "logreg", "lasso", "nn").
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::LinReg => "linreg",
            TaskKind::LogReg => "logreg",
            TaskKind::Lasso => "lasso",
            TaskKind::Nn => "nn",
        }
    }

    /// Parse a CLI task name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "linreg" => Some(TaskKind::LinReg),
            "logreg" => Some(TaskKind::LogReg),
            "lasso" => Some(TaskKind::Lasso),
            "nn" => Some(TaskKind::Nn),
            _ => None,
        }
    }

    /// Flat parameter dimension for feature count d.
    pub fn theta_dim(self, d: usize) -> usize {
        match self {
            TaskKind::Nn => nn::param_dim(d, nn::HIDDEN),
            _ => d,
        }
    }
}

/// A worker-local objective f_m: value + (sub)gradient.
///
/// `grad_loss_into` writes ∇f_m(θ) into `grad` and returns f_m(θ).
pub trait WorkerObjective: Send {
    /// Parameter dimension d.
    fn dim(&self) -> usize;
    /// Write ∇f_m(θ) into `grad`, return f_m(θ).
    fn grad_loss_into(&self, theta: &[f64], grad: &mut [f64]) -> f64;

    /// Objective value only (defaults to computing the gradient too;
    /// overridden where a cheaper pass exists).
    fn loss(&self, theta: &[f64]) -> f64 {
        let mut g = vec![0.0; self.dim()];
        self.grad_loss_into(theta, &mut g)
    }
}

/// Numerically-stable σ(z).
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        let e = (-z).exp();
        1.0 / (1.0 + e)
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// log(1 + eᶻ) without overflow.
#[inline]
pub fn log1pexp(z: f64) -> f64 {
    if z > 35.0 {
        z
    } else if z < -35.0 {
        0.0
    } else {
        z.exp().ln_1p()
    }
}

// ---------------------------------------------------------------------------
// linear regression: ½‖Xθ − y‖²
// ---------------------------------------------------------------------------

/// Worker objective for ½‖Xθ − y‖² over a (possibly padded) shard.
///
/// The shard's feature block and labels are `Arc`-shared with the
/// owning [`Shard`], never copied — at M workers the objectives add
/// O(1) resident memory on top of the dataset itself.
pub struct LinRegTask {
    x: Arc<Matrix>,
    y: Arc<Vec<f64>>,
    /// scratch residual buffer (hot path is allocation-free)
    resid: std::cell::RefCell<Vec<f64>>,
}

impl LinRegTask {
    /// Objective over one worker's shard.
    pub fn new(shard: &Shard) -> Self {
        Self {
            x: Arc::clone(&shard.x),
            y: Arc::clone(&shard.y),
            resid: std::cell::RefCell::new(vec![0.0; shard.x.rows]),
        }
    }
}

// RefCell scratch is only touched from the owning worker thread.
unsafe impl Sync for LinRegTask {}

impl WorkerObjective for LinRegTask {
    fn dim(&self) -> usize {
        self.x.cols
    }

    fn grad_loss_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        // single fused sweep over X (see Matrix::fused_residual_grad)
        let mut r = self.resid.borrow_mut();
        grad.fill(0.0);
        self.x.fused_residual_grad(theta, &self.y, &mut r, grad)
    }
}

// ---------------------------------------------------------------------------
// ℓ2-regularized logistic regression
// ---------------------------------------------------------------------------

/// Σ log(1+exp(−y xᵀθ)) + ½λ_m‖θ‖² over a shard (mask-aware).
///
/// Shard storage is `Arc`-shared (see [`LinRegTask`]).
pub struct LogRegTask {
    x: Arc<Matrix>,
    y: Arc<Vec<f64>>,
    mask: Arc<Vec<f64>>,
    lam: f64,
}

impl LogRegTask {
    /// Objective over one worker's shard with per-worker λ_m = `lam`.
    pub fn new(shard: &Shard, lam: f64) -> Self {
        Self {
            x: Arc::clone(&shard.x),
            y: Arc::clone(&shard.y),
            mask: Arc::clone(&shard.mask),
            lam,
        }
    }
}

impl WorkerObjective for LogRegTask {
    fn dim(&self) -> usize {
        self.x.cols
    }

    fn grad_loss_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        // fused single sweep over X via the shared coefficient kernel
        // (the same schedule as the Pallas logreg kernel): margin,
        // loss term, coefficient, and the rank-1 gradient update all
        // from one row visit — see Matrix::fused_coeff_grad
        grad.fill(0.0);
        let (y, lam) = (&self.y, self.lam);
        let loss = self.x.fused_coeff_grad(
            theta,
            &self.mask,
            |i, z| {
                let margin = y[i] * z;
                (log1pexp(-margin), -y[i] * sigmoid(-margin))
            },
            grad,
        );
        linalg::axpy(lam, theta, grad);
        loss + 0.5 * lam * linalg::norm2_sq(theta)
    }
}

// ---------------------------------------------------------------------------
// lasso (subgradient)
// ---------------------------------------------------------------------------

/// ½‖Xθ − y‖² + λ_m‖θ‖₁; subgradient with sign(0) = 0 (paper §IV).
pub struct LassoTask {
    inner: LinRegTask,
    lam: f64,
}

impl LassoTask {
    /// Objective over one worker's shard with per-worker λ_m = `lam`.
    pub fn new(shard: &Shard, lam: f64) -> Self {
        Self { inner: LinRegTask::new(shard), lam }
    }
}

impl WorkerObjective for LassoTask {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn grad_loss_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        let sq_loss = self.inner.grad_loss_into(theta, grad);
        for (g, &t) in grad.iter_mut().zip(theta) {
            *g += self.lam * t.signum() * f64::from(t != 0.0);
        }
        sq_loss + self.lam * linalg::norm1(theta)
    }
}

/// Build the right objective for (task, shard, λ).
pub fn build_objective(
    task: TaskKind,
    shard: &Shard,
    lam: f64,
) -> Box<dyn WorkerObjective> {
    match task {
        TaskKind::LinReg => Box::new(LinRegTask::new(shard)),
        TaskKind::LogReg => Box::new(LogRegTask::new(shard, lam)),
        TaskKind::Lasso => Box::new(LassoTask::new(shard, lam)),
        TaskKind::Nn => Box::new(NnTask::new(shard, lam, nn::HIDDEN)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::shard_whole;
    use crate::data::synthetic;
    use crate::rng::Xoshiro256;

    fn fixture(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Xoshiro256::new(seed);
        let ds = synthetic::gaussian_pm1(&mut rng, n, d);
        shard_whole(&ds)
    }

    /// Central-difference check: ∇f ≈ (f(θ+h e_i) − f(θ−h e_i)) / 2h.
    fn check_gradient(obj: &dyn WorkerObjective, theta: &[f64], tol: f64) {
        let p = theta.len();
        let mut grad = vec![0.0; p];
        obj.grad_loss_into(theta, &mut grad);
        let h = 1e-5;
        let mut tp = theta.to_vec();
        for i in 0..p {
            tp[i] = theta[i] + h;
            let fp = obj.loss(&tp);
            tp[i] = theta[i] - h;
            let fm = obj.loss(&tp);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[i] - fd).abs() < tol * (1.0 + fd.abs()),
                "coord {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn linreg_gradient_matches_fd() {
        let shard = fixture(40, 6, 1);
        let obj = LinRegTask::new(&shard);
        let theta = Xoshiro256::new(2).gaussian_vec(6);
        check_gradient(&obj, &theta, 1e-4);
    }

    #[test]
    fn logreg_gradient_matches_fd() {
        let shard = fixture(40, 6, 3);
        let obj = LogRegTask::new(&shard, 0.01);
        let theta = Xoshiro256::new(4).gaussian_vec(6);
        check_gradient(&obj, &theta, 1e-4);
    }

    #[test]
    fn lasso_subgradient_matches_fd_away_from_zero() {
        let shard = fixture(40, 6, 5);
        let obj = LassoTask::new(&shard, 0.3);
        // keep θ away from 0 so the subgradient is the gradient
        let theta: Vec<f64> = Xoshiro256::new(6)
            .gaussian_vec(6)
            .iter()
            .map(|v| v + 2.0 * v.signum() + f64::from(*v == 0.0))
            .collect();
        check_gradient(&obj, &theta, 1e-4);
    }

    #[test]
    fn lasso_sign_zero_contributes_nothing() {
        let shard = fixture(10, 4, 7);
        let obj = LassoTask::new(&shard, 5.0);
        let lin = LinRegTask::new(&shard);
        let theta = vec![0.0; 4];
        let mut g_lasso = vec![0.0; 4];
        let mut g_lin = vec![0.0; 4];
        obj.grad_loss_into(&theta, &mut g_lasso);
        lin.grad_loss_into(&theta, &mut g_lin);
        assert_eq!(g_lasso, g_lin);
    }

    #[test]
    fn logreg_masked_rows_are_inert() {
        let mut rng = Xoshiro256::new(8);
        let ds = synthetic::gaussian_pm1(&mut rng, 16, 4);
        let base = shard_whole(&ds);
        // hand-pad with 8 zero rows
        let mut padded = base.clone();
        let mut x = Matrix::zeros(24, 4);
        for i in 0..16 {
            x.row_mut(i).copy_from_slice(base.x.row(i));
        }
        padded.x = Arc::new(x);
        Arc::make_mut(&mut padded.y).extend(std::iter::repeat_n(0.0, 8));
        Arc::make_mut(&mut padded.mask).extend(std::iter::repeat_n(0.0, 8));
        let theta = Xoshiro256::new(9).gaussian_vec(4);
        let (o1, o2) = (
            LogRegTask::new(&base, 0.1),
            LogRegTask::new(&padded, 0.1),
        );
        let mut g1 = vec![0.0; 4];
        let mut g2 = vec![0.0; 4];
        let l1 = o1.grad_loss_into(&theta, &mut g1);
        let l2 = o2.grad_loss_into(&theta, &mut g2);
        assert!((l1 - l2).abs() < 1e-12);
        for i in 0..4 {
            assert!((g1[i] - g2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn objectives_share_shard_storage_instead_of_cloning() {
        let shard = fixture(16, 4, 99);
        let lin = LinRegTask::new(&shard);
        let log = LogRegTask::new(&shard, 0.1);
        // Arc-shared, not copied: same allocation as the shard's
        assert!(Arc::ptr_eq(&lin.x, &shard.x));
        assert!(Arc::ptr_eq(&lin.y, &shard.y));
        assert!(Arc::ptr_eq(&log.x, &shard.x));
        assert!(Arc::ptr_eq(&log.mask, &shard.mask));
    }

    #[test]
    fn sigmoid_stability() {
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!((log1pexp(1000.0) - 1000.0).abs() < 1e-9);
        assert_eq!(log1pexp(-1000.0), 0.0);
        assert!((log1pexp(0.0) - 2f64.ln()).abs() < 1e-15);
    }
}
