//! Objective definitions — the pure-rust gradient backend.
//!
//! Mirrors python/compile/kernels/ref.py exactly (same loss
//! conventions, §IV of the paper), in f64 so objective errors down to
//! 1e-7 are resolvable.  The PJRT backend (runtime/) computes the same
//! functions from the AOT artifacts in f32; integration tests compare
//! the two.
//!
//! Every implementation is allocation-free on the hot path: gradients
//! are written into caller buffers through
//! [`WorkerObjective::grad_loss_into`], and all evaluation scratch
//! (residuals, activations) lives in a caller-owned [`TaskWorkspace`]
//! — objectives themselves are immutable shared state (`Send + Sync`,
//! no interior mutability), which is what lets one objective be read
//! from any pool thread without `unsafe`.
//!
//! Two gradient flavors per objective:
//!
//! * [`WorkerObjective::grad_loss_into`] — the full-shard sweep
//!   (the paper's deterministic regime; bit-for-bit the legacy path).
//! * [`WorkerObjective::grad_loss_batch_into`] — a row-subset sweep
//!   driven by an index slice, scaled by `n_real / |B|` so the batch
//!   gradient is an unbiased estimator of the full-shard gradient
//!   (the CSGD-style stochastic regime; see `data::batch`).

pub mod nn;
pub mod smoothness;

use std::sync::Arc;

use crate::data::Shard;
use crate::linalg::{self, Matrix};

pub use nn::NnTask;

/// Which of the paper's four learning tasks is being solved.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// linear regression ½‖Xθ − y‖²
    LinReg,
    /// ℓ2-regularized logistic regression
    LogReg,
    /// lasso (ℓ1-regularized least squares, subgradient)
    Lasso,
    /// 1×30-sigmoid neural network (nonconvex)
    Nn,
}

impl TaskKind {
    /// CLI name ("linreg", "logreg", "lasso", "nn").
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::LinReg => "linreg",
            TaskKind::LogReg => "logreg",
            TaskKind::Lasso => "lasso",
            TaskKind::Nn => "nn",
        }
    }

    /// Parse a CLI task name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "linreg" => Some(TaskKind::LinReg),
            "logreg" => Some(TaskKind::LogReg),
            "lasso" => Some(TaskKind::Lasso),
            "nn" => Some(TaskKind::Nn),
            _ => None,
        }
    }

    /// Flat parameter dimension for feature count d.
    pub fn theta_dim(self, d: usize) -> usize {
        match self {
            TaskKind::Nn => nn::param_dim(d, nn::HIDDEN),
            _ => d,
        }
    }
}

/// Caller-owned evaluation scratch, one per worker.
///
/// Buffers are sized lazily on first use and reused across rounds, so
/// the steady-state round stays allocation-free while the objectives
/// themselves hold no mutable state (they are plain `Sync` shared
/// data — no `RefCell`, no `unsafe impl Sync`).
#[derive(Default)]
pub struct TaskWorkspace {
    /// residual r (linreg/lasso) / NN output residual — n rows
    pub(crate) resid: Vec<f64>,
    /// NN hidden activations z — n·h
    pub(crate) z: Vec<f64>,
    /// NN backprop term dz — n·h
    pub(crate) dz: Vec<f64>,
}

/// Resize-and-borrow helper: a no-op in the steady state (the buffer
/// keeps its length between rounds of one objective).
#[inline]
pub(crate) fn scratch(buf: &mut Vec<f64>, n: usize) -> &mut [f64] {
    if buf.len() != n {
        buf.resize(n, 0.0);
    }
    &mut buf[..]
}

/// A worker-local objective f_m: value + (sub)gradient.
///
/// `grad_loss_into` writes ∇f_m(θ) into `grad` and returns f_m(θ);
/// `grad_loss_batch_into` does the same over a row subset, scaled to
/// an unbiased full-shard estimate.  All scratch lives in the
/// caller-owned [`TaskWorkspace`], so implementations are immutable
/// (`Send + Sync`) shared state.
pub trait WorkerObjective: Send + Sync {
    /// Parameter dimension d.
    fn dim(&self) -> usize;

    /// Real (unpadded) sample count n_m — the row universe batch
    /// schedules draw from.  Real rows always occupy the shard prefix
    /// `0..num_rows()` (see `data::partition`).
    fn num_rows(&self) -> usize;

    /// Write ∇f_m(θ) into `grad`, return f_m(θ) — the full-shard
    /// sweep (bit-for-bit the legacy deterministic path).
    fn grad_loss_into(
        &self,
        theta: &[f64],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64;

    /// Write the minibatch gradient estimate into `grad` and return
    /// the matching loss estimate: data terms are summed over `rows`
    /// (absolute row indices, each in `0..num_rows()`) and scaled by
    /// `num_rows() / rows.len()`; regularizers enter once, unscaled.
    /// `rows` must be non-empty.
    fn grad_loss_batch_into(
        &self,
        theta: &[f64],
        rows: &[u32],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64;

    /// Objective value only (defaults to computing the gradient too;
    /// overridden where a cheaper forward-only pass exists).
    fn loss(&self, theta: &[f64], ws: &mut TaskWorkspace) -> f64 {
        let mut g = vec![0.0; self.dim()];
        self.grad_loss_into(theta, ws, &mut g)
    }
}

/// Numerically-stable σ(z).
#[inline]
pub fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        let e = (-z).exp();
        1.0 / (1.0 + e)
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// log(1 + eᶻ) without overflow.
#[inline]
pub fn log1pexp(z: f64) -> f64 {
    if z > 35.0 {
        z
    } else if z < -35.0 {
        0.0
    } else {
        z.exp().ln_1p()
    }
}

/// Unbiasedness scale `n_real / |B|` for a batch of `b` rows.
#[inline]
fn batch_scale(n_real: usize, b: usize) -> f64 {
    debug_assert!(b > 0, "empty batch");
    n_real as f64 / b as f64
}

// ---------------------------------------------------------------------------
// linear regression: ½‖Xθ − y‖²
// ---------------------------------------------------------------------------

/// Worker objective for ½‖Xθ − y‖² over a (possibly padded) shard.
///
/// The shard's feature block and labels are `Arc`-shared with the
/// owning [`Shard`], never copied — at M workers the objectives add
/// O(1) resident memory on top of the dataset itself.
pub struct LinRegTask {
    x: Arc<Matrix>,
    y: Arc<Vec<f64>>,
    n_real: usize,
}

impl LinRegTask {
    /// Objective over one worker's shard.
    pub fn new(shard: &Shard) -> Self {
        Self {
            x: Arc::clone(&shard.x),
            y: Arc::clone(&shard.y),
            n_real: shard.n_real,
        }
    }
}

impl WorkerObjective for LinRegTask {
    fn dim(&self) -> usize {
        self.x.cols
    }

    fn num_rows(&self) -> usize {
        self.n_real
    }

    fn grad_loss_into(
        &self,
        theta: &[f64],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        // single fused sweep over X (see Matrix::fused_residual_grad)
        let r = scratch(&mut ws.resid, self.x.rows);
        grad.fill(0.0);
        self.x.fused_residual_grad(theta, &self.y, r, grad)
    }

    fn grad_loss_batch_into(
        &self,
        theta: &[f64],
        rows: &[u32],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        let r = scratch(&mut ws.resid, self.x.rows);
        grad.fill(0.0);
        let loss =
            self.x.fused_residual_grad_rows(theta, &self.y, rows, r, grad);
        let s = batch_scale(self.n_real, rows.len());
        if s != 1.0 {
            linalg::scale(s, grad);
        }
        loss * s
    }

    fn loss(&self, theta: &[f64], _ws: &mut TaskWorkspace) -> f64 {
        // forward-only pass, same accumulation order as the fused sweep
        let mut loss = 0.0;
        for i in 0..self.x.rows {
            let r = linalg::dot(self.x.row(i), theta) - self.y[i];
            loss += r * r;
        }
        0.5 * loss
    }
}

// ---------------------------------------------------------------------------
// ℓ2-regularized logistic regression
// ---------------------------------------------------------------------------

/// Σ log(1+exp(−y xᵀθ)) + ½λ_m‖θ‖² over a shard (mask-aware).
///
/// Shard storage is `Arc`-shared (see [`LinRegTask`]).
pub struct LogRegTask {
    x: Arc<Matrix>,
    y: Arc<Vec<f64>>,
    mask: Arc<Vec<f64>>,
    lam: f64,
    n_real: usize,
}

impl LogRegTask {
    /// Objective over one worker's shard with per-worker λ_m = `lam`.
    pub fn new(shard: &Shard, lam: f64) -> Self {
        Self {
            x: Arc::clone(&shard.x),
            y: Arc::clone(&shard.y),
            mask: Arc::clone(&shard.mask),
            lam,
            n_real: shard.n_real,
        }
    }
}

impl WorkerObjective for LogRegTask {
    fn dim(&self) -> usize {
        self.x.cols
    }

    fn num_rows(&self) -> usize {
        self.n_real
    }

    fn grad_loss_into(
        &self,
        theta: &[f64],
        _ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        // fused single sweep over X via the shared coefficient kernel
        // (the same schedule as the Pallas logreg kernel): margin,
        // loss term, coefficient, and the rank-1 gradient update all
        // from one row visit — see Matrix::fused_coeff_grad
        grad.fill(0.0);
        let (y, lam) = (&self.y, self.lam);
        let loss = self.x.fused_coeff_grad(
            theta,
            &self.mask,
            |i, z| {
                let margin = y[i] * z;
                (log1pexp(-margin), -y[i] * sigmoid(-margin))
            },
            grad,
        );
        linalg::axpy(lam, theta, grad);
        loss + 0.5 * lam * linalg::norm2_sq(theta)
    }

    fn grad_loss_batch_into(
        &self,
        theta: &[f64],
        rows: &[u32],
        _ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        grad.fill(0.0);
        let y = &self.y;
        let loss = self.x.fused_coeff_grad_rows(
            theta,
            &self.mask,
            rows,
            |i, z| {
                let margin = y[i] * z;
                (log1pexp(-margin), -y[i] * sigmoid(-margin))
            },
            grad,
        );
        let s = batch_scale(self.n_real, rows.len());
        if s != 1.0 {
            linalg::scale(s, grad);
        }
        linalg::axpy(self.lam, theta, grad);
        loss * s + 0.5 * self.lam * linalg::norm2_sq(theta)
    }

    fn loss(&self, theta: &[f64], _ws: &mut TaskWorkspace) -> f64 {
        // forward-only pass, same per-row op order as the fused sweep
        let mut loss = 0.0;
        for i in 0..self.x.rows {
            if self.mask[i] == 0.0 {
                continue;
            }
            let z = linalg::dot(self.x.row(i), theta);
            loss += log1pexp(-(self.y[i] * z));
        }
        loss + 0.5 * self.lam * linalg::norm2_sq(theta)
    }
}

// ---------------------------------------------------------------------------
// lasso (subgradient)
// ---------------------------------------------------------------------------

/// ½‖Xθ − y‖² + λ_m‖θ‖₁; subgradient with sign(0) = 0 (paper §IV).
pub struct LassoTask {
    inner: LinRegTask,
    lam: f64,
}

impl LassoTask {
    /// Objective over one worker's shard with per-worker λ_m = `lam`.
    pub fn new(shard: &Shard, lam: f64) -> Self {
        Self { inner: LinRegTask::new(shard), lam }
    }

    fn add_l1_subgrad(&self, theta: &[f64], grad: &mut [f64]) {
        for (g, &t) in grad.iter_mut().zip(theta) {
            *g += self.lam * t.signum() * f64::from(t != 0.0);
        }
    }
}

impl WorkerObjective for LassoTask {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn num_rows(&self) -> usize {
        self.inner.num_rows()
    }

    fn grad_loss_into(
        &self,
        theta: &[f64],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        let sq_loss = self.inner.grad_loss_into(theta, ws, grad);
        self.add_l1_subgrad(theta, grad);
        sq_loss + self.lam * linalg::norm1(theta)
    }

    fn grad_loss_batch_into(
        &self,
        theta: &[f64],
        rows: &[u32],
        ws: &mut TaskWorkspace,
        grad: &mut [f64],
    ) -> f64 {
        // data term scaled inside the inner batch pass; the ℓ1
        // regularizer enters once, unscaled
        let sq_loss = self.inner.grad_loss_batch_into(theta, rows, ws, grad);
        self.add_l1_subgrad(theta, grad);
        sq_loss + self.lam * linalg::norm1(theta)
    }

    fn loss(&self, theta: &[f64], ws: &mut TaskWorkspace) -> f64 {
        self.inner.loss(theta, ws) + self.lam * linalg::norm1(theta)
    }
}

/// Build the right objective for (task, shard, λ).
pub fn build_objective(
    task: TaskKind,
    shard: &Shard,
    lam: f64,
) -> Box<dyn WorkerObjective> {
    match task {
        TaskKind::LinReg => Box::new(LinRegTask::new(shard)),
        TaskKind::LogReg => Box::new(LogRegTask::new(shard, lam)),
        TaskKind::Lasso => Box::new(LassoTask::new(shard, lam)),
        TaskKind::Nn => Box::new(NnTask::new(shard, lam, nn::HIDDEN)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::shard_whole;
    use crate::data::synthetic;
    use crate::rng::Xoshiro256;

    fn fixture(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Xoshiro256::new(seed);
        let ds = synthetic::gaussian_pm1(&mut rng, n, d);
        shard_whole(&ds)
    }

    /// Central-difference check: ∇f ≈ (f(θ+h e_i) − f(θ−h e_i)) / 2h.
    fn check_gradient(obj: &dyn WorkerObjective, theta: &[f64], tol: f64) {
        let p = theta.len();
        let mut ws = TaskWorkspace::default();
        let mut grad = vec![0.0; p];
        obj.grad_loss_into(theta, &mut ws, &mut grad);
        let h = 1e-5;
        let mut tp = theta.to_vec();
        for i in 0..p {
            tp[i] = theta[i] + h;
            let fp = obj.loss(&tp, &mut ws);
            tp[i] = theta[i] - h;
            let fm = obj.loss(&tp, &mut ws);
            tp[i] = theta[i];
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[i] - fd).abs() < tol * (1.0 + fd.abs()),
                "coord {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn linreg_gradient_matches_fd() {
        let shard = fixture(40, 6, 1);
        let obj = LinRegTask::new(&shard);
        let theta = Xoshiro256::new(2).gaussian_vec(6);
        check_gradient(&obj, &theta, 1e-4);
    }

    #[test]
    fn logreg_gradient_matches_fd() {
        let shard = fixture(40, 6, 3);
        let obj = LogRegTask::new(&shard, 0.01);
        let theta = Xoshiro256::new(4).gaussian_vec(6);
        check_gradient(&obj, &theta, 1e-4);
    }

    #[test]
    fn lasso_subgradient_matches_fd_away_from_zero() {
        let shard = fixture(40, 6, 5);
        let obj = LassoTask::new(&shard, 0.3);
        // keep θ away from 0 so the subgradient is the gradient
        let theta: Vec<f64> = Xoshiro256::new(6)
            .gaussian_vec(6)
            .iter()
            .map(|v| v + 2.0 * v.signum() + f64::from(*v == 0.0))
            .collect();
        check_gradient(&obj, &theta, 1e-4);
    }

    #[test]
    fn lasso_sign_zero_contributes_nothing() {
        let shard = fixture(10, 4, 7);
        let obj = LassoTask::new(&shard, 5.0);
        let lin = LinRegTask::new(&shard);
        let theta = vec![0.0; 4];
        let mut ws = TaskWorkspace::default();
        let mut g_lasso = vec![0.0; 4];
        let mut g_lin = vec![0.0; 4];
        obj.grad_loss_into(&theta, &mut ws, &mut g_lasso);
        lin.grad_loss_into(&theta, &mut ws, &mut g_lin);
        assert_eq!(g_lasso, g_lin);
    }

    #[test]
    fn logreg_masked_rows_are_inert() {
        let mut rng = Xoshiro256::new(8);
        let ds = synthetic::gaussian_pm1(&mut rng, 16, 4);
        let base = shard_whole(&ds);
        // hand-pad with 8 zero rows
        let mut padded = base.clone();
        let mut x = Matrix::zeros(24, 4);
        for i in 0..16 {
            x.row_mut(i).copy_from_slice(base.x.row(i));
        }
        padded.x = Arc::new(x);
        Arc::make_mut(&mut padded.y).extend(std::iter::repeat_n(0.0, 8));
        Arc::make_mut(&mut padded.mask).extend(std::iter::repeat_n(0.0, 8));
        let theta = Xoshiro256::new(9).gaussian_vec(4);
        let (o1, o2) = (
            LogRegTask::new(&base, 0.1),
            LogRegTask::new(&padded, 0.1),
        );
        let mut ws = TaskWorkspace::default();
        let mut g1 = vec![0.0; 4];
        let mut g2 = vec![0.0; 4];
        let l1 = o1.grad_loss_into(&theta, &mut ws, &mut g1);
        let l2 = o2.grad_loss_into(&theta, &mut ws, &mut g2);
        assert!((l1 - l2).abs() < 1e-12);
        for i in 0..4 {
            assert!((g1[i] - g2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn objectives_share_shard_storage_instead_of_cloning() {
        let shard = fixture(16, 4, 99);
        let lin = LinRegTask::new(&shard);
        let log = LogRegTask::new(&shard, 0.1);
        // Arc-shared, not copied: same allocation as the shard's
        assert!(Arc::ptr_eq(&lin.x, &shard.x));
        assert!(Arc::ptr_eq(&lin.y, &shard.y));
        assert!(Arc::ptr_eq(&log.x, &shard.x));
        assert!(Arc::ptr_eq(&log.mask, &shard.mask));
    }

    #[test]
    fn loss_only_pass_matches_grad_pass_value_bitwise() {
        for task in
            [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
        {
            let shard = fixture(20, 5, 31);
            let obj = build_objective(task, &shard, 0.05);
            let theta = Xoshiro256::new(32).gaussian_vec(obj.dim());
            let mut ws = TaskWorkspace::default();
            let mut g = vec![0.0; obj.dim()];
            let via_grad = obj.grad_loss_into(&theta, &mut ws, &mut g);
            let direct = obj.loss(&theta, &mut ws);
            assert_eq!(
                via_grad.to_bits(),
                direct.to_bits(),
                "{}: loss-only pass diverged",
                task.name()
            );
        }
    }

    #[test]
    fn batch_over_all_rows_is_bitwise_the_full_gradient() {
        for task in
            [TaskKind::LinReg, TaskKind::LogReg, TaskKind::Lasso, TaskKind::Nn]
        {
            let shard = fixture(18, 5, 41);
            let obj = build_objective(task, &shard, 0.05);
            let theta = Xoshiro256::new(42).gaussian_vec(obj.dim());
            let mut ws = TaskWorkspace::default();
            let mut g_full = vec![0.0; obj.dim()];
            let l_full = obj.grad_loss_into(&theta, &mut ws, &mut g_full);
            let rows: Vec<u32> = (0..obj.num_rows() as u32).collect();
            let mut g_batch = vec![0.0; obj.dim()];
            let l_batch =
                obj.grad_loss_batch_into(&theta, &rows, &mut ws, &mut g_batch);
            assert_eq!(
                l_full.to_bits(),
                l_batch.to_bits(),
                "{}: loss diverged",
                task.name()
            );
            for (a, b) in g_full.iter().zip(&g_batch) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{}: gradient diverged",
                    task.name()
                );
            }
        }
    }

    #[test]
    fn batch_gradient_is_scaled_to_an_unbiased_estimate() {
        // averaging the scaled batch gradient over every singleton
        // batch {i} recovers the full gradient exactly (linearity)
        let shard = fixture(12, 4, 51);
        let obj = LinRegTask::new(&shard);
        let theta = Xoshiro256::new(52).gaussian_vec(4);
        let mut ws = TaskWorkspace::default();
        let mut g_full = vec![0.0; 4];
        obj.grad_loss_into(&theta, &mut ws, &mut g_full);
        let n = obj.num_rows();
        let mut g_mean = vec![0.0; 4];
        let mut g_i = vec![0.0; 4];
        for i in 0..n as u32 {
            obj.grad_loss_batch_into(&theta, &[i], &mut ws, &mut g_i);
            linalg::axpy(1.0 / n as f64, &g_i, &mut g_mean);
        }
        for (a, b) in g_full.iter().zip(&g_mean) {
            assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn regularizers_enter_the_batch_gradient_once_unscaled() {
        let shard = fixture(10, 4, 61);
        let theta = Xoshiro256::new(62).gaussian_vec(4);
        let mut ws = TaskWorkspace::default();
        // logreg: batch grad at λ vs λ=0 differs by exactly λθ
        let (a, b) = (
            LogRegTask::new(&shard, 0.5),
            LogRegTask::new(&shard, 0.0),
        );
        let rows = [1u32, 3];
        let mut ga = vec![0.0; 4];
        let mut gb = vec![0.0; 4];
        a.grad_loss_batch_into(&theta, &rows, &mut ws, &mut ga);
        b.grad_loss_batch_into(&theta, &rows, &mut ws, &mut gb);
        for i in 0..4 {
            assert!((ga[i] - gb[i] - 0.5 * theta[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn sigmoid_stability() {
        assert!(sigmoid(1000.0) <= 1.0);
        assert!(sigmoid(-1000.0) >= 0.0);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-15);
        assert!((log1pexp(1000.0) - 1000.0).abs() < 1e-9);
        assert_eq!(log1pexp(-1000.0), 0.0);
        assert!((log1pexp(0.0) - 2f64.ln()).abs() < 1e-15);
    }
}
