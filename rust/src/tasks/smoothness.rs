//! Smoothness-constant estimation (power iteration).
//!
//! The paper's step-size protocol is α = 1/L with L = Σ_m L_m (f = Σ f_m
//! and each worker's Hessian bound adds).  For the quadratic tasks
//! L_m = λ_max(X_mᵀX_m); for logistic L_m = ¼λ_max(X_mᵀX_m) + λ_m
//! (since σ′ ≤ ¼).  Lemma 2's condition L_m² ≤ ε₁ is checked against
//! these same estimates by `theory/`.

use crate::linalg::Matrix;

use super::TaskKind;

/// λ_max(XᵀX) via power iteration on v ↦ Xᵀ(Xv), to relative
/// tolerance 1e-10 (deterministic start vector, no RNG needed).
pub fn lambda_max_xtx(x: &Matrix) -> f64 {
    let d = x.cols;
    if d == 0 || x.rows == 0 {
        return 0.0;
    }
    // deterministic, dense start vector
    let mut v: Vec<f64> = (0..d)
        .map(|i| 1.0 + (i as f64 * 0.618_033_988_75).fract())
        .collect();
    let mut xv = vec![0.0; x.rows];
    let mut w = vec![0.0; d];
    let mut prev = 0.0;
    for _ in 0..10_000 {
        x.gemv(&v, &mut xv);
        x.gemv_t_into(&xv, &mut w);
        let norm = w.iter().map(|a| a * a).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        for i in 0..d {
            v[i] = w[i] / norm;
        }
        // Rayleigh quotient = ‖Xv‖² after normalization step
        x.gemv(&v, &mut xv);
        let lam = xv.iter().map(|a| a * a).sum::<f64>();
        if (lam - prev).abs() <= 1e-10 * lam.max(1.0) {
            return lam;
        }
        prev = lam;
    }
    prev
}

/// Worker smoothness constant L_m for a task over shard features.
/// `wscale` is the data-term multiplier (1/N_m for the mean-loss NN
/// regime, 1.0 elsewhere) — curvature scales linearly with it.
pub fn worker_smoothness_scaled(
    task: TaskKind,
    x: &Matrix,
    lam: f64,
    wscale: f64,
) -> f64 {
    let top = lambda_max_xtx(x);
    match task {
        TaskKind::LinReg | TaskKind::Lasso => top * wscale,
        TaskKind::LogReg => 0.25 * top * wscale + lam,
        // Nonconvex: no global Hessian bound; the paper uses hand-picked
        // α for the NN task, so report the data curvature scale.
        TaskKind::Nn => top * wscale,
    }
}

/// Worker smoothness with the plain sum loss (wscale = 1).
pub fn worker_smoothness(task: TaskKind, x: &Matrix, lam: f64) -> f64 {
    worker_smoothness_scaled(task, x, lam, 1.0)
}

/// Global L = Σ_m L_m (f = Σ_m f_m).
pub fn global_smoothness(task: TaskKind, shards: &[&Matrix], lam: f64) -> f64 {
    shards.iter().map(|x| worker_smoothness(task, x, lam)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_iteration_on_known_spectrum() {
        // X = diag(3, 2, 1) ⇒ λ_max(XᵀX) = 9
        let x = Matrix::from_rows(vec![
            vec![3.0, 0.0, 0.0],
            vec![0.0, 2.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ]);
        let lam = lambda_max_xtx(&x);
        assert!((lam - 9.0).abs() < 1e-8, "λ={lam}");
    }

    #[test]
    fn rank_one_matrix() {
        // X = u vᵀ with ‖u‖=√2, ‖v‖=√3 ⇒ λ_max = ‖u‖²‖v‖² = 6
        let x = Matrix::from_rows(vec![
            vec![1.0, 1.0, 1.0],
            vec![1.0, 1.0, 1.0],
        ]);
        let lam = lambda_max_xtx(&x);
        assert!((lam - 6.0).abs() < 1e-8, "λ={lam}");
    }

    #[test]
    fn zero_matrix_is_zero() {
        let x = Matrix::zeros(4, 3);
        assert_eq!(lambda_max_xtx(&x), 0.0);
    }

    #[test]
    fn logistic_smoothness_is_quarter_plus_reg() {
        let x = Matrix::from_rows(vec![vec![2.0, 0.0], vec![0.0, 1.0]]);
        let l = worker_smoothness(TaskKind::LogReg, &x, 0.5);
        assert!((l - (0.25 * 4.0 + 0.5)).abs() < 1e-8);
    }

    #[test]
    fn global_sums_workers() {
        let a = Matrix::from_rows(vec![vec![1.0]]);
        let b = Matrix::from_rows(vec![vec![2.0]]);
        let g = global_smoothness(TaskKind::LinReg, &[&a, &b], 0.0);
        assert!((g - 5.0).abs() < 1e-10);
    }
}
