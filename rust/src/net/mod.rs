//! Simulated network substrate.
//!
//! The paper counts *uplink transmissions* as its efficiency metric;
//! this module counts exactly that, plus per-link bytes and an
//! optional latency/drop model for the failure-injection tests (a
//! capability the paper assumes away — dropped uplinks simply leave
//! the server's aggregate stale, which eq. (5) tolerates by design,
//! and the tests verify it).

use crate::rng::Xoshiro256;

/// Per-link accounting.
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkStats {
    pub messages: u64,
    pub bytes: u64,
}

/// Directions from the server's point of view.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// server → worker (θ broadcast)
    Down,
    /// worker → server (δ∇ upload)
    Up,
}

/// Latency model: fixed + per-byte cost (the "communication is ~2500×
/// a memory access" premise from the paper's introduction).
#[derive(Clone, Copy, Debug)]
pub struct LatencyModel {
    pub fixed_us: f64,
    pub per_kib_us: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        // LAN-ish defaults; experiments report counts, latency is for
        // the simulated-wallclock columns only.
        Self { fixed_us: 500.0, per_kib_us: 8.0 }
    }
}

impl LatencyModel {
    pub fn transfer_us(&self, bytes: u64) -> f64 {
        self.fixed_us + self.per_kib_us * (bytes as f64 / 1024.0)
    }
}

/// The simulated star network (server + M workers).
pub struct SimNetwork {
    pub up: Vec<LinkStats>,
    pub down: Vec<LinkStats>,
    pub latency: LatencyModel,
    /// probability an *uplink* message is dropped (failure injection)
    pub drop_prob: f64,
    rng: Xoshiro256,
    /// accumulated simulated wallclock (µs), taking the per-round max
    /// across links (synchronous rounds)
    pub sim_clock_us: f64,
    dropped: u64,
}

impl SimNetwork {
    pub fn new(m_workers: usize) -> Self {
        Self {
            up: vec![LinkStats::default(); m_workers],
            down: vec![LinkStats::default(); m_workers],
            latency: LatencyModel::default(),
            drop_prob: 0.0,
            rng: Xoshiro256::new(0x5EED_0002),
            sim_clock_us: 0.0,
            dropped: 0,
        }
    }

    pub fn with_drops(mut self, prob: f64, seed: u64) -> Self {
        self.drop_prob = prob;
        self.rng = Xoshiro256::new(seed);
        self
    }

    /// Record a message; returns false if it was dropped.
    pub fn send(&mut self, dir: Direction, worker: usize, bytes: u64) -> bool {
        let stats = match dir {
            Direction::Down => &mut self.down[worker],
            Direction::Up => &mut self.up[worker],
        };
        if dir == Direction::Up
            && self.drop_prob > 0.0
            && self.rng.next_f64() < self.drop_prob
        {
            self.dropped += 1;
            return false;
        }
        stats.messages += 1;
        stats.bytes += bytes;
        true
    }

    /// Record one round's downlink broadcast: θᵏ goes only to the
    /// scheduled workers (partial participation keeps unscheduled
    /// links silent in both directions).
    pub fn broadcast(&mut self, active: &[bool], bytes: u64) {
        for (id, &scheduled) in active.iter().enumerate() {
            if scheduled {
                self.send(Direction::Down, id, bytes);
            }
        }
    }

    /// Advance the synchronous-round clock: one broadcast down to all
    /// M workers in parallel + the slowest uplink among transmitters.
    pub fn advance_round(&mut self, down_bytes: u64, up_bytes_each: &[u64]) {
        let down = self.latency.transfer_us(down_bytes);
        let up = up_bytes_each
            .iter()
            .map(|&b| self.latency.transfer_us(b))
            .fold(0.0, f64::max);
        self.sim_clock_us += down + up;
    }

    pub fn total_up_messages(&self) -> u64 {
        self.up.iter().map(|l| l.messages).sum()
    }

    pub fn total_up_bytes(&self) -> u64 {
        self.up.iter().map(|l| l.bytes).sum()
    }

    pub fn total_down_messages(&self) -> u64 {
        self.down.iter().map(|l| l.messages).sum()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_up_and_down_separately() {
        let mut n = SimNetwork::new(2);
        assert!(n.send(Direction::Down, 0, 100));
        assert!(n.send(Direction::Up, 0, 50));
        assert!(n.send(Direction::Up, 1, 50));
        assert_eq!(n.total_down_messages(), 1);
        assert_eq!(n.total_up_messages(), 2);
        assert_eq!(n.total_up_bytes(), 100);
    }

    #[test]
    fn drops_are_uplink_only_and_counted() {
        let mut n = SimNetwork::new(1).with_drops(1.0, 7);
        assert!(n.send(Direction::Down, 0, 10)); // downlink never drops
        assert!(!n.send(Direction::Up, 0, 10));
        assert_eq!(n.dropped(), 1);
        assert_eq!(n.total_up_messages(), 0);
    }

    #[test]
    fn broadcast_skips_unscheduled_workers() {
        let mut n = SimNetwork::new(3);
        n.broadcast(&[true, false, true], 100);
        assert_eq!(n.total_down_messages(), 2);
        assert_eq!(n.down[0].bytes, 100);
        assert_eq!(n.down[1].messages, 0);
        assert_eq!(n.down[2].bytes, 100);
    }

    #[test]
    fn round_clock_takes_max_uplink() {
        let mut n = SimNetwork::new(3);
        n.latency = LatencyModel { fixed_us: 100.0, per_kib_us: 0.0 };
        n.advance_round(1024, &[10, 10, 10]);
        // down 100 + slowest up 100
        assert!((n.sim_clock_us - 200.0).abs() < 1e-9);
        n.advance_round(0, &[]);
        // no uplinks this round: just the broadcast
        assert!((n.sim_clock_us - 300.0).abs() < 1e-9);
    }

    #[test]
    fn latency_model_scales_with_bytes() {
        let l = LatencyModel { fixed_us: 1.0, per_kib_us: 2.0 };
        assert!((l.transfer_us(2048) - 5.0).abs() < 1e-12);
    }
}
